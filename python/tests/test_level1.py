"""L1 correctness: every level-1 Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps sizes (including non-power-of-two, which exercises the
window-divisor shrink in ``pick_window``) and window hints, asserting
allclose against ref.py — the core correctness signal of the build path.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import kernels as K
from compile.kernels import ref
from compile.kernels.common import pick_window

from .conftest import TOL, finite_f32

sizes = st.integers(min_value=1, max_value=768)
windows = st.one_of(st.none(), st.integers(min_value=1, max_value=256))
alphas = st.floats(min_value=-4.0, max_value=4.0, width=32)


def _vec(seed, n, scale=1.0):
    return finite_f32(np.random.default_rng(seed), n, scale)


@given(n=sizes, w=windows, alpha=alphas, seed=st.integers(0, 2**31))
def test_axpy_matches_ref(n, w, alpha, seed):
    x, y = _vec(seed, n), _vec(seed + 1, n)
    got = K.axpy(np.float32(alpha), x, y, window=w)
    np.testing.assert_allclose(got, ref.axpy(np.float32(alpha), x, y), **TOL)


@given(n=sizes, w=windows, alpha=alphas, seed=st.integers(0, 2**31))
def test_scal_matches_ref(n, w, alpha, seed):
    x = _vec(seed, n)
    got = K.scal(np.float32(alpha), x, window=w)
    np.testing.assert_allclose(got, ref.scal(np.float32(alpha), x), **TOL)


@given(n=sizes, w=windows, seed=st.integers(0, 2**31))
def test_copy_is_identity(n, w, seed):
    x = _vec(seed, n)
    np.testing.assert_array_equal(np.asarray(K.copy(x, window=w)), x)


@given(n=sizes, w=windows, seed=st.integers(0, 2**31))
def test_dot_matches_ref(n, w, seed):
    x, y = _vec(seed, n), _vec(seed + 1, n)
    got = K.dot(x, y, window=w)
    np.testing.assert_allclose(got, ref.dot(x, y), **TOL)


@given(n=sizes, w=windows, seed=st.integers(0, 2**31))
def test_nrm2_matches_ref(n, w, seed):
    x = _vec(seed, n)
    np.testing.assert_allclose(K.nrm2(x, window=w), ref.nrm2(x), **TOL)


@given(n=sizes, w=windows, seed=st.integers(0, 2**31))
def test_asum_matches_ref(n, w, seed):
    x = _vec(seed, n)
    np.testing.assert_allclose(K.asum(x, window=w), ref.asum(x), **TOL)


@given(n=sizes, w=windows, seed=st.integers(0, 2**31))
def test_iamax_matches_ref(n, w, seed):
    x = _vec(seed, n)
    assert int(K.iamax(x, window=w)) == int(ref.iamax(x))


def test_iamax_prefers_first_index():
    """BLAS ixamax returns the FIRST maximal index on ties."""
    x = np.array([1.0, -3.0, 3.0, 3.0], dtype=np.float32)
    assert int(K.iamax(x, window=2)) == 1


def test_axpy_zero_alpha_is_y():
    y = np.arange(64, dtype=np.float32)
    got = K.axpy(np.float32(0.0), np.ones(64, np.float32), y, window=16)
    np.testing.assert_array_equal(np.asarray(got), y)


def test_dot_zero_vectors():
    z = np.zeros(128, np.float32)
    assert float(K.dot(z, z, window=32)) == 0.0


def test_nrm2_unit_basis():
    e = np.zeros(256, np.float32)
    e[17] = -5.0
    np.testing.assert_allclose(K.nrm2(e, window=64), 5.0, rtol=1e-6)


@pytest.mark.parametrize("n,w", [(1, 1), (1, None), (7, 3), (4096, 4096)])
def test_pick_window_divides(n, w):
    chosen = pick_window(n, w)
    assert n % chosen == 0 and 1 <= chosen <= n
