"""L2 registry tests: every routine builds, jits, and matches its oracle."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

from .conftest import TOL, finite_f32


def _materialize(example_args, rng):
    out = []
    for spec in example_args:
        arr = finite_f32(rng, tuple(spec.shape)) if spec.shape else None
        out.append(jnp.asarray(arr, dtype=spec.dtype)
                   if str(spec.dtype) != "int32"
                   else jnp.asarray(arr, jnp.int32))
    return out


@pytest.mark.parametrize("name", sorted(model.REGISTRY))
def test_registry_builds_and_runs(name):
    rng = np.random.default_rng(42)
    fn, example_args = model.build(name, 64)
    args = _materialize(example_args, rng)
    out = jax.jit(fn)(*args)
    # rot returns two outputs; everything else one
    expected = 2 if name == "rot" else 1
    assert isinstance(out, tuple) and len(out) == expected


def test_axpy_model_matches_ref():
    rng = np.random.default_rng(1)
    fn, _ = model.build("axpy", 256)
    alpha = np.array([1.25], np.float32)
    x, y = finite_f32(rng, 256), finite_f32(rng, 256)
    (got,) = fn(alpha, x, y)
    np.testing.assert_allclose(got, ref.axpy(alpha[0], x, y), **TOL)


def test_axpy_neg_is_w_minus_alpha_v():
    rng = np.random.default_rng(2)
    fn, _ = model.build("axpy_neg", 128)
    alpha = np.array([0.75], np.float32)
    v, w = finite_f32(rng, 128), finite_f32(rng, 128)
    (got,) = fn(alpha, v, w)
    np.testing.assert_allclose(got, w - alpha[0] * v, **TOL)


def test_gemv_model_matches_ref():
    rng = np.random.default_rng(3)
    fn, _ = model.build("gemv", 64)
    alpha = np.array([1.5], np.float32)
    beta = np.array([-0.5], np.float32)
    a = finite_f32(rng, (64, 64))
    x, y = finite_f32(rng, 64), finite_f32(rng, 64)
    (got,) = fn(alpha, a, x, beta, y)
    np.testing.assert_allclose(got, ref.gemv(alpha[0], a, x, beta[0], y),
                               rtol=5e-4, atol=5e-4)


def test_unknown_routine_raises():
    with pytest.raises(KeyError):
        model.build("does_not_exist", 64)


def test_lower_hlo_text_is_parseable_hlo():
    text = model.lower_hlo_text("axpy", 4096)
    assert "HloModule" in text
    assert "ENTRY" in text
    # stable parameter signature: alpha, x, y
    assert text.count("parameter(") >= 3


def test_lowered_scalar_routines_return_rank1():
    """Reductions are reshaped to (1,) so the Rust loader sees rank-1."""
    text = model.lower_hlo_text("dot", 4096)
    assert "f32[1]" in text


def test_aot_sizes_are_registered():
    for name, rdef in model.REGISTRY.items():
        assert rdef.aot_sizes, f"{name} has no AOT sizes"
        assert all(s > 0 for s in rdef.aot_sizes)
