"""L1 correctness for gemv (level 2) and gemm (level 3) window tilings."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import kernels as K
from compile.kernels import ref

from .conftest import finite_f32

# gemv/gemm accumulate across tiles: scale tolerance with problem size.
TOL = dict(rtol=5e-4, atol=5e-4)

dims = st.integers(min_value=1, max_value=96)
blocks = st.one_of(st.none(), st.integers(min_value=1, max_value=48))
scalars = st.floats(min_value=-2.0, max_value=2.0, width=32)


def _rng(seed):
    return np.random.default_rng(seed)


@given(m=dims, n=dims, bm=blocks, bn=blocks, alpha=scalars, beta=scalars,
       seed=st.integers(0, 2**31))
def test_gemv_matches_ref(m, n, bm, bn, alpha, beta, seed):
    r = _rng(seed)
    a = finite_f32(r, (m, n))
    x = finite_f32(r, n)
    y = finite_f32(r, m)
    got = K.gemv(np.float32(alpha), a, x, np.float32(beta), y,
                 block_m=bm, block_n=bn)
    want = ref.gemv(np.float32(alpha), a, x, np.float32(beta), y)
    np.testing.assert_allclose(got, want, **TOL)


@given(m=dims, k=dims, n=dims, alpha=scalars, beta=scalars,
       seed=st.integers(0, 2**31))
def test_gemm_matches_ref(m, k, n, alpha, beta, seed):
    r = _rng(seed)
    a = finite_f32(r, (m, k))
    b = finite_f32(r, (k, n))
    c = finite_f32(r, (m, n))
    got = K.gemm(np.float32(alpha), a, b, np.float32(beta), c,
                 block_m=16, block_n=16, block_k=16)
    want = ref.gemm(np.float32(alpha), a, b, np.float32(beta), c)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("m,n", [(1, 1), (1, 64), (64, 1), (33, 65)])
def test_gemv_degenerate_shapes(m, n):
    r = _rng(7)
    a = finite_f32(r, (m, n))
    x = finite_f32(r, n)
    y = finite_f32(r, m)
    got = K.gemv(np.float32(1.5), a, x, np.float32(-0.5), y)
    want = ref.gemv(np.float32(1.5), a, x, np.float32(-0.5), y)
    np.testing.assert_allclose(got, want, **TOL)


def test_gemv_identity_matrix():
    n = 64
    a = np.eye(n, dtype=np.float32)
    x = np.arange(n, dtype=np.float32)
    y = np.zeros(n, dtype=np.float32)
    got = K.gemv(np.float32(1.0), a, x, np.float32(0.0), y,
                 block_m=16, block_n=16)
    np.testing.assert_allclose(got, x, rtol=1e-6)


def test_gemm_beta_only():
    """alpha=0 must reduce to beta*C regardless of A, B contents."""
    r = _rng(11)
    a = finite_f32(r, (32, 32)) * 1e3
    b = finite_f32(r, (32, 32)) * 1e3
    c = finite_f32(r, (32, 32))
    got = K.gemm(np.float32(0.0), a, b, np.float32(2.0), c,
                 block_m=16, block_n=16, block_k=16)
    np.testing.assert_allclose(got, 2.0 * c, rtol=1e-5)
