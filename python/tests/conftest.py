"""Shared fixtures/strategies for the AIEBLAS python test-suite."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import HealthCheck, settings

# Pallas interpret mode is slow; keep example counts modest but meaningful.
settings.register_profile(
    "aieblas",
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("aieblas")

# f32 windowed reductions accumulate rounding; these are the tolerances the
# Rust-side numeric validation uses as well (rust/src/runtime/exec.rs).
TOL = dict(rtol=2e-4, atol=2e-5)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xA1EB1A5)


def finite_f32(rng_, shape, scale=1.0):
    return (rng_.standard_normal(shape) * scale).astype(np.float32)
