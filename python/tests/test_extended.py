"""Extended BLAS coverage (paper §V): axpby, rot (multi-output), ger."""

from __future__ import annotations

import numpy as np
from hypothesis import given, strategies as st

from compile import kernels as K
from compile.kernels import ref

from .conftest import TOL, finite_f32

sizes = st.integers(min_value=1, max_value=512)
windows = st.one_of(st.none(), st.integers(min_value=1, max_value=128))
scalars = st.floats(min_value=-3.0, max_value=3.0, width=32)


@given(n=sizes, w=windows, alpha=scalars, beta=scalars, seed=st.integers(0, 2**31))
def test_axpby_matches_ref(n, w, alpha, beta, seed):
    r = np.random.default_rng(seed)
    x, y = finite_f32(r, n), finite_f32(r, n)
    got = K.axpby(np.float32(alpha), np.float32(beta), x, y, window=w)
    np.testing.assert_allclose(
        got, ref.axpby(np.float32(alpha), np.float32(beta), x, y), **TOL
    )


@given(n=sizes, w=windows, theta=st.floats(0.0, 6.3), seed=st.integers(0, 2**31))
def test_rot_matches_ref(n, w, theta, seed):
    r = np.random.default_rng(seed)
    c, s = np.float32(np.cos(theta)), np.float32(np.sin(theta))
    x, y = finite_f32(r, n), finite_f32(r, n)
    xo, yo = K.rot(c, s, x, y, window=w)
    rxo, ryo = ref.rot(c, s, x, y)
    np.testing.assert_allclose(xo, rxo, **TOL)
    np.testing.assert_allclose(yo, ryo, **TOL)


def test_rot_preserves_norm():
    """A Givens rotation is orthogonal: ||(x', y')|| == ||(x, y)||."""
    r = np.random.default_rng(5)
    x, y = finite_f32(r, 256), finite_f32(r, 256)
    c, s = np.float32(np.cos(0.7)), np.float32(np.sin(0.7))
    xo, yo = K.rot(c, s, x, y, window=64)
    before = np.sum(x * x + y * y)
    after = np.sum(np.asarray(xo) ** 2 + np.asarray(yo) ** 2)
    np.testing.assert_allclose(after, before, rtol=1e-4)


@given(m=st.integers(1, 64), n=st.integers(1, 64), alpha=scalars,
       seed=st.integers(0, 2**31))
def test_ger_matches_ref(m, n, alpha, seed):
    r = np.random.default_rng(seed)
    x, y = finite_f32(r, m), finite_f32(r, n)
    a = finite_f32(r, (m, n))
    got = K.ger(np.float32(alpha), x, y, a, block_m=16, block_n=16)
    np.testing.assert_allclose(got, ref.ger(np.float32(alpha), x, y, a),
                               rtol=5e-4, atol=5e-4)


def test_ger_alpha_zero_is_identity():
    r = np.random.default_rng(9)
    a = finite_f32(r, (32, 32))
    got = K.ger(np.float32(0.0), finite_f32(r, 32), finite_f32(r, 32), a)
    np.testing.assert_array_equal(np.asarray(got), a)


def test_rot_lowered_has_two_outputs():
    from compile import model
    text = model.lower_hlo_text("rot", 64)
    assert "HloModule" in text
    # tuple of two f32[64] results
    assert text.count("f32[64]") >= 2
