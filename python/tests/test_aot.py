"""AOT driver tests: artifact emission + manifest integrity."""

from __future__ import annotations

import hashlib
import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build_all(str(out), sizes_cap=4096,
                             routines=["axpy", "dot", "axpydot", "axpy_neg"])
    return str(out), manifest


def test_manifest_written(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["interchange"] == "hlo-text"


def test_every_entry_has_artifact_file(built):
    out, manifest = built
    for e in manifest["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), e["key"]
        text = open(path).read()
        assert "HloModule" in text
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]


def test_entry_input_signatures(built):
    _, manifest = built
    by_key = {e["key"]: e for e in manifest["entries"]}
    axpy = by_key["axpy_n4096"]
    assert [i["shape"] for i in axpy["inputs"]] == [[1], [4096], [4096]]
    assert all(i["dtype"] == "float32" for i in axpy["inputs"])


def test_sizes_cap_respected(built):
    _, manifest = built
    assert all(e["size"] <= 4096 for e in manifest["entries"])


def test_artifact_key_format():
    assert aot.artifact_key("gemv", 512) == "gemv_n512"


def test_registry_covers_fig3_routines():
    """Fig. 3 needs axpy, gemv, dot and both axpydot variants."""
    for required in ["axpy", "gemv", "dot", "axpydot", "axpy_neg"]:
        assert required in model.REGISTRY
