"""Composed-routine (dataflow) correctness: fused axpydot.

The key property behind Fig. 3's DF/no-DF comparison: the fused dataflow
kernel and the two-stage (axpy_neg then dot) composition must agree — the
performance differs, the numerics must not.
"""

from __future__ import annotations

import numpy as np
from hypothesis import given, strategies as st

from compile import kernels as K
from compile.kernels import ref

from .conftest import TOL, finite_f32

sizes = st.integers(min_value=1, max_value=768)
windows = st.one_of(st.none(), st.integers(min_value=1, max_value=256))
alphas = st.floats(min_value=-4.0, max_value=4.0, width=32)


@given(n=sizes, w=windows, alpha=alphas, seed=st.integers(0, 2**31))
def test_axpydot_matches_ref(n, w, alpha, seed):
    r = np.random.default_rng(seed)
    wv, vv, uv = (finite_f32(r, n) for _ in range(3))
    got = K.axpydot(np.float32(alpha), wv, vv, uv, window=w)
    want = ref.axpydot(np.float32(alpha), wv, vv, uv)
    np.testing.assert_allclose(got, want, **TOL)


@given(n=sizes, alpha=alphas, seed=st.integers(0, 2**31))
def test_fused_equals_staged(n, alpha, seed):
    """DF (fused) == no-DF (axpy with -alpha, then dot)."""
    r = np.random.default_rng(seed)
    wv, vv, uv = (finite_f32(r, n) for _ in range(3))
    a = np.float32(alpha)
    fused = K.axpydot(a, wv, vv, uv, window=64)
    z = K.axpy(np.float32(-a), vv, wv, window=64)  # z = w - alpha*v
    staged = K.dot(np.asarray(z), uv, window=64)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(staged), **TOL)


def test_axpydot_orthogonal_is_zero():
    n = 128
    wv = np.zeros(n, np.float32)
    vv = np.zeros(n, np.float32)
    uv = np.ones(n, np.float32)
    assert float(K.axpydot(np.float32(3.0), wv, vv, uv, window=32)) == 0.0


def test_axpydot_alpha_zero_reduces_to_dot():
    r = np.random.default_rng(3)
    n = 256
    wv, vv, uv = (finite_f32(r, n) for _ in range(3))
    got = K.axpydot(np.float32(0.0), wv, vv, uv, window=64)
    np.testing.assert_allclose(got, ref.dot(wv, uv), **TOL)
