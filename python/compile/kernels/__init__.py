"""L1: AIEBLAS Pallas kernels (window-tiled, interpret=True).

One module per BLAS level plus the composed dataflow routines; ``ref``
holds the pure-jnp oracles.
"""

from . import ref  # noqa: F401
from .common import DEFAULT_WINDOW, F32_LANES, VECTOR_BITS, pick_window  # noqa: F401
from .composed import axpydot  # noqa: F401
from .level1 import asum, axpby, axpy, copy, dot, iamax, nrm2, rot, scal  # noqa: F401
from .level2 import gemv, ger  # noqa: F401
from .level3 import gemm  # noqa: F401

__all__ = [
    "ref",
    "axpy", "axpby", "rot", "scal", "copy", "dot", "nrm2", "asum", "iamax",
    "gemv", "ger", "gemm", "axpydot",
    "DEFAULT_WINDOW", "VECTOR_BITS", "F32_LANES", "pick_window",
]
