"""Composed (dataflow) AIEBLAS routines.

``axpydot`` (paper §III, Fig. 1): beta = z^T u with z = w - alpha*v. On the
AIE this is an axpy kernel streaming its output window directly into a dot
kernel over the NoC — never touching off-chip memory. The Pallas analog is a
single *fused* kernel: the z window lives only in local memory (VMEM) and
the dot partial accumulates across the grid sweep.

The non-dataflow variant (two separate HLO modules with a host round-trip
for z) is intentionally NOT fused here — the Rust coordinator materializes
it from the standalone ``axpy`` and ``dot`` artifacts, mirroring the paper's
"w/o DF" configuration that bounces z through DDR.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import first_step, pick_window, reduction_out_spec, scalar_spec, vec_spec


def _axpydot_kernel(alpha_ref, w_ref, v_ref, u_ref, o_ref):
    # axpy stage: z window, produced and consumed entirely on-chip.
    z = w_ref[...] - alpha_ref[0] * v_ref[...]
    # dot stage: consumes the z window immediately (the DF edge).
    partial = jnp.sum(z * u_ref[...])

    @pl.when(first_step())
    def _init():
        o_ref[0] = partial

    @pl.when(jnp.logical_not(first_step()))
    def _acc():
        o_ref[0] += partial


def axpydot(alpha, w, v, u, *, window=None):
    """beta = (w - alpha*v)^T u, fused dataflow implementation."""
    n = w.shape[0]
    ww = pick_window(n, window)
    call = pl.pallas_call(
        _axpydot_kernel,
        grid=(n // ww,),
        in_specs=[scalar_spec(), vec_spec(ww), vec_spec(ww), vec_spec(ww)],
        out_specs=reduction_out_spec(),
        out_shape=jax.ShapeDtypeStruct((1,), w.dtype),
        interpret=True,
    )
    return call(jnp.reshape(alpha, (1,)).astype(w.dtype), w, v, u)[0]
