"""Level-2 AIEBLAS routines (gemv) as tiled Pallas kernels.

The AIE mapping (DESIGN.md §2): the matrix is streamed through the tile as
(bm x bn) windows; a row-block of the result vector is accumulated across the
column-tile sweep, exactly like the generated ADF gemv kernel that acquires
one matrix window per iteration and keeps the partial y-block in registers.

Grid iteration order: the *last* grid dimension varies fastest, so with grid
(rows, cols) the column sweep is innermost and the accumulator pattern
(init at j == 0, add afterwards) is sound.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import pick_window


def _gemv_kernel(alpha_ref, beta_ref, a_ref, x_ref, y_ref, o_ref):
    partial = alpha_ref[0] * (a_ref[...] @ x_ref[...])

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = beta_ref[0] * y_ref[...] + partial

    @pl.when(pl.program_id(1) != 0)
    def _acc():
        o_ref[...] += partial


def gemv(alpha, a, x, beta, y, *, block_m=None, block_n=None):
    """y' = alpha*A@x + beta*y with (bm x bn) matrix windows.

    Default tile 16 x 256 f32 = 16 KB: half of the 32 KB AIE local memory,
    leaving room for the ping-pong buffer, the x/y blocks and the
    accumulator. ``pick_window`` shrinks each dimension to a divisor of the
    problem size (the AIEBLAS window-divisibility invariant).
    """
    m, n = a.shape
    bm = pick_window(m, block_m or 16)
    bn = pick_window(n, block_n or 256)
    grid = (m // bm, n // bn)
    call = pl.pallas_call(
        _gemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),          # alpha
            pl.BlockSpec((1,), lambda i, j: (0,)),          # beta
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),    # A window
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # x block
            pl.BlockSpec((bm,), lambda i, j: (i,)),         # y block
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )
    one = lambda s: jnp.reshape(s, (1,)).astype(a.dtype)
    return call(one(alpha), one(beta), a, x, y)


def _ger_kernel(alpha_ref, x_ref, y_ref, a_ref, o_ref):
    # rank-1 update of an (bm x bn) window: A + alpha * x_block y_block^T
    o_ref[...] = a_ref[...] + alpha_ref[0] * (
        x_ref[...][:, None] * y_ref[...][None, :]
    )


def ger(alpha, x, y, a, *, block_m=None, block_n=None):
    """A' = A + alpha * x y^T (BLAS sger), tiled over matrix windows."""
    m, n = a.shape
    bm = pick_window(m, block_m or 16)
    bn = pick_window(n, block_n or 256)
    grid = (m // bm, n // bn)
    call = pl.pallas_call(
        _ger_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )
    one = lambda s: jnp.reshape(s, (1,)).astype(a.dtype)
    return call(one(alpha), x, y, a)
