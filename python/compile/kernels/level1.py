"""Level-1 AIEBLAS routines as window-tiled Pallas kernels.

Each routine mirrors the structure of the generated ADF kernel (see
rust/src/codegen/aie_kernel.rs): the input vectors arrive window by window
(BlockSpec blocks = ADF windows staged in tile-local memory), the body is a
vectorized loop over the window, and reductions carry an accumulator across
grid steps (the ADF analog keeps it in a register across window
acquisitions).

All kernels are out-of-place, like AIEBLAS routines, because dataflow
composition needs distinct input/output streams.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import first_step, pallas_call_1d, pick_window


# --------------------------------------------------------------------------
# elementwise kernels
# --------------------------------------------------------------------------

def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


def axpy(alpha, x, y, *, window=None):
    """z = alpha*x + y, windowed over a 1-D grid."""
    n = x.shape[0]
    w = pick_window(n, window)
    call = pallas_call_1d(_axpy_kernel, n, w, num_in=2, dtype=x.dtype,
                          scalars=1)
    return call(jnp.reshape(alpha, (1,)).astype(x.dtype), x, y)


def _scal_kernel(alpha_ref, x_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...]


def scal(alpha, x, *, window=None):
    """z = alpha*x."""
    n = x.shape[0]
    w = pick_window(n, window)
    call = pallas_call_1d(_scal_kernel, n, w, num_in=1, dtype=x.dtype,
                          scalars=1)
    return call(jnp.reshape(alpha, (1,)).astype(x.dtype), x)


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def copy(x, *, window=None):
    """z = x (window-by-window move, the ADF passthrough kernel)."""
    n = x.shape[0]
    w = pick_window(n, window)
    call = pallas_call_1d(_copy_kernel, n, w, num_in=1, dtype=x.dtype)
    return call(x)


# --------------------------------------------------------------------------
# reduction kernels
# --------------------------------------------------------------------------

def _dot_kernel(x_ref, y_ref, o_ref):
    partial = jnp.sum(x_ref[...] * y_ref[...])

    @pl.when(first_step())
    def _init():
        o_ref[0] = partial

    @pl.when(jnp.logical_not(first_step()))
    def _acc():
        o_ref[0] += partial


def dot(x, y, *, window=None):
    """x^T y as a windowed reduction; returns a scalar."""
    n = x.shape[0]
    w = pick_window(n, window)
    call = pallas_call_1d(_dot_kernel, n, w, num_in=2, dtype=x.dtype,
                          out_reduce=True)
    return call(x, y)[0]


def _sumsq_kernel(x_ref, o_ref):
    xb = x_ref[...]
    partial = jnp.sum(xb * xb)

    @pl.when(first_step())
    def _init():
        o_ref[0] = partial

    @pl.when(jnp.logical_not(first_step()))
    def _acc():
        o_ref[0] += partial


def nrm2(x, *, window=None):
    """||x||_2 — windowed sum of squares, sqrt applied at L2.

    The generated ADF kernel accumulates the sum of squares on-tile and the
    final sqrt runs once on the last window; lowering the sqrt outside the
    pallas_call produces the identical fused HLO.
    """
    n = x.shape[0]
    w = pick_window(n, window)
    call = pallas_call_1d(_sumsq_kernel, n, w, num_in=1, dtype=x.dtype,
                          out_reduce=True)
    return jnp.sqrt(call(x)[0])


def _asum_kernel(x_ref, o_ref):
    partial = jnp.sum(jnp.abs(x_ref[...]))

    @pl.when(first_step())
    def _init():
        o_ref[0] = partial

    @pl.when(jnp.logical_not(first_step()))
    def _acc():
        o_ref[0] += partial


def asum(x, *, window=None):
    """sum |x_i|."""
    n = x.shape[0]
    w = pick_window(n, window)
    call = pallas_call_1d(_asum_kernel, n, w, num_in=1, dtype=x.dtype,
                          out_reduce=True)
    return call(x)[0]


def _iamax_kernel(x_ref, val_ref, idx_ref):
    """Running (max |x|, first index) pair across windows."""
    xb = jnp.abs(x_ref[...])
    local_idx = jnp.argmax(xb).astype(jnp.int32)
    local_val = xb[local_idx]
    w = x_ref.shape[0]
    global_idx = (pl.program_id(0) * w + local_idx).astype(jnp.int32)

    @pl.when(first_step())
    def _init():
        val_ref[0] = local_val
        idx_ref[0] = global_idx

    @pl.when(jnp.logical_not(first_step()))
    def _acc():
        # strict > keeps the FIRST maximal index, per BLAS ixamax.
        better = local_val > val_ref[0]
        val_ref[0] = jnp.where(better, local_val, val_ref[0])
        idx_ref[0] = jnp.where(better, global_idx, idx_ref[0])


def iamax(x, *, window=None):
    """First index of the element with maximum magnitude."""
    n = x.shape[0]
    w = pick_window(n, window)
    grid = (n // w,)
    call = pl.pallas_call(
        _iamax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((w,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=True,
    )
    _, idx = call(x)
    return idx[0]


def _axpby_kernel(alpha_ref, beta_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + beta_ref[0] * y_ref[...]


def axpby(alpha, beta, x, y, *, window=None):
    """z = alpha*x + beta*y (extended-BLAS axpby)."""
    n = x.shape[0]
    w = pick_window(n, window)
    call = pallas_call_1d(_axpby_kernel, n, w, num_in=2, dtype=x.dtype,
                          scalars=2)
    one = lambda s: jnp.reshape(s, (1,)).astype(x.dtype)
    return call(one(alpha), one(beta), x, y)


def _rot_kernel(c_ref, s_ref, x_ref, y_ref, xo_ref, yo_ref):
    c, s = c_ref[0], s_ref[0]
    xb, yb = x_ref[...], y_ref[...]
    xo_ref[...] = c * xb + s * yb
    yo_ref[...] = c * yb - s * xb


def rot(c, s, x, y, *, window=None):
    """Apply a Givens plane rotation: returns (c*x + s*y, c*y - s*x).

    Two windowed outputs — exercises the multi-output path end to end
    (Pallas multi-out_specs, HLO tuple, rust decompose_tuple).
    """
    import jax as _jax
    n = x.shape[0]
    w = pick_window(n, window)
    call = pl.pallas_call(
        _rot_kernel,
        grid=(n // w,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((w,), lambda i: (i,)),
            pl.BlockSpec((w,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((w,), lambda i: (i,)),
            pl.BlockSpec((w,), lambda i: (i,)),
        ],
        out_shape=[
            _jax.ShapeDtypeStruct((n,), x.dtype),
            _jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=True,
    )
    one = lambda v: jnp.reshape(v, (1,)).astype(x.dtype)
    return call(one(c), one(s), x, y)
