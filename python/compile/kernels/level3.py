"""Level-3 AIEBLAS routines (gemm) as tiled Pallas kernels.

gemm is listed by the paper as BLAS-coverage future work (§V); it is
implemented here with the same window discipline as gemv: a 3-D grid
(i, j, k) with k innermost, accumulating an (bm x bn) C tile across the
k-sweep — the TPU/VMEM re-think of the ACAP GEMM designs the paper cites
([14], [16]).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import pick_window


def _gemm_kernel(alpha_ref, beta_ref, a_ref, b_ref, c_ref, o_ref):
    partial = alpha_ref[0] * (a_ref[...] @ b_ref[...])

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = beta_ref[0] * c_ref[...] + partial

    @pl.when(pl.program_id(2) != 0)
    def _acc():
        o_ref[...] += partial


def gemm(alpha, a, b, beta, c, *, block_m=None, block_n=None, block_k=None):
    """C' = alpha*A@B + beta*C with (bm x bk)·(bk x bn) windows."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = pick_window(m, block_m or 32)
    bn = pick_window(n, block_n or 32)
    bk = pick_window(k, block_k or 64)
    grid = (m // bm, n // bn, k // bk)
    call = pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, l: (0,)),           # alpha
            pl.BlockSpec((1,), lambda i, j, l: (0,)),           # beta
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),     # A window
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),     # B window
            pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),     # C input
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )
    one = lambda s: jnp.reshape(s, (1,)).astype(a.dtype)
    return call(one(alpha), one(beta), a, b, c)
