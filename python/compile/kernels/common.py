"""Shared helpers for the AIEBLAS Pallas kernels.

The AIE analog (DESIGN.md SS2): a *window* is a block staged into the 32 KB
tile-local memory; we express the same HBM<->local schedule with Pallas
``BlockSpec``s. Kernels are always lowered with ``interpret=True`` — the CPU
PJRT client cannot execute Mosaic custom-calls, and correctness (not
wallclock) is the signal we take from this path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default window: 65536 f32 elements = 256 KB per buffer. The hardware
# adaptation rule (DESIGN.md SS2): Pallas windows tile for *VMEM* (~16 MB),
# not the AIE's 32 KB local memory — the 32 KB constraint lives in the L3
# simulator, while the L1 kernel should use the TPU-appropriate block size.
# 3 input buffers x 256 KB double-buffered ~ 1.5 MB, comfortably in VMEM,
# and n = 2^20 lowers to 16 grid steps instead of 256 (PJRT hot-path time
# dropped 6x; EXPERIMENTS.md SSPerf L2).
DEFAULT_WINDOW = 65536

# AIE vector datapath is 512 bits = 16 f32 lanes; kept for documentation of
# the lane-utilization estimates in DESIGN.md §7 (Pallas vectorizes blocks
# itself, so lanes are implicit).
VECTOR_BITS = 512
F32_LANES = VECTOR_BITS // 32


def cdiv(a: int, b: int) -> int:
    """Ceiling division."""
    return -(-a // b)


def pick_window(n: int, window: int | None = None) -> int:
    """Choose a window (block) size that divides ``n``.

    AIEBLAS requires the window to divide the problem size (the generated
    ADF kernels iterate whole windows); we enforce the same invariant and
    shrink to the largest divisor <= requested window.
    """
    w = min(window or DEFAULT_WINDOW, n)
    while n % w != 0:
        w -= 1
    return max(w, 1)


def scalar_spec():
    """BlockSpec for a broadcast scalar passed as a shape-(1,) array."""
    return pl.BlockSpec((1,), lambda *_: (0,))


def vec_spec(window: int):
    """BlockSpec for a 1-D vector tiled into windows over a 1-D grid."""
    return pl.BlockSpec((window,), lambda i: (i,))


def reduction_out_spec():
    """BlockSpec for a shape-(1,) accumulator output shared by all steps."""
    return pl.BlockSpec((1,), lambda *_: (0,))


def pallas_call_1d(kernel, n: int, window: int, num_in: int, dtype,
                   *, scalars: int = 0, out_reduce: bool = False):
    """Build a 1-D windowed ``pallas_call``.

    ``scalars`` leading inputs are shape-(1,) broadcast scalars; the
    remaining ``num_in`` inputs are length-``n`` vectors. The output is
    either a length-``n`` vector (elementwise) or a shape-(1,) reduction.
    """
    grid = (cdiv(n, window),)
    in_specs = [scalar_spec() for _ in range(scalars)]
    in_specs += [vec_spec(window) for _ in range(num_in)]
    if out_reduce:
        out_spec = reduction_out_spec()
        out_shape = jax.ShapeDtypeStruct((1,), dtype)
    else:
        out_spec = vec_spec(window)
        out_shape = jax.ShapeDtypeStruct((n,), dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=True,
    )


def first_step():
    """Predicate: true on the first grid step (for accumulator init)."""
    return pl.program_id(0) == 0


__all__ = [
    "DEFAULT_WINDOW",
    "VECTOR_BITS",
    "F32_LANES",
    "cdiv",
    "pick_window",
    "scalar_spec",
    "vec_spec",
    "reduction_out_spec",
    "pallas_call_1d",
    "first_step",
    "jnp",
    "jax",
    "pl",
    "functools",
]
