"""Pure-jnp oracles for every AIEBLAS routine.

These are the correctness references the Pallas kernels are tested against
(pytest + hypothesis in python/tests/). They mirror the scalar reference
implementations in rust/src/blas/reference.rs; the Rust test-suite checks
the two references against each other through the PJRT artifacts.

BLAS semantics follow the updated BLAS standard [Blackford et al., 2002],
the same reference the paper cites ([13]).
"""

from __future__ import annotations

import jax.numpy as jnp


def axpy(alpha, x, y):
    """z = alpha * x + y  (BLAS saxpy, out-of-place as in AIEBLAS)."""
    return alpha * x + y


def scal(alpha, x):
    """z = alpha * x."""
    return alpha * x


def copy(x):
    """z = x."""
    return x


def dot(x, y):
    """x^T y."""
    return jnp.dot(x, y)


def nrm2(x):
    """||x||_2."""
    return jnp.sqrt(jnp.sum(x * x))


def asum(x):
    """sum |x_i| (1-norm)."""
    return jnp.sum(jnp.abs(x))


def iamax(x):
    """argmax_i |x_i| (first index of max magnitude, BLAS ixamax)."""
    return jnp.argmax(jnp.abs(x)).astype(jnp.int32)


def gemv(alpha, a, x, beta, y):
    """y' = alpha * A @ x + beta * y."""
    return alpha * (a @ x) + beta * y


def gemm(alpha, a, b, beta, c):
    """C' = alpha * A @ B + beta * C."""
    return alpha * (a @ b) + beta * c


def axpydot(alpha, w, v, u):
    """beta = z^T u with z = w - alpha * v (composed routine, paper §III).

    Matches the paper's axpydot definition from the updated BLAS [13]:
    an axpy (with negated alpha) feeding a dot product.
    """
    z = w - alpha * v
    return jnp.dot(z, u)


def axpby(alpha, beta, x, y):
    """z = alpha*x + beta*y."""
    return alpha * x + beta * y


def rot(c, s, x, y):
    """Givens rotation: (c*x + s*y, c*y - s*x)."""
    return c * x + s * y, c * y - s * x


def ger(alpha, x, y, a):
    """A' = A + alpha * x y^T."""
    return a + alpha * jnp.outer(x, y)
