"""L2: AIEBLAS routine compute graphs in JAX.

The *routine registry* maps a routine name + problem size to a jittable
function built on the L1 Pallas kernels. This mirrors the Rust-side registry
(rust/src/blas/mod.rs); the two are kept in sync by the manifest that
``aot.py`` emits and the Rust runtime consumes.

Every routine function:
  * takes only array arguments (scalars as shape-(1,) f32 arrays so the
    lowered HLO has a stable parameter signature for the Rust loader);
  * returns a tuple (lowered with return_tuple=True on the XLA side).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from . import kernels as K


@dataclass(frozen=True)
class RoutineDef:
    """Registry entry: how to build + lower one routine at a given size."""

    name: str
    #: builder(size) -> (fn, example_args); fn takes/returns jnp arrays.
    build: Callable[[int], tuple]
    #: human description used in the manifest.
    doc: str = ""
    #: sizes precompiled into artifacts/ by aot.py.
    aot_sizes: Sequence[int] = field(default_factory=tuple)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _scalar():
    return _f32(1)


# --------------------------------------------------------------------------
# builders — each returns (fn, example_args)
# --------------------------------------------------------------------------

def _build_axpy(n, window=None):
    def fn(alpha, x, y):
        return (K.axpy(alpha[0], x, y, window=window),)
    return fn, (_scalar(), _f32(n), _f32(n))


def _build_scal(n, window=None):
    def fn(alpha, x):
        return (K.scal(alpha[0], x, window=window),)
    return fn, (_scalar(), _f32(n))


def _build_copy(n, window=None):
    def fn(x):
        return (K.copy(x, window=window),)
    return fn, (_f32(n),)


def _build_dot(n, window=None):
    def fn(x, y):
        return (jnp.reshape(K.dot(x, y, window=window), (1,)),)
    return fn, (_f32(n), _f32(n))


def _build_nrm2(n, window=None):
    def fn(x):
        return (jnp.reshape(K.nrm2(x, window=window), (1,)),)
    return fn, (_f32(n),)


def _build_asum(n, window=None):
    def fn(x):
        return (jnp.reshape(K.asum(x, window=window), (1,)),)
    return fn, (_f32(n),)


def _build_iamax(n, window=None):
    def fn(x):
        return (jnp.reshape(K.iamax(x, window=window), (1,)),)
    return fn, (_f32(n),)


def _build_axpby(n, window=None):
    def fn(alpha, beta, x, y):
        return (K.axpby(alpha[0], beta[0], x, y, window=window),)
    return fn, (_scalar(), _scalar(), _f32(n), _f32(n))


def _build_rot(n, window=None):
    def fn(c, s, x, y):
        xo, yo = K.rot(c[0], s[0], x, y, window=window)
        return (xo, yo)
    return fn, (_scalar(), _scalar(), _f32(n), _f32(n))


def _build_ger(n, block_m=None, block_n=None):
    def fn(alpha, x, y, a):
        return (K.ger(alpha[0], x, y, a, block_m=block_m, block_n=block_n),)
    return fn, (_scalar(), _f32(n), _f32(n), _f32(n, n))


def _build_gemv(n, block_m=None, block_n=None):
    def fn(alpha, a, x, beta, y):
        return (K.gemv(alpha[0], a, x, beta[0], y,
                       block_m=block_m, block_n=block_n),)
    return fn, (_scalar(), _f32(n, n), _f32(n), _scalar(), _f32(n))


def _build_gemm(n, **blocks):
    def fn(alpha, a, b, beta, c):
        return (K.gemm(alpha[0], a, b, beta[0], c, **blocks),)
    return fn, (_scalar(), _f32(n, n), _f32(n, n), _scalar(), _f32(n, n))


def _build_axpydot(n, window=None):
    """Dataflow (fused) axpydot: one HLO module, z never leaves the chip."""
    def fn(alpha, w, v, u):
        return (jnp.reshape(K.axpydot(alpha[0], w, v, u, window=window), (1,)),)
    return fn, (_scalar(), _f32(n), _f32(n), _f32(n))


def _build_axpy_neg(n, window=None):
    """axpy with negated alpha: the first stage of non-dataflow axpydot.

    The Rust coordinator composes no-DF axpydot as axpy_neg -> (DDR round
    trip) -> dot, so the stage artifact must match the paper's z = w -
    alpha*v definition.
    """
    def fn(alpha, v, w):
        return (K.axpy(-alpha[0], v, w, window=window),)
    return fn, (_scalar(), _f32(n), _f32(n))


# Vector sizes swept by Fig. 3 (axpy / dot / axpydot panels).
VEC_SIZES = (4096, 16384, 65536, 262144, 1048576)
# Matrix sizes swept by Fig. 3 (gemv panel).
MAT_SIZES = (64, 128, 256, 512)
GEMM_SIZES = (64, 128, 256)

REGISTRY: dict[str, RoutineDef] = {
    r.name: r
    for r in [
        RoutineDef("axpy", _build_axpy, "z = alpha*x + y", VEC_SIZES),
        RoutineDef("axpy_neg", _build_axpy_neg,
                   "z = w - alpha*v (no-DF axpydot stage 1)", VEC_SIZES),
        RoutineDef("scal", _build_scal, "z = alpha*x", VEC_SIZES[:3]),
        RoutineDef("copy", _build_copy, "z = x", VEC_SIZES[:3]),
        RoutineDef("dot", _build_dot, "x^T y", VEC_SIZES),
        RoutineDef("nrm2", _build_nrm2, "||x||_2", VEC_SIZES[:3]),
        RoutineDef("asum", _build_asum, "sum |x_i|", VEC_SIZES[:3]),
        RoutineDef("iamax", _build_iamax, "argmax |x_i|", VEC_SIZES[:3]),
        RoutineDef("axpby", _build_axpby, "z = alpha*x + beta*y", VEC_SIZES[:3]),
        RoutineDef("rot", _build_rot, "Givens rotation (2 outputs)", VEC_SIZES[:3]),
        RoutineDef("ger", _build_ger, "A += alpha*x@y^T", MAT_SIZES[:3]),
        RoutineDef("gemv", _build_gemv, "y = alpha*A@x + beta*y", MAT_SIZES),
        RoutineDef("gemm", _build_gemm, "C = alpha*A@B + beta*C", GEMM_SIZES),
        RoutineDef("axpydot", _build_axpydot,
                   "beta = (w - alpha*v)^T u, fused dataflow", VEC_SIZES),
    ]
}


def build(name: str, size: int, **params):
    """Build (fn, example_args) for a registered routine at ``size``."""
    if name not in REGISTRY:
        raise KeyError(f"unknown routine {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name].build(size, **params)


def lower_hlo_text(name: str, size: int, **params) -> str:
    """Lower a routine to HLO *text* (the Rust interchange format).

    HLO text, not ``.serialize()``: jax >= 0.5 emits protos with 64-bit
    instruction ids that xla_extension 0.5.1 rejects; the text parser
    reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
    """
    from jax._src.lib import xla_client as xc

    fn, example_args = build(name, size, **params)
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
