"""AOT driver: lower every registered routine/size to artifacts/.

Emits one ``<routine>_n<size>.hlo.txt`` per (routine, size) pair plus a
``manifest.json`` the Rust runtime uses to locate artifacts
(rust/src/runtime/manifest.rs). Python runs ONCE here — never on the
request path; after ``make artifacts`` the Rust binary is self-contained.

HLO *text* (NOT ``lowered.compile()`` / proto ``.serialize()``) is the
interchange format — see model.lower_hlo_text for why.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

from . import model


def artifact_key(name: str, size: int) -> str:
    return f"{name}_n{size}"


def input_signature(example_args) -> list[dict]:
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
    ]


def build_all(out_dir: str, *, sizes_cap: int | None = None,
              routines: list[str] | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    names = routines or sorted(model.REGISTRY)
    for name in names:
        rdef = model.REGISTRY[name]
        sizes = list(rdef.aot_sizes)
        if sizes_cap is not None:
            sizes = [s for s in sizes if s <= sizes_cap]
        for size in sizes:
            t0 = time.time()
            text = model.lower_hlo_text(name, size)
            fname = artifact_key(name, size) + ".hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            import jax
            fn, example_args = model.build(name, size)
            out_shapes = jax.eval_shape(fn, *example_args)
            entries.append({
                "key": artifact_key(name, size),
                "routine": name,
                "size": size,
                "file": fname,
                "inputs": input_signature(example_args),
                "num_outputs": len(out_shapes),
                "doc": rdef.doc,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            })
            print(f"  {artifact_key(name, size):24s} "
                  f"{len(text) / 1024:8.1f} KiB  {time.time() - t0:5.2f}s",
                  file=sys.stderr)
    manifest = {
        "version": 1,
        "generator": "aieblas python/compile/aot.py",
        "interchange": "hlo-text",
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts",
                   help="output directory for HLO artifacts + manifest")
    p.add_argument("--max-size", type=int, default=None,
                   help="cap precompiled sizes (faster dev builds)")
    p.add_argument("--routines", nargs="*", default=None,
                   help="subset of routines to build")
    args = p.parse_args()
    manifest = build_all(args.out, sizes_cap=args.max_size,
                         routines=args.routines)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
