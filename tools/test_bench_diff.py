#!/usr/bin/env python3
"""Unit tests for tools/bench_diff.py (stdlib only).

Run: python3 tools/test_bench_diff.py
"""

import json
import sys
import tempfile
import unittest
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
import bench_diff  # noqa: E402


def write_doc(directory, name, cases, smoke=False):
    doc = {"bench": name, "unit": "seconds", "smoke": smoke, "cases": cases}
    path = Path(directory) / f"BENCH_{name}.json"
    path.write_text(json.dumps(doc))
    return path


def run_diff(base, cur, **kw):
    argv = [str(base), str(cur)]
    argv += ["--max-regression", str(kw.get("max_regression", 0.20))]
    argv += ["--min-seconds", str(kw.get("min_seconds", 1e-3))]
    return bench_diff.main(argv)


class BenchDiffTests(unittest.TestCase):
    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        root = Path(self._tmp.name)
        self.base = root / "base"
        self.cur = root / "cur"
        self.base.mkdir()
        self.cur.mkdir()

    def tearDown(self):
        self._tmp.cleanup()

    def test_new_file_without_baseline_is_informational(self):
        # a brand-new BENCH file must be reported, not gated: exit 0 even
        # though nothing is comparable.
        write_doc(self.cur, "warm_path", [{"case": "size=16", "get_median_s": 2.0}])
        self.assertEqual(run_diff(self.base, self.cur), 0)

    def test_regression_beyond_threshold_fails(self):
        write_doc(self.base, "x", [{"case": "a", "run_median_s": 1.0}])
        write_doc(self.cur, "x", [{"case": "a", "run_median_s": 1.5}])
        self.assertEqual(run_diff(self.base, self.cur), 1)

    def test_within_threshold_passes(self):
        write_doc(self.base, "x", [{"case": "a", "run_median_s": 1.0}])
        write_doc(self.cur, "x", [{"case": "a", "run_median_s": 1.1}])
        self.assertEqual(run_diff(self.base, self.cur), 0)

    def test_baseline_prefixed_fields_are_never_gated(self):
        # naive_/pr2_/untuned_/shed_ fields time deliberately old configs;
        # a 100x "regression" there must not fail the build.
        write_doc(self.base, "x", [{"case": "a", "naive_get_median_s": 1.0,
                                    "run_median_s": 1.0}])
        write_doc(self.cur, "x", [{"case": "a", "naive_get_median_s": 100.0,
                                   "run_median_s": 1.0}])
        self.assertEqual(run_diff(self.base, self.cur), 0)

    def test_sub_min_seconds_baselines_are_ignored(self):
        # a 1 µs-scale median may regress 10x without failing: below
        # --min-seconds the ratio is timing noise.
        write_doc(self.base, "x", [{"case": "a", "get_median_s": 1e-6}])
        write_doc(self.cur, "x", [{"case": "a", "get_median_s": 1e-5}])
        self.assertEqual(run_diff(self.base, self.cur), 0)

    def test_smoke_flag_mismatch_skips_file(self):
        write_doc(self.base, "x", [{"case": "a", "run_median_s": 1.0}], smoke=True)
        write_doc(self.cur, "x", [{"case": "a", "run_median_s": 9.0}], smoke=False)
        self.assertEqual(run_diff(self.base, self.cur), 0)

    def test_new_case_in_existing_file_is_informational(self):
        write_doc(self.base, "x", [{"case": "a", "run_median_s": 1.0}])
        write_doc(self.cur, "x", [{"case": "a", "run_median_s": 1.0},
                                  {"case": "b", "run_median_s": 99.0}])
        self.assertEqual(run_diff(self.base, self.cur), 0)

    def test_empty_current_dir_is_ok(self):
        self.assertEqual(run_diff(self.base, self.cur), 0)


if __name__ == "__main__":
    unittest.main()
