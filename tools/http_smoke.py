#!/usr/bin/env python3
"""End-to-end smoke test for the aieblas HTTP front door (DESIGN.md §13).

Drives the real `aieblas serve` binary over loopback TCP, stdlib only:

1. **Cold serve.** A fresh process with a fresh `--cache-dir`: a cold
   `/v1/run` must report `cache.misses >= 1` and `disk_writes >= 1`;
   a garbage body must come back `400` with a structured
   `{"error": {"code": ...}}`; `POST /v1/drain` must settle in-flight
   work and exit the process cleanly.
2. **Warm start.** A second process sharing the same store: the same
   spec must serve with `cache.misses == 0` (zero lowerings) and
   `cache.disk_hits > 0` — the fleet warm-start guarantee.
3. **Shard fleet.** Two processes with `--peers a,b --shard-index 0/1`
   on a fresh store: distinct specs all POSTed to shard A must all
   succeed, and each shard's `/v1/statsz` request count must match the
   routing rule `shard = fnv1a64(cache_key) % len(peers)` (replicated
   below) — proving wrong-shard requests were proxied to their owner.
4. **Failover.** The same fleet shape, then SIGKILL one shard: the
   survivor must answer the dead shard's keys `200` by serving them
   locally from the shared store (`metrics.failover_served >= 1`), and
   its health probes must trip the victim's circuit breaker `open`
   (DESIGN.md §14).

Usage:
  python3 tools/http_smoke.py --binary target/release/aieblas
"""

import argparse
import json
import re
import shutil
import socket
import subprocess
import sys
import tempfile
import threading
import time
import urllib.error
import urllib.request

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = 0xFFFFFFFFFFFFFFFF


def fnv1a64(data: bytes) -> int:
    """The crate's util::fnv1a64 — keep byte-for-byte identical."""
    h = FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & MASK64
    return h


def cache_key(name: str, size: int) -> str:
    """Canonical cache key of a single-routine PL axpy spec.

    Mirrors Spec::to_json().to_compact(): BTreeMap ordering (sorted
    keys), defaults filled in, no whitespace. Any drift from the Rust
    rendering fails phase 3 loudly, which is the point.
    """
    return (
        '{"connections":[],"data_source":"pl","platform":"vck5000",'
        '"routines":[{"name":"%s","routine":"axpy","size":%d}]}' % (name, size)
    )


def shard_of(name: str, size: int, peers: int) -> int:
    return fnv1a64(cache_key(name, size).encode()) % peers


def run_body(name: str, size: int) -> dict:
    return {"spec": {"routines": [{"routine": "axpy", "name": name, "size": size}]}}


def http(addr: str, method: str, path: str, body=None, raw: bytes = None):
    """One request; returns (status, parsed-json). 4xx/5xx don't raise."""
    data = raw if raw is not None else (
        None if body is None else json.dumps(body).encode()
    )
    req = urllib.request.Request(
        "http://%s%s" % (addr, path),
        data=data,
        method=method,
        headers={"content-type": "application/json"},
    )
    try:
        with urllib.request.urlopen(req, timeout=60) as resp:
            return resp.status, json.loads(resp.read().decode())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read().decode())


class Server:
    """One `aieblas serve` process; parses the announced address."""

    def __init__(self, binary, cache_dir, listen="127.0.0.1:0", extra=()):
        cmd = [binary, "serve", "--listen", listen, "--cache-dir", cache_dir]
        cmd += list(extra)
        self.proc = subprocess.Popen(
            cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True
        )
        self.addr = self._wait_for_addr()

    def _wait_for_addr(self, timeout=60):
        found = {}

        def reader():
            for line in self.proc.stdout:
                sys.stdout.write("  | " + line)
                m = re.search(r"serving on http://(\S+)", line)
                if m and "addr" not in found:
                    found["addr"] = m.group(1)

        self.reader = threading.Thread(target=reader, daemon=True)
        self.reader.start()
        deadline = threading.Event()
        for _ in range(timeout * 10):
            if "addr" in found:
                return found["addr"]
            if self.proc.poll() is not None:
                raise RuntimeError("server exited before announcing its address")
            deadline.wait(0.1)
        raise RuntimeError("server never announced its address")

    def drain(self):
        status, body = http(self.addr, "POST", "/v1/drain", body={})
        assert status == 200, body
        assert body.get("drained") is True, body
        self.proc.wait(timeout=60)
        assert self.proc.returncode == 0, "serve exited %r" % self.proc.returncode

    def kill(self):
        if self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait(timeout=10)


def check(cond, msg):
    if not cond:
        raise AssertionError(msg)
    print("  ok: %s" % msg)


def free_ports(n):
    socks = [socket.socket() for _ in range(n)]
    for s in socks:
        s.bind(("127.0.0.1", 0))
    ports = [s.getsockname()[1] for s in socks]
    for s in socks:
        s.close()
    return ports


def phase_cold(binary, store):
    print("== phase 1: cold serve, error shapes, drain ==")
    srv = Server(binary, store)
    try:
        status, body = http(srv.addr, "POST", "/v1/run", body=run_body("smoke", 256))
        check(status == 200, "cold /v1/run is 200")
        check(body["cache"]["misses"] >= 1, "cold run lowered (misses >= 1)")
        check(body["cache"]["disk_writes"] >= 1, "plan written through to the store")
        check(body["v"] == 1, "versioned envelope")

        status, body = http(srv.addr, "POST", "/v1/run", raw=b"{nope")
        check(status == 400, "garbage body is 400")
        check("code" in body.get("error", {}), "error body is structured")

        status, body = http(srv.addr, "GET", "/v1/healthz")
        check(status == 200 and body["status"] == "ok", "healthz ok")
        srv.drain()
        print("  ok: drained and exited 0")
    finally:
        srv.kill()


def phase_warm(binary, store):
    print("== phase 2: second process, zero-lowering warm start ==")
    srv = Server(binary, store)
    try:
        status, body = http(srv.addr, "POST", "/v1/run", body=run_body("smoke", 256))
        check(status == 200, "warm /v1/run is 200")
        check(body["cache"]["misses"] == 0, "second process performed zero lowerings")
        check(body["cache"]["disk_hits"] > 0, "plan served from the shared store")
        srv.drain()
    finally:
        srv.kill()


def phase_shards(binary, store):
    print("== phase 3: two-shard fleet, proxy to owner ==")
    ports = free_ports(2)
    peers = ["127.0.0.1:%d" % p for p in ports]
    peer_flag = ",".join(peers)

    # Distinct specs with deterministic ownership under the replicated
    # routing rule; grow until both shards own at least one.
    specs, expected = [], [0, 0]
    size = 64
    while len(specs) < 8 or min(expected) == 0:
        name = "shard%d" % len(specs)
        owner = shard_of(name, size, 2)
        specs.append((name, size, owner))
        expected[owner] += 1
        size += 16
        if len(specs) > 64:
            raise AssertionError("64 distinct specs all hashed to one shard")

    servers = []
    try:
        for i in range(2):
            servers.append(
                Server(
                    binary,
                    store,
                    listen=peers[i],
                    extra=["--peers", peer_flag, "--shard-index", str(i)],
                )
            )
        a = servers[0].addr
        for name, size, _owner in specs:
            status, body = http(a, "POST", "/v1/run", body=run_body(name, size))
            check(status == 200, "run %s (size %d) is 200" % (name, size))

        for i, srv in enumerate(servers):
            status, stats = http(srv.addr, "GET", "/v1/statsz")
            check(status == 200, "shard %d statsz is 200" % i)
            got = int(stats["requests"])
            check(
                got == expected[i],
                "shard %d executed %d request(s) (routing rule agrees)" % (i, got),
            )

        status, health = http(servers[1].addr, "GET", "/v1/healthz")
        check(health["shards"]["self_index"] == 1, "healthz reports shard index")
        check(len(health["shards"]["peers"]) == 2, "healthz reports the peer map")

        for srv in servers:
            srv.drain()
    finally:
        for srv in servers:
            srv.kill()


def phase_failover(binary, store):
    print("== phase 4: kill one shard, breaker-gated local failover ==")
    ports = free_ports(2)
    peers = ["127.0.0.1:%d" % p for p in ports]
    peer_flag = ",".join(peers)

    # Specs owned by shard 1 — the shard we are about to kill — so the
    # survivor cannot answer them without failing over.
    victim_specs, size = [], 96
    while len(victim_specs) < 2:
        name = "kill%d" % size
        if shard_of(name, size, 2) == 1:
            victim_specs.append((name, size))
        size += 16
        if size > 96 + 64 * 16:
            raise AssertionError("64 distinct specs all hashed to shard 0")

    servers = []
    try:
        for i in range(2):
            servers.append(
                Server(
                    binary,
                    store,
                    listen=peers[i],
                    extra=[
                        "--peers", peer_flag,
                        "--shard-index", str(i),
                        "--probe-interval-ms", "100",
                    ],
                )
            )
        a = servers[0].addr
        # Warm the victim's keys through the fleet (proxied to shard 1),
        # which also writes the plans through to the shared store.
        for name, size in victim_specs:
            status, _ = http(a, "POST", "/v1/run", body=run_body(name, size))
            check(status == 200, "warm %s via its owner is 200" % name)

        # SIGKILL, not drain: the survivor must *discover* the outage.
        servers[1].kill()
        print("  ok: shard 1 killed (no drain)")
        for name, size in victim_specs:
            status, body = http(a, "POST", "/v1/run", body=run_body(name, size))
            check(status == 200, "dead shard's key %s still answers 200" % name)

        status, stats = http(a, "GET", "/v1/statsz")
        check(status == 200, "survivor statsz is 200")
        check(
            int(stats["metrics"]["failover_served"]) >= 1,
            "survivor counted failover_served",
        )

        deadline = time.time() + 15
        while True:
            status, health = http(a, "GET", "/v1/healthz")
            breaker = health["shards"]["peers"][1]["breaker"]
            if breaker == "open":
                break
            if time.time() > deadline:
                raise AssertionError(
                    "victim breaker never opened (last: %r)" % breaker
                )
            time.sleep(0.2)
        print("  ok: probes tripped the victim's breaker open")

        servers[0].drain()
    finally:
        for srv in servers:
            srv.kill()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--binary",
        default="target/release/aieblas",
        help="path to the aieblas CLI (default: target/release/aieblas)",
    )
    args = ap.parse_args()

    warm_store = tempfile.mkdtemp(prefix="aieblas-http-smoke-warm-")
    shard_store = tempfile.mkdtemp(prefix="aieblas-http-smoke-shard-")
    failover_store = tempfile.mkdtemp(prefix="aieblas-http-smoke-failover-")
    try:
        phase_cold(args.binary, warm_store)
        phase_warm(args.binary, warm_store)
        phase_shards(args.binary, shard_store)
        phase_failover(args.binary, failover_store)
    finally:
        shutil.rmtree(warm_store, ignore_errors=True)
        shutil.rmtree(shard_store, ignore_errors=True)
        shutil.rmtree(failover_store, ignore_errors=True)
    print("http smoke: all phases passed")


if __name__ == "__main__":
    main()
