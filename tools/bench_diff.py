#!/usr/bin/env python3
"""Compare two directories of BENCH_*.json perf artifacts.

Starts the "diffing the series across runs" ROADMAP item: CI downloads the
previous successful run's bench-json artifact and fails the build when any
case's median regresses by more than --max-regression (default 20%).

Dependency-free (stdlib only). Matching rules:

* files are matched by name (BENCH_sim_engine.json vs BENCH_sim_engine.json);
* a file pair is skipped when the runs are not comparable (different
  `smoke` flags, or a side is unreadable);
* cases are matched by their "case" field; within a matched case, every
  numeric field named `median_s` or ending in `_median_s` is compared —
  except informational baseline fields (`pr2_*`, `naive_*`), which time
  deliberately old engine configurations and are not perf targets;
* baselines below --min-seconds are ignored (CI passes 1e-3: timings
  under a millisecond on shared runners are noise, not signal);
* a case/field present on only one side is reported but never fails the
  diff (benches grow new cases as the engine grows);
* a brand-new BENCH_*.json with no baseline artifact at all is reported
  informationally (its gated fields are printed as "new, not gated") and
  never fails the diff — the next run picks it up as a baseline.

Exit status: 0 = OK (or nothing comparable), 1 = at least one regression.
"""

import argparse
import json
import sys
from pathlib import Path


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"  note: unreadable {path}: {e}")
        return None


# Fields timing pinned-old engine configurations: informational context
# for the speedup columns, never gated. ("untuned_" covers the autotuner
# bench's no-search baseline; "shed_" covers the serve_stress admission
# counters, which scale with offered load rather than engine speed;
# "degraded_" covers the fleet bench's one-shard-down phase, whose
# latency includes breaker transients rather than engine speed.)
BASELINE_FIELD_PREFIXES = ("pr2_", "naive_", "untuned_", "shed_", "degraded_")


def median_fields(case):
    for key, value in case.items():
        if key.startswith(BASELINE_FIELD_PREFIXES):
            continue
        if key == "median_s" or key.endswith("_median_s"):
            if isinstance(value, (int, float)):
                yield key, float(value)


def diff_file(name, base_doc, cur_doc, args):
    if base_doc.get("smoke") != cur_doc.get("smoke"):
        print(f"  {name}: smoke flags differ (base {base_doc.get('smoke')} vs "
              f"current {cur_doc.get('smoke')}); not comparable, skipping")
        return []
    base_cases = {c.get("case"): c for c in base_doc.get("cases", []) if c.get("case")}
    regressions = []
    for cur in cur_doc.get("cases", []):
        label = cur.get("case")
        if not label:
            continue
        base = base_cases.get(label)
        if base is None:
            print(f"  {name}/{label}: new case (no baseline)")
            continue
        for field, cur_v in median_fields(cur):
            base_v = base.get(field)
            if not isinstance(base_v, (int, float)):
                print(f"  {name}/{label}.{field}: no baseline field")
                continue
            base_v = float(base_v)
            if base_v < args.min_seconds:
                continue  # below timing resolution; ratios are noise
            ratio = cur_v / base_v - 1.0
            marker = "REGRESSION" if ratio > args.max_regression else "ok"
            print(f"  {name}/{label}.{field}: base {base_v:.6g}s -> "
                  f"current {cur_v:.6g}s ({ratio:+.1%}) {marker}")
            if ratio > args.max_regression:
                regressions.append((name, label, field, base_v, cur_v, ratio))
    return regressions


def report_new_file(name, cur_doc):
    """A bench artifact with no baseline: print what the next run will
    gate against, but never fail on it."""
    print(f"  {name}: new bench (no baseline artifact) — informational only")
    for case in cur_doc.get("cases", []):
        label = case.get("case")
        if not label:
            continue
        for field, value in median_fields(case):
            print(f"    {name}/{label}.{field}: {value:.6g}s (new, not gated)")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", type=Path, help="directory with the previous run's BENCH_*.json")
    ap.add_argument("current", type=Path, help="directory with this run's BENCH_*.json")
    ap.add_argument("--max-regression", type=float, default=0.20,
                    help="fail when median grows by more than this fraction (default 0.20)")
    ap.add_argument("--min-seconds", type=float, default=1e-6,
                    help="ignore baselines below this many seconds (default 1e-6)")
    args = ap.parse_args(argv)

    current_files = sorted(args.current.glob("BENCH_*.json"))
    if not current_files:
        print(f"no BENCH_*.json under {args.current}; nothing to diff")
        return 0

    regressions = []
    compared = 0
    for cur_path in current_files:
        base_path = args.baseline / cur_path.name
        if not base_path.exists():
            cur_doc = load(cur_path)
            if cur_doc is not None:
                report_new_file(cur_path.name, cur_doc)
            continue
        base_doc, cur_doc = load(base_path), load(cur_path)
        if base_doc is None or cur_doc is None:
            continue
        print(f"{cur_path.name}:")
        regressions += diff_file(cur_path.name, base_doc, cur_doc, args)
        compared += 1

    if compared == 0:
        print("no comparable bench files; treating as OK")
        return 0
    if regressions:
        print(f"\n{len(regressions)} median regression(s) beyond "
              f"{args.max_regression:.0%}:")
        for name, label, field, base_v, cur_v, ratio in regressions:
            print(f"  {name}/{label}.{field}: {base_v:.6g}s -> {cur_v:.6g}s ({ratio:+.1%})")
        return 1
    print(f"\nbench-diff OK: {compared} file(s), no median regression beyond "
          f"{args.max_regression:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
