//! End-to-end driver: the full AIEBLAS system on the paper's entire
//! evaluation (Fig. 3), proving all layers compose:
//!
//!   L1/L2  Pallas kernels -> JAX -> HLO artifacts   (make artifacts)
//!   L3     spec -> codegen -> graph -> place/route -> DES simulation
//!   rt     PJRT executes the HLO artifacts; outputs checked against the
//!          Rust reference for every routine/size in the sweep
//!
//! Prints the three Fig. 3 panels (axpy, gemv, axpydot) with the paper's
//! variants, the §IV claim checks, and a numerics table. Results are
//! recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_fig3`

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{experiments, AieBlas, Config};
use aieblas::runtime::Provenance;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    aieblas::init();
    let system = AieBlas::new(Config::default())?;

    println!("== artifacts ==");
    println!(
        "{} precompiled HLO modules under {:?}\n",
        system.executor().manifest().len(),
        system.config.artifacts_dir
    );

    // --- Fig. 3 panels -----------------------------------------------------
    let axpy = experiments::single_routine_panel(
        &system,
        RoutineKind::Axpy,
        &experiments::VEC_SIZES,
    )?;
    println!("{}", experiments::panel_table("axpy", &axpy).render());

    let gemv = experiments::single_routine_panel(
        &system,
        RoutineKind::Gemv,
        &experiments::MAT_SIZES,
    )?;
    println!("{}", experiments::panel_table("gemv", &gemv).render());

    let axpydot = experiments::axpydot_panel(&system, &experiments::VEC_SIZES)?;
    println!("{}", experiments::panel_table("axpydot", &axpydot).render());

    // --- §IV claims ----------------------------------------------------------
    println!("== paper claims (§IV) ==");
    let mut ok = true;
    for &n in &experiments::VEC_SIZES {
        let pl = experiments::lookup(&axpy, n, "aie (PL)").unwrap();
        let nopl = experiments::lookup(&axpy, n, "aie (no PL)").unwrap();
        let cpu = experiments::lookup(&axpy, n, "cpu").unwrap();
        let df = experiments::lookup(&axpydot, n, "aie (w/ DF)").unwrap();
        let nodf = experiments::lookup(&axpydot, n, "aie (w/o DF)").unwrap();
        let c1 = nopl < pl;
        let c2 = (1.5..3.5).contains(&(nodf / df));
        let c3 = cpu < pl;
        ok &= c1 && c2 && c3;
        println!(
            "n={n:>8}: C1 no-PL<PL {}  C2 DF speedup {:.2}x {}  C3 CPU {:.1}x faster {}",
            if c1 { "OK" } else { "FAIL" },
            nodf / df,
            if c2 { "OK" } else { "FAIL" },
            pl / cpu,
            if c3 { "OK" } else { "FAIL" },
        );
    }

    // --- numerics through the real artifacts ---------------------------------
    println!("\n== numerics (PJRT artifacts vs Rust reference) ==");
    let mut pjrt_count = 0;
    for kind in [
        RoutineKind::Axpy,
        RoutineKind::Dot,
        RoutineKind::Gemv,
        RoutineKind::Axpydot,
        RoutineKind::Nrm2,
        RoutineKind::Asum,
        RoutineKind::Scal,
        RoutineKind::Iamax,
    ] {
        let sizes = system.executor().manifest().sizes_for(kind.name());
        let Some(&n) = sizes.iter().find(|&&s| s >= 16384).or(sizes.first()) else {
            println!("  {:8} (no artifact; run `make artifacts`)", kind.name());
            continue;
        };
        let num = system.run_numeric(kind, n)?;
        if num.backend == Provenance::Pjrt {
            pjrt_count += 1;
        }
        println!(
            "  {:8} n={n:>7}  backend {:?}  max rel err {:.2e}",
            kind.name(),
            num.backend,
            num.max_rel_err
        );
        assert!(num.max_rel_err < 1e-2, "{} numerics out of tolerance", kind.name());
    }

    println!(
        "\nE2E {}: {} routines served by PJRT artifacts; claims {}",
        if ok { "PASS" } else { "FAIL" },
        pjrt_count,
        if ok { "hold" } else { "FAILED" }
    );
    if !ok {
        std::process::exit(1);
    }
    Ok(())
}
