//! gemv across matrix sizes with placement hints — the Fig. 3 gemv panel
//! plus the §III placement-constraint feature.
//!
//! Run: `cargo run --release --example gemv_sweep`

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{experiments, AieBlas, Config};
use aieblas::spec::{DataSource, Placement, Spec};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    aieblas::init();
    let system = AieBlas::new(Config::default())?;

    // Fig. 3 gemv panel: PL vs no-PL vs CPU model.
    let rows = experiments::single_routine_panel(
        &system,
        RoutineKind::Gemv,
        &experiments::MAT_SIZES,
    )?;
    println!("{}", experiments::panel_table("gemv", &rows).render());

    // placement hints (paper §III): pin the kernel near the shim, compare
    // the router's view.
    for (label, placement) in [
        ("auto", None),
        ("pinned (0,0)", Some(Placement { col: 0, row: 0 })),
        ("pinned far (49,7)", Some(Placement { col: 49, row: 7 })),
    ] {
        let mut spec = Spec::single(RoutineKind::Gemv, "mv", 256, DataSource::Pl);
        spec.routines[0].placement = placement;
        let rep = system.run_spec_sim_only(&spec)?;
        println!(
            "gemv n=256 {label:18} -> {:.3} ms ({} NoC hops)",
            rep.makespan_s * 1e3,
            rep.noc_hops
        );
    }
    Ok(())
}
