//! Generate a complete Vitis design from a spec — the paper's Fig. 1
//! workflow artifacts (AIE kernels, PL movers, ADF graph, CMake project) —
//! and print a tour of the generated sources.
//!
//! Run: `cargo run --release --example codegen_project`

use aieblas::codegen;
use aieblas::spec::Spec;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    aieblas::init();
    let spec = Spec::from_json_str(
        r#"{
        "platform": "vck5000",
        "routines": [
            {"routine": "axpy", "name": "vadd", "size": 65536, "alpha": -2.0},
            {"routine": "dot",  "name": "vdot", "size": 65536,
             "placement": {"col": 10, "row": 2}}
        ],
        "connections": [{"from": "vadd.z", "to": "vdot.x"}]
    }"#,
    )?;

    let project = codegen::generate(&spec)?;
    let out = std::path::Path::new("generated/axpydot_design");
    project.write_to(out)?;

    println!(
        "generated {} files / {} lines under {}\n",
        project.files.len(),
        project.total_lines(),
        out.display()
    );
    for path in project.files.keys() {
        println!("  {path}");
    }

    println!("\n--- aie/kernels/vadd.cc (vectorized AIE kernel) ---");
    println!("{}", project.get("aie/kernels/vadd.cc").unwrap());
    println!("--- aie/graph.h (dataflow composition) ---");
    println!("{}", project.get("aie/graph.h").unwrap());
    Ok(())
}
