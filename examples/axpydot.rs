//! The paper's flagship composition (Fig. 1): axpydot, β = zᵀu with
//! z = w − αv, as an on-chip dataflow pipeline vs the non-dataflow
//! two-design variant — reproducing the ~2× pipelining win of Fig. 3.
//!
//! Run: `cargo run --release --example axpydot`

use aieblas::coordinator::{AieBlas, Config};
use aieblas::spec::Spec;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    aieblas::init();
    let system = AieBlas::new(Config::default())?;

    println!("axpydot: beta = (w - alpha*v)^T u   [paper Fig. 1 / Fig. 3]\n");
    println!("{:>10}  {:>14}  {:>14}  {:>8}", "n", "w/ DF", "w/o DF", "speedup");
    for exp in [14usize, 16, 18, 20] {
        let n = 1 << exp;
        let df = system.run_axpydot(n, true)?;
        let nodf = system.run_axpydot(n, false)?;
        println!(
            "{:>10}  {:>11.3} ms  {:>11.3} ms  {:>7.2}x",
            n,
            df.makespan_s * 1e3,
            nodf.makespan_s * 1e3,
            nodf.makespan_s / df.makespan_s
        );
    }

    // numerics through the fused PJRT artifact (the dataflow analog at L1:
    // z never leaves the chip / the kernel).
    let rep = system.run_spec(&Spec::axpydot_dataflow(65536, 2.0))?;
    println!("\ndataflow design details:\n{}", rep.summary());
    Ok(())
}
