//! Quickstart: the 30-second AIEBLAS tour.
//!
//! Writes a JSON spec, validates it, runs it end-to-end (simulated VCK5000
//! timing + PJRT numerics) and prints the report — the workflow of the
//! paper's Fig. 1.
//!
//! Run: `cargo run --release --example quickstart`

use aieblas::coordinator::{AieBlas, Config};
use aieblas::spec::Spec;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    aieblas::init();

    // 1. the user-facing artifact: a JSON routine specification.
    let spec_json = r#"{
        "platform": "vck5000",
        "data_source": "pl",
        "routines": [
            {"routine": "axpy", "name": "my_axpy", "size": 65536,
             "window_size": 1024}
        ]
    }"#;
    let spec = Spec::from_json_str(spec_json)?;
    println!("spec OK: {} routine(s)\n", spec.routines.len());

    // 2. run it: build graph -> place -> route -> simulate + numerics.
    let system = AieBlas::new(Config::default())?;
    let report = system.run_spec(&spec)?;
    println!("{}\n", report.summary());

    // 3. inspect per-kernel activity.
    for k in &report.sim.kernels {
        println!(
            "kernel {} @ {}: {} window iterations, {:.1}% utilized",
            k.name, k.location, k.iterations, k.utilization * 100.0
        );
    }

    // 4. where did the time go? Memory-bound level-1 BLAS: the PL movers
    //    dominate — exactly the paper's §IV observation.
    println!(
        "\noff-chip traffic: {:.2} MB at {:.2} GB/s effective",
        report.sim.device_bytes as f64 / 1e6,
        report.sim.achieved_ddr_bw() / 1e9
    );
    Ok(())
}
