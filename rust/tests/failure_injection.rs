//! Failure-injection tests: every malformed input or broken environment
//! must produce a structured error (or a documented fallback), never a
//! panic or silent wrong answer.

use std::path::Path;

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{AieBlas, Config};
use aieblas::runtime::{Manifest, NumericExecutor};
use aieblas::spec::{DataSource, Spec};

#[test]
fn malformed_spec_documents_reject() {
    for (name, bad) in [
        ("not json", "hello"),
        ("not an object", "[1,2,3]"),
        ("missing routines", r#"{"platform": "vck5000"}"#),
        ("routine not object", r#"{"routines": [42]}"#),
        ("missing name", r#"{"routines": [{"routine": "axpy", "size": 8}]}"#),
        ("zero size", r#"{"routines": [{"routine": "axpy", "name": "a", "size": 0}]}"#),
        ("negative size", r#"{"routines": [{"routine": "axpy", "name": "a", "size": -4}]}"#),
        ("fractional size", r#"{"routines": [{"routine": "axpy", "name": "a", "size": 4.5}]}"#),
        (
            "bad placement",
            r#"{"routines": [{"routine": "axpy", "name": "a", "size": 8, "placement": {"col": 1}}]}"#,
        ),
        (
            "dangling connection",
            r#"{"routines": [{"routine": "axpy", "name": "a", "size": 8}],
                "connections": [{"from": "a.z", "to": "ghost.x"}]}"#,
        ),
    ] {
        let err = Spec::from_json_str(bad);
        assert!(err.is_err(), "{name} should be rejected");
    }
}

#[test]
fn corrupt_manifest_rejected() {
    let dir = std::env::temp_dir().join(format!("aieblas_badmanifest_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"interchange": "hlo-text"}"#).unwrap();
    assert!(Manifest::load(&dir).is_err(), "missing entries array");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn missing_artifact_file_falls_back_not_panics() {
    // manifest points at a file that does not exist → PJRT load fails →
    // reference fallback serves the request.
    let dir = std::env::temp_dir().join(format!("aieblas_ghostfile_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "interchange": "hlo-text", "entries": [
            {"key": "axpy_n8", "routine": "axpy", "size": 8,
             "file": "ghost.hlo.txt",
             "inputs": [{"shape": [1], "dtype": "float32"},
                         {"shape": [8], "dtype": "float32"},
                         {"shape": [8], "dtype": "float32"}],
             "num_outputs": 1}
        ]}"#,
    )
    .unwrap();
    let ex = NumericExecutor::new(&dir).unwrap();
    let (out, backend) = ex
        .execute("axpy", 8, &[vec![1.0], vec![1.0; 8], vec![2.0; 8]])
        .unwrap();
    assert_eq!(backend, aieblas::runtime::Provenance::Reference);
    assert_eq!(out, vec![3.0; 8]);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupt_hlo_text_falls_back() {
    let dir = std::env::temp_dir().join(format!("aieblas_badhlo_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "this is not an HLO module").unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "interchange": "hlo-text", "entries": [
            {"key": "dot_n4", "routine": "dot", "size": 4,
             "file": "bad.hlo.txt",
             "inputs": [{"shape": [4], "dtype": "float32"},
                         {"shape": [4], "dtype": "float32"}],
             "num_outputs": 1}
        ]}"#,
    )
    .unwrap();
    let ex = NumericExecutor::new(&dir).unwrap();
    let (out, backend) = ex
        .execute("dot", 4, &[vec![1.0, 2.0, 3.0, 4.0], vec![1.0; 4]])
        .unwrap();
    assert_eq!(backend, aieblas::runtime::Provenance::Reference);
    assert_eq!(out, vec![10.0]);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn wrong_input_length_is_error_not_garbage() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let ex = NumericExecutor::new(&dir).unwrap();
    // too-short x: validated up front, structured Runtime error.
    let r = ex.execute("axpy", 4096, &[vec![1.0], vec![0.0; 16], vec![0.0; 4096]]);
    assert!(matches!(r, Err(aieblas::Error::Runtime(_))), "{r:?}");
    // wrong arity too
    let r = ex.execute("axpy", 4096, &[vec![1.0]]);
    assert!(matches!(r, Err(aieblas::Error::Runtime(_))), "{r:?}");
}

#[test]
fn oversized_design_rejected_cleanly() {
    // 500 kernels > 400 tiles
    let mut spec = Spec { platform: "vck5000".into(), ..Default::default() };
    for i in 0..500 {
        spec.routines.push(aieblas::spec::RoutineSpec {
            kind: RoutineKind::Scal,
            name: format!("k{i}"),
            size: 64,
            window: None,
            vector_bits: 512,
            placement: None,
            burst: false,
            alpha: Some(1.0),
            beta: None,
            split: 1,
        });
    }
    let sys = AieBlas::new(Config {
        artifacts_dir: "/nonexistent".into(),
        check_numerics: false,
        ..Default::default()
    })
    .unwrap();
    let err = sys.run_spec_sim_only(&spec).unwrap_err();
    assert!(matches!(err, aieblas::Error::Placement(_)), "{err}");
}

#[test]
fn channel_exhaustion_rejected_cleanly() {
    // each axpy needs 4 channels; 80 unconnected axpys need 240 in + 80
    // out < limits, but 100 need 300+100 → AIE→PL fits, PL→AIE fits 300 ≤
    // 312... use 110: 330 > 312 → routing error.
    let mut spec = Spec { platform: "vck5000".into(), ..Default::default() };
    for i in 0..110 {
        spec.routines.push(aieblas::spec::RoutineSpec {
            kind: RoutineKind::Axpy,
            name: format!("k{i}"),
            size: 4096,
            window: None,
            vector_bits: 512,
            placement: None,
            burst: false,
            alpha: None,
            beta: None,
            split: 1,
        });
    }
    let sys = AieBlas::new(Config {
        artifacts_dir: "/nonexistent".into(),
        check_numerics: false,
        ..Default::default()
    })
    .unwrap();
    let err = sys.run_spec_sim_only(&spec).unwrap_err();
    assert!(matches!(err, aieblas::Error::Routing(_)), "{err}");
}

/// Hostile serving configs (ISSUE 7 satellite): zeroed-out knobs and
/// absurd linger/watermark values must be clamped into a working server,
/// not divide-by-zero, spin or stall forever.
#[test]
fn hostile_serve_configs_are_clamped_not_fatal() {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use aieblas::pipeline::Pipeline;
    use aieblas::runtime::{CpuBackend, ExecInputs};
    use aieblas::serve::{AdmissionPolicy, RoutineServer, ServeConfig};

    let spec = Spec::single(RoutineKind::Axpy, "a", 256, DataSource::Pl);
    let hostile = [
        // everything zero: batch/capacity/workers/pool clamps
        ServeConfig {
            max_batch: 0,
            linger: Duration::ZERO,
            queue_capacity: 0,
            workers: 0,
            max_inflight_per_tenant: 0,
            min_workers: 0,
            max_workers: 0,
            target_queue_wait: Duration::ZERO,
            ..Default::default()
        },
        // absurd linger (10 hours) and a watermark far beyond capacity:
        // the linger cap must keep dispatch prompt anyway.
        ServeConfig {
            max_batch: 4,
            linger: Duration::from_secs(36_000),
            queue_capacity: 8,
            workers: 1,
            policy: AdmissionPolicy::RejectAboveWatermark(usize::MAX),
            ..Default::default()
        },
        // watermark 0 (clamped to 1) with inverted pool bounds
        ServeConfig {
            queue_capacity: 4,
            workers: 2,
            policy: AdmissionPolicy::RejectAboveWatermark(0),
            min_workers: 7,
            max_workers: 1,
            ..Default::default()
        },
    ];
    for (i, cfg) in hostile.into_iter().enumerate() {
        let server = RoutineServer::new(
            Arc::new(Pipeline::default()),
            Arc::new(CpuBackend),
            cfg,
        );
        let t0 = Instant::now();
        let outcome = server
            .submit(&spec, ExecInputs::random_for(&spec, i as u64))
            .wait_timeout(Duration::from_secs(30));
        assert!(outcome.is_ok(), "hostile config {i} must still serve: {outcome:?}");
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "hostile config {i} must answer promptly (linger clamp)"
        );
        server.join();
    }
}

/// Malformed deadline/tenant options: an already-expired deadline is shed
/// (blocking submit gets a structured error, never a hang), and an empty
/// tenant string is untenanted — quota applies per real tenant only.
#[test]
fn malformed_deadline_and_tenant_requests_fail_structurally() {
    use std::sync::Arc;
    use std::time::Duration;

    use aieblas::pipeline::Pipeline;
    use aieblas::runtime::{CpuBackend, ExecInputs, SlowBackend};
    use aieblas::serve::{RequestOpts, RoutineServer, ServeConfig, ShedReason, SubmitOutcome};

    let spec = Spec::single(RoutineKind::Dot, "d", 256, DataSource::Pl);
    let server = RoutineServer::new(
        Arc::new(Pipeline::default()),
        // slow enough that quota-held requests stay in flight for the test
        Arc::new(SlowBackend::new(CpuBackend, Duration::from_millis(50))),
        ServeConfig { max_batch: 1, workers: 1, max_inflight_per_tenant: 1, ..Default::default() },
    );

    // expired deadline via try_submit: structured shed reason.
    let expired = RequestOpts::default().with_deadline_in(Duration::ZERO);
    let out = server.try_submit(&spec, ExecInputs::random_for(&spec, 0), expired);
    assert_eq!(out.shed_reason(), Some(ShedReason::DeadlineExpired));

    // expired deadline via blocking submit: structured error, not a hang.
    let expired = RequestOpts::default().with_deadline_in(Duration::ZERO);
    let err = server
        .submit_with(&spec, ExecInputs::random_for(&spec, 1), expired)
        .wait_timeout(Duration::from_secs(30));
    match err {
        Err(aieblas::Error::Runtime(msg)) => assert!(msg.contains("deadline"), "{msg}"),
        other => panic!("expected structured deadline rejection, got {other:?}"),
    }

    // real tenant: quota of 1 binds while its first request is in flight.
    let first = server
        .try_submit(&spec, ExecInputs::random_for(&spec, 2), RequestOpts::default().tenant("t0"));
    assert!(first.is_accepted());
    let second = server
        .try_submit(&spec, ExecInputs::random_for(&spec, 3), RequestOpts::default().tenant("t0"));
    assert_eq!(second.shed_reason(), Some(ShedReason::TenantQuota));

    // empty tenant string normalizes to untenanted: never quota-limited.
    for seed in 4..7 {
        let opts = RequestOpts::default().tenant("");
        match server.try_submit(&spec, ExecInputs::random_for(&spec, seed), opts) {
            SubmitOutcome::Accepted(_) => {}
            SubmitOutcome::Shed(reason) => panic!("empty tenant shed with {reason}"),
        }
    }

    let report = server.join();
    assert_eq!(report.metrics.shed_deadline, 2);
    assert_eq!(report.metrics.shed_tenant_quota, 1);
}

#[test]
fn onchip_design_with_many_kernels_still_runs() {
    // the no-PL configuration must not be limited by interface channels.
    let mut spec = Spec {
        platform: "vck5000".into(),
        data_source: DataSource::OnChip,
        ..Default::default()
    };
    for i in 0..110 {
        spec.routines.push(aieblas::spec::RoutineSpec {
            kind: RoutineKind::Axpy,
            name: format!("k{i}"),
            size: 4096,
            window: None,
            vector_bits: 512,
            placement: None,
            burst: false,
            alpha: None,
            beta: None,
            split: 1,
        });
    }
    let sys = AieBlas::new(Config {
        artifacts_dir: "/nonexistent".into(),
        check_numerics: false,
        ..Default::default()
    })
    .unwrap();
    let rep = sys.run_spec_sim_only(&spec).unwrap();
    assert_eq!(rep.pl_to_aie_channels, 0);
}

#[test]
fn fault_plan_clamps_hostile_values_and_rejects_garbage() {
    use aieblas::util::faults::{FaultPlan, FaultSite, MAX_STALL};

    // probabilities clamp to [0, 1]; stall clamps to [0, MAX_STALL].
    let plan = FaultPlan::parse(
        "seed=1,connect_refuse=7.5,http_503=-3,read_stall=1,read_stall_ms=999999",
    )
    .unwrap();
    assert_eq!(plan.rate(FaultSite::ConnectRefuse), 1.0);
    assert_eq!(plan.rate(FaultSite::Http503Burst), 0.0);
    assert!(plan.stall() <= MAX_STALL);

    // typos and garbage are errors, not silently inert chaos plans.
    for bad in [
        "seed=notanumber",
        "connect_refused=0.5", // typo'd site name
        "http_503=nan",
        "read_stall_ms=abc",
        "=0.5",
    ] {
        assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should be rejected");
    }
}

#[test]
fn http_config_clamps_hostile_probe_interval() {
    use std::time::Duration;

    use aieblas::http::HttpConfig;

    let fast = HttpConfig { probe_interval: Duration::ZERO, ..Default::default() }.normalized();
    assert!(fast.probe_interval >= Duration::from_millis(10), "zero would spin the probe loop");
    let slow = HttpConfig {
        probe_interval: Duration::from_secs(1 << 20),
        ..Default::default()
    }
    .normalized();
    assert!(slow.probe_interval <= Duration::from_secs(60), "a dead peer must be noticed");
}
