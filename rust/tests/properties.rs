//! Property-based tests over the system's invariants (DESIGN.md §6),
//! using the in-tree `util::proptest` framework: random specs must always
//! produce graphs, placements, routings and simulations that uphold the
//! conservation laws — or be rejected with a structured error, never
//! panic.

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::graph::build::build_graph;
use aieblas::graph::place::{place, Location};
use aieblas::graph::route::{check_routing, route};
use aieblas::graph::NodeKind;
use aieblas::sim::simulate;
use aieblas::spec::{DataSource, RoutineSpec, Spec};
use aieblas::util::proptest::{forall, usize_in, Config as PropConfig, Gen, Prop};
use aieblas::util::rng::Rng;

/// Generator: a random valid single/multi-routine spec.
fn spec_gen() -> Gen<Spec> {
    Gen::new(|rng: &mut Rng| {
        let kinds = [
            RoutineKind::Axpy,
            RoutineKind::Scal,
            RoutineKind::Copy,
            RoutineKind::Dot,
            RoutineKind::Nrm2,
            RoutineKind::Asum,
            RoutineKind::Gemv,
            RoutineKind::Axpydot,
        ];
        let n_routines = rng.range(1, 6);
        let source = if rng.bool() { DataSource::Pl } else { DataSource::OnChip };
        let mut spec = Spec {
            platform: "vck5000".into(),
            data_source: source,
            ..Default::default()
        };
        for i in 0..n_routines {
            let kind = *rng.choose(&kinds);
            let size = if kind.level() >= 2 {
                1 << rng.range(5, 9) // 32..512
            } else {
                1 << rng.range(6, 18)
            };
            spec.routines.push(RoutineSpec {
                kind,
                name: format!("k{i}"),
                size,
                window: rng.bool().then(|| 1 << rng.range(4, 9)),
                vector_bits: *rng.choose(&[128usize, 256, 512]),
                placement: None,
                burst: rng.bool(),
                alpha: rng.bool().then(|| rng.f32_in(-4.0, 4.0)),
                beta: None,
                split: 1,
            });
        }
        // maybe chain compatible vector outputs into vector inputs
        let candidates: Vec<usize> = (0..spec.routines.len().saturating_sub(1)).collect();
        for &i in &candidates {
            let (a, b) = (spec.routines[i].clone(), spec.routines[i + 1].clone());
            if a.kind.is_composite() || b.kind.is_composite() {
                continue;
            }
            let out_vec = a.kind.outputs().iter().find(|p| p.ty == aieblas::blas::PortType::Vector);
            let in_vec = b.kind.inputs().iter().find(|p| p.ty == aieblas::blas::PortType::Vector);
            if let (Some(o), Some(inp)) = (out_vec, in_vec) {
                if a.size == b.size && rng.bool() {
                    spec.connections.push(aieblas::spec::Connection {
                        from_kernel: a.name.clone(),
                        from_port: o.name.to_string(),
                        to_kernel: b.name.clone(),
                        to_port: inp.name.to_string(),
                    });
                }
            }
        }
        spec
    })
}

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, ..Default::default() }
}

#[test]
fn random_specs_validate_or_error_cleanly() {
    forall(&spec_gen(), cfg(150), |spec| match aieblas::spec::validate(spec) {
        Ok(()) | Err(aieblas::Error::Spec(_)) | Err(aieblas::Error::Placement(_)) => Prop::Pass,
        Err(e) => Prop::Fail(format!("unexpected error class: {e}")),
    });
}

#[test]
fn valid_specs_build_graphs_upholding_invariants() {
    forall(&spec_gen(), cfg(100), |spec| {
        if aieblas::spec::validate(spec).is_err() {
            return Prop::Discard;
        }
        match build_graph(spec) {
            Ok(out) => match out.graph.check_invariants() {
                Ok(()) => Prop::Pass,
                Err(e) => Prop::Fail(format!("invariants: {e}")),
            },
            Err(e) => Prop::Fail(format!("build: {e}")),
        }
    });
}

#[test]
fn placement_never_collides_and_stays_on_grid() {
    let arch = ArchConfig::vck5000();
    forall(&spec_gen(), cfg(80), |spec| {
        if aieblas::spec::validate(spec).is_err() {
            return Prop::Discard;
        }
        let g = build_graph(spec).unwrap().graph;
        let p = match place(&g, &arch) {
            Ok(p) => p,
            Err(e) => return Prop::Fail(format!("place: {e}")),
        };
        let mut tiles = std::collections::BTreeSet::new();
        for node in &g.nodes {
            match (&node.kind, p.of(node.id)) {
                (NodeKind::AieKernel { .. }, Location::Tile { col, row }) => {
                    if col >= arch.cols || row >= arch.rows {
                        return Prop::Fail(format!("{} off grid ({col},{row})", node.name));
                    }
                    if !tiles.insert((col, row)) {
                        return Prop::Fail(format!("tile ({col},{row}) reused"));
                    }
                }
                (NodeKind::AieKernel { .. }, other) => {
                    return Prop::Fail(format!("{} not on a tile: {other:?}", node.name))
                }
                _ => {}
            }
        }
        Prop::Pass
    });
}

#[test]
fn routing_conservation_holds() {
    let arch = ArchConfig::vck5000();
    forall(&spec_gen(), cfg(80), |spec| {
        if aieblas::spec::validate(spec).is_err() {
            return Prop::Discard;
        }
        let g = build_graph(spec).unwrap().graph;
        let p = place(&g, &arch).unwrap();
        match route(&g, &p, &arch) {
            Ok(r) => match check_routing(&g, &r) {
                Ok(()) => Prop::Pass,
                Err(e) => Prop::Fail(e.to_string()),
            },
            Err(aieblas::Error::Routing(_)) => Prop::Pass, // capacity exceeded is a clean reject
            Err(e) => Prop::Fail(format!("unexpected: {e}")),
        }
    });
}

#[test]
fn simulation_time_positive_and_bytes_conserved() {
    let arch = ArchConfig::vck5000();
    forall(&spec_gen(), cfg(60), |spec| {
        if aieblas::spec::validate(spec).is_err() {
            return Prop::Discard;
        }
        let g = build_graph(spec).unwrap().graph;
        let p = place(&g, &arch).unwrap();
        let Ok(r) = route(&g, &p, &arch) else { return Prop::Discard };
        let rep = match simulate(&g, &p, &r, &arch) {
            Ok(rep) => rep,
            Err(e) => return Prop::Fail(format!("sim: {e}")),
        };
        if rep.makespan_s <= 0.0 || !rep.makespan_s.is_finite() {
            return Prop::Fail(format!("nonpositive makespan {}", rep.makespan_s));
        }
        // bytes conservation: device bytes = Σ mover-edge totals
        let expected: u64 = g
            .edges
            .iter()
            .filter(|e| g.node(e.src).kind.is_pl() || g.node(e.dst).kind.is_pl())
            .map(|e| e.total_bytes() as u64)
            .sum();
        if rep.device_bytes != expected {
            return Prop::Fail(format!("device bytes {} != {expected}", rep.device_bytes));
        }
        // utilization bounded
        for k in &rep.kernels {
            if !(0.0..=1.0 + 1e-9).contains(&k.utilization) {
                return Prop::Fail(format!("{} utilization {}", k.name, k.utilization));
            }
        }
        Prop::Pass
    });
}

#[test]
fn sim_time_monotone_in_problem_size() {
    let sizes = usize_in(6, 18);
    forall(&sizes, cfg(25), |&exp| {
        let arch = ArchConfig::vck5000();
        let t = |n: usize| {
            let spec = Spec::single(RoutineKind::Axpy, "a", n, DataSource::Pl);
            let g = build_graph(&spec).unwrap().graph;
            let p = place(&g, &arch).unwrap();
            let r = route(&g, &p, &arch).unwrap();
            simulate(&g, &p, &r, &arch).unwrap().makespan_s
        };
        let n = 1usize << exp;
        Prop::from(t(2 * n) > t(n))
    });
}

#[test]
fn dataflow_never_slower_than_non_dataflow() {
    let sizes = usize_in(10, 20);
    let sys = aieblas::coordinator::AieBlas::new(aieblas::coordinator::Config {
        artifacts_dir: "/nonexistent".into(),
        check_numerics: false,
        cpu_samples: 1,
        ..Default::default()
    })
    .unwrap();
    forall(&sizes, cfg(15), |&exp| {
        let n = 1usize << exp;
        let df = sys.run_axpydot(n, true).unwrap().makespan_s;
        let nodf = sys.run_axpydot(n, false).unwrap().makespan_s;
        Prop::from(df <= nodf)
    });
}

#[test]
fn generated_specs_codegen_deterministically() {
    forall(&spec_gen(), cfg(25), |spec| {
        if aieblas::spec::validate(spec).is_err() {
            return Prop::Discard;
        }
        let a = aieblas::codegen::generate(spec).unwrap();
        let b = aieblas::codegen::generate(spec).unwrap();
        Prop::from(a.files == b.files)
    });
}

#[test]
fn spec_json_round_trips() {
    forall(&spec_gen(), cfg(80), |spec| {
        if aieblas::spec::validate(spec).is_err() {
            return Prop::Discard;
        }
        let text = spec.to_json().to_pretty();
        match Spec::from_json_str(&text) {
            Ok(reparsed) if reparsed == *spec => Prop::Pass,
            Ok(_) => Prop::Fail("round-trip changed the spec".into()),
            Err(e) => Prop::Fail(format!("reparse: {e}")),
        }
    });
}

#[test]
fn cpu_baseline_matches_reference_on_random_inputs() {
    let gen = usize_in(1, 1 << 17);
    forall(&gen, cfg(30), |&n| {
        let mut rng = Rng::new(n as u64);
        let x = rng.normal_vec_f32(n);
        let y = rng.normal_vec_f32(n);
        let mut z1 = vec![0.0; n];
        let mut z2 = vec![0.0; n];
        aieblas::blas::cpu::axpy(1.5, &x, &y, &mut z1);
        aieblas::blas::reference::axpy(1.5, &x, &y, &mut z2);
        for i in 0..n {
            if (z1[i] - z2[i]).abs() > 1e-5 * (1.0 + z2[i].abs()) {
                return Prop::Fail(format!("axpy mismatch at {i}"));
            }
        }
        let d1 = aieblas::blas::cpu::dot(&x, &y);
        let d2 = aieblas::blas::reference::dot(&x, &y);
        Prop::from((d1 - d2).abs() <= 5e-3 * (1.0 + d2.abs()))
    });
}
