//! Fleet fault-tolerance chaos suite (ISSUE 10, DESIGN.md §14): real TCP
//! fleets with shards killed mid-load, plus deterministic fault injection.
//!
//! Coverage pinned here:
//! * kill one shard of a 3-shard fleet under traffic — every request
//!   still resolves 200 (failover serves the dead shard's keys locally
//!   from the shared store), the receiver's breaker trips open, and
//!   restarting the shard closes the breaker and resumes proxying with
//!   a disk-warm cache;
//! * a seeded `FaultPlan` injecting 503 bursts is survived by a
//!   retrying client, and two identical runs inject *identically* (the
//!   reproducibility contract that makes chaos failures debuggable);
//! * an injected plan-store write failure degrades to memory-only
//!   serving (counted as `store_fallbacks`), never a request failure.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::http::client::{self, ClientConfig, RetryPolicy};
use aieblas::http::{HealthConfig, HttpConfig, HttpServer, ShardRouter};
use aieblas::pipeline::{Pipeline, PlanKey, PlanStore};
use aieblas::runtime::CpuBackend;
use aieblas::serve::{RoutineServer, ServeConfig};
use aieblas::spec::{DataSource, Spec};
use aieblas::util::faults::{FaultPlan, FaultSite};
use aieblas::util::json::{obj, Json};

fn store_dir(tag: &str) -> PathBuf {
    static NEXT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("aieblas-chaos-{tag}-{}-{n}", std::process::id()))
}

fn spec_of(size: usize) -> Spec {
    Spec::single(RoutineKind::Axpy, "a", size, DataSource::Pl)
}

fn run_body(spec: &Spec) -> Json {
    obj(vec![("spec", spec.to_json())])
}

fn cc() -> ClientConfig {
    ClientConfig {
        connect_timeout: Duration::from_secs(2),
        io_timeout: Duration::from_secs(30),
        ..Default::default()
    }
}

/// Poll `f` until it returns true or `deadline` elapses.
fn wait_for(what: &str, deadline: Duration, mut f: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !f() {
        assert!(t0.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Breaker state of `peer` as the receiver at `addr` reports it.
fn breaker_of(addr: &str, peer: usize) -> String {
    let (s, health) = client::get(addr, "/v1/healthz", &cc()).expect("healthz");
    assert_eq!(s, 200);
    health
        .path("shards.peers")
        .and_then(Json::as_arr)
        .and_then(|p| p.get(peer))
        .and_then(|p| p.get("breaker"))
        .and_then(Json::as_str)
        .expect("peer breaker field")
        .to_string()
}

/// One shard process: bind `peers[i]` with fast probe/breaker/retry
/// tuning so the whole trip→recover cycle fits a test run.
fn bind_shard(peers: &[String], i: usize, dir: &std::path::Path) -> HttpServer {
    let router = ShardRouter::new(peers.to_vec(), i)
        .unwrap()
        .with_health(HealthConfig {
            trip_threshold: 2,
            cooldown: Duration::from_millis(200),
        })
        .with_retry(RetryPolicy {
            max_attempts: 2,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(20),
            budget: Duration::from_millis(200),
        })
        .with_client(ClientConfig {
            connect_timeout: Duration::from_millis(500),
            io_timeout: Duration::from_secs(5),
            ..Default::default()
        });
    let pipeline = Pipeline::new(ArchConfig::vck5000()).with_disk_store(dir);
    let server = Arc::new(RoutineServer::new(
        Arc::new(pipeline),
        Arc::new(CpuBackend),
        ServeConfig::default(),
    ));
    let cfg = HttpConfig {
        read_timeout: Duration::from_millis(500),
        drain_timeout: Duration::from_secs(5),
        probe_interval: Duration::from_millis(50),
        ..Default::default()
    };
    HttpServer::bind(&peers[i], server, Some(router), cfg).expect("bind shard")
}

/// The §14 availability contract, end to end: kill one shard of three
/// under traffic, observe zero client-visible failures, breaker trip,
/// recovery on restart, and disk-warm serving by the restarted shard.
#[test]
fn killed_shard_fails_over_then_recovers_when_restarted() {
    let dir = store_dir("failover");
    // Reserve three ports up front so the full shard map is known before
    // any server starts (std binds with SO_REUSEADDR, so the reserved
    // ports rebind cleanly).
    let ports: Vec<u16> = {
        let listeners: Vec<std::net::TcpListener> = (0..3)
            .map(|_| std::net::TcpListener::bind("127.0.0.1:0").expect("reserve port"))
            .collect();
        listeners.iter().map(|l| l.local_addr().unwrap().port()).collect()
    };
    let peers: Vec<String> = ports.iter().map(|p| format!("127.0.0.1:{p}")).collect();
    let mut servers: Vec<Option<HttpServer>> =
        (0..3).map(|i| Some(bind_shard(&peers, i, &dir))).collect();

    // Find a spec owned by a non-zero shard; shard 0 is the receiver all
    // client traffic lands on.
    let router = ShardRouter::new(peers.clone(), 0).unwrap();
    let (victim, spec) = (1..64)
        .map(|i| spec_of(64 + 16 * i))
        .find_map(|s| {
            let shard = router.shard_of(&PlanKey::of(&s));
            (shard != 0).then_some((shard, s))
        })
        .expect("64 distinct specs cannot all hash to shard 0");

    // Warm path: the receiver proxies to the live owner.
    let (s, b) = client::post_json(&peers[0], "/v1/run", &run_body(&spec), &cc()).unwrap();
    assert_eq!(s, 200, "{}", b.to_compact());
    assert_eq!(breaker_of(&peers[0], victim), "closed");

    // Kill the owner (graceful shutdown still closes the listener; the
    // next dial refuses, which is what the breaker counts).
    servers[victim].take().unwrap().shutdown();

    // Every request for the dead shard's key must still resolve 200 —
    // first via the transport-failure fallback, then (breaker open) via
    // straight local failover with no dial at all.
    for round in 0..6 {
        let (s, b) = client::post_json(&peers[0], "/v1/run", &run_body(&spec), &cc()).unwrap();
        assert_eq!(s, 200, "round {round}: {}", b.to_compact());
    }
    // Probes every 50 ms push the breaker open even without traffic.
    wait_for("breaker to trip open", Duration::from_secs(10), || {
        breaker_of(&peers[0], victim) == "open"
    });

    let (s, stats) = client::get(&peers[0], "/v1/statsz", &cc()).unwrap();
    assert_eq!(s, 200);
    let failover = stats.path("metrics.failover_served").and_then(Json::as_u64).unwrap();
    assert!(failover >= 1, "failover_served = {failover}");
    assert!(stats.path("metrics.breaker_trips").and_then(Json::as_u64).unwrap() >= 1);

    // Restart the shard on its old port: probes must close the breaker.
    servers[victim] = Some(bind_shard(&peers, victim, &dir));
    wait_for("breaker to close after restart", Duration::from_secs(10), || {
        breaker_of(&peers[0], victim) == "closed"
    });
    let (_, stats) = client::get(&peers[0], "/v1/statsz", &cc()).unwrap();
    assert!(stats.path("metrics.breaker_closes").and_then(Json::as_u64).unwrap() >= 1);

    // Proxying resumes, and the restarted owner is disk-warm: the run
    // response's cache counters come from the executing process, which
    // must have lowered nothing.
    let (s, b) = client::post_json(&peers[0], "/v1/run", &run_body(&spec), &cc()).unwrap();
    assert_eq!(s, 200, "{}", b.to_compact());
    assert_eq!(b.path("cache.misses").and_then(Json::as_u64), Some(0), "restart served cold");
    assert!(b.path("cache.disk_hits").and_then(Json::as_u64).unwrap() >= 1);
    let (_, victim_stats) = client::get(&peers[victim], "/v1/statsz", &cc()).unwrap();
    assert!(
        victim_stats.get("requests").and_then(Json::as_f64).unwrap() >= 1.0,
        "restarted owner served the proxied request"
    );

    for srv in servers.into_iter().flatten() {
        srv.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Seeded server-side 503 bursts: a retrying client survives every one,
/// and two identical rounds inject identical fault counts — the
/// reproducibility contract a chaos seed exists for.
#[test]
fn injected_503_bursts_are_survived_and_reproducible() {
    let round = || -> u64 {
        let faults = FaultPlan::parse("seed=42,http_503=0.4").unwrap();
        let pipeline = Pipeline::new(ArchConfig::vck5000());
        let server = Arc::new(RoutineServer::new(
            Arc::new(pipeline),
            Arc::new(CpuBackend),
            ServeConfig::default(),
        ));
        let cfg = HttpConfig {
            read_timeout: Duration::from_millis(500),
            drain_timeout: Duration::from_secs(5),
            faults: Some(faults.clone()),
            ..Default::default()
        };
        let srv = HttpServer::bind("127.0.0.1:0", server, None, cfg).expect("bind");
        let addr = srv.local_addr().to_string();

        let policy = RetryPolicy {
            max_attempts: 16,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(5),
            budget: Duration::from_secs(10),
        };
        let body = run_body(&spec_of(128)).to_compact().into_bytes();
        for i in 0..20 {
            let resp = client::request_with_retry(
                &addr,
                "POST",
                "/v1/run",
                Some(&body),
                &[],
                &cc(),
                &policy,
                true,
            )
            .unwrap_or_else(|e| panic!("request {i} not survived: {e}"));
            assert_eq!(resp.status, 200, "request {i}");
        }
        // read the count before shutdown adds stray connections.
        let injected = faults.injected(FaultSite::Http503Burst);
        srv.shutdown();
        injected
    };

    let a = round();
    let b = round();
    assert!(a > 0, "p=0.4 over ≥20 connections injected nothing");
    assert_eq!(a, b, "same seed, same traffic ⇒ same injections");
}

/// An always-on 503 fault: the refusal is structured (ApiError body)
/// and carries the `retry-after` back-off hint §14 promises clients.
#[test]
fn injected_503_carries_retry_after_and_structured_body() {
    let pipeline = Pipeline::new(ArchConfig::vck5000());
    let server = Arc::new(RoutineServer::new(
        Arc::new(pipeline),
        Arc::new(CpuBackend),
        ServeConfig::default(),
    ));
    let cfg = HttpConfig {
        read_timeout: Duration::from_millis(500),
        drain_timeout: Duration::from_secs(5),
        faults: Some(FaultPlan::parse("http_503=1").unwrap()),
        ..Default::default()
    };
    let srv = HttpServer::bind("127.0.0.1:0", server, None, cfg).expect("bind");
    let addr = srv.local_addr().to_string();

    let resp = client::request(&addr, "GET", "/v1/healthz", None, &[], &cc()).unwrap();
    assert_eq!(resp.status, 503);
    assert_eq!(resp.header("retry-after"), Some("1"), "back-off hint missing");
    let json = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    assert_eq!(json.path("error.code").and_then(Json::as_str), Some("shed_draining"));
    assert_eq!(json.path("error.retryable").and_then(Json::as_bool), Some(true));

    srv.shutdown();
}

/// An injected plan-store write failure must degrade to memory-only
/// serving: the lowering succeeds, the fallback is counted, and nothing
/// reaches disk.
#[test]
fn store_write_fault_degrades_to_memory_only_serving() {
    let dir = store_dir("storefault");
    let store = PlanStore::open(&dir)
        .with_faults(FaultPlan::parse("seed=7,store_write_fail=1").unwrap());
    let pipeline = Pipeline::new(ArchConfig::vck5000()).with_store(store);
    let spec = spec_of(256);

    let plan = pipeline.lower(&spec).expect("lowering survives the injected write failure");
    let stats = pipeline.cache().stats();
    assert_eq!(stats.misses, 1);
    assert_eq!(stats.disk_writes, 0, "injected failure persisted nothing");
    assert!(stats.store_fallbacks >= 1, "fallback must be counted");

    // warm from memory as usual …
    let again = pipeline.lower(&spec).unwrap();
    assert!(Arc::ptr_eq(&plan, &again), "second lookup is a memory hit");
    assert!(pipeline.cache().stats().hits >= 1);

    // … but a fresh process finds an empty store and re-lowers.
    let fresh = Pipeline::new(ArchConfig::vck5000()).with_disk_store(&dir);
    fresh.lower(&spec).unwrap();
    let s = fresh.cache().stats();
    assert_eq!((s.misses, s.disk_hits), (1, 0), "nothing was persisted to warm from");

    std::fs::remove_dir_all(&dir).ok();
}
