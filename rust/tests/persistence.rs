//! Persistent plan-store tests (ISSUE 4 acceptance):
//!
//! * round-trip property: for randomized specs, `lower → serialize →
//!   deserialize → execute` is bit-identical to `lower → execute` on the
//!   Sim, Cpu and Reference backends, and a second `Pipeline` pointed at
//!   the same cache directory serves the spec with **zero lowerings**;
//! * corruption: a truncated entry, garbage JSON, a bumped (or pre-tuned
//!   v1) format version, a malformed `tuned` field and an arch-fingerprint
//!   mismatch each fall back to a clean re-lower (no panic, `rejected`
//!   incremented, entry rewritten);
//! * tuned entries (ISSUE 6): a tuning pipeline warm-starts from a
//!   persisted tuned plan (`tune_skipped`), and rejects entries tuned
//!   under another tuner version.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::pipeline::store::{plan_from_json, plan_to_json};
use aieblas::pipeline::{ExecutablePlan, Pipeline};
use aieblas::runtime::{
    Backend, CpuBackend, ExecInputs, NumericExecutor, ReferenceBackend, SimBackend,
};
use aieblas::spec::{DataSource, Spec};
use aieblas::tune::{TuneConfig, TuneMode};
use aieblas::util::json::Json;
use aieblas::util::proptest::{forall, one_of, pair, usize_in, Config, Gen, Prop};

/// Fresh per-test store directory (no tempdir crate in the offline
/// registry); removed on success, best-effort.
fn store_dir(tag: &str) -> PathBuf {
    static NEXT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("aieblas-persist-{tag}-{}-{n}", std::process::id()))
}

/// The single `*.plan.json` entry in a store directory.
fn entry_path(dir: &Path) -> PathBuf {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .expect("store dir exists")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.to_string_lossy().ends_with(".plan.json"))
        .collect();
    assert_eq!(entries.len(), 1, "expected exactly one store entry");
    entries.pop().unwrap()
}

fn vck_pipeline(dir: &Path) -> Pipeline {
    Pipeline::new(ArchConfig::vck5000()).with_disk_store(dir)
}

/// Execute `plan` on one backend, returning per-routine outputs and the
/// simulated makespan (when the backend models the device).
fn execute(
    backend: &dyn Backend,
    plan: Arc<ExecutablePlan>,
    inputs: &ExecInputs,
) -> (Vec<Vec<f32>>, Option<f64>) {
    let prepared = backend.prepare(plan).unwrap();
    let outcome = backend.execute(&prepared, inputs).unwrap();
    let outputs = outcome.results.iter().map(|r| r.output.clone()).collect();
    (outputs, outcome.sim.map(|s| s.makespan_s))
}

/// Generator over a diverse spec population: single routines across kinds,
/// sizes, sources and non-functional parameters, plus composed shapes
/// (axpydot dataflow, scal chains).
fn spec_gen() -> Gen<Spec> {
    let kinds = one_of(vec![
        RoutineKind::Axpy,
        RoutineKind::Scal,
        RoutineKind::Dot,
        RoutineKind::Copy,
        RoutineKind::Nrm2,
    ]);
    let shapes = pair(pair(kinds, usize_in(0, 5)), usize_in(0, 3));
    shapes.map(|((kind, variant), source_sel)| {
        let size = [256usize, 1000, 4096][variant % 3];
        match variant {
            // composed shapes exercise multi-kernel graphs + on-chip edges
            0 => Spec::axpydot_dataflow(4096, 2.0),
            1 => Spec::chain(RoutineKind::Scal, 3, 1024),
            _ => {
                let source = if source_sel % 2 == 0 { DataSource::Pl } else { DataSource::OnChip };
                let mut spec = Spec::single(kind, "k", size, source);
                if source_sel == 1 {
                    spec.routines[0].window = Some(128);
                }
                if source_sel == 3 {
                    spec.routines[0].burst = true;
                }
                if kind == RoutineKind::Axpy && variant % 2 == 0 {
                    spec.routines[0].alpha = Some(-1.5);
                }
                spec
            }
        }
    })
}

#[test]
fn round_trip_plans_execute_bit_identically_and_warm_start() {
    let executor = NumericExecutor::new(std::path::Path::new("/nonexistent_dir_xyz")).unwrap();
    let dir = store_dir("roundtrip");
    let gen = spec_gen();
    forall(&gen, Config { cases: 18, ..Default::default() }, |spec| {
        // lower once (writing through to the shared store directory) ...
        let warm_writer = vck_pipeline(&dir);
        let plan = warm_writer.lower(spec).unwrap();

        // ... and round-trip the plan through the JSON serializers.
        let back = Arc::new(match plan_from_json(&plan_to_json(&plan)) {
            Ok(p) => p,
            Err(e) => return Prop::Fail(format!("deserialize failed: {e}")),
        });
        if back.graph() != plan.graph()
            || back.placement().locations != plan.placement().locations
            || back.project().files != plan.project().files
        {
            return Prop::Fail("deserialized plan artifacts differ".into());
        }

        // execution must be bit-identical on every backend.
        let inputs = ExecInputs::random_for(spec, 0x5E11 ^ spec.cache_key().len() as u64);
        let sim = SimBackend::with_executor(&executor);
        let backends: [&dyn Backend; 3] = [&CpuBackend, &ReferenceBackend, &sim];
        for backend in backends {
            let (fresh, fresh_mk) = execute(backend, plan.clone(), &inputs);
            let (stored, stored_mk) = execute(backend, back.clone(), &inputs);
            if fresh != stored {
                return Prop::Fail(format!("{}: outputs differ after round trip", backend.name()));
            }
            if fresh_mk != stored_mk {
                return Prop::Fail(format!("{}: sim makespan differs", backend.name()));
            }
        }

        // a second pipeline on the same cache dir must serve the spec with
        // zero lowerings (one disk hit, nothing rejected).
        let warm_reader = vck_pipeline(&dir);
        let reread = warm_reader.lower(spec).unwrap();
        let s = warm_reader.cache().stats();
        if (s.misses, s.disk_hits, s.rejected) != (0, 1, 0) {
            return Prop::Fail(format!("expected pure disk warm start, got {s:?}"));
        }
        if reread.graph() != plan.graph() {
            return Prop::Fail("disk-warmed plan differs from fresh lowering".into());
        }
        Prop::Pass
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// Shared scaffold for the corruption cases: prewarm one entry, let the
/// caller mangle it, then check the next pipeline re-lowers cleanly
/// (rejected = 1), rewrites the entry, and a third pipeline warm-starts.
fn corruption_falls_back(tag: &str, mangle: impl FnOnce(&Path)) {
    let dir = store_dir(tag);
    let spec = Spec::axpydot_dataflow(4096, 2.0);
    vck_pipeline(&dir).lower(&spec).unwrap();
    mangle(&entry_path(&dir));

    let relower = vck_pipeline(&dir);
    let plan = relower.lower(&spec).unwrap();
    assert_eq!(plan.graph().num_aie_kernels(), 2, "re-lowered plan must be usable");
    let s = relower.cache().stats();
    assert_eq!(s.rejected, 1, "{tag}: bad entry must be rejected");
    assert_eq!(s.misses, 1, "{tag}: rejection must fall back to one clean lowering");
    assert_eq!(s.disk_writes, 1, "{tag}: the re-lowered plan must overwrite the bad entry");

    let warm = vck_pipeline(&dir);
    warm.lower(&spec).unwrap();
    let s = warm.cache().stats();
    assert_eq!(
        (s.misses, s.disk_hits, s.rejected),
        (0, 1, 0),
        "{tag}: overwritten entry must serve warm starts again"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_entry_falls_back_to_relower() {
    corruption_falls_back("truncated", |path| {
        let text = std::fs::read_to_string(path).unwrap();
        let mut cut = text.len() / 2;
        while !text.is_char_boundary(cut) {
            cut -= 1;
        }
        std::fs::write(path, &text[..cut]).unwrap();
    });
}

#[test]
fn garbage_json_falls_back_to_relower() {
    corruption_falls_back("garbage", |path| {
        std::fs::write(path, "this is { not json at all ]").unwrap();
    });
}

#[test]
fn format_version_bump_falls_back_to_relower() {
    // 999 models a future format; 1 is the real pre-tuned-entry era —
    // both must be rejected and re-lowered, never half-parsed.
    for version in [999.0, 1.0] {
        corruption_falls_back(&format!("version{version}"), |path| {
            let doc = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
            let mut map = doc.as_obj().unwrap().clone();
            map.insert("format_version".into(), Json::Num(version));
            std::fs::write(path, Json::Obj(map).to_pretty()).unwrap();
        });
    }
}

#[test]
fn malformed_tuned_field_falls_back_to_relower() {
    corruption_falls_back("tuned-corrupt", |path| {
        // `tuned` must be null or a provenance object; a bare number is
        // corruption, not "untuned".
        let doc = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        let mut map = doc.as_obj().unwrap().clone();
        map.insert("tuned".into(), Json::Num(7.0));
        std::fs::write(path, Json::Obj(map).to_pretty()).unwrap();
    });
}

fn tuned_pipeline(dir: &Path) -> Pipeline {
    Pipeline::new(ArchConfig::vck5000())
        .with_tuning(TuneConfig { mode: TuneMode::Full, max_candidates: 4, shortlist: 2 })
        .with_disk_store(dir)
}

#[test]
fn tuned_entries_warm_start_tuning_pipelines() {
    let dir = store_dir("tuned");
    // naive PL movers: the tuner installs the burst variant (`tuned` = 1).
    let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl);
    let writer = tuned_pipeline(&dir);
    let a = writer.lower(&spec).unwrap();
    let s = writer.cache().stats();
    assert_eq!((s.misses, s.disk_writes, s.tuned), (1, 1, 1));

    // a restarted tuning process trusts the persisted search: zero
    // lowerings, zero searches, one tuned warm start.
    let reader = tuned_pipeline(&dir);
    let b = reader.lower(&spec).unwrap();
    let s = reader.cache().stats();
    assert_eq!((s.misses, s.disk_hits, s.tune_skipped, s.rejected), (0, 1, 1, 0));
    assert_eq!(a.graph(), b.graph());
    assert_eq!(a.placement().locations, b.placement().locations);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_tuner_version_falls_back_to_retune() {
    let dir = store_dir("tunerver");
    let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl);
    tuned_pipeline(&dir).lower(&spec).unwrap();

    // model an entry tuned by a different tuner generation.
    let path = entry_path(&dir);
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let mut root = doc.as_obj().unwrap().clone();
    let mut tuned_obj = root["tuned"].as_obj().unwrap().clone();
    tuned_obj.insert("tuner_version".into(), Json::Num(999.0));
    root.insert("tuned".into(), Json::Obj(tuned_obj));
    std::fs::write(&path, Json::Obj(root).to_pretty()).unwrap();

    // a tuning pipeline must re-run the search rather than trust it...
    let retune = tuned_pipeline(&dir);
    retune.lower(&spec).unwrap();
    let s = retune.cache().stats();
    assert_eq!((s.rejected, s.misses, s.tune_skipped, s.disk_writes), (1, 1, 0, 1));

    // ...while a non-tuning pipeline takes any valid plan (the entry was
    // just rewritten under the current tuner version anyway).
    let off = vck_pipeline(&dir);
    off.lower(&spec).unwrap();
    let s = off.cache().stats();
    assert_eq!((s.misses, s.disk_hits, s.rejected), (0, 1, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn arch_fingerprint_mismatch_falls_back_to_relower() {
    let dir = store_dir("fingerprint");
    let spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
    vck_pipeline(&dir).lower(&spec).unwrap();

    // same spec, same directory, different default architecture: the
    // persisted vck5000 plan must NOT execute on a ryzen_ai pipeline.
    let other = Pipeline::new(ArchConfig::ryzen_ai()).with_disk_store(&dir);
    let plan = other.lower(&spec).unwrap();
    assert_eq!(plan.arch(), &ArchConfig::ryzen_ai());
    let s = other.cache().stats();
    assert_eq!((s.rejected, s.misses, s.disk_hits), (1, 1, 0));

    // the vck5000 entry was overwritten by the ryzen_ai write-through, so
    // the original pipeline now rejects in turn — still no panic, and the
    // store converges to whoever lowered last.
    let back = vck_pipeline(&dir);
    back.lower(&spec).unwrap();
    assert_eq!(back.cache().stats().rejected, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_named_platform_arch_falls_back_to_relower() {
    let dir = store_dir("platform");
    let mut spec = Spec::single(RoutineKind::Axpy, "a", 2048, DataSource::Pl);
    spec.platform = "ryzen_ai".into();
    vck_pipeline(&dir).lower(&spec).unwrap();

    // model a later build changing ryzen_ai's constants: the persisted
    // plan's embedded arch no longer equals what resolution produces
    // today (the fingerprint only covers the *default* arch, so this
    // must be caught by the per-spec arch equality check).
    let path = entry_path(&dir);
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let mut root = doc.as_obj().unwrap().clone();
    let mut plan_obj = root["plan"].as_obj().unwrap().clone();
    let mut arch_obj = plan_obj["arch"].as_obj().unwrap().clone();
    arch_obj.insert("rows".into(), Json::Num(3.0));
    plan_obj.insert("arch".into(), Json::Obj(arch_obj));
    root.insert("plan".into(), Json::Obj(plan_obj));
    std::fs::write(&path, Json::Obj(root).to_pretty()).unwrap();

    let relower = vck_pipeline(&dir);
    let plan = relower.lower(&spec).unwrap();
    assert_eq!(plan.arch(), &ArchConfig::ryzen_ai(), "must re-lower with current constants");
    let s = relower.cache().stats();
    assert_eq!((s.rejected, s.misses, s.disk_hits), (1, 1, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_survives_missing_directory() {
    // a cache dir that does not exist yet: first lower creates it.
    let dir = store_dir("fresh").join("nested/deeper");
    let spec = Spec::single(RoutineKind::Dot, "d", 1024, DataSource::Pl);
    let pipeline = vck_pipeline(&dir);
    pipeline.lower(&spec).unwrap();
    assert_eq!(pipeline.cache().stats().disk_writes, 1);
    assert_eq!(pipeline.store().unwrap().stats().entries, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_tmp_sweep_is_surfaced_in_cache_stats() {
    // A crash between tmp-write and rename leaves a `.<hash>.<n>.tmp`
    // orphan behind. Opening the store past the grace window sweeps it,
    // and the pipeline surfaces the count as `CacheStats.tmp_swept`.
    use aieblas::pipeline::store::PlanStore;
    use std::time::Duration;

    let dir = store_dir("tmpsweep");
    std::fs::create_dir_all(&dir).unwrap();
    let orphan = dir.join(".00000000deadbeef.1.tmp");
    std::fs::write(&orphan, b"{\"partial\":").unwrap();

    // Default grace (60 s): a freshly written tmp is an in-flight write
    // from a live peer, not a crash leftover — it must survive the open.
    let fresh = Pipeline::new(ArchConfig::vck5000()).with_disk_store(&dir);
    assert_eq!(fresh.cache().stats().tmp_swept, 0);
    assert!(orphan.exists(), "fresh tmp must survive default-grace open");

    // Zero grace: the orphan is stale by definition and gets swept.
    let swept = Pipeline::new(ArchConfig::vck5000())
        .with_store(PlanStore::open_with_grace(&dir, Duration::ZERO));
    assert_eq!(swept.cache().stats().tmp_swept, 1);
    assert!(!orphan.exists(), "stale tmp must be removed at open");

    // The sweep never touches real entries: lower, drop, re-open.
    let spec = Spec::single(RoutineKind::Axpy, "sweep", 512, DataSource::Pl);
    swept.lower(&spec).unwrap();
    drop(swept);
    let reopened = Pipeline::new(ArchConfig::vck5000())
        .with_store(PlanStore::open_with_grace(&dir, Duration::ZERO));
    reopened.lower(&spec).unwrap();
    let s = reopened.cache().stats();
    assert_eq!((s.tmp_swept, s.disk_hits, s.misses), (0, 1, 0));
    let _ = std::fs::remove_dir_all(&dir);
}
