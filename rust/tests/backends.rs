//! Backend-parity and plan-cache integration tests (ISSUE 1 acceptance):
//! every non-composite routine kind, at two sizes, must produce numerically
//! agreeing outputs through `CpuBackend`, `ReferenceBackend` and
//! `SimBackend` via the full trait interface; and a repeated `run_spec`
//! must be served from the plan cache (hit counter > 0, no re-lowering).

use std::sync::Arc;

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{AieBlas, Config};
use aieblas::pipeline::{lower_spec, Pipeline};
use aieblas::runtime::{
    Backend, CpuBackend, ExecInputs, NumericExecutor, ReferenceBackend, SimBackend,
};
use aieblas::spec::{DataSource, Spec};

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + b.abs())
}

fn sizes_for(kind: RoutineKind) -> [usize; 2] {
    if kind.level() >= 2 {
        [16, 64]
    } else {
        [256, 4096]
    }
}

#[test]
fn all_backends_agree_on_every_noncomposite_routine() {
    let executor = NumericExecutor::new(std::path::Path::new("/nonexistent_dir_xyz")).unwrap();
    for kind in RoutineKind::ALL.into_iter().filter(|k| !k.is_composite()) {
        for n in sizes_for(kind) {
            let spec = Spec::single(kind, "k", n, DataSource::Pl);
            let plan = Arc::new(lower_spec(&spec).unwrap());
            let inputs = ExecInputs::random_for(&spec, 0xBAC0 ^ n as u64);

            let sim = SimBackend::with_executor(&executor);
            let backends: [&dyn Backend; 3] = [&CpuBackend, &ReferenceBackend, &sim];
            let mut outputs = Vec::new();
            for backend in backends {
                let prepared = backend.prepare(plan.clone()).unwrap();
                let outcome = backend.execute(&prepared, &inputs).unwrap();
                assert_eq!(outcome.backend, backend.name());
                assert_eq!(outcome.results.len(), 1, "{kind} n={n} via {}", backend.name());
                outputs.push((backend.name(), outcome.results[0].output.clone()));
            }

            let (_, reference) = outputs[1].clone();
            for (name, out) in &outputs {
                assert_eq!(out.len(), reference.len(), "{kind} n={n} via {name}");
                if kind == RoutineKind::Iamax {
                    assert_eq!(out[0] as usize, reference[0] as usize, "{kind} n={n} via {name}");
                    continue;
                }
                for (i, (a, b)) in out.iter().zip(&reference).enumerate() {
                    assert!(
                        close(*a, *b, 5e-3),
                        "{kind} n={n} via {name} at {i}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn sim_backend_reports_timing_alongside_numerics() {
    let executor = NumericExecutor::new(std::path::Path::new("/nonexistent_dir_xyz")).unwrap();
    let spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
    let plan = Arc::new(lower_spec(&spec).unwrap());
    let backend = SimBackend::with_executor(&executor);
    let prepared = backend.prepare(plan).unwrap();
    let outcome = backend.execute(&prepared, &ExecInputs::random_for(&spec, 1)).unwrap();
    let sim = outcome.sim.expect("sim backend models device timing");
    assert!(sim.makespan_s > 0.0);
    assert_eq!(outcome.results.len(), 1);
    // cpu/reference model no device
    let cpu = CpuBackend
        .execute(
            &CpuBackend.prepare(Arc::new(lower_spec(&spec).unwrap())).unwrap(),
            &ExecInputs::random_for(&spec, 1),
        )
        .unwrap();
    assert!(cpu.sim.is_none());
}

#[test]
fn second_run_spec_hits_the_plan_cache() {
    let sys = AieBlas::new(Config {
        artifacts_dir: "/nonexistent".into(),
        cpu_samples: 1,
        check_numerics: true,
        ..Default::default()
    })
    .unwrap();
    let spec = Spec::axpydot_dataflow(16384, 2.0);

    let cold = sys.run_spec(&spec).unwrap();
    // cold run: exactly one lowering (run_spec + cpu_baseline share it)
    assert_eq!(cold.plan_cache.misses, 1, "cold run must lower exactly once");
    assert_eq!(cold.plan_cache.entries, 1);

    let warm = sys.run_spec(&spec).unwrap();
    assert!(warm.plan_cache.hits > 0, "warm run must hit the plan cache");
    assert_eq!(warm.plan_cache.misses, 1, "warm run must not re-lower");
    assert!(warm.summary().contains("plan cache"), "{}", warm.summary());

    // identical timing from the cached plan
    assert_eq!(cold.sim.makespan_s, warm.sim.makespan_s);
}

#[test]
fn pipeline_reuses_plans_across_backends() {
    let pipeline = Pipeline::default();
    let spec = Spec::single(RoutineKind::Gemv, "g", 64, DataSource::Pl);
    let plan_a = pipeline.lower(&spec).unwrap();
    let plan_b = pipeline.lower(&spec).unwrap();
    assert!(Arc::ptr_eq(&plan_a, &plan_b));

    // one lowered plan drives all three backends
    let inputs = ExecInputs::random_for(&spec, 3);
    let sim = SimBackend::timing_only();
    for backend in [&CpuBackend as &dyn Backend, &ReferenceBackend, &sim] {
        let prepared = backend.prepare(plan_a.clone()).unwrap();
        backend.execute(&prepared, &inputs).unwrap();
    }
    let stats = pipeline.cache().stats();
    assert_eq!((stats.hits, stats.misses), (1, 1));
}

#[test]
fn cached_plan_retains_generated_project() {
    // the RoutinePlan stage is codegen'd: a cache hit must hand back the
    // generated Vitis sources without re-running the generator.
    let pipeline = Pipeline::default();
    let spec = Spec::single(RoutineKind::Axpy, "vadd", 4096, DataSource::Pl);
    let plan = pipeline.lower(&spec).unwrap();
    assert!(plan.project().get("aie/kernels/vadd.cc").is_some());
    assert!(plan.project().get("CMakeLists.txt").is_some());
    let again = pipeline.lower(&spec).unwrap();
    assert!(Arc::ptr_eq(&plan, &again));
}
