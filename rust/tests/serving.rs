//! Concurrency acceptance tests (ISSUE 3): a shared `Pipeline` must be
//! usable from many threads with single-flight cold lowering (miss count ==
//! distinct specs, one shared `Arc` per key), and every batched execution
//! path — `Backend::execute_batch`, `ShardedBackend`, `RoutineServer` —
//! must produce outputs bit-identical to per-request sequential
//! `Backend::execute` on all three backends.

use std::collections::HashMap;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use aieblas::blas::RoutineKind;
use aieblas::pipeline::{ExecutablePlan, Pipeline};
use aieblas::runtime::{
    Backend, CpuBackend, ExecInputs, ReferenceBackend, ShardedBackend, SimBackend, SlowBackend,
};
use aieblas::serve::{AdmissionPolicy, RequestOpts, RoutineServer, ServeConfig, SubmitOutcome};
use aieblas::spec::{DataSource, Spec};

fn workload_specs() -> Vec<Spec> {
    vec![
        Spec::single(RoutineKind::Axpy, "a", 1024, DataSource::Pl),
        Spec::single(RoutineKind::Dot, "d", 2048, DataSource::Pl),
        Spec::single(RoutineKind::Scal, "s", 512, DataSource::OnChip),
        Spec::axpydot_dataflow(4096, 2.0),
    ]
}

/// N threads × M specs × R rounds through one shared pipeline: every
/// thread must observe the same `Arc` per spec, and the cache must record
/// exactly one miss per distinct spec (single-flight), everything else
/// hits.
#[test]
fn multithreaded_hammer_on_shared_pipeline() {
    let pipeline = Arc::new(Pipeline::default());
    let specs = workload_specs();
    let threads = 8;
    let rounds = 5;
    let barrier = Arc::new(Barrier::new(threads));

    let per_thread: Vec<Vec<Arc<ExecutablePlan>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let pipeline = pipeline.clone();
                let specs = specs.clone();
                let barrier = barrier.clone();
                s.spawn(move || {
                    barrier.wait();
                    let mut plans = Vec::new();
                    for round in 0..rounds {
                        // stagger the order so threads race on different keys
                        for i in 0..specs.len() {
                            let spec = &specs[(i + t + round) % specs.len()];
                            plans.push(pipeline.lower(spec).unwrap());
                        }
                    }
                    plans
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // plan identity: group every returned Arc by cache key — one allocation
    // per key across all threads and rounds.
    let mut by_key: HashMap<String, Arc<ExecutablePlan>> = HashMap::new();
    for plans in &per_thread {
        for plan in plans {
            let key = plan.spec().cache_key();
            match by_key.get(&key) {
                Some(first) => {
                    assert!(Arc::ptr_eq(first, plan), "same key must share one plan: {key}")
                }
                None => {
                    by_key.insert(key, plan.clone());
                }
            }
        }
    }
    assert_eq!(by_key.len(), specs.len());

    let stats = pipeline.cache().stats();
    assert_eq!(
        stats.misses,
        specs.len() as u64,
        "single-flight: each distinct spec lowers exactly once"
    );
    let total = (threads * rounds * specs.len()) as u64;
    assert_eq!(stats.hits + stats.misses, total, "every lookup is a hit or the one miss");
    assert_eq!(stats.entries, specs.len());
    assert_eq!(stats.evictions, 0);
}

fn assert_outcomes_bit_identical(
    label: &str,
    batched: &[aieblas::Result<aieblas::runtime::ExecOutcome>],
    sequential: &[aieblas::runtime::ExecOutcome],
) {
    assert_eq!(batched.len(), sequential.len(), "{label}");
    for (i, (b, s)) in batched.iter().zip(sequential).enumerate() {
        let b = b.as_ref().unwrap_or_else(|e| panic!("{label}[{i}] failed: {e}"));
        assert_eq!(b.backend, s.backend, "{label}[{i}]");
        assert_eq!(b.results.len(), s.results.len(), "{label}[{i}]");
        for (br, sr) in b.results.iter().zip(&s.results) {
            assert_eq!(br.routine, sr.routine, "{label}[{i}]");
            assert_eq!(br.provenance, sr.provenance, "{label}[{i}]");
            // bit-identical, not approximately equal
            let b_bits: Vec<u32> = br.output.iter().map(|v| v.to_bits()).collect();
            let s_bits: Vec<u32> = sr.output.iter().map(|v| v.to_bits()).collect();
            assert_eq!(b_bits, s_bits, "{label}[{i}] routine {}", br.routine);
        }
        match (&b.sim, &s.sim) {
            (Some(bs), Some(ss)) => {
                assert_eq!(bs.makespan_s.to_bits(), ss.makespan_s.to_bits(), "{label}[{i}]")
            }
            (None, None) => {}
            _ => panic!("{label}[{i}]: sim report presence differs"),
        }
    }
}

/// `execute_batch` (including each backend's amortizing override) must be
/// indistinguishable from per-request `execute` on all three backends.
#[test]
fn execute_batch_matches_sequential_on_all_backends() {
    let pipeline = Pipeline::default();
    let sim = SimBackend::timing_only();
    let backends: [&dyn Backend; 3] = [&CpuBackend, &ReferenceBackend, &sim];
    for spec in workload_specs() {
        let plan = pipeline.lower(&spec).unwrap();
        let batch: Vec<ExecInputs> =
            (0..6).map(|seed| ExecInputs::random_for(&spec, 0xBA7C4 ^ seed)).collect();
        for backend in backends {
            let prepared = backend.prepare(plan.clone()).unwrap();
            let sequential: Vec<_> = batch
                .iter()
                .map(|inputs| backend.execute(&prepared, inputs).unwrap())
                .collect();
            let batched = backend.execute_batch(&prepared, &batch);
            let label = format!("{}/{}", backend.name(), spec.cache_key());
            assert_outcomes_bit_identical(&label, &batched, &sequential);
        }
    }
}

/// The sharded adapter fans a batch across worker threads without changing
/// a single output bit, at several fan-out widths.
#[test]
fn sharded_backend_matches_sequential() {
    let pipeline = Pipeline::default();
    let spec = Spec::single(RoutineKind::Gemv, "g", 128, DataSource::Pl);
    let plan = pipeline.lower(&spec).unwrap();
    let batch: Vec<ExecInputs> =
        (0..9).map(|seed| ExecInputs::random_for(&spec, 0x5AAD ^ seed)).collect();

    let inner = CpuBackend;
    let prepared = inner.prepare(plan.clone()).unwrap();
    let sequential: Vec<_> =
        batch.iter().map(|inputs| inner.execute(&prepared, inputs).unwrap()).collect();

    for workers in [1, 2, 4, 16] {
        let sharded = ShardedBackend::new(CpuBackend, workers);
        assert_eq!(sharded.name(), "cpu", "adapter is name-transparent");
        let prepared = sharded.prepare(plan.clone()).unwrap();
        let batched = sharded.execute_batch(&prepared, &batch);
        assert_outcomes_bit_identical(&format!("sharded-{workers}"), &batched, &sequential);
    }
}

/// End-to-end serving: concurrent clients, batching server, sharded CPU
/// backend — every response must equal the direct sequential execution of
/// the same (spec, inputs), and the shared cache must show one miss per
/// distinct spec.
#[test]
fn routine_server_serves_concurrent_clients_correctly() {
    let specs = workload_specs();
    let clients = 4;
    let per_client = 12;
    let pipeline = Arc::new(Pipeline::default());
    let server = RoutineServer::new(
        pipeline.clone(),
        Arc::new(ShardedBackend::new(CpuBackend, 2)),
        ServeConfig {
            max_batch: 4,
            linger: Duration::from_millis(2),
            queue_capacity: 32,
            workers: 3,
            ..Default::default()
        },
    );

    std::thread::scope(|s| {
        for c in 0..clients {
            let server = &server;
            let specs = &specs;
            s.spawn(move || {
                for r in 0..per_client {
                    let spec = &specs[(c + r) % specs.len()];
                    let seed = (c * 1000 + r) as u64;
                    let inputs = ExecInputs::random_for(spec, seed);
                    let outcome = server.submit(spec, inputs.clone()).wait().unwrap();
                    // parity with a direct, unbatched, unsharded execution
                    let plan = aieblas::pipeline::lower_spec(spec).unwrap();
                    let direct = CpuBackend
                        .execute(&CpuBackend.prepare(Arc::new(plan)).unwrap(), &inputs)
                        .unwrap();
                    assert_eq!(outcome.results.len(), direct.results.len());
                    for (a, b) in outcome.results.iter().zip(&direct.results) {
                        let a_bits: Vec<u32> = a.output.iter().map(|v| v.to_bits()).collect();
                        let b_bits: Vec<u32> = b.output.iter().map(|v| v.to_bits()).collect();
                        assert_eq!(a_bits, b_bits, "served output must match direct execution");
                    }
                }
            });
        }
    });

    let report = server.join();
    assert_eq!(report.requests, (clients * per_client) as u64);
    assert_eq!(report.failed, 0);
    assert!(report.batches <= report.requests, "batches never exceed requests");
    assert!(report.mean_batch >= 1.0);
    assert_eq!(report.cache.misses, specs.len() as u64, "server lowers each spec once");
}

/// With one dispatcher and a generous linger, a burst of same-spec
/// requests must coalesce into fewer dispatches than requests.
#[test]
fn server_coalesces_same_key_bursts() {
    let spec = Spec::single(RoutineKind::Axpy, "a", 1024, DataSource::Pl);
    let pipeline = Arc::new(Pipeline::default());
    // warm the plan so dispatch latency doesn't eat the linger window
    pipeline.lower(&spec).unwrap();
    let server = RoutineServer::new(
        pipeline,
        Arc::new(CpuBackend),
        ServeConfig {
            max_batch: 8,
            linger: Duration::from_millis(50),
            queue_capacity: 64,
            workers: 1,
            ..Default::default()
        },
    );
    let tickets: Vec<_> =
        (0..8).map(|i| server.submit(&spec, ExecInputs::random_for(&spec, i))).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let report = server.join();
    assert_eq!(report.requests, 8);
    assert!(
        report.batches < 8,
        "8 same-key requests with a 50 ms linger must coalesce (got {} batches)",
        report.batches
    );
    assert!(report.max_batch >= 2);
}

/// Queue saturation under the reject-when-full policy: overload sheds
/// (with the reason counted), but every *accepted* request's output stays
/// bit-identical to a direct sequential execution — shedding changes who
/// gets served, never what the served requests compute.
#[test]
fn queue_saturation_sheds_with_reason_and_preserves_accepted_outputs() {
    let spec = Spec::single(RoutineKind::Axpy, "a", 1024, DataSource::Pl);
    let pipeline = Arc::new(Pipeline::default());
    pipeline.lower(&spec).unwrap();
    let server = RoutineServer::new(
        pipeline,
        // 5 ms per dispatch holds the single worker busy so rapid
        // submissions overwhelm the 4-deep queue deterministically.
        Arc::new(SlowBackend::new(CpuBackend, Duration::from_millis(5))),
        ServeConfig {
            max_batch: 1,
            linger: Duration::ZERO,
            queue_capacity: 4,
            workers: 1,
            policy: AdmissionPolicy::RejectWhenFull,
            ..Default::default()
        },
    );

    let total = 64u64;
    let mut accepted: Vec<(u64, aieblas::serve::Ticket)> = Vec::new();
    let mut shed = 0u64;
    for seed in 0..total {
        let inputs = ExecInputs::random_for(&spec, seed);
        match server.try_submit(&spec, inputs, RequestOpts::default()) {
            SubmitOutcome::Accepted(t) => accepted.push((seed, t)),
            SubmitOutcome::Shed(_) => shed += 1,
        }
    }
    assert!(shed > 0, "64 rapid submits into a 4-deep queue over a 5 ms backend must shed");
    assert!(!accepted.is_empty(), "the queue must still admit some requests");

    let plan = Arc::new(aieblas::pipeline::lower_spec(&spec).unwrap());
    let prepared = CpuBackend.prepare(plan).unwrap();
    for (seed, ticket) in accepted {
        let outcome = ticket.wait().unwrap();
        let direct = CpuBackend.execute(&prepared, &ExecInputs::random_for(&spec, seed)).unwrap();
        let a_bits: Vec<u32> = outcome.results[0].output.iter().map(|v| v.to_bits()).collect();
        let b_bits: Vec<u32> = direct.results[0].output.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a_bits, b_bits, "accepted request {seed} must match direct execution");
    }

    let report = server.join();
    assert_eq!(report.metrics.shed_queue_full, shed, "every shed is counted with its reason");
    assert_eq!(report.requests + report.metrics.shed_total(), total, "accounting balances");
    assert_eq!(report.failed, 0);
}

/// Regression (ISSUE 7 satellite): a submit racing drain/shutdown must
/// never enqueue a request that no worker will answer — every ticket
/// resolves, accepted ones successfully, refused ones with a structured
/// draining rejection.
#[test]
fn submit_racing_drain_never_hangs() {
    let spec = Spec::single(RoutineKind::Dot, "d", 512, DataSource::Pl);
    let pipeline = Arc::new(Pipeline::default());
    pipeline.lower(&spec).unwrap();
    let server = RoutineServer::new(
        pipeline,
        Arc::new(SlowBackend::new(CpuBackend, Duration::from_millis(1))),
        ServeConfig { max_batch: 2, workers: 2, ..Default::default() },
    );

    std::thread::scope(|s| {
        let server = &server;
        let spec = &spec;
        let submitter = s.spawn(move || {
            // hammer submits until the drain flips admissions off; a full
            // queue is back-pressure, not the signal to stop.
            let mut tickets = Vec::new();
            for seed in 0.. {
                let inputs = ExecInputs::random_for(spec, seed);
                match server.try_submit(spec, inputs, RequestOpts::default()) {
                    SubmitOutcome::Accepted(t) => tickets.push(t),
                    SubmitOutcome::Shed(aieblas::serve::ShedReason::Draining) => break,
                    SubmitOutcome::Shed(_) => std::thread::yield_now(),
                }
            }
            tickets
        });
        std::thread::sleep(Duration::from_millis(5));
        assert!(server.drain(Duration::from_secs(60)), "drain must settle accepted work");
        let tickets = submitter.join().unwrap();
        for t in tickets {
            // bounded wait: a hang here is exactly the regression under test.
            t.wait_timeout(Duration::from_secs(60)).unwrap();
        }
    });

    let report = server.join();
    assert!(report.metrics.shed_draining >= 1);
    assert_eq!(report.failed, 0, "accepted requests all execute; none are abandoned");
}
