//! Chaos suite (ISSUE 7): hammer `RoutineServer` with hostile
//! multi-tenant traffic — hot and cold specs, malformed specs, expired
//! deadlines, an over-quota tenant and a background flood, all over a
//! deliberately slow backend with an adaptive pool — and assert the
//! hardening invariants:
//!
//! * every submitted ticket resolves (no hangs),
//! * `attempts == answered + shed` exactly (nothing double-counted,
//!   nothing lost),
//! * no dispatcher dies (a sentinel request still succeeds afterwards),
//! * high-priority p99 latency beats background p99 under saturation.

use std::sync::Arc;
use std::time::Duration;

use aieblas::blas::RoutineKind;
use aieblas::pipeline::Pipeline;
use aieblas::runtime::{CpuBackend, ExecInputs, SlowBackend};
use aieblas::serve::{
    AdmissionPolicy, Priority, RequestOpts, RoutineServer, ServeConfig, SubmitOutcome, Ticket,
};
use aieblas::spec::{DataSource, Spec};

/// One traffic stream's tally: how many submissions it attempted and the
/// tickets for the accepted ones. Sheds are `attempts - tickets.len()`.
struct Stream {
    attempts: u64,
    tickets: Vec<Ticket>,
}

fn hot_specs() -> Vec<Spec> {
    vec![
        Spec::single(RoutineKind::Axpy, "hot_a", 1024, DataSource::Pl),
        Spec::single(RoutineKind::Dot, "hot_d", 2048, DataSource::Pl),
        Spec::single(RoutineKind::Scal, "hot_s", 512, DataSource::OnChip),
        Spec::axpydot_dataflow(2048, 2.0),
    ]
}

#[test]
fn chaos_mixed_hostile_load_preserves_invariants() {
    let server = RoutineServer::new(
        Arc::new(Pipeline::default()),
        // 2 ms per dispatch: long enough that queues build, deadlines
        // expire and quotas bind; short enough for a quick test.
        Arc::new(SlowBackend::new(CpuBackend, Duration::from_millis(2))),
        ServeConfig {
            max_batch: 4,
            linger: Duration::from_micros(200),
            queue_capacity: 512,
            workers: 2,
            policy: AdmissionPolicy::RejectAboveWatermark(480),
            max_inflight_per_tenant: 4,
            min_workers: 2,
            max_workers: 4,
            target_queue_wait: Duration::from_micros(500),
        },
    );

    // primer: several ms of normal-priority backlog, submitted before any
    // stream spawns. The normal lane dequeues ahead of background, so
    // every background request with a ~1 ms deadline submitted during the
    // chaos window is guaranteed to expire in the queue rather than
    // depending on thread-scheduling luck.
    let primer_spec = Spec::single(RoutineKind::Axpy, "primer", 1024, DataSource::OnChip);
    let primer: Vec<Ticket> = (0..16u64)
        .map(|i| server.submit(&primer_spec, ExecInputs::random_for(&primer_spec, i)))
        .collect();

    let streams: Vec<Stream> = std::thread::scope(|s| {
        let server = &server;
        let mut handles = Vec::new();

        // stream 1: hot traffic — four specs the cache keeps warm.
        handles.push(s.spawn(move || {
            let specs = hot_specs();
            let mut st = Stream { attempts: 0, tickets: Vec::new() };
            for i in 0..64u64 {
                let spec = &specs[(i as usize) % specs.len()];
                st.attempts += 1;
                let inputs = ExecInputs::random_for(spec, i);
                match server.try_submit(spec, inputs, RequestOpts::default()) {
                    SubmitOutcome::Accepted(t) => st.tickets.push(t),
                    SubmitOutcome::Shed(_) => {}
                }
            }
            st
        }));

        // stream 2: cold traffic — two dozen distinct specs, each a cache
        // miss that must not stall hot traffic's coalescing.
        handles.push(s.spawn(move || {
            let mut st = Stream { attempts: 0, tickets: Vec::new() };
            for i in 0..24u64 {
                let spec = Spec::single(
                    RoutineKind::Axpy,
                    &format!("cold_{i}"),
                    256 + 32 * (i as usize),
                    DataSource::Pl,
                );
                st.attempts += 1;
                let inputs = ExecInputs::random_for(&spec, i);
                match server.try_submit(&spec, inputs, RequestOpts::default()) {
                    SubmitOutcome::Accepted(t) => st.tickets.push(t),
                    SubmitOutcome::Shed(_) => {}
                }
            }
            st
        }));

        // stream 3: malformed specs — admitted, then failed per-request at
        // lowering; the dispatcher must survive every one.
        handles.push(s.spawn(move || {
            let bad = Spec { routines: vec![], ..Default::default() };
            let mut st = Stream { attempts: 0, tickets: Vec::new() };
            for _ in 0..16 {
                st.attempts += 1;
                match server.try_submit(&bad, ExecInputs::default(), RequestOpts::default()) {
                    SubmitOutcome::Accepted(t) => st.tickets.push(t),
                    SubmitOutcome::Shed(_) => {}
                }
            }
            st
        }));

        // stream 4: deadline abuse — half already expired at submit
        // (guaranteed shed), half with deadlines far shorter than the
        // backlog (dropped at dequeue as misses).
        handles.push(s.spawn(move || {
            let spec = Spec::single(RoutineKind::Dot, "deadline", 512, DataSource::Pl);
            let mut st = Stream { attempts: 0, tickets: Vec::new() };
            for i in 0..32u64 {
                let opts = if i % 2 == 0 {
                    RequestOpts::default().with_deadline_in(Duration::ZERO)
                } else {
                    // background priority: queues behind the flood, so a
                    // 1 ms deadline cannot survive the multi-ms backlog.
                    RequestOpts::default()
                        .with_priority(Priority::Background)
                        .with_deadline_in(Duration::from_millis(1))
                };
                st.attempts += 1;
                match server.try_submit(&spec, ExecInputs::random_for(&spec, i), opts) {
                    SubmitOutcome::Accepted(t) => st.tickets.push(t),
                    SubmitOutcome::Shed(_) => {}
                }
                std::thread::yield_now();
            }
            st
        }));

        // stream 5: greedy tenant — 32 requests against a 4-in-flight
        // quota; most must shed with TenantQuota, none may starve others.
        handles.push(s.spawn(move || {
            let spec = Spec::single(RoutineKind::Scal, "greedy", 1024, DataSource::Pl);
            let mut st = Stream { attempts: 0, tickets: Vec::new() };
            for i in 0..32u64 {
                let opts = RequestOpts::default().tenant("greedy");
                st.attempts += 1;
                match server.try_submit(&spec, ExecInputs::random_for(&spec, i), opts) {
                    SubmitOutcome::Accepted(t) => st.tickets.push(t),
                    SubmitOutcome::Shed(_) => {}
                }
            }
            st
        }));

        // stream 6: high-priority hot spec — must cut every queue.
        handles.push(s.spawn(move || {
            let spec = Spec::single(RoutineKind::Axpy, "vip", 1024, DataSource::Pl);
            let mut st = Stream { attempts: 0, tickets: Vec::new() };
            for i in 0..30u64 {
                let opts = RequestOpts::default().with_priority(Priority::High).tenant("vip");
                st.attempts += 1;
                match server.try_submit(&spec, ExecInputs::random_for(&spec, i), opts) {
                    SubmitOutcome::Accepted(t) => st.tickets.push(t),
                    SubmitOutcome::Shed(_) => {}
                }
                // pace the VIP stream so its requests sample the whole
                // chaos window rather than one early burst.
                std::thread::sleep(Duration::from_micros(300));
            }
            st
        }));

        // stream 7: background flood — a different spec than the VIP so
        // the two classes never share a coalesced batch.
        handles.push(s.spawn(move || {
            let spec = Spec::single(RoutineKind::Dot, "flood", 1024, DataSource::Pl);
            let mut st = Stream { attempts: 0, tickets: Vec::new() };
            for i in 0..64u64 {
                let opts = RequestOpts::default().with_priority(Priority::Background);
                st.attempts += 1;
                match server.try_submit(&spec, ExecInputs::random_for(&spec, i), opts) {
                    SubmitOutcome::Accepted(t) => st.tickets.push(t),
                    SubmitOutcome::Shed(_) => {}
                }
            }
            st
        }));

        handles.into_iter().map(|h| h.join().expect("stream thread panicked")).collect()
    });

    // every ticket resolves — success or structured error, never a hang.
    let mut attempts = 16u64; // the primer submissions
    for t in primer {
        t.wait_timeout(Duration::from_secs(60)).expect("primer request must succeed");
    }
    for st in streams {
        attempts += st.attempts;
        for t in st.tickets {
            match t.wait_timeout(Duration::from_secs(60)) {
                Err(aieblas::Error::Runtime(msg)) if msg.contains("timed out") => {
                    panic!("ticket unresolved after 60 s: {msg}")
                }
                _ => {}
            }
        }
    }

    // no dispatcher died: a sentinel request still round-trips.
    let sentinel = Spec::single(RoutineKind::Axpy, "sentinel", 512, DataSource::Pl);
    attempts += 1;
    server
        .submit(&sentinel, ExecInputs::random_for(&sentinel, 0))
        .wait_timeout(Duration::from_secs(60))
        .expect("sentinel request must succeed after the chaos");

    let report = server.join();
    let m = &report.metrics;

    // exact accounting: every attempt was either answered or shed.
    assert_eq!(
        report.requests + m.shed_total(),
        attempts,
        "attempts must equal answered + shed (report: {m:?})"
    );
    assert!(m.shed_tenant_quota > 0, "greedy tenant must hit its quota ({m:?})");
    assert!(m.shed_deadline > 0, "pre-expired deadlines must shed at submit ({m:?})");
    assert!(m.deadline_missed > 0, "short deadlines must be dropped at dequeue ({m:?})");
    assert!(m.pool_grown >= 1, "the adaptive pool must grow under this backlog ({m:?})");

    // priority isolation: both classes completed work, and the VIP class
    // saw strictly better tail latency than the flood.
    let p99 = |class: Priority| {
        let p = m.priorities.iter().find(|p| p.class == class).expect("class present");
        assert!(p.completed > 0, "{class} must complete requests ({m:?})");
        p.p99_s
    };
    let high = p99(Priority::High);
    let background = p99(Priority::Background);
    assert!(
        high < background,
        "high-priority p99 ({high:.6}s) must beat background p99 ({background:.6}s)"
    );
}
