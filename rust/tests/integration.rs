//! Integration tests: the full pipeline (spec JSON → graph → placement →
//! routing → simulation → codegen → numerics) across realistic scenarios.

use std::path::Path;

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{experiments, AieBlas, Config};
use aieblas::spec::{DataSource, Spec};

fn system() -> AieBlas {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    AieBlas::new(Config {
        artifacts_dir: dir,
        cpu_samples: 1,
        check_numerics: false,
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn spec_json_to_report_full_path() {
    let spec = Spec::from_json_str(
        r#"{
        "platform": "vck5000",
        "data_source": "pl",
        "routines": [
            {"routine": "axpy", "name": "vadd", "size": 65536, "alpha": -2.0},
            {"routine": "dot",  "name": "vdot", "size": 65536}
        ],
        "connections": [{"from": "vadd.z", "to": "vdot.x"}]
    }"#,
    )
    .unwrap();
    let report = system().run_spec(&spec).unwrap();
    assert!(report.sim.makespan_s > 0.0);
    assert_eq!(report.sim.kernels.len(), 2);
    // z flows on-chip: only w, v, u enter + beta leaves
    assert_eq!(report.sim.pl_to_aie_channels, 3);
    assert_eq!(report.sim.aie_to_pl_channels, 1);
}

#[test]
fn every_routine_kind_runs_end_to_end() {
    let sys = system();
    for kind in RoutineKind::ALL {
        let n = if kind.level() >= 2 { 128 } else { 16384 };
        for source in [DataSource::Pl, DataSource::OnChip] {
            let spec = Spec::single(kind, "k", n, source);
            let rep = sys.run_spec_sim_only(&spec).unwrap_or_else(|e| {
                panic!("{kind} with {source:?} failed: {e}");
            });
            assert!(rep.makespan_s > 0.0, "{kind} {source:?}");
        }
    }
}

#[test]
fn fig3_claim_c1_no_pl_faster_all_routines_all_sizes() {
    let sys = system();
    for kind in [RoutineKind::Axpy, RoutineKind::Gemv, RoutineKind::Dot] {
        let sizes: &[usize] = if kind.level() >= 2 { &[64, 256, 512] } else { &[4096, 65536, 1048576] };
        for &n in sizes {
            let pl = sys
                .run_spec_sim_only(&Spec::single(kind, "k", n, DataSource::Pl))
                .unwrap();
            let nopl = sys
                .run_spec_sim_only(&Spec::single(kind, "k", n, DataSource::OnChip))
                .unwrap();
            assert!(
                nopl.makespan_s < pl.makespan_s,
                "{kind} n={n}: no-PL {} !< PL {}",
                nopl.makespan_s,
                pl.makespan_s
            );
        }
    }
}

#[test]
fn fig3_claim_c2_dataflow_doubles_axpydot() {
    let sys = system();
    for &n in &[16384usize, 262144, 1048576] {
        let df = sys.run_axpydot(n, true).unwrap().makespan_s;
        let nodf = sys.run_axpydot(n, false).unwrap().makespan_s;
        let speedup = nodf / df;
        assert!(
            (1.7..2.6).contains(&speedup),
            "n={n}: DF speedup {speedup:.2} outside the paper's ~2x"
        );
    }
}

#[test]
fn fig3_claim_c3_cpu_advantage_grows_to_about_10x() {
    let sys = system();
    let mut last_ratio = 0.0;
    for &n in &experiments::VEC_SIZES {
        let pl = sys
            .run_spec_sim_only(&Spec::single(RoutineKind::Axpy, "k", n, DataSource::Pl))
            .unwrap()
            .makespan_s;
        let cpu = experiments::cpu_time_model(RoutineKind::Axpy, n);
        let ratio = pl / cpu;
        assert!(ratio > last_ratio * 0.8, "CPU advantage should broadly grow with n");
        last_ratio = ratio;
    }
    // at the largest size the paper reports "up to 10x"
    assert!(
        (5.0..20.0).contains(&last_ratio),
        "largest-size CPU advantage {last_ratio:.1}x should be near 10x"
    );
}

#[test]
fn generated_project_compiles_structurally() {
    // "compiles" without Vitis = structural checks on every generated file
    let spec = Spec::axpydot_dataflow(65536, 2.0);
    let proj = aieblas::codegen::generate(&spec).unwrap();
    for (path, contents) in &proj.files {
        assert!(!contents.is_empty(), "{path} empty");
        if path.ends_with(".cc") || path.ends_with(".cpp") || path.ends_with(".h") {
            // balanced braces — catches template bugs cheaply
            let open = contents.matches('{').count();
            let close = contents.matches('}').count();
            assert_eq!(open, close, "{path}: unbalanced braces");
        }
    }
    assert!(proj.total_lines() > 100);
}

#[test]
fn larger_designs_place_and_route() {
    // 64 kernels with mixed hints — exercises placement + channel budget
    let mut spec = Spec { platform: "vck5000".into(), ..Default::default() };
    for i in 0..64 {
        spec.routines.push(aieblas::spec::RoutineSpec {
            kind: if i % 3 == 0 { RoutineKind::Dot } else { RoutineKind::Axpy },
            name: format!("k{i}"),
            size: 4096,
            window: None,
            vector_bits: 512,
            placement: (i < 8).then_some(aieblas::spec::Placement { col: i, row: 0 }),
            burst: i % 2 == 0,
            alpha: None,
            beta: None,
            split: 1,
        });
    }
    let rep = system().run_spec_sim_only(&spec).unwrap();
    assert_eq!(rep.kernels.len(), 64);
    assert!(rep.pl_to_aie_channels <= 312);
    assert!(rep.aie_to_pl_channels <= 234);
}

#[test]
fn chain_of_connected_kernels_pipelines() {
    // scal -> copy -> dot chain: a 3-stage pipeline must beat the sum of
    // its isolated stages.
    let sys = system();
    let n = 1 << 18;
    let spec = Spec::from_json_str(&format!(
        r#"{{
        "routines": [
            {{"routine": "scal", "name": "s1", "size": {n}, "alpha": 2.0}},
            {{"routine": "copy", "name": "c1", "size": {n}}},
            {{"routine": "dot",  "name": "d1", "size": {n}}}
        ],
        "connections": [
            {{"from": "s1.z", "to": "c1.x"}},
            {{"from": "c1.z", "to": "d1.x"}}
        ]
    }}"#
    ))
    .unwrap();
    let chained = sys.run_spec_sim_only(&spec).unwrap().makespan_s;
    let isolated: f64 = [
        Spec::single(RoutineKind::Scal, "s1", n, DataSource::Pl),
        Spec::single(RoutineKind::Copy, "c1", n, DataSource::Pl),
        Spec::single(RoutineKind::Dot, "d1", n, DataSource::Pl),
    ]
    .iter()
    .map(|s| sys.run_spec_sim_only(s).unwrap().makespan_s)
    .sum();
    assert!(
        chained < isolated,
        "3-stage pipeline {chained} should beat sequential {isolated}"
    );
}

#[test]
fn numerics_via_artifacts_when_present() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let sys = AieBlas::new(Config {
        artifacts_dir: dir,
        cpu_samples: 1,
        check_numerics: true,
        ..Default::default()
    })
    .unwrap();
    if sys.executor().manifest().is_empty() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rep = sys
        .run_spec(&Spec::single(RoutineKind::Axpydot, "ad", 65536, DataSource::Pl))
        .unwrap();
    let (_, num) = &rep.numerics[0];
    assert!(num.max_rel_err < 1e-3, "axpydot err {}", num.max_rel_err);
}

#[test]
fn split_axpy_uses_more_channels_and_is_faster() {
    // §V future work 2: multi-AIE routines exploit the several AIE-PL
    // interfaces. 4-way split axpy: 12 in + 4 out channels, ~DDR-bound
    // speedup over the single-kernel design.
    let sys = system();
    let n = 1 << 20;
    let single = Spec::single(RoutineKind::Axpy, "k", n, DataSource::Pl);
    let mut split = single.clone();
    split.routines[0].split = 4;
    let r1 = sys.run_spec_sim_only(&single).unwrap();
    let r4 = sys.run_spec_sim_only(&split).unwrap();
    assert_eq!(r4.kernels.len(), 4);
    assert!(r4.pl_to_aie_channels > r1.pl_to_aie_channels);
    assert!(
        r4.makespan_s < r1.makespan_s / 1.5,
        "4-way split {} should beat single {} by >1.5x",
        r4.makespan_s,
        r1.makespan_s
    );
    // vector data is striped, not duplicated; only the broadcast alpha
    // scalar is replicated per part (3 extra f32 = 12 bytes).
    assert_eq!(r4.device_bytes, r1.device_bytes + 3 * 4);
}

#[test]
fn split_dot_combines_partials_on_chip() {
    let sys = system();
    let n = 1 << 18;
    let mut spec = Spec::single(RoutineKind::Dot, "d", n, DataSource::Pl);
    spec.routines[0].split = 8;
    let rep = sys.run_spec_sim_only(&spec).unwrap();
    assert_eq!(rep.kernels.len(), 8);
    // one scalar result leaves the array, not eight
    assert_eq!(rep.aie_to_pl_channels, 1);
}

#[test]
fn split_validation_rules() {
    // split on gemv (level 2) rejected
    let mut spec = Spec::single(RoutineKind::Gemv, "g", 256, DataSource::Pl);
    spec.routines[0].split = 2;
    assert!(aieblas::spec::validate(&spec).is_err());
    // split not dividing size rejected
    let mut spec = Spec::single(RoutineKind::Axpy, "a", 1000, DataSource::Pl);
    spec.routines[0].split = 3;
    assert!(aieblas::spec::validate(&spec).is_err());
    // split nrm2 (non-additive combine) rejected
    let mut spec = Spec::single(RoutineKind::Nrm2, "m", 1024, DataSource::Pl);
    spec.routines[0].split = 2;
    assert!(aieblas::spec::validate(&spec).is_err());
}

#[test]
fn new_routines_full_pipeline() {
    // axpby, rot, ger: §V BLAS-coverage expansion, end to end.
    let sys = system();
    for (kind, n) in [
        (RoutineKind::Axpby, 16384usize),
        (RoutineKind::Rot, 16384),
        (RoutineKind::Ger, 128),
    ] {
        let rep = sys
            .run_spec_sim_only(&Spec::single(kind, "k", n, DataSource::Pl))
            .unwrap();
        assert!(rep.makespan_s > 0.0, "{kind}");
        let num = sys.run_numeric(kind, if kind.level() >= 2 { 64 } else { 4096 }).unwrap();
        assert!(num.max_rel_err < 1e-3, "{kind} err {}", num.max_rel_err);
    }
}

#[test]
fn ryzen_ai_platform_runs_and_is_channel_constrained() {
    // paper §I ref [11]: the AIE family in commodity CPUs. Smaller array,
    // fewer interface channels — the same spec must still run, and a
    // design that fits the VCK5000's 312 channels must be rejected here.
    let sys = system();
    let mut spec = Spec::single(RoutineKind::Axpy, "a", 1 << 18, DataSource::Pl);
    spec.platform = "ryzen_ai".into();
    let rep = sys.run_spec_sim_only(&spec).unwrap();
    assert!(rep.makespan_s > 0.0);

    // 8 axpys = 24 in-channels > the NPU's 20 → routing reject
    let mut big = Spec { platform: "ryzen_ai".into(), ..Default::default() };
    for i in 0..8 {
        big.routines.push(aieblas::spec::RoutineSpec {
            kind: RoutineKind::Axpy,
            name: format!("k{i}"),
            size: 4096,
            window: None,
            vector_bits: 512,
            placement: None,
            burst: false,
            alpha: None,
            beta: None,
            split: 1,
        });
    }
    assert!(matches!(
        sys.run_spec_sim_only(&big).unwrap_err(),
        aieblas::Error::Routing(_)
    ));
}

#[test]
fn traced_simulation_matches_untraced_and_exports() {
    let sys = system();
    let spec = Spec::axpydot_dataflow(65536, 2.0);
    let plain = sys.run_spec_sim_only(&spec).unwrap();
    let (rep, trace) = sys.run_spec_traced(&spec).unwrap();
    assert!((rep.makespan_s - plain.makespan_s).abs() < 1e-12);
    assert!(!trace.is_empty());
    // every kernel iteration recorded
    let spans_for_axpy = trace
        .spans
        .iter()
        .filter(|s| trace.name_of(s.node) == "axpy_stage")
        .count();
    assert_eq!(spans_for_axpy, rep.kernels[0].iterations);
    // exports are well-formed
    assert!(aieblas::util::json::Json::parse(&trace.to_chrome_json()).is_ok());
    assert!(trace.to_gantt(60).contains('#'));
}
