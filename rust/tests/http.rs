//! End-to-end tests for the HTTP front door (ISSUE 9): real TCP sockets
//! against in-process `HttpServer`s.
//!
//! Coverage pinned here:
//! * `/v1/run` + `/v1/batch` round trips (values, cache counters, stats);
//! * every error path returns a structured `ApiError` with the v1 status
//!   mapping (malformed body 400, bad spec 400, bad route 404, wrong
//!   method 405, oversized body 413, expired deadline 504, draining 503);
//! * drain under load resolves every in-flight connection;
//! * two servers sharing one `--cache-dir`: a spec lowered by A is a
//!   disk-warm zero-lowering hit on B (the fleet warm-start guarantee);
//! * shard routing: a request landing on the wrong shard is proxied to
//!   the owner and executes there;
//! * keep-alive: two requests over one connection.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::http::client::{self, ClientConfig};
use aieblas::http::{HttpConfig, HttpServer, ShardRouter};
use aieblas::pipeline::{Pipeline, PlanKey};
use aieblas::runtime::{Backend, CpuBackend, SlowBackend};
use aieblas::serve::{RoutineServer, ServeConfig};
use aieblas::spec::{DataSource, Spec};
use aieblas::util::json::{obj, Json};

/// Fresh per-test store directory (no tempdir crate in the offline
/// registry); removed on success, best-effort.
fn store_dir(tag: &str) -> PathBuf {
    static NEXT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("aieblas-http-{tag}-{}-{n}", std::process::id()))
}

fn spec_of(size: usize) -> Spec {
    Spec::single(RoutineKind::Axpy, "a", size, DataSource::Pl)
}

fn run_body(spec: &Spec) -> Json {
    obj(vec![("spec", spec.to_json())])
}

/// Start an HTTP server over a fresh pipeline + CpuBackend.
fn start(
    cache_dir: Option<&std::path::Path>,
    router: Option<ShardRouter>,
    http_cfg: HttpConfig,
    backend: Arc<dyn Backend>,
    serve_cfg: ServeConfig,
) -> HttpServer {
    let mut pipeline = Pipeline::new(ArchConfig::vck5000());
    if let Some(dir) = cache_dir {
        pipeline = pipeline.with_disk_store(dir);
    }
    let server = Arc::new(RoutineServer::new(Arc::new(pipeline), backend, serve_cfg));
    HttpServer::bind("127.0.0.1:0", server, router, http_cfg).expect("bind loopback")
}

fn quick_http_cfg() -> HttpConfig {
    HttpConfig {
        read_timeout: Duration::from_millis(500),
        drain_timeout: Duration::from_secs(10),
        ..Default::default()
    }
}

fn default_start() -> HttpServer {
    start(None, None, quick_http_cfg(), Arc::new(CpuBackend), ServeConfig::default())
}

fn cc() -> ClientConfig {
    ClientConfig { io_timeout: Duration::from_secs(30), ..Default::default() }
}

fn addr(srv: &HttpServer) -> String {
    srv.local_addr().to_string()
}

/// Error body shape: `{"v":1,"error":{"code":<expected>,...}}`.
fn assert_api_error(status: u16, body: &Json, want_status: u16, want_code: &str) {
    assert_eq!(status, want_status, "body: {}", body.to_compact());
    let err = body.get("error").expect("error object");
    assert_eq!(err.get("code").and_then(Json::as_str), Some(want_code));
    assert!(err.get("message").and_then(Json::as_str).is_some());
    assert!(err.get("retryable").and_then(Json::as_bool).is_some());
    assert_eq!(body.get("v").and_then(Json::as_u64), Some(1));
}

#[test]
fn run_then_statsz_round_trip() {
    let srv = default_start();
    let a = addr(&srv);

    let (status, body) = client::post_json(&a, "/v1/run", &run_body(&spec_of(256)), &cc()).unwrap();
    assert_eq!(status, 200, "{}", body.to_compact());
    assert_eq!(body.get("v").and_then(Json::as_u64), Some(1));
    let outputs = body.get("outputs").and_then(Json::as_arr).expect("outputs");
    assert_eq!(outputs.len(), 1);
    assert_eq!(outputs[0].get("routine").and_then(Json::as_str), Some("a"));
    assert_eq!(outputs[0].get("len").and_then(Json::as_usize), Some(256));
    assert_eq!(
        outputs[0].get("values").and_then(Json::as_arr).map(|v| v.len()),
        Some(256),
        "include_values defaults on"
    );
    assert_eq!(body.path("cache.misses").and_then(Json::as_u64), Some(1), "cold lowering");

    // same spec again: warm hit, and checksum mode slims the payload.
    let mut body2 = run_body(&spec_of(256));
    if let Json::Obj(map) = &mut body2 {
        map.insert("include_values".into(), Json::Bool(false));
    }
    let (status, warm) = client::post_json(&a, "/v1/run", &body2, &cc()).unwrap();
    assert_eq!(status, 200);
    assert!(warm.path("outputs").and_then(Json::as_arr).unwrap()[0].get("values").is_none());
    assert!(warm.path("outputs").and_then(Json::as_arr).unwrap()[0].get("checksum").is_some());
    assert!(warm.path("cache.hits").and_then(Json::as_u64).unwrap() >= 1);

    let (status, stats) = client::get(&a, "/v1/statsz", &cc()).unwrap();
    assert_eq!(status, 200);
    assert_eq!(stats.get("v").and_then(Json::as_u64), Some(1));
    assert_eq!(stats.path("cache.misses").and_then(Json::as_u64), Some(1));
    assert!(stats.get("requests").and_then(Json::as_f64).unwrap() >= 2.0);
    assert!(stats.get("metrics").is_some(), "ServeMetrics embedded");

    let (status, health) = client::get(&a, "/v1/healthz", &cc()).unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(health.get("draining").and_then(Json::as_bool), Some(false));

    srv.shutdown();
}

#[test]
fn batch_round_trip_preserves_order() {
    let srv = default_start();
    let a = addr(&srv);

    let batch = obj(vec![(
        "requests",
        Json::Arr(vec![
            run_body(&spec_of(64)),
            Json::parse(r#"{"spec": {"routines": []}}"#).unwrap(), // invalid spec
            run_body(&spec_of(128)),
        ]),
    )]);
    let (status, body) = client::post_json(&a, "/v1/batch", &batch, &cc()).unwrap();
    assert_eq!(status, 200, "{}", body.to_compact());
    let results = body.get("results").and_then(Json::as_arr).expect("results");
    assert_eq!(results.len(), 3);
    assert_eq!(
        results[0].path("outputs").and_then(Json::as_arr).unwrap()[0]
            .get("len")
            .and_then(Json::as_usize),
        Some(64)
    );
    assert_eq!(
        results[1].path("error.code").and_then(Json::as_str),
        Some("bad_request"),
        "per-item failures are structured in place"
    );
    assert_eq!(
        results[2].path("outputs").and_then(Json::as_arr).unwrap()[0]
            .get("len")
            .and_then(Json::as_usize),
        Some(128)
    );

    // a bare array works too.
    let (status, body) =
        client::post_json(&a, "/v1/batch", &Json::Arr(vec![run_body(&spec_of(64))]), &cc())
            .unwrap();
    assert_eq!(status, 200);
    assert_eq!(body.get("results").and_then(Json::as_arr).map(|r| r.len()), Some(1));

    srv.shutdown();
}

#[test]
fn every_error_path_returns_structured_api_error() {
    let mut http_cfg = quick_http_cfg();
    http_cfg.max_body = 1024;
    let srv = start(None, None, http_cfg, Arc::new(CpuBackend), ServeConfig::default());
    let a = addr(&srv);

    // malformed JSON → 400.
    let resp = client::request(&a, "POST", "/v1/run", Some(b"{nope"), &[], &cc()).unwrap();
    let json = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    assert_api_error(resp.status, &json, 400, "bad_request");

    // valid JSON, invalid spec → 400.
    let bad_spec = Json::parse(r#"{"spec": {"routines": []}}"#).unwrap();
    let (s, b) = client::post_json(&a, "/v1/run", &bad_spec, &cc()).unwrap();
    assert_api_error(s, &b, 400, "bad_request");

    // unknown request field → 400.
    let (s, b) = client::post_json(
        &a,
        "/v1/run",
        &Json::parse(r#"{"spec": {"routines": []}, "bogus": true}"#).unwrap(),
        &cc(),
    )
    .unwrap();
    assert_api_error(s, &b, 400, "bad_request");

    // unknown route → 404; known route, wrong method → 405.
    let (s, b) = client::get(&a, "/v2/run", &cc()).unwrap();
    assert_api_error(s, &b, 404, "not_found");
    let (s, b) = client::get(&a, "/v1/run", &cc()).unwrap();
    assert_api_error(s, &b, 405, "method_not_allowed");

    // body over max_body → 413.
    let big = vec![b'x'; 4096];
    let resp = client::request(&a, "POST", "/v1/run", Some(&big), &[], &cc()).unwrap();
    let json = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    assert_api_error(resp.status, &json, 413, "payload_too_large");

    // deadline_ms 0 is already expired at admission → 504.
    let mut body = run_body(&spec_of(64));
    if let Json::Obj(map) = &mut body {
        map.insert("deadline_ms".into(), Json::Num(0.0));
    }
    let (s, b) = client::post_json(&a, "/v1/run", &body, &cc()).unwrap();
    assert_api_error(s, &b, 504, "deadline_expired");

    srv.shutdown();
}

#[test]
fn drain_rejects_new_work_and_reports_draining() {
    let srv = default_start();
    let a = addr(&srv);

    let (s, _) = client::post_json(&a, "/v1/run", &run_body(&spec_of(64)), &cc()).unwrap();
    assert_eq!(s, 200);

    let (s, b) = client::post_json(
        &a,
        "/v1/drain",
        &Json::parse(r#"{"timeout_ms": 5000}"#).unwrap(),
        &cc(),
    )
    .unwrap();
    assert_eq!(s, 200);
    assert_eq!(b.get("drained").and_then(Json::as_bool), Some(true));

    let (s, b) = client::get(&a, "/v1/healthz", &cc()).unwrap();
    assert_eq!(s, 200);
    assert_eq!(b.get("draining").and_then(Json::as_bool), Some(true));

    // post-drain submissions shed with the draining code → 503.
    let (s, b) = client::post_json(&a, "/v1/run", &run_body(&spec_of(64)), &cc()).unwrap();
    assert_api_error(s, &b, 503, "shed_draining");
}

/// Drain while slow requests are in flight: every connection must still
/// get a parseable JSON response (success or structured error) — none
/// may hang or be dropped mid-frame.
#[test]
fn drain_under_load_resolves_every_connection() {
    let backend = Arc::new(SlowBackend::new(CpuBackend, Duration::from_millis(30)));
    let serve_cfg = ServeConfig::builder().workers(1).max_batch(1).build();
    let srv = start(None, None, quick_http_cfg(), backend, serve_cfg);
    let a = addr(&srv);

    let results: Vec<(u16, Json)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let a = a.clone();
                // distinct sizes so nothing coalesces: 6 serial 30 ms runs.
                s.spawn(move || {
                    client::post_json(&a, "/v1/run", &run_body(&spec_of(64 << i)), &cc()).unwrap()
                })
            })
            .collect();
        // let the queue build, then drain mid-flight.
        std::thread::sleep(Duration::from_millis(40));
        let (s_drain, b) = client::post_json(&a, "/v1/drain", &Json::Null, &cc()).unwrap();
        assert_eq!(s_drain, 200, "{}", b.to_compact());
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    for (status, body) in &results {
        let ok = *status == 200 && body.get("outputs").is_some();
        let structured_err = body.path("error.code").and_then(Json::as_str).is_some();
        assert!(
            ok || structured_err,
            "connection resolved to neither success nor ApiError: {status} {}",
            body.to_compact()
        );
    }
    // drain answered everything; at least the in-flight request ran.
    assert!(results.iter().any(|(s, _)| *s == 200), "nothing completed");
}

/// The fleet warm-start guarantee: server B, sharing A's store, serves
/// A's spec with zero lowerings and a disk hit.
#[test]
fn second_server_on_shared_store_is_disk_warm() {
    let dir = store_dir("warm");
    let spec = spec_of(512);

    let a_srv = start(
        Some(&dir),
        None,
        quick_http_cfg(),
        Arc::new(CpuBackend),
        ServeConfig::default(),
    );
    let (s, b) = client::post_json(&addr(&a_srv), "/v1/run", &run_body(&spec), &cc()).unwrap();
    assert_eq!(s, 200);
    assert_eq!(b.path("cache.misses").and_then(Json::as_u64), Some(1));
    assert!(b.path("cache.disk_writes").and_then(Json::as_u64).unwrap() >= 1, "wrote through");
    a_srv.shutdown();

    let b_srv = start(
        Some(&dir),
        None,
        quick_http_cfg(),
        Arc::new(CpuBackend),
        ServeConfig::default(),
    );
    let (s, b) = client::post_json(&addr(&b_srv), "/v1/run", &run_body(&spec), &cc()).unwrap();
    assert_eq!(s, 200);
    assert_eq!(b.path("cache.misses").and_then(Json::as_u64), Some(0), "zero lowerings on B");
    assert!(b.path("cache.disk_hits").and_then(Json::as_u64).unwrap() >= 1, "served from disk");
    b_srv.shutdown();

    std::fs::remove_dir_all(&dir).ok();
}

/// Two shards, each claiming half the key space: a spec owned by the
/// *other* shard is proxied there and executes on the owner (visible in
/// the owner's statsz request count).
#[test]
fn shard_router_proxies_to_the_owner() {
    let dir = store_dir("shard");
    // Reserve two distinct loopback ports up front (bind both before
    // dropping either) so the full shard map is known before any server
    // starts; the tiny release-then-rebind window is benign in-process.
    let ports: Vec<u16> = {
        let listeners: Vec<std::net::TcpListener> = (0..2)
            .map(|_| std::net::TcpListener::bind("127.0.0.1:0").expect("reserve port"))
            .collect();
        listeners.iter().map(|l| l.local_addr().unwrap().port()).collect()
    };
    let peers: Vec<String> = ports.iter().map(|p| format!("127.0.0.1:{p}")).collect();

    let bind_shard = |i: usize| {
        let router = ShardRouter::new(peers.clone(), i).unwrap();
        let pipeline = Pipeline::new(ArchConfig::vck5000()).with_disk_store(&dir);
        let server = Arc::new(RoutineServer::new(
            Arc::new(pipeline),
            Arc::new(CpuBackend),
            ServeConfig::default(),
        ));
        HttpServer::bind(&peers[i], server, Some(router), quick_http_cfg()).expect("bind shard")
    };
    let srv_a = bind_shard(0);
    let srv_b = bind_shard(1);

    // find one spec per shard (the routing rule is public and stable).
    let router = ShardRouter::new(peers.clone(), 0).unwrap();
    let mut owned = [None, None];
    for i in 0..32 {
        let spec = spec_of(64 + 16 * i);
        let shard = router.shard_of(&PlanKey::of(&spec));
        if owned[shard].is_none() {
            owned[shard] = Some(spec);
        }
    }
    let (spec_for_a, spec_for_b) =
        (owned[0].take().expect("shard-0 spec"), owned[1].take().expect("shard-1 spec"));

    // both POSTed to A: A's own spec runs locally, B's is proxied.
    for spec in [&spec_for_a, &spec_for_b] {
        let (s, b) = client::post_json(&peers[0], "/v1/run", &run_body(spec), &cc()).unwrap();
        assert_eq!(s, 200, "{}", b.to_compact());
    }
    let (_, stats_a) = client::get(&peers[0], "/v1/statsz", &cc()).unwrap();
    let (_, stats_b) = client::get(&peers[1], "/v1/statsz", &cc()).unwrap();
    assert_eq!(stats_a.get("requests").and_then(Json::as_f64), Some(1.0), "A ran its own spec");
    assert_eq!(stats_b.get("requests").and_then(Json::as_f64), Some(1.0), "B ran the proxied one");

    // healthz exposes the shard map.
    let (_, health) = client::get(&peers[1], "/v1/healthz", &cc()).unwrap();
    assert_eq!(health.path("shards.self_index").and_then(Json::as_usize), Some(1));
    assert_eq!(
        health.path("shards.peers").and_then(Json::as_arr).map(|p| p.len()),
        Some(2)
    );

    srv_a.shutdown();
    srv_b.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Two requests over one kept-alive connection, framed by hand.
#[test]
fn keep_alive_serves_sequential_requests_on_one_connection() {
    use std::io::{BufReader, Write};

    let srv = default_start();
    let stream = std::net::TcpStream::connect(srv.local_addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    let body = run_body(&spec_of(64)).to_compact();
    let frame = format!(
        "POST /v1/run HTTP/1.1\r\nhost: t\r\ncontent-type: application/json\r\ncontent-length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    for round in 0..2 {
        writer.write_all(frame.as_bytes()).unwrap();
        writer.flush().unwrap();
        let resp = aieblas::http::framing::read_response(&mut reader, 1 << 20).unwrap();
        assert_eq!(resp.status, 200, "round {round}");
        assert_eq!(resp.header("connection"), Some("keep-alive"), "round {round}");
    }
    drop(writer);
    drop(reader);
    srv.shutdown();
}
