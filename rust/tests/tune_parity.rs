//! Tuned-vs-untuned parity (ISSUE 6 acceptance): the autotuner may change
//! placement, routing and mover strategy, but never numerics — for every
//! spec the tuned plan's outputs are **bit-identical** to the untuned
//! lowering on the Cpu, Reference and Sim backends, and the tuned plan's
//! simulated makespan never exceeds the untuned one.

use std::sync::Arc;

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::pipeline::{lower_spec, ExecutablePlan};
use aieblas::runtime::{Backend, CpuBackend, ExecInputs, ReferenceBackend, SimBackend};
use aieblas::sim::simulate_plan;
use aieblas::spec::{DataSource, Spec};
use aieblas::tune::{tune_spec, TuneConfig, TuneMode};
use aieblas::util::proptest::{forall, one_of, pair, usize_in, Config, Gen, Prop};

/// Spec set spanning the tuner's interesting shapes: naive PL movers (the
/// burst-variant win), on-chip generation, multirate (outside the analytic
/// model), and a composed multi-kernel graph.
fn parity_specs() -> Vec<Spec> {
    vec![
        Spec::single(RoutineKind::Axpy, "a", 1 << 15, DataSource::Pl),
        Spec::single(RoutineKind::Dot, "d", 1 << 14, DataSource::OnChip),
        Spec::single(RoutineKind::Gemv, "g", 512, DataSource::Pl),
        Spec::axpydot_dataflow(1 << 14, 2.0),
    ]
}

fn outputs(backend: &dyn Backend, plan: Arc<ExecutablePlan>, inputs: &ExecInputs) -> Vec<Vec<f32>> {
    let prepared = backend.prepare(plan).unwrap();
    let outcome = backend.execute(&prepared, inputs).unwrap();
    outcome.results.into_iter().map(|r| r.output).collect()
}

/// Bit-exact output comparison of an untuned and a tuned lowering of
/// `spec` across all three backends; returns an error description.
fn check_parity(spec: &Spec, cfg: &TuneConfig) -> Result<(), String> {
    let untuned = Arc::new(lower_spec(spec).map_err(|e| e.to_string())?);
    let tuned =
        Arc::new(tune_spec(spec, &ArchConfig::vck5000(), cfg).map_err(|e| e.to_string())?.plan);
    let inputs = ExecInputs::random_for(spec, 0xBEEF ^ spec.cache_key().len() as u64);
    let sim = SimBackend::timing_only();
    let backends: [&dyn Backend; 3] = [&CpuBackend, &ReferenceBackend, &sim];
    for backend in backends {
        let a = outputs(backend, untuned.clone(), &inputs);
        let b = outputs(backend, tuned.clone(), &inputs);
        if a != b {
            return Err(format!("{}: tuned outputs differ from untuned", backend.name()));
        }
    }
    Ok(())
}

#[test]
fn full_tuned_plans_execute_bit_identically_to_untuned() {
    let cfg = TuneConfig { mode: TuneMode::Full, max_candidates: 6, shortlist: 2 };
    for spec in &parity_specs() {
        check_parity(spec, &cfg).unwrap();
    }
}

#[test]
fn full_tuning_never_increases_simulated_makespan() {
    let cfg = TuneConfig { mode: TuneMode::Full, max_candidates: 6, shortlist: 2 };
    for spec in &parity_specs() {
        let untuned = simulate_plan(&lower_spec(spec).unwrap()).unwrap().makespan_s;
        let plan = tune_spec(spec, &ArchConfig::vck5000(), &cfg).unwrap().plan;
        let tuned = simulate_plan(&plan).unwrap().makespan_s;
        assert!(tuned <= untuned, "tuned {tuned} > untuned {untuned} for {:?}", spec.cache_key());
    }
}

#[test]
fn analytic_tuned_plans_keep_parity_on_randomized_specs() {
    // analytic mode runs no DES, so a wider randomized sweep stays cheap.
    let cfg = TuneConfig { mode: TuneMode::Analytic, max_candidates: 6, shortlist: 2 };
    let kinds = one_of(vec![
        RoutineKind::Axpy,
        RoutineKind::Scal,
        RoutineKind::Dot,
        RoutineKind::Copy,
        RoutineKind::Nrm2,
    ]);
    let gen: Gen<Spec> = pair(pair(kinds, usize_in(0, 3)), usize_in(0, 1)).map(
        |((kind, size_sel), source_sel)| {
            let size = [1usize << 12, 1000, 1 << 14, 4096][size_sel % 4];
            let source = if source_sel == 0 { DataSource::Pl } else { DataSource::OnChip };
            let mut spec = Spec::single(kind, "k", size, source);
            if size_sel == 2 {
                spec.routines[0].window = Some(128);
            }
            spec
        },
    );
    forall(&gen, Config { cases: 12, ..Default::default() }, |spec| {
        match check_parity(spec, &cfg) {
            Ok(()) => Prop::Pass,
            Err(why) => Prop::Fail(why),
        }
    });
}
