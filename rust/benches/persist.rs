//! Persistent-plan-store bench: cold lowering vs disk-warm (deserialize a
//! persisted plan) vs memory-warm (plan-cache hit) latency — the three
//! start states a serving process can find itself in (DESIGN.md §10).
//!
//! Emits `BENCH_persist.json` (working directory, or under
//! `AIEBLAS_BENCH_JSON_DIR`) to extend the tracked perf series.
//!
//! Smoke mode (CI): `AIEBLAS_BENCH_SMOKE=1` shrinks sizes so the run is a
//! pass/fail completion check, no timing assertions.
//!
//! Run: `cargo bench --bench persist`

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::pipeline::Pipeline;
use aieblas::spec::{DataSource, Spec};
use aieblas::util::bench::Bench;
use aieblas::util::json::{obj, Json};

fn main() {
    aieblas::init();
    let smoke = std::env::var("AIEBLAS_BENCH_SMOKE").is_ok();
    let mut b = Bench::new("persist");
    let mut json_rows: Vec<Json> = Vec::new();

    let vec_n = if smoke { 1 << 12 } else { 1 << 20 };
    let mat_n = if smoke { 64 } else { 256 };
    let cases = [
        ("axpy".to_string(), Spec::single(RoutineKind::Axpy, "a", vec_n, DataSource::Pl)),
        ("gemv".to_string(), Spec::single(RoutineKind::Gemv, "g", mat_n, DataSource::Pl)),
        ("axpydot_df".to_string(), Spec::axpydot_dataflow(vec_n, 2.0)),
    ];

    // fresh store directory per process so disk-warm numbers never mix
    // runs; removed at the end.
    let dir = std::env::temp_dir().join(format!("aieblas-bench-persist-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    for (label, spec) in &cases {
        // cold: a fresh pipeline with no store — full validate + codegen +
        // place + route every call.
        let cold = b.bench(&format!("lower/cold/{label}"), || {
            Pipeline::new(ArchConfig::vck5000()).lower(spec).unwrap().graph().nodes.len()
        });

        // disk-warm: entry persisted once, then every call is a fresh
        // pipeline (new process stand-in) deserializing from the store.
        Pipeline::new(ArchConfig::vck5000()).with_disk_store(&dir).lower(spec).unwrap();
        let disk = b.bench(&format!("lower/disk_warm/{label}"), || {
            let p = Pipeline::new(ArchConfig::vck5000()).with_disk_store(&dir);
            let n = p.lower(spec).unwrap().graph().nodes.len();
            assert_eq!(p.cache().stats().misses, 0, "disk-warm case must not lower");
            n
        });

        // memory-warm: one long-lived pipeline, plan-cache hit.
        let warm_pipeline = Pipeline::new(ArchConfig::vck5000());
        warm_pipeline.lower(spec).unwrap();
        let mem = b.bench(&format!("lower/mem_warm/{label}"), || {
            warm_pipeline.lower(spec).unwrap().graph().nodes.len()
        });

        eprintln!(
            "  {label}: cold {:.3} ms | disk-warm {:.3} ms ({:.1}x) | mem-warm {:.6} ms ({:.0}x)",
            cold.median * 1e3,
            disk.median * 1e3,
            cold.median / disk.median.max(1e-12),
            mem.median * 1e3,
            cold.median / mem.median.max(1e-12),
        );
        json_rows.push(obj(vec![
            ("case", label.as_str().into()),
            ("cold_median_s", cold.median.into()),
            ("disk_warm_median_s", disk.median.into()),
            ("mem_warm_median_s", mem.median.into()),
            ("disk_speedup", (cold.median / disk.median.max(1e-12)).into()),
            ("mem_speedup", (cold.median / mem.median.max(1e-12)).into()),
        ]));
    }

    b.finish();
    let _ = std::fs::remove_dir_all(&dir);

    let doc = obj(vec![
        ("bench", "persist".into()),
        ("unit", "seconds".into()),
        ("smoke", smoke.into()),
        ("cases", Json::Arr(json_rows)),
    ]);
    let out_dir = std::env::var("AIEBLAS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{out_dir}/BENCH_persist.json");
    match std::fs::write(&path, doc.to_pretty() + "\n") {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
