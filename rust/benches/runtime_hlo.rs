//! PJRT runtime benches: artifact compile time (cold) vs cached execution
//! (hot) — verifying the request path never recompiles (§Perf L3 target).
//!
//! Run: `cargo bench --bench runtime_hlo` (needs `make artifacts`)

use aieblas::runtime::NumericExecutor;
use aieblas::util::bench::Bench;
use aieblas::util::rng::Rng;

fn main() {
    aieblas::init();
    let dir = std::path::Path::new("artifacts");
    let ex = NumericExecutor::new(dir).unwrap();
    if ex.manifest().is_empty() {
        eprintln!("no artifacts found — run `make artifacts` first; skipping");
        return;
    }
    let mut b = Bench::new("runtime_hlo");
    let mut rng = Rng::new(3);

    for &n in &[4096usize, 65536, 1048576] {
        if !ex.has_artifact("axpy", n) {
            continue;
        }
        let inputs = vec![vec![1.5f32], rng.normal_vec_f32(n), rng.normal_vec_f32(n)];
        // first call compiles (cold) — measured separately
        let t0 = std::time::Instant::now();
        ex.execute("axpy", n, &inputs).unwrap();
        eprintln!("  axpy n={n}: cold compile+run {:.2} ms", t0.elapsed().as_secs_f64() * 1e3);
        b.bench(&format!("pjrt/axpy/n={n}/hot"), || {
            ex.execute("axpy", n, &inputs).unwrap().0[0]
        });
    }

    if ex.has_artifact("axpydot", 65536) {
        let n = 65536;
        let inputs = vec![
            vec![2.0f32],
            rng.normal_vec_f32(n),
            rng.normal_vec_f32(n),
            rng.normal_vec_f32(n),
        ];
        b.bench("pjrt/axpydot/n=65536/hot", || {
            ex.execute("axpydot", n, &inputs).unwrap().0[0]
        });
    }

    if ex.has_artifact("gemv", 256) {
        let n = 256;
        let inputs = vec![
            vec![1.0f32],
            rng.normal_vec_f32(n * n),
            rng.normal_vec_f32(n),
            vec![0.5f32],
            rng.normal_vec_f32(n),
        ];
        b.bench("pjrt/gemv/n=256/hot", || {
            ex.execute("gemv", n, &inputs).unwrap().0[0]
        });
    }
    b.finish();
}
