//! Plan-cache bench: cold vs warm `run_spec` (and raw pipeline lowering),
//! quantifying what the cache saves on the serving path — re-validation,
//! re-codegen, re-placement and re-routing all skipped on a hit.
//!
//! Emits `BENCH_plan_cache.json` (in the working directory, or under
//! `AIEBLAS_BENCH_JSON_DIR` when set) to start the perf trajectory.
//!
//! Run: `cargo bench --bench plan_cache`

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::coordinator::{AieBlas, Config};
use aieblas::pipeline::Pipeline;
use aieblas::spec::{DataSource, Spec};
use aieblas::util::bench::Bench;
use aieblas::util::json::{obj, Json};

fn fresh_system() -> AieBlas {
    AieBlas::new(Config {
        artifacts_dir: "/nonexistent".into(),
        check_numerics: false,
        cpu_samples: 1,
        ..Default::default()
    })
    .unwrap()
}

fn main() {
    aieblas::init();
    let mut b = Bench::new("plan_cache");
    let mut json_rows: Vec<Json> = Vec::new();

    let cases = [
        ("axpy/n=2^16", Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl)),
        ("axpy/n=2^20", Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::Pl)),
        ("gemv/n=256", Spec::single(RoutineKind::Gemv, "g", 256, DataSource::Pl)),
        ("axpydot_df/n=2^16", Spec::axpydot_dataflow(1 << 16, 2.0)),
    ];

    for (label, spec) in &cases {
        // raw lowering: a fresh pipeline every call (cold) vs one pipeline
        // reused (warm, cache hit).
        let cold_lower = b.bench(&format!("lower/cold/{label}"), || {
            Pipeline::new(ArchConfig::vck5000()).lower(spec).unwrap().graph().nodes.len()
        });
        let warm_pipeline = Pipeline::new(ArchConfig::vck5000());
        warm_pipeline.lower(spec).unwrap();
        let warm_lower = b.bench(&format!("lower/warm/{label}"), || {
            warm_pipeline.lower(spec).unwrap().graph().nodes.len()
        });

        // full run_spec_sim_only: cold system each call vs warm system.
        let cold_run = b.bench(&format!("run_sim/cold/{label}"), || {
            fresh_system().run_spec_sim_only(spec).unwrap().makespan_s
        });
        let warm_sys = fresh_system();
        warm_sys.run_spec_sim_only(spec).unwrap();
        let warm_run = b.bench(&format!("run_sim/warm/{label}"), || {
            warm_sys.run_spec_sim_only(spec).unwrap().makespan_s
        });

        let stats = warm_sys.plan_cache().stats();
        eprintln!(
            "  {label}: lower {:.1}x faster warm, run_spec_sim_only {:.1}x faster warm \
             (cache: {} hits / {} misses)",
            cold_lower.median / warm_lower.median.max(1e-12),
            cold_run.median / warm_run.median.max(1e-12),
            stats.hits,
            stats.misses,
        );
        json_rows.push(obj(vec![
            ("case", (*label).into()),
            ("lower_cold_median_s", cold_lower.median.into()),
            ("lower_warm_median_s", warm_lower.median.into()),
            ("lower_speedup", (cold_lower.median / warm_lower.median.max(1e-12)).into()),
            ("run_cold_median_s", cold_run.median.into()),
            ("run_warm_median_s", warm_run.median.into()),
            ("run_speedup", (cold_run.median / warm_run.median.max(1e-12)).into()),
            ("cache_hits", (stats.hits as f64).into()),
            ("cache_misses", (stats.misses as f64).into()),
        ]));
    }

    b.finish();

    let doc = obj(vec![
        ("bench", "plan_cache".into()),
        ("unit", "seconds".into()),
        ("cases", Json::Arr(json_rows)),
    ]);
    let dir = std::env::var("AIEBLAS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{dir}/BENCH_plan_cache.json");
    match std::fs::write(&path, doc.to_pretty() + "\n") {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
