//! Fleet fault-tolerance bench (DESIGN.md §14): what does losing a shard
//! cost, and how fast does the breaker react?
//!
//! A 2-shard loopback fleet over one shared plan store, driven through
//! shard 0:
//! * `steady` — both shards up: mixed local + proxied warm requests;
//! * `degraded` — shard 1 killed: the same workload served via breaker-
//!   gated local failover (`degraded_*` fields are exempt from the CI
//!   regression gate — failover latency includes breaker transients);
//! * `breaker` — trip latency (kill → breaker open) and recover latency
//!   (restart → breaker closed), both probe-driven.
//!
//! Emits `BENCH_fleet.json` (working directory, or under
//! `AIEBLAS_BENCH_JSON_DIR`) to extend the tracked perf series.
//!
//! Smoke mode (CI): `AIEBLAS_BENCH_SMOKE=1` shrinks request counts so the
//! run is a pass/fail completion check, no timing assertions.
//!
//! Run: `cargo bench --bench fleet`

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::http::client::{self, ClientConfig, RetryPolicy};
use aieblas::http::{HealthConfig, HttpConfig, HttpServer, ShardRouter};
use aieblas::pipeline::{Pipeline, PlanKey};
use aieblas::runtime::CpuBackend;
use aieblas::serve::{RoutineServer, ServeConfig};
use aieblas::spec::{DataSource, Spec};
use aieblas::util::bench::{Bench, Stats};
use aieblas::util::json::{obj, Json};

fn store_dir() -> PathBuf {
    std::env::temp_dir().join(format!("aieblas-bench-fleet-{}", std::process::id()))
}

fn bind_shard(peers: &[String], i: usize, dir: &std::path::Path) -> HttpServer {
    let router = ShardRouter::new(peers.to_vec(), i)
        .expect("router")
        .with_health(HealthConfig {
            trip_threshold: 2,
            cooldown: Duration::from_millis(200),
        })
        .with_retry(RetryPolicy {
            max_attempts: 2,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(20),
            budget: Duration::from_millis(200),
        })
        .with_client(ClientConfig {
            connect_timeout: Duration::from_millis(500),
            io_timeout: Duration::from_secs(10),
            ..Default::default()
        });
    let pipeline = Pipeline::new(ArchConfig::vck5000()).with_disk_store(dir);
    let server = Arc::new(RoutineServer::new(
        Arc::new(pipeline),
        Arc::new(CpuBackend),
        ServeConfig::default(),
    ));
    let cfg = HttpConfig {
        probe_interval: Duration::from_millis(50),
        drain_timeout: Duration::from_secs(5),
        ..Default::default()
    };
    HttpServer::bind(&peers[i], server, Some(router), cfg).expect("bind shard")
}

fn breaker_of(addr: &str, peer: usize) -> String {
    let (_, health) = client::get(addr, "/v1/healthz", &ClientConfig::default()).expect("healthz");
    health
        .path("shards.peers")
        .and_then(Json::as_arr)
        .and_then(|p| p.get(peer))
        .and_then(|p| p.get("breaker"))
        .and_then(Json::as_str)
        .expect("peer breaker field")
        .to_string()
}

/// Seconds until `breaker_of(addr, peer)` reports `want` (10 s cap).
fn wait_breaker(addr: &str, peer: usize, want: &str) -> f64 {
    let t0 = Instant::now();
    while breaker_of(addr, peer) != want {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "breaker never became {want}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    t0.elapsed().as_secs_f64()
}

/// Drive `total` warm requests round-robin over `bodies` into `addr`,
/// returning per-request latency samples. Every response must be 200 —
/// in degraded mode that is exactly the §14 availability contract.
fn drive(addr: &str, bodies: &[Vec<u8>], total: usize, phase: &str) -> Vec<f64> {
    let cfg = ClientConfig::default();
    let policy = RetryPolicy::default();
    let mut xs = Vec::with_capacity(total);
    for i in 0..total {
        let body = &bodies[i % bodies.len()];
        let t = Instant::now();
        let resp =
            client::request_with_retry(addr, "POST", "/v1/run", Some(body), &[], &cfg, &policy, true)
                .unwrap_or_else(|e| panic!("{phase} request {i} failed: {e}"));
        assert_eq!(resp.status, 200, "{phase} request {i}");
        xs.push(t.elapsed().as_secs_f64());
    }
    xs
}

fn main() {
    aieblas::init();
    let smoke = std::env::var("AIEBLAS_BENCH_SMOKE").is_ok();
    let mut b = Bench::new("fleet");
    let mut json_rows: Vec<Json> = Vec::new();

    let total = if smoke { 24 } else { 160 };
    let size = if smoke { 256 } else { 4096 };
    let dir = store_dir();
    let _ = std::fs::remove_dir_all(&dir);

    let ports: Vec<u16> = {
        let listeners: Vec<std::net::TcpListener> = (0..2)
            .map(|_| std::net::TcpListener::bind("127.0.0.1:0").expect("reserve port"))
            .collect();
        listeners.iter().map(|l| l.local_addr().unwrap().port()).collect()
    };
    let peers: Vec<String> = ports.iter().map(|p| format!("127.0.0.1:{p}")).collect();
    let mut servers: Vec<Option<HttpServer>> =
        (0..2).map(|i| Some(bind_shard(&peers, i, &dir))).collect();

    // A body per shard so the steady workload exercises both the local
    // path and the proxy hop; `include_values: false` keeps payloads flat.
    let router = ShardRouter::new(peers.clone(), 0).expect("router");
    let mut by_shard: [Option<Spec>; 2] = [None, None];
    for i in 0..64 {
        let spec = Spec::single(RoutineKind::Axpy, "a", size + 16 * i, DataSource::Pl);
        let shard = router.shard_of(&PlanKey::of(&spec));
        if by_shard[shard].is_none() {
            by_shard[shard] = Some(spec);
        }
    }
    let bodies: Vec<Vec<u8>> = by_shard
        .iter()
        .map(|s| {
            let spec = s.as_ref().expect("64 specs cover both shards");
            let mut body = obj(vec![("spec", spec.to_json())]);
            if let Json::Obj(map) = &mut body {
                map.insert("include_values".into(), Json::Bool(false));
            }
            body.to_compact().into_bytes()
        })
        .collect();

    // Prime: one lowering per spec, written through to the shared store.
    drive(&peers[0], &bodies, bodies.len(), "prime");

    // Phase 1: both shards up.
    let t0 = Instant::now();
    let steady = Stats::from_samples(drive(&peers[0], &bodies, total, "steady"));
    let steady_rps = total as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    b.record("steady", steady);
    json_rows.push(obj(vec![
        ("case", "steady".into()),
        ("median_s", steady.median.into()),
        ("rps", steady_rps.into()),
    ]));

    // Phase 2: kill shard 1 and time the probe-driven breaker trip.
    servers[1].take().expect("shard 1 live").shutdown();
    let trip_s = wait_breaker(&peers[0], 1, "open");

    // Phase 3: the same workload, one shard down. Shard 1's keys are
    // served locally via failover; throughput dips, availability holds.
    let t0 = Instant::now();
    let degraded = Stats::from_samples(drive(&peers[0], &bodies, total, "degraded"));
    let degraded_rps = total as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    b.record("degraded", degraded);
    json_rows.push(obj(vec![
        ("case", "degraded".into()),
        ("degraded_median_s", degraded.median.into()),
        ("degraded_rps", degraded_rps.into()),
    ]));

    // Phase 4: restart and time the probe-driven recovery.
    servers[1] = Some(bind_shard(&peers, 1, &dir));
    let recover_s = wait_breaker(&peers[0], 1, "closed");
    json_rows.push(obj(vec![
        ("case", "breaker".into()),
        ("trip_s", trip_s.into()),
        ("recover_s", recover_s.into()),
    ]));
    eprintln!(
        "  fleet: steady {steady_rps:.0} req/s, one-shard-down {degraded_rps:.0} req/s, \
         breaker trip {:.0} ms / recover {:.0} ms",
        trip_s * 1e3,
        recover_s * 1e3
    );

    for srv in servers.into_iter().flatten() {
        srv.shutdown();
    }
    let _ = std::fs::remove_dir_all(&dir);
    b.finish();

    let doc = obj(vec![
        ("bench", "fleet".into()),
        ("unit", "seconds".into()),
        ("smoke", smoke.into()),
        ("cases", Json::Arr(json_rows)),
    ]);
    let out_dir = std::env::var("AIEBLAS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{out_dir}/BENCH_fleet.json");
    match std::fs::write(&path, doc.to_pretty() + "\n") {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
