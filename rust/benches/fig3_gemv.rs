//! Fig. 3 gemv panel: AIE w/ PL movers vs AIE no-PL vs CPU across matrix
//! sizes (n×n).
//!
//! Run: `cargo bench --bench fig3_gemv`

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{experiments, AieBlas, Config};
use aieblas::util::bench::{Bench, Stats};

fn main() {
    aieblas::init();
    let sys = AieBlas::new(Config { check_numerics: false, ..Default::default() }).unwrap();
    let mut b = Bench::new("fig3_gemv");
    for &n in &experiments::MAT_SIZES {
        let rows = experiments::single_routine_panel(&sys, RoutineKind::Gemv, &[n]).unwrap();
        for r in &rows {
            b.record(
                &format!("gemv/n={n}/{}", r.variant),
                Stats::from_samples(vec![r.seconds]),
            );
        }
    }
    b.finish();
}
