//! Simulator-engine microbenchmarks: host wallclock of the DES itself
//! (the L3 hot path the §Perf pass optimizes) across graph shapes:
//! token-loop throughput, composed pipelines, a deep 8-stage chain that
//! stresses the ready queue, wide fan-out, and the PR 5 headline cases —
//! multi-rate fast-forward on gemv's re-read `x` edge and parallel
//! simulation of independent components — each timed against the PR 2
//! engine configuration (`multirate: false, threads: 1`) with the
//! speedup recorded in the JSON.
//!
//! Emits `BENCH_sim_engine.json` (working directory, or under
//! `AIEBLAS_BENCH_JSON_DIR`) in the same shape as `BENCH_plan_cache.json`
//! to extend the perf trajectory. With `--features sim-naive` each case
//! also times the pre-PR-2 worklist engine and records the speedup.
//!
//! Run: `cargo bench --bench sim_engine [--features sim-naive]`
//! Smoke mode (CI): `AIEBLAS_BENCH_SMOKE=1` shrinks problem sizes so a
//! hanging or panicking engine is caught without timing noise.

use std::cell::Cell;

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{AieBlas, Config};
use aieblas::sim::SimOptions;
use aieblas::spec::{DataSource, RoutineSpec, Spec};
use aieblas::util::bench::Bench;
use aieblas::util::json::{obj, Json};

/// Time one spec on the event engine (and, with `sim-naive`, the old
/// worklist engine); append a JSON row comparing the two.
fn bench_case(sys: &AieBlas, b: &mut Bench, rows: &mut Vec<Json>, label: &str, spec: &Spec) {
    let makespan = Cell::new(0.0f64);
    let engine = b.bench(&format!("engine/{label}"), || {
        makespan.set(sys.run_spec_sim_only(spec).unwrap().makespan_s);
        makespan.get()
    });
    #[cfg_attr(not(feature = "sim-naive"), allow(unused_mut))]
    let mut fields: Vec<(&str, Json)> = vec![
        ("case", label.into()),
        ("engine_median_s", engine.median.into()),
        ("makespan_s", makespan.get().into()),
    ];
    #[cfg(feature = "sim-naive")]
    {
        let plan = aieblas::pipeline::lower_spec(spec).unwrap();
        let naive = b.bench(&format!("naive/{label}"), || {
            aieblas::sim::naive::simulate(
                plan.graph(),
                plan.placement(),
                plan.routing(),
                plan.arch(),
            )
            .unwrap()
            .makespan_s
        });
        eprintln!(
            "  {label}: engine {:.1}x faster than naive worklist",
            naive.median / engine.median.max(1e-12)
        );
        fields.push(("naive_median_s", naive.median.into()));
        fields.push(("speedup", (naive.median / engine.median.max(1e-12)).into()));
    }
    rows.push(obj(fields));
}

/// Time one spec under two engine configurations (the current default vs
/// the pinned PR 2 configuration) and record the speedup.
fn bench_vs_pr2(
    b: &mut Bench,
    rows: &mut Vec<Json>,
    label: &str,
    spec: &Spec,
    new: &SimOptions,
    pr2: &SimOptions,
) {
    let plan = aieblas::pipeline::lower_spec(spec).unwrap();
    let sim = |opts: &SimOptions| {
        aieblas::sim::simulate_with(
            plan.graph(),
            plan.placement(),
            plan.routing(),
            plan.arch(),
            opts,
        )
        .unwrap()
        .makespan_s
    };
    let makespan = Cell::new(0.0f64);
    let new_stats = b.bench(&format!("engine/{label}"), || {
        makespan.set(sim(new));
        makespan.get()
    });
    let pr2_stats = b.bench(&format!("pr2/{label}"), || sim(pr2));
    let speedup = pr2_stats.median / new_stats.median.max(1e-12);
    eprintln!("  {label}: new engine {speedup:.1}x faster than the PR 2 engine");
    rows.push(obj(vec![
        ("case", label.into()),
        ("engine_median_s", new_stats.median.into()),
        ("pr2_median_s", pr2_stats.median.into()),
        ("speedup_vs_pr2", speedup.into()),
        ("makespan_s", makespan.get().into()),
    ]));
}

fn main() {
    aieblas::init();
    // CI smoke mode: bounded problem sizes — catches hangs/panics/regressed
    // scaling without asserting on wallclock.
    let smoke = std::env::var("AIEBLAS_BENCH_SMOKE").is_ok();
    let sys = AieBlas::new(Config { check_numerics: false, ..Default::default() }).unwrap();
    let mut b = Bench::new("sim_engine");
    let mut rows: Vec<Json> = Vec::new();

    // single kernel, many windows (token-loop throughput + fast-forward)
    let exps: &[usize] = if smoke { &[12, 14] } else { &[16, 20, 22] };
    for &exp in exps {
        let spec = Spec::single(RoutineKind::Axpy, "a", 1 << exp, DataSource::Pl);
        bench_case(&sys, &mut b, &mut rows, &format!("sim/axpy_pl/n=2^{exp}"), &spec);
    }

    // composed pipeline
    let n = if smoke { 1 << 14 } else { 1 << 20 };
    let spec = Spec::axpydot_dataflow(n, 2.0);
    bench_case(&sys, &mut b, &mut rows, "sim/axpydot_df", &spec);

    // deep pipeline: 8 chained stages (ready-queue stress — every token
    // wakes exactly one consumer; the old engine rescanned all 8 stages)
    let n = if smoke { 1 << 14 } else { 1 << 20 };
    bench_case(&sys, &mut b, &mut rows, "sim/deep8", &Spec::chain(RoutineKind::Copy, 8, n));

    // wide graph: 16 independent kernels (independent fast-forward regions)
    let n = if smoke { 1 << 12 } else { 1 << 16 };
    let mut wide = Spec { platform: "vck5000".into(), ..Default::default() };
    for i in 0..16 {
        wide.routines.push(RoutineSpec::new(RoutineKind::Axpy, format!("k{i}"), n));
    }
    bench_case(&sys, &mut b, &mut rows, "sim/wide16", &wide);

    // --- PR 5 headline cases: new engine vs the PR 2 configuration --------
    // `multirate: false, threads: 1` pins the PR 2 configuration
    // (uniform-rate fast-forward only, components one after another) —
    // a reconstruction of the old detector, not the old binary.
    let pr2 = SimOptions { multirate: false, threads: 1 };
    let new = SimOptions::default();

    // gemv: the re-read x edge fires once per n/16 kernel iterations; the
    // PR 2 detector can at best skip fragments between x fires, while the
    // multi-rate detector jumps whole hyperperiods in closed form.
    let n = if smoke { 512 } else { 2048 };
    let gemv = Spec::single(RoutineKind::Gemv, "g", n, DataSource::Pl);
    bench_vs_pr2(&mut b, &mut rows, &format!("sim/gemv_multirate/n={n}"), &gemv, &new, &pr2);

    // wide16 again, explicitly pinning thread counts: the win here is
    // parallel simulation of the 16 independent components.
    let n = if smoke { 1 << 13 } else { 1 << 18 };
    let mut wide_par = Spec { platform: "vck5000".into(), ..Default::default() };
    for i in 0..16 {
        wide_par.routines.push(RoutineSpec::new(RoutineKind::Axpy, format!("k{i}"), n));
    }
    bench_vs_pr2(
        &mut b,
        &mut rows,
        &format!("sim/wide16_parallel/n={n}"),
        &wide_par,
        &new,
        &pr2,
    );

    // pipeline stages separately: build+place+route without simulate
    let arch = aieblas::arch::ArchConfig::vck5000();
    let spec2 = Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::Pl);
    b.bench("graph/build+place+route/n=2^20", || {
        let built = aieblas::graph::build::build_graph(&spec2).unwrap();
        let p = aieblas::graph::place::place(&built.graph, &arch).unwrap();
        aieblas::graph::route::route(&built.graph, &p, &arch).unwrap().total_hops()
    });
    b.finish();

    let doc = obj(vec![
        ("bench", "sim_engine".into()),
        ("unit", "seconds".into()),
        ("smoke", smoke.into()),
        ("cases", Json::Arr(rows)),
    ]);
    let dir = std::env::var("AIEBLAS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{dir}/BENCH_sim_engine.json");
    match std::fs::write(&path, doc.to_pretty() + "\n") {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
