//! Simulator-engine microbenchmarks: host wallclock of the DES itself
//! (the L3 hot path the §Perf pass optimizes) across graph shapes.
//!
//! Run: `cargo bench --bench sim_engine`

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{AieBlas, Config};
use aieblas::spec::{DataSource, Spec};
use aieblas::util::bench::Bench;

fn main() {
    aieblas::init();
    let sys = AieBlas::new(Config { check_numerics: false, ..Default::default() }).unwrap();
    let mut b = Bench::new("sim_engine");

    // single kernel, many windows (token-loop throughput)
    for exp in [16usize, 20, 22] {
        let spec = Spec::single(RoutineKind::Axpy, "a", 1 << exp, DataSource::Pl);
        b.bench(&format!("sim/axpy_pl/n=2^{exp}"), || {
            sys.run_spec_sim_only(&spec).unwrap().makespan_s
        });
    }

    // composed pipeline
    let spec = Spec::axpydot_dataflow(1 << 20, 2.0);
    b.bench("sim/axpydot_df/n=2^20", || {
        sys.run_spec_sim_only(&spec).unwrap().makespan_s
    });

    // wide graph: 16 independent kernels (placement + routing pressure)
    let mut wide = Spec { platform: "vck5000".into(), ..Default::default() };
    for i in 0..16 {
        wide.routines.push(aieblas::spec::RoutineSpec {
            kind: RoutineKind::Axpy,
            name: format!("k{i}"),
            size: 1 << 16,
            window: None,
            vector_bits: 512,
            placement: None,
            burst: false,
            alpha: None,
            beta: None,
            split: 1,
        });
    }
    b.bench("sim/wide16/n=2^16", || {
        sys.run_spec_sim_only(&wide).unwrap().makespan_s
    });

    // pipeline stages separately: build+place+route without simulate
    let arch = aieblas::arch::ArchConfig::vck5000();
    let spec2 = Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::Pl);
    b.bench("graph/build+place+route/n=2^20", || {
        let built = aieblas::graph::build::build_graph(&spec2).unwrap();
        let p = aieblas::graph::place::place(&built.graph, &arch).unwrap();
        aieblas::graph::route::route(&built.graph, &p, &arch).unwrap().total_hops()
    });
    b.finish();
}
