//! Placement-autotuner bench (DESIGN.md §11): untuned vs analytic-tuned vs
//! full-tuned simulated makespan, plus the search cost of each tier, over
//! naive-PL workloads where the tuner's burst variant is the headline win.
//!
//! The makespan columns double as the ISSUE 6 acceptance gate (asserted
//! below, in smoke mode too — they are simulated device times, not host
//! wallclock): the tuned makespan never exceeds the untuned one on any
//! case, at least one case improves by ≥10%, and the analytic model's
//! prediction lands within 5% of the DES on a uniform-rate pipeline.
//!
//! Emits `BENCH_tune.json` (working directory, or under
//! `AIEBLAS_BENCH_JSON_DIR`) to extend the tracked perf series.
//!
//! Smoke mode (CI): `AIEBLAS_BENCH_SMOKE=1` shrinks sizes so the run is a
//! pass/fail completion check, no host-timing assertions.
//!
//! Run: `cargo bench --bench tune`

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::pipeline::lower_spec;
use aieblas::sim::{analytic, simulate_plan};
use aieblas::spec::{DataSource, Spec};
use aieblas::tune::{tune_spec, TuneConfig, TuneMode};
use aieblas::util::bench::Bench;
use aieblas::util::json::{obj, Json};

fn main() {
    aieblas::init();
    let smoke = std::env::var("AIEBLAS_BENCH_SMOKE").is_ok();
    let mut b = Bench::new("tune");
    let mut json_rows: Vec<Json> = Vec::new();

    let arch = ArchConfig::vck5000();
    let vec_n = if smoke { 1 << 14 } else { 1 << 20 };
    let cases = [
        ("axpy".to_string(), Spec::single(RoutineKind::Axpy, "a", vec_n, DataSource::Pl)),
        ("axpydot_df".to_string(), Spec::axpydot_dataflow(vec_n, 2.0)),
        ("scal_chain".to_string(), Spec::chain(RoutineKind::Scal, 3, vec_n / 4)),
    ];
    let cfg = |mode: TuneMode| TuneConfig { mode, max_candidates: 8, shortlist: 3 };

    let mut best_speedup: f64 = 0.0;
    for (label, spec) in &cases {
        let untuned_makespan = simulate_plan(&lower_spec(spec).unwrap()).unwrap().makespan_s;

        // search cost per tier (host wallclock), winning plan kept for the
        // simulated-makespan columns.
        let analytic_search = b.bench(&format!("search/analytic/{label}"), || {
            tune_spec(spec, &arch, &cfg(TuneMode::Analytic)).unwrap().report.candidates.len()
        });
        let analytic_plan = tune_spec(spec, &arch, &cfg(TuneMode::Analytic)).unwrap().plan;
        let analytic_makespan = simulate_plan(&analytic_plan).unwrap().makespan_s;

        let full_search = b.bench(&format!("search/full/{label}"), || {
            tune_spec(spec, &arch, &cfg(TuneMode::Full)).unwrap().report.candidates.len()
        });
        let full_plan = tune_spec(spec, &arch, &cfg(TuneMode::Full)).unwrap().plan;
        let full_makespan = simulate_plan(&full_plan).unwrap().makespan_s;

        // acceptance: tuning never loses, on any case, at any size.
        assert!(
            full_makespan <= untuned_makespan,
            "{label}: full-tuned {full_makespan} > untuned {untuned_makespan}"
        );
        assert!(
            analytic_makespan <= untuned_makespan,
            "{label}: analytic-tuned {analytic_makespan} > untuned {untuned_makespan}"
        );
        best_speedup = best_speedup.max(untuned_makespan / full_makespan.max(1e-12));

        eprintln!(
            "  {label}: untuned {:.3} ms | analytic {:.3} ms | full {:.3} ms ({:.2}x) | \
             search {:.3} / {:.3} ms",
            untuned_makespan * 1e3,
            analytic_makespan * 1e3,
            full_makespan * 1e3,
            untuned_makespan / full_makespan.max(1e-12),
            analytic_search.median * 1e3,
            full_search.median * 1e3,
        );
        json_rows.push(obj(vec![
            ("case", label.as_str().into()),
            ("untuned_makespan_s", untuned_makespan.into()),
            ("analytic_makespan_s", analytic_makespan.into()),
            ("full_makespan_s", full_makespan.into()),
            ("full_speedup", (untuned_makespan / full_makespan.max(1e-12)).into()),
            ("analytic_search_median_s", analytic_search.median.into()),
            ("full_search_median_s", full_search.median.into()),
        ]));
    }
    assert!(
        best_speedup >= 1.0 / 0.9,
        "no case improved by >= 10% (best speedup {best_speedup:.3})"
    );

    // analytic-model fidelity on a uniform-rate pipeline: the prediction
    // must land within 5% of the DES makespan (ISSUE 6 acceptance).
    let mut uniform = Spec::single(RoutineKind::Axpy, "u", vec_n.max(1 << 14), DataSource::Pl);
    uniform.routines[0].window = Some(128);
    let uniform_plan = lower_spec(&uniform).unwrap();
    let predicted = analytic::predict_plan(&uniform_plan)
        .expect("uniform axpy must be inside the analytic model's validity domain");
    let simulated = simulate_plan(&uniform_plan).unwrap().makespan_s;
    let rel_err = (predicted - simulated).abs() / simulated;
    assert!(
        rel_err <= 0.05,
        "analytic {predicted} vs DES {simulated}: rel err {rel_err:.4} > 5%"
    );
    eprintln!("  analytic fidelity (uniform axpy): rel err {:.3}%", rel_err * 100.0);

    b.finish();

    let doc = obj(vec![
        ("bench", "tune".into()),
        ("unit", "seconds".into()),
        ("smoke", smoke.into()),
        ("analytic_rel_err", rel_err.into()),
        ("cases", Json::Arr(json_rows)),
    ]);
    let out_dir = std::env::var("AIEBLAS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{out_dir}/BENCH_tune.json");
    match std::fs::write(&path, doc.to_pretty() + "\n") {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
