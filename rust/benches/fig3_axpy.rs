//! Fig. 3 axpy panel: AIE w/ PL movers vs AIE no-PL vs CPU, across input
//! sizes. The simulated-device series are measured as wallclock of the
//! simulation *plus* reported as simulated device time (the figure's
//! quantity); CPU is the measured Rust baseline + the paper-testbed model.
//!
//! Run: `cargo bench --bench fig3_axpy`

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{experiments, AieBlas, Config};
use aieblas::util::bench::{Bench, Stats};

fn main() {
    aieblas::init();
    let sys = AieBlas::new(Config { check_numerics: false, ..Default::default() }).unwrap();
    let mut b = Bench::new("fig3_axpy");

    for &n in &experiments::VEC_SIZES {
        let rows = experiments::single_routine_panel(&sys, RoutineKind::Axpy, &[n]).unwrap();
        for r in &rows {
            // simulated device time is deterministic: record as 1 sample.
            b.record(
                &format!("axpy/n={n}/{}", r.variant),
                Stats::from_samples(vec![r.seconds]),
            );
        }
    }

    // harness overhead: how long one full pipeline (build->place->route->
    // simulate) takes on the host.
    b.bench("axpy/harness/sim-pipeline n=2^20", || {
        sys.run_spec_sim_only(&aieblas::spec::Spec::single(
            RoutineKind::Axpy,
            "a",
            1 << 20,
            aieblas::spec::DataSource::Pl,
        ))
        .unwrap()
        .makespan_s
    });
    b.finish();
}
