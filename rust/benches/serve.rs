//! Serving-layer benchmark: throughput and latency of `RoutineServer`
//! under a synthetic multi-client workload, batched vs unbatched and at
//! 1/2/4 sharded-backend workers — the ROADMAP's async/batched-serving and
//! sharded-execution items made measurable.
//!
//! Emits `BENCH_serve.json` (working directory, or under
//! `AIEBLAS_BENCH_JSON_DIR`) in the same shape as the other BENCH files:
//! per-case throughput (req/s), p50/p99 latency, mean batch size, and the
//! batched-vs-unbatched throughput ratio on the CPU backend.
//!
//! Run: `cargo bench --bench serve`
//! Smoke mode (CI): `AIEBLAS_BENCH_SMOKE=1` shrinks the workload so a
//! deadlocked queue, lost wakeup or panicking worker fails fast; no
//! timing assertions.

use std::sync::Arc;
use std::time::Duration;

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::pipeline::Pipeline;
use aieblas::runtime::{Backend, CpuBackend, ExecInputs, ShardedBackend};
use aieblas::serve::{RoutineServer, ServeConfig, ServeReport};
use aieblas::spec::{DataSource, Spec};
use aieblas::util::json::{obj, Json};

struct Workload {
    specs: Vec<Spec>,
    requests: usize,
    clients: usize,
}

/// Push the whole workload through a fresh server and return its report.
fn drive(workload: &Workload, backend: Arc<dyn Backend>, cfg: ServeConfig) -> ServeReport {
    let server = RoutineServer::new(Arc::new(Pipeline::new(ArchConfig::vck5000())), backend, cfg);
    std::thread::scope(|s| {
        for c in 0..workload.clients {
            let server = &server;
            s.spawn(move || {
                let mut tickets = Vec::new();
                for r in (c..workload.requests).step_by(workload.clients) {
                    let spec = &workload.specs[r % workload.specs.len()];
                    tickets.push(server.submit(spec, ExecInputs::random_for(spec, r as u64)));
                }
                for t in tickets {
                    t.wait().expect("serve request failed");
                }
            });
        }
    });
    server.join()
}

fn row(label: &str, r: &ServeReport) -> Json {
    eprintln!(
        "  {label}: {:.0} req/s, p50 {:.3} ms, p99 {:.3} ms, mean batch {:.2}",
        r.throughput_rps,
        r.p50_latency_s * 1e3,
        r.p99_latency_s * 1e3,
        r.mean_batch
    );
    obj(vec![
        ("case", label.into()),
        ("requests", (r.requests as f64).into()),
        ("batches", (r.batches as f64).into()),
        ("mean_batch", r.mean_batch.into()),
        ("throughput_rps", r.throughput_rps.into()),
        ("p50_latency_s", r.p50_latency_s.into()),
        ("p99_latency_s", r.p99_latency_s.into()),
        ("p50_queue_wait_s", r.p50_queue_wait_s.into()),
        ("cache_misses", (r.cache.misses as f64).into()),
        ("cache_hits", (r.cache.hits as f64).into()),
    ])
}

fn main() {
    aieblas::init();
    let smoke = std::env::var("AIEBLAS_BENCH_SMOKE").is_ok();
    let n = if smoke { 256 } else { 1 << 14 };
    let workload = Workload {
        specs: (0..4)
            .map(|i| Spec::single(RoutineKind::Axpy, &format!("r{i}"), n, DataSource::Pl))
            .collect(),
        requests: if smoke { 64 } else { 512 },
        clients: 4,
    };
    let linger = Duration::from_micros(if smoke { 50 } else { 200 });
    eprintln!(
        "== bench: serve ({} requests, {} clients, axpy n={n}, smoke={smoke}) ==",
        workload.requests, workload.clients
    );

    let mut rows: Vec<Json> = Vec::new();

    // batched vs unbatched, CPU backend (the acceptance comparison)
    let unbatched = drive(
        &workload,
        Arc::new(CpuBackend),
        ServeConfig { max_batch: 1, linger: Duration::ZERO, workers: 2, ..Default::default() },
    );
    rows.push(row("cpu/unbatched", &unbatched));
    let batched = drive(
        &workload,
        Arc::new(CpuBackend),
        ServeConfig { max_batch: 8, linger, workers: 2, ..Default::default() },
    );
    rows.push(row("cpu/batched", &batched));
    let ratio = batched.throughput_rps / unbatched.throughput_rps.max(1e-9);
    eprintln!("  batched vs unbatched throughput: {ratio:.2}x");

    // sharded fan-out sweep: 1 / 2 / 4 workers per batch
    for shards in [1usize, 2, 4] {
        let report = drive(
            &workload,
            Arc::new(ShardedBackend::new(CpuBackend, shards)),
            ServeConfig { max_batch: 8, linger, workers: 2, ..Default::default() },
        );
        rows.push(row(&format!("cpu/sharded_w{shards}"), &report));
    }

    let doc = obj(vec![
        ("bench", "serve".into()),
        ("unit", "seconds".into()),
        ("smoke", smoke.into()),
        ("batched_vs_unbatched_throughput", ratio.into()),
        ("cases", Json::Arr(rows)),
    ]);
    let dir = std::env::var("AIEBLAS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{dir}/BENCH_serve.json");
    match std::fs::write(&path, doc.to_pretty() + "\n") {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
