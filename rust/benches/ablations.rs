//! §V ablation benches (A1–A4): burst movers, multi-AIE splits, window
//! size, vector width, and gemv tiling — the paper's future-work levers,
//! quantified on the simulator.
//!
//! Run: `cargo bench --bench ablations`

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{experiments, AieBlas, Config};

fn main() {
    aieblas::init();
    let sys = AieBlas::new(Config { check_numerics: false, ..Default::default() }).unwrap();

    println!("\n== A1: burst-optimized vs naive PL movers (axpy) ==");
    println!(
        "{}",
        experiments::ablation_burst(&sys, RoutineKind::Axpy, &[1 << 14, 1 << 17, 1 << 20])
            .unwrap()
            .render()
    );

    println!("== A2: multi-AIE / multi-port split (axpy, n = 2^20) ==");
    println!(
        "{}",
        experiments::ablation_multi_port(&sys, 1 << 20, &[1, 2, 4, 8, 16])
            .unwrap()
            .render()
    );

    println!("== A3a: window-size sweep (axpy, n = 2^20) ==");
    println!(
        "{}",
        experiments::ablation_window(&sys, RoutineKind::Axpy, 1 << 20, &[64, 128, 256, 512, 1024])
            .unwrap()
            .render()
    );

    println!("== A3b: vector-width sweep (axpy, n = 2^20, on-chip) ==");
    println!(
        "{}",
        experiments::ablation_vector_width(&sys, RoutineKind::Axpy, 1 << 20)
            .unwrap()
            .render()
    );

    println!("== A4: gemv window (tiling) sweep (n = 512) ==");
    println!(
        "{}",
        experiments::ablation_window(&sys, RoutineKind::Gemv, 512, &[16, 32, 64])
            .unwrap()
            .render()
    );
}
