//! Warm-path scalability bench (DESIGN.md §12): multithreaded warm-hit
//! throughput of the sharded O(1) plan cache, against an in-bench
//! replica of the old design (one mutex around a `HashMap` plus a
//! `VecDeque` recency list refreshed by linear scan).
//!
//! Sweeps thread counts × cache sizes and emits `BENCH_warm_path.json`
//! with, per cache size:
//!
//! * `get_median_s` — single-thread warm `get` cost per op (sub-ms, so
//!   CI's `--min-seconds 1e-3` gate treats it as informational);
//! * `naive_get_median_s` — same op on the old design (`naive_` prefix
//!   exempts it from the bench-diff gate);
//! * `tput_tN_ops_per_s` / `naive_tput_tN_ops_per_s` — aggregate warm
//!   `get` throughput at N threads;
//! * `scaling_vs_1t` and `scaling_efficiency` — top-thread-count
//!   throughput relative to 1 thread (efficiency = scaling / threads).
//!
//! Two properties are asserted in-process:
//!
//! * O(1) `get`: per-op warm-hit cost at the largest size must stay
//!   within 8× of the smallest (the old design is linear in size);
//! * scalability: ≥4× 1-thread throughput at 16 threads — checked only
//!   on full (non-smoke) runs on machines with ≥16 logical cores.
//!
//! Run: `cargo bench --bench warm_path`

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use aieblas::blas::RoutineKind;
use aieblas::pipeline::{lower_spec, ExecutablePlan, PlanCache, PlanKey};
use aieblas::spec::{DataSource, Spec};
use aieblas::util::bench::Bench;
use aieblas::util::json::{obj, Json};
use aieblas::util::rng::Rng;

/// The pre-overhaul plan cache, reproduced verbatim in spirit: one lock
/// around the whole structure, recency tracked in a `VecDeque` whose
/// refresh is an O(len) `iter().position()` scan. Times the design this
/// PR replaced; its fields carry the `naive_` prefix in the JSON so the
/// bench-diff gate never targets them.
type NaiveInner = (HashMap<PlanKey, Arc<ExecutablePlan>>, VecDeque<PlanKey>);

struct NaiveLru {
    capacity: usize,
    inner: Mutex<NaiveInner>,
}

impl NaiveLru {
    fn new(capacity: usize) -> NaiveLru {
        NaiveLru { capacity: capacity.max(1), inner: Mutex::new((HashMap::new(), VecDeque::new())) }
    }

    fn get(&self, key: &PlanKey) -> Option<Arc<ExecutablePlan>> {
        let mut inner = self.inner.lock().unwrap();
        let (map, order) = &mut *inner;
        let plan = map.get(key).cloned()?;
        if let Some(pos) = order.iter().position(|k| k == key) {
            order.remove(pos);
            order.push_back(key.clone());
        }
        Some(plan)
    }

    fn insert(&self, key: PlanKey, plan: Arc<ExecutablePlan>) {
        let mut inner = self.inner.lock().unwrap();
        let (map, order) = &mut *inner;
        if map.contains_key(&key) {
            return;
        }
        while map.len() >= self.capacity {
            let Some(evicted) = order.pop_front() else { break };
            map.remove(&evicted);
        }
        order.push_back(key.clone());
        map.insert(key, plan);
    }
}

/// Aggregate warm-`get` throughput: `threads` workers hammer random
/// resident keys until the deadline; returns total ops per second.
fn throughput_ops_per_s<F>(keys: &[PlanKey], threads: usize, dur: Duration, op: F) -> f64
where
    F: Fn(&PlanKey) + Sync,
{
    let total = AtomicU64::new(0);
    let barrier = Barrier::new(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let (op, total, barrier) = (&op, &total, &barrier);
            s.spawn(move || {
                let mut rng = Rng::new(0xC0FF_EE00 + t as u64);
                barrier.wait();
                let deadline = Instant::now() + dur;
                let mut ops = 0u64;
                // check the clock once per chunk so timing overhead does
                // not drown the measured op.
                while Instant::now() < deadline {
                    for _ in 0..64 {
                        op(&keys[rng.below(keys.len() as u64) as usize]);
                    }
                    ops += 64;
                }
                total.fetch_add(ops, Ordering::Relaxed);
            });
        }
    });
    total.load(Ordering::Relaxed) as f64 / dur.as_secs_f64()
}

fn main() {
    aieblas::init();
    let smoke = std::env::var("AIEBLAS_BENCH_SMOKE").is_ok();
    let sizes: &[usize] = if smoke { &[16, 256] } else { &[16, 1024, 16384] };
    let threads: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8, 16] };
    let hammer_dur = Duration::from_millis(if smoke { 20 } else { 100 });
    let iters = if smoke { 2_000u32 } else { 20_000 };
    eprintln!("== bench: warm_path (sizes {sizes:?}, threads {threads:?}, smoke={smoke}) ==");

    // every entry shares one real lowered plan: the bench times cache
    // bookkeeping, not lowering, and plan identity is irrelevant to it.
    let spec = Spec::single(RoutineKind::Scal, "s", 4096, DataSource::Pl);
    let plan = Arc::new(lower_spec(&spec).expect("lower scal spec"));

    let mut b = Bench::new("warm_path");
    let mut json_rows: Vec<Json> = Vec::new();
    let mut per_op_by_size: Vec<(usize, f64)> = Vec::new();

    for &size in sizes {
        let keys: Vec<PlanKey> =
            (0..size).map(|i| PlanKey::new(format!("warm-path-key-{i}"))).collect();
        let cache = PlanCache::new(size);
        let naive = NaiveLru::new(size);
        for key in &keys {
            cache.insert(key.clone(), plan.clone());
            naive.insert(key.clone(), plan.clone());
        }
        assert_eq!(cache.len(), size, "every key must be resident for a warm-hit bench");

        // single-thread per-op cost (strided walk touches every key).
        let sharded = b.bench(&format!("get/sharded/size={size}"), || {
            let mut hit = 0usize;
            let mut idx = 0usize;
            for _ in 0..iters {
                idx = (idx + 17) % size;
                hit += cache.get(&keys[idx]).is_some() as usize;
            }
            hit
        });
        let naive_stats = b.bench(&format!("get/naive/size={size}"), || {
            let mut hit = 0usize;
            let mut idx = 0usize;
            for _ in 0..iters {
                idx = (idx + 17) % size;
                hit += naive.get(&keys[idx]).is_some() as usize;
            }
            hit
        });
        let get_median_s = sharded.median / iters as f64;
        let naive_get_median_s = naive_stats.median / iters as f64;
        per_op_by_size.push((size, get_median_s));

        let mut row = vec![
            ("case", format!("size={size}").into()),
            ("get_median_s", get_median_s.into()),
            ("naive_get_median_s", naive_get_median_s.into()),
        ];
        let mut tput_1t = f64::NAN;
        let mut tput_top = f64::NAN;
        for &t in threads {
            let tput = throughput_ops_per_s(&keys, t, hammer_dur, |k| {
                std::hint::black_box(cache.get(k));
            });
            let naive_tput = throughput_ops_per_s(&keys, t, hammer_dur, |k| {
                std::hint::black_box(naive.get(k));
            });
            if t == 1 {
                tput_1t = tput;
            }
            tput_top = tput;
            eprintln!(
                "  size={size} t={t}: sharded {:.2}M ops/s, naive {:.2}M ops/s",
                tput / 1e6,
                naive_tput / 1e6
            );
            row.push((Box::leak(format!("tput_t{t}_ops_per_s").into_boxed_str()), tput.into()));
            row.push((
                Box::leak(format!("naive_tput_t{t}_ops_per_s").into_boxed_str()),
                naive_tput.into(),
            ));
        }
        let top_threads = *threads.last().unwrap();
        let scaling = tput_top / tput_1t.max(1.0);
        row.push(("scaling_vs_1t", scaling.into()));
        row.push(("scaling_efficiency", (scaling / top_threads as f64).into()));
        json_rows.push(obj(row));

        // the 16-thread scalability acceptance bar: only meaningful off
        // smoke and with enough cores to actually run 16 ways.
        let cores = std::thread::available_parallelism().map_or(1, |p| p.get());
        if !smoke && top_threads >= 16 && cores >= 16 {
            assert!(
                scaling >= 4.0,
                "16-thread warm-hit throughput must be >=4x 1-thread \
                 (size={size}: {scaling:.2}x on {cores} cores)"
            );
        }
    }

    // O(1) warm get: cost must be flat in cache size. The old design is
    // linear (a 16384-entry scan costs ~1000x a 16-entry one), so a
    // loose 8x envelope cleanly separates O(1) from O(len) while
    // tolerating cache-hierarchy noise on shared runners.
    let (small_size, small) = per_op_by_size.iter().copied().min_by_key(|e| e.0).unwrap();
    let (large_size, large) = per_op_by_size.iter().copied().max_by_key(|e| e.0).unwrap();
    let flatness = large / small.max(1e-12);
    eprintln!(
        "  flatness: size={small_size} {:.1}ns vs size={large_size} {:.1}ns ({flatness:.2}x)",
        small * 1e9,
        large * 1e9
    );
    assert!(
        flatness < 8.0,
        "warm get must be O(1) in cache size: size={large_size} costs {flatness:.2}x \
         size={small_size} ({large:.3e}s vs {small:.3e}s)"
    );

    b.finish();

    let doc = obj(vec![
        ("bench", "warm_path".into()),
        ("unit", "seconds".into()),
        ("smoke", smoke.into()),
        ("flatness_ratio", flatness.into()),
        ("cases", Json::Arr(json_rows)),
    ]);
    let dir = std::env::var("AIEBLAS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{dir}/BENCH_warm_path.json");
    match std::fs::write(&path, doc.to_pretty() + "\n") {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
