//! CPU baseline microbenchmarks: the threaded Rust BLAS (OpenBLAS
//! stand-in) across routines/sizes, with achieved-GB/s so the roofline
//! calibration in arch::HostConfig can be checked against this machine.
//!
//! Run: `cargo bench --bench cpu_baseline`

use aieblas::blas::{cpu, RoutineKind};
use aieblas::util::bench::Bench;
use aieblas::util::rng::Rng;

fn main() {
    aieblas::init();
    let mut b = Bench::new("cpu_baseline");
    let mut rng = Rng::new(1);

    for exp in [14usize, 17, 20] {
        let n = 1 << exp;
        let x = rng.normal_vec_f32(n);
        let y = rng.normal_vec_f32(n);
        let u = rng.normal_vec_f32(n);
        let mut z = vec![0.0f32; n];

        let s = b.bench(&format!("axpy/n=2^{exp}"), || {
            cpu::axpy(1.5, &x, &y, &mut z);
            z[0]
        });
        eprintln!(
            "  axpy n=2^{exp}: {:.2} GB/s",
            (3.0 * 4.0 * n as f64) / s.median / 1e9
        );
        b.bench(&format!("dot/n=2^{exp}"), || cpu::dot(&x, &y));
        b.bench(&format!("axpydot/n=2^{exp}"), || cpu::axpydot(1.5, &x, &y, &u));
        b.bench(&format!("nrm2/n=2^{exp}"), || cpu::nrm2(&x));
    }

    for n in [128usize, 512] {
        let a = rng.normal_vec_f32(n * n);
        let x = rng.normal_vec_f32(n);
        let y = rng.normal_vec_f32(n);
        let mut out = vec![0.0f32; n];
        b.bench(&format!("gemv/n={n}"), || {
            cpu::gemv(1.0, &a, n, n, &x, 0.5, &y, &mut out);
            out[0]
        });
    }

    // model-vs-measured calibration table
    eprintln!("\n  paper-testbed model vs this machine (axpy):");
    for exp in [14usize, 17, 20] {
        let n = 1 << exp;
        let model = aieblas::arch::HostConfig::default()
            .blas_call_time(RoutineKind::Axpy.flops(n), RoutineKind::Axpy.offchip_bytes(n));
        eprintln!("    n=2^{exp}: model {:.1} µs", model * 1e6);
    }
    b.finish();
}
