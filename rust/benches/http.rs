//! HTTP front-door bench: framing + handler overhead on top of the
//! serving layer, measured over real loopback TCP (DESIGN.md §13).
//!
//! Three cases:
//! * `healthz` — pure frame/dispatch round trip, no serving work;
//! * `run_warm` — a plan-cached `/v1/run` in checksum mode, the steady
//!   state of a serving process;
//! * `run_concurrent` — 4 clients hammering the same warm spec: per-
//!   request latency distribution plus aggregate requests/s.
//!
//! Emits `BENCH_http.json` (working directory, or under
//! `AIEBLAS_BENCH_JSON_DIR`) to extend the tracked perf series.
//!
//! Smoke mode (CI): `AIEBLAS_BENCH_SMOKE=1` shrinks sizes so the run is a
//! pass/fail completion check, no timing assertions.
//!
//! Run: `cargo bench --bench http`

use std::sync::Arc;
use std::time::Instant;

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::http::client::{self, ClientConfig};
use aieblas::http::{HttpConfig, HttpServer};
use aieblas::pipeline::Pipeline;
use aieblas::runtime::CpuBackend;
use aieblas::serve::{RoutineServer, ServeConfig};
use aieblas::spec::{DataSource, Spec};
use aieblas::util::bench::{Bench, Stats};
use aieblas::util::json::{obj, Json};

fn main() {
    aieblas::init();
    let smoke = std::env::var("AIEBLAS_BENCH_SMOKE").is_ok();
    let mut b = Bench::new("http");
    let mut json_rows: Vec<Json> = Vec::new();

    let size = if smoke { 256 } else { 4096 };
    let total = if smoke { 32 } else { 256 };
    let clients = 4usize;

    let pipeline = Arc::new(Pipeline::new(ArchConfig::vck5000()));
    let server =
        Arc::new(RoutineServer::new(pipeline, Arc::new(CpuBackend), ServeConfig::default()));
    let http = HttpServer::bind("127.0.0.1:0", server, None, HttpConfig::default())
        .expect("bind loopback");
    let addr = http.local_addr().to_string();
    let cfg = ClientConfig::default();

    // healthz: the floor — one connection, one frame, no serving work.
    let health = b.bench("healthz", || {
        let (status, _) = client::get(&addr, "/v1/healthz", &cfg).unwrap();
        assert_eq!(status, 200);
        status
    });
    json_rows.push(obj(vec![
        ("case", "healthz".into()),
        ("median_s", health.median.into()),
    ]));

    // warm /v1/run: the plan is cached after the priming call; checksum
    // mode keeps the response payload flat across sizes.
    let spec = Spec::single(RoutineKind::Axpy, "a", size, DataSource::Pl);
    let mut body = obj(vec![("spec", spec.to_json())]);
    if let Json::Obj(map) = &mut body {
        map.insert("include_values".into(), Json::Bool(false));
    }
    let (status, first) = client::post_json(&addr, "/v1/run", &body, &cfg).unwrap();
    assert_eq!(status, 200, "priming run failed: {}", first.to_compact());
    let warm = b.bench("run_warm", || {
        let (status, _) = client::post_json(&addr, "/v1/run", &body, &cfg).unwrap();
        assert_eq!(status, 200);
        status
    });
    json_rows.push(obj(vec![
        ("case", "run_warm".into()),
        ("median_s", warm.median.into()),
    ]));

    // concurrent: 4 clients over the same warm spec. Latency samples are
    // per request; rps is the aggregate over the phase's wall clock.
    let t0 = Instant::now();
    let latencies: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let (addr, body, cfg) = (&addr, &body, &cfg);
                s.spawn(move || {
                    let mut xs = Vec::new();
                    for _ in (c..total).step_by(clients) {
                        let t = Instant::now();
                        let (status, _) = client::post_json(addr, "/v1/run", body, cfg).unwrap();
                        assert_eq!(status, 200);
                        xs.push(t.elapsed().as_secs_f64());
                    }
                    xs
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    let rps = latencies.len() as f64 / wall.max(1e-9);
    let p99 = {
        let mut xs = latencies.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[((xs.len() as f64 * 0.99) as usize).min(xs.len() - 1)]
    };
    let conc = Stats::from_samples(latencies);
    b.record("run_concurrent", conc);
    eprintln!(
        "  concurrent: {total} request(s), {clients} client(s): {rps:.0} req/s, p99 {:.3} ms",
        p99 * 1e3
    );
    json_rows.push(obj(vec![
        ("case", "run_concurrent".into()),
        ("median_s", conc.median.into()),
        ("p99_s", p99.into()),
        ("rps", rps.into()),
    ]));

    // graceful exit: stop the listener and drain the serving layer so the
    // bench process leaves no threads behind.
    http.shutdown();
    b.finish();

    let doc = obj(vec![
        ("bench", "http".into()),
        ("unit", "seconds".into()),
        ("smoke", smoke.into()),
        ("cases", Json::Arr(json_rows)),
    ]);
    let out_dir = std::env::var("AIEBLAS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{out_dir}/BENCH_http.json");
    match std::fs::write(&path, doc.to_pretty() + "\n") {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
