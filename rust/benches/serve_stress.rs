//! Overload stress benchmark for `RoutineServer` hardening (ISSUE 7):
//! measures what admission control does when offered load exceeds
//! capacity, instead of the closed-loop in-capacity view `BENCH_serve`
//! gives.
//!
//! Two phases:
//! 1. **calibrate** — a closed-loop run under the default `Block` policy
//!    establishes the sustainable throughput of the (deliberately slowed)
//!    backend.
//! 2. **overload_2x** — an open-loop run offers 2x that rate, paced
//!    across clients, under `RejectWhenFull` with mixed priority classes.
//!    The server must shed the excess at admission while keeping accepted
//!    throughput near the calibrated ceiling and high-priority tail
//!    latency below background tail latency.
//!
//! Emits `BENCH_serve_stress.json` (working directory, or under
//! `AIEBLAS_BENCH_JSON_DIR`). Shed counters are run-size-dependent, so
//! `tools/bench_diff.py` treats `shed_*` fields as non-regression
//! baselines. The accounting invariant `attempts == answered + shed` is
//! asserted in-process for both phases.
//!
//! Run: `cargo bench --bench serve_stress`
//! Smoke mode (CI): `AIEBLAS_BENCH_SMOKE=1` shrinks the workload; no
//! timing assertions, only the accounting invariant.

use std::sync::Arc;
use std::time::{Duration, Instant};

use aieblas::arch::ArchConfig;
use aieblas::blas::RoutineKind;
use aieblas::pipeline::Pipeline;
use aieblas::runtime::{CpuBackend, ExecInputs, SlowBackend};
use aieblas::serve::{
    AdmissionPolicy, Priority, RequestOpts, RoutineServer, ServeConfig, ServeReport, SubmitOutcome,
};
use aieblas::spec::{DataSource, Spec};
use aieblas::util::json::{obj, Json};

const CLIENTS: usize = 4;

fn specs(n: usize) -> Vec<Spec> {
    (0..4).map(|i| Spec::single(RoutineKind::Axpy, &format!("r{i}"), n, DataSource::Pl)).collect()
}

fn server(backend_delay: Duration, policy: AdmissionPolicy) -> RoutineServer {
    RoutineServer::new(
        Arc::new(Pipeline::new(ArchConfig::vck5000())),
        Arc::new(SlowBackend::new(CpuBackend, backend_delay)),
        ServeConfig {
            max_batch: 8,
            linger: Duration::from_micros(100),
            queue_capacity: 128,
            workers: 2,
            policy,
            ..Default::default()
        },
    )
}

/// Deterministic priority mix by request index: 1/8 high, 3/8 background.
fn priority_for(i: usize) -> Priority {
    match i % 8 {
        0 => Priority::High,
        1 | 3 | 5 => Priority::Background,
        _ => Priority::Normal,
    }
}

/// Closed loop: every client keeps one request window in flight until the
/// budget is spent. Establishes the sustainable rate.
fn calibrate(requests: usize, backend_delay: Duration, specs: &[Spec]) -> ServeReport {
    let server = server(backend_delay, AdmissionPolicy::Block);
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let server = &server;
            s.spawn(move || {
                let mut tickets = Vec::new();
                for r in (c..requests).step_by(CLIENTS) {
                    let spec = &specs[r % specs.len()];
                    tickets.push(server.submit(spec, ExecInputs::random_for(spec, r as u64)));
                }
                for t in tickets {
                    t.wait().expect("calibration request failed");
                }
            });
        }
    });
    let report = server.join();
    assert_eq!(
        report.requests + report.metrics.shed_total(),
        requests as u64,
        "calibration accounting must balance"
    );
    report
}

/// Open loop: offer `offered_rps` across the clients for `requests`
/// attempts, never blocking; excess load must shed, not queue unboundedly.
fn overload(
    requests: usize,
    offered_rps: f64,
    backend_delay: Duration,
    specs: &[Spec],
) -> ServeReport {
    let server = server(backend_delay, AdmissionPolicy::RejectWhenFull);
    let interval = Duration::from_secs_f64(CLIENTS as f64 / offered_rps.max(1.0));
    let shed: u64 = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let server = &server;
                s.spawn(move || {
                    let start = Instant::now();
                    let mut shed = 0u64;
                    let mut tickets = Vec::new();
                    for (k, r) in (c..requests).step_by(CLIENTS).enumerate() {
                        // fixed-schedule pacing: sleep to the k-th slot so
                        // a slow server cannot slow the offered rate down.
                        let due = interval * (k as u32);
                        let now = start.elapsed();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        let spec = &specs[r % specs.len()];
                        let opts = RequestOpts::default().with_priority(priority_for(r));
                        let inputs = ExecInputs::random_for(spec, r as u64);
                        match server.try_submit(spec, inputs, opts) {
                            SubmitOutcome::Accepted(t) => tickets.push(t),
                            SubmitOutcome::Shed(_) => shed += 1,
                        }
                    }
                    for t in tickets {
                        t.wait().expect("accepted request must be answered");
                    }
                    shed
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).sum()
    });
    let report = server.join();
    assert_eq!(report.metrics.shed_total(), shed, "client-side and server-side shed counts agree");
    assert_eq!(
        report.requests + report.metrics.shed_total(),
        requests as u64,
        "overload accounting must balance"
    );
    report
}

fn row(label: &str, offered_rps: f64, attempts: usize, r: &ServeReport) -> Json {
    let m = &r.metrics;
    let shed_rate = m.shed_total() as f64 / (attempts as f64).max(1.0);
    let class_p99 = |class: Priority| {
        m.priorities.iter().find(|p| p.class == class).map(|p| p.p99_s).unwrap_or(0.0)
    };
    let high_p99 = class_p99(Priority::High);
    let background_p99 = class_p99(Priority::Background);
    eprintln!(
        "  {label}: offered {offered_rps:.0} req/s -> {:.0} req/s served, \
         shed {} ({:.1}%), p99 {:.3} ms (high {:.3} ms / bg {:.3} ms)",
        r.throughput_rps,
        m.shed_total(),
        shed_rate * 100.0,
        r.p99_latency_s * 1e3,
        high_p99 * 1e3,
        background_p99 * 1e3,
    );
    obj(vec![
        ("case", label.into()),
        ("offered_rps", offered_rps.into()),
        ("attempts", (attempts as f64).into()),
        ("requests", (r.requests as f64).into()),
        ("throughput_rps", r.throughput_rps.into()),
        ("p50_latency_s", r.p50_latency_s.into()),
        ("p99_latency_s", r.p99_latency_s.into()),
        ("high_p99_s", high_p99.into()),
        ("background_p99_s", background_p99.into()),
        ("shed_total", (m.shed_total() as f64).into()),
        ("shed_queue_full", (m.shed_queue_full as f64).into()),
        ("shed_rate", shed_rate.into()),
        ("pool_grown", (m.pool_grown as f64).into()),
    ])
}

fn main() {
    aieblas::init();
    let smoke = std::env::var("AIEBLAS_BENCH_SMOKE").is_ok();
    let requests = if smoke { 192 } else { 2048 };
    // the slow backend bounds capacity at roughly
    // max_batch / delay per dispatcher, so overload is reachable quickly.
    let backend_delay = Duration::from_micros(if smoke { 500 } else { 250 });
    let specs = specs(if smoke { 256 } else { 4096 });
    eprintln!("== bench: serve_stress ({requests} requests, {CLIENTS} clients, smoke={smoke}) ==");

    let calibrated = calibrate(requests, backend_delay, &specs);
    let sustainable_rps = calibrated.throughput_rps;
    eprintln!("  calibrate: sustainable {sustainable_rps:.0} req/s (block policy)");

    let offered_rps = (2.0 * sustainable_rps).max(100.0);
    let overloaded = overload(requests, offered_rps, backend_delay, &specs);

    let doc = obj(vec![
        ("bench", "serve_stress".into()),
        ("unit", "seconds".into()),
        ("smoke", smoke.into()),
        ("sustainable_rps", sustainable_rps.into()),
        (
            "cases",
            Json::Arr(vec![
                row("calibrate", sustainable_rps, requests, &calibrated),
                row("overload_2x", offered_rps, requests, &overloaded),
            ]),
        ),
    ]);
    let dir = std::env::var("AIEBLAS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{dir}/BENCH_serve_stress.json");
    match std::fs::write(&path, doc.to_pretty() + "\n") {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
