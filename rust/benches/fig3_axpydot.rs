//! Fig. 3 axpydot panel: dataflow vs non-dataflow vs CPU — the paper's
//! composition experiment (pipelined on-chip execution ≈ 2×).
//!
//! Run: `cargo bench --bench fig3_axpydot`

use aieblas::coordinator::{experiments, AieBlas, Config};
use aieblas::util::bench::{Bench, Stats};

fn main() {
    aieblas::init();
    let sys = AieBlas::new(Config { check_numerics: false, ..Default::default() }).unwrap();
    let mut b = Bench::new("fig3_axpydot");
    for &n in &experiments::VEC_SIZES {
        let rows = experiments::axpydot_panel(&sys, &[n]).unwrap();
        for r in &rows {
            b.record(
                &format!("axpydot/n={n}/{}", r.variant),
                Stats::from_samples(vec![r.seconds]),
            );
        }
    }
    b.finish();
}
