//! L3 coordinator: the end-to-end AIEBLAS driver.
//!
//! A thin front-end over the staged pipeline and the backend layer: specs
//! are lowered once through [`Pipeline`] (memoized in the plan cache) and
//! executed through [`Backend`] implementations — [`SimBackend`] for the
//! paper's simulated-device timing + artifact numerics, [`CpuBackend`] for
//! the measured CPU baseline, [`ReferenceBackend`] as ground truth. The
//! coordinator itself no longer orchestrates codegen, placement or
//! simulation inline (DESIGN.md §2–§3).

pub mod experiments;

use std::path::PathBuf;
use std::sync::Arc;

use crate::arch::ArchConfig;
use crate::pipeline::{CacheStats, ExecutablePlan, Pipeline, PlanCache};
use crate::runtime::{
    Backend, CpuBackend, ExecInputs, NumericExecutor, Provenance, ReferenceBackend, SimBackend,
};
use crate::serve::{RoutineServer, ServeConfig};
use crate::sim::SimReport;
use crate::spec::{DataSource, Spec};
use crate::tune::TuneConfig;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Directory holding `manifest.json` + HLO artifacts.
    pub artifacts_dir: PathBuf,
    /// Target architecture (defaults to the VCK5000).
    pub arch: ArchConfig,
    /// Samples for CPU baseline timing.
    pub cpu_samples: usize,
    /// Validate numerics against the reference implementation.
    pub check_numerics: bool,
    /// Resident capacity of the plan cache.
    pub plan_cache_capacity: usize,
    /// Directory for the persistent plan store (`pipeline::store`); `None`
    /// keeps lowering memoization in-memory only.
    pub cache_dir: Option<PathBuf>,
    /// Placement-autotuner policy for cold lowerings (`crate::tune`);
    /// defaults to off (install the first valid plan).
    pub tune: TuneConfig,
    /// Serving-layer defaults (admission policy, quotas, pool bounds)
    /// used by [`AieBlas::serve_default`].
    pub serve: ServeConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: PathBuf::from("artifacts"),
            arch: ArchConfig::vck5000(),
            cpu_samples: 5,
            check_numerics: true,
            plan_cache_capacity: Pipeline::DEFAULT_CACHE_CAPACITY,
            cache_dir: None,
            tune: TuneConfig::default(),
            serve: ServeConfig::default(),
        }
    }
}

/// Numeric-execution outcome for one routine.
#[derive(Debug, Clone)]
pub struct NumericResult {
    /// Which implementation produced the numbers.
    pub backend: Provenance,
    /// max |out - reference| / (1 + |reference|) over all outputs.
    pub max_rel_err: f64,
    pub outputs: usize,
}

/// The result of running one spec end to end.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Simulated device timing.
    pub sim: SimReport,
    /// Numeric execution of each routine in the spec (when enabled).
    pub numerics: Vec<(String, NumericResult)>,
    /// Measured wallclock of the CPU baseline for the same math, seconds.
    pub cpu_time_s: Option<f64>,
    /// Plan-cache counters at report time (serving observability).
    pub plan_cache: CacheStats,
}

impl RunReport {
    pub fn summary(&self) -> String {
        let mut s = format!("AIE (simulated): {}", self.sim.summary());
        if let Some(cpu) = self.cpu_time_s {
            s.push_str(&format!(
                "\nCPU baseline: {:.3} ms ({:.2}× vs AIE)",
                cpu * 1e3,
                self.sim.makespan_s / cpu
            ));
        }
        for (name, n) in &self.numerics {
            s.push_str(&format!(
                "\nnumerics[{name}]: {:?}, max rel err {:.2e} over {} outputs",
                n.backend, n.max_rel_err, n.outputs
            ));
        }
        s.push_str(&format!(
            "\nplan cache: {} hit(s) / {} miss(es), {} plan(s) resident, {} eviction(s)",
            self.plan_cache.hits,
            self.plan_cache.misses,
            self.plan_cache.entries,
            self.plan_cache.evictions
        ));
        if self.plan_cache.disk_hits + self.plan_cache.disk_writes + self.plan_cache.rejected > 0 {
            s.push_str(&format!(
                "\nplan store: {} disk hit(s), {} write(s), {} rejected",
                self.plan_cache.disk_hits, self.plan_cache.disk_writes, self.plan_cache.rejected
            ));
        }
        if self.plan_cache.tuned + self.plan_cache.tune_skipped > 0 {
            s.push_str(&format!(
                "\nautotuner: {} tuned lowering(s), {} tuned warm start(s)",
                self.plan_cache.tuned, self.plan_cache.tune_skipped
            ));
        }
        s
    }
}

/// The AIEBLAS system handle.
pub struct AieBlas {
    pub config: Config,
    executor: NumericExecutor,
    pipeline: Arc<Pipeline>,
}

/// Fluent construction for [`AieBlas`]: defaults and validation in one
/// place. Hostile values are clamped rather than rejected (matching the
/// serving layer's envelope): zero sample counts and zero cache capacity
/// become 1. `build()` is the only exit, so every builder-made system has
/// passed through the same normalization.
#[derive(Debug, Clone)]
pub struct AieBlasBuilder {
    config: Config,
}

impl AieBlasBuilder {
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.config.artifacts_dir = dir.into();
        self
    }

    pub fn arch(mut self, arch: ArchConfig) -> Self {
        self.config.arch = arch;
        self
    }

    pub fn cpu_samples(mut self, n: usize) -> Self {
        self.config.cpu_samples = n;
        self
    }

    pub fn check_numerics(mut self, on: bool) -> Self {
        self.config.check_numerics = on;
        self
    }

    pub fn plan_cache_capacity(mut self, n: usize) -> Self {
        self.config.plan_cache_capacity = n;
        self
    }

    /// Enable the persistent plan store under `dir`.
    pub fn cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.config.cache_dir = Some(dir.into());
        self
    }

    pub fn tune(mut self, tune: TuneConfig) -> Self {
        self.config.tune = tune;
        self
    }

    /// Serving defaults used by [`AieBlas::serve_default`].
    pub fn serve(mut self, serve: ServeConfig) -> Self {
        self.config.serve = serve;
        self
    }

    /// Clamp hostile values and construct the system.
    pub fn build(mut self) -> Result<AieBlas> {
        self.config.cpu_samples = self.config.cpu_samples.max(1);
        self.config.plan_cache_capacity = self.config.plan_cache_capacity.max(1);
        AieBlas::new(self.config)
    }
}

impl AieBlas {
    /// Start an [`AieBlasBuilder`] from [`Config::default`]. Preferred over
    /// filling in a `Config` literal: validation lives in `build()`.
    /// (`AieBlas::new(Config)` remains for existing callers.)
    pub fn builder() -> AieBlasBuilder {
        AieBlasBuilder { config: Config::default() }
    }

    pub fn new(config: Config) -> Result<AieBlas> {
        let executor = NumericExecutor::new(&config.artifacts_dir)?;
        let mut pipeline =
            Pipeline::with_cache_capacity(config.arch.clone(), config.plan_cache_capacity)
                .with_tuning(config.tune.clone());
        if let Some(dir) = &config.cache_dir {
            pipeline = pipeline.with_disk_store(dir);
        }
        let pipeline = Arc::new(pipeline);
        Ok(AieBlas { config, executor, pipeline })
    }

    pub fn executor(&self) -> &NumericExecutor {
        &self.executor
    }

    /// The plan cache memoizing spec lowering (hits/misses/entries).
    pub fn plan_cache(&self) -> &PlanCache {
        self.pipeline.cache()
    }

    /// The shared lowering pipeline (thread-safe, single-flight); hand a
    /// clone to a [`RoutineServer`] or any worker thread.
    pub fn pipeline(&self) -> Arc<Pipeline> {
        self.pipeline.clone()
    }

    /// Spin up a serving front-end over this system's pipeline: bounded
    /// request queue, same-plan batching, `backend`-pool dispatch.
    pub fn serve(&self, backend: Arc<dyn Backend>, cfg: ServeConfig) -> RoutineServer {
        RoutineServer::new(self.pipeline.clone(), backend, cfg)
    }

    /// [`AieBlas::serve`] with this system's configured serving defaults
    /// (`Config::serve`), so deployments set admission policy, quotas and
    /// pool bounds once at system construction.
    pub fn serve_default(&self, backend: Arc<dyn Backend>) -> RoutineServer {
        self.serve(backend, self.config.serve.clone())
    }

    /// Lower a spec through the staged pipeline (cached).
    pub fn lower(&self, spec: &Spec) -> Result<Arc<ExecutablePlan>> {
        self.pipeline.lower(spec)
    }

    /// Run a full spec: simulate timing + execute numerics + CPU baseline.
    pub fn run_spec(&self, spec: &Spec) -> Result<RunReport> {
        let plan = self.pipeline.lower(spec)?;
        let backend = SimBackend::with_executor(&self.executor);
        let prepared = backend.prepare(plan)?;
        let inputs = if self.config.check_numerics {
            ExecInputs::random_for(spec, numeric_seed(spec))
        } else {
            ExecInputs::default()
        };
        let outcome = backend.execute(&prepared, &inputs)?;
        let sim = outcome
            .sim
            .clone()
            .ok_or_else(|| Error::Runtime("sim backend produced no timing".into()))?;

        let mut numerics = Vec::new();
        if self.config.check_numerics {
            let reference = ReferenceBackend
                .execute(&ReferenceBackend.prepare(prepared.plan_arc().clone())?, &inputs)?;
            for (got, want) in outcome.results.iter().zip(&reference.results) {
                numerics.push((
                    got.routine.to_string(),
                    NumericResult {
                        backend: got.provenance,
                        max_rel_err: max_rel_err(&got.output, &want.output),
                        outputs: got.output.len(),
                    },
                ));
            }
        }
        let cpu_time_s = self.cpu_baseline(spec);
        Ok(RunReport { sim, numerics, cpu_time_s, plan_cache: self.pipeline.cache().stats() })
    }

    /// Execute one routine numerically on random inputs; compare the
    /// executor's output (PJRT when artifacts exist) against the reference
    /// backend.
    pub fn run_numeric(
        &self,
        kind: crate::blas::RoutineKind,
        size: usize,
    ) -> Result<NumericResult> {
        let mut rng = Rng::new(0xA1EB1A5 ^ size as u64);
        let inputs: Vec<Vec<f32>> = kind
            .inputs()
            .iter()
            .map(|p| rng.normal_vec_f32(p.ty.elements(size)))
            .collect();
        let (out, backend) = self.executor.execute(kind.name(), size, &inputs)?;
        let reference = ReferenceBackend::run_kind(kind, size, &inputs)?;
        Ok(NumericResult {
            backend,
            max_rel_err: max_rel_err(&out, &reference),
            outputs: out.len(),
        })
    }

    /// Measure the multithreaded CPU baseline for the spec's routines
    /// through [`CpuBackend`] (executed sequentially, like a host would
    /// call BLAS). `None` when the spec cannot be lowered or executed.
    pub fn cpu_baseline(&self, spec: &Spec) -> Option<f64> {
        let plan = self.pipeline.lower(spec).ok()?;
        let backend = CpuBackend;
        let prepared = backend.prepare(plan).ok()?;
        // pre-generate inputs outside the timed region
        let inputs = ExecInputs::random_for(spec, 7);
        let mut samples = Vec::with_capacity(self.config.cpu_samples.max(1));
        for _ in 0..self.config.cpu_samples.max(1) {
            samples.push(backend.execute(&prepared, &inputs).ok()?.wall_s);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(samples[samples.len() / 2])
    }

    /// The paper's axpydot experiment: dataflow (single fused design) vs
    /// non-dataflow (axpy design, z through DDR, then dot design).
    pub fn run_axpydot(&self, n: usize, dataflow: bool) -> Result<SimReport> {
        use crate::blas::RoutineKind;
        if dataflow {
            let spec = Spec::axpydot_dataflow(n, 2.0);
            self.run_spec_sim_only(&spec)
        } else {
            // two independent designs executed back to back; z makes a
            // full DDR round trip between them.
            let axpy = self.run_spec_sim_only(&Spec::single(
                RoutineKind::Axpy,
                "axpy_stage",
                n,
                DataSource::Pl,
            ))?;
            let dot = self.run_spec_sim_only(&Spec::single(
                RoutineKind::Dot,
                "dot_stage",
                n,
                DataSource::Pl,
            ))?;
            let mut combined = axpy.clone();
            combined.makespan_s = axpy.makespan_s + dot.makespan_s;
            combined.device_bytes = axpy.device_bytes + dot.device_bytes;
            combined.interface_bytes = axpy.interface_bytes + dot.interface_bytes;
            combined.flops = axpy.flops + dot.flops;
            combined.kernels.extend(dot.kernels);
            Ok(combined)
        }
    }

    /// Simulation only (no numerics / CPU timing) — the benches' hot path.
    /// Warm plans skip codegen + placement + routing via the plan cache.
    pub fn run_spec_sim_only(&self, spec: &Spec) -> Result<SimReport> {
        let plan = self.pipeline.lower(spec)?;
        let backend = SimBackend::timing_only();
        let prepared = backend.prepare(plan)?;
        let outcome = backend.execute(&prepared, &ExecInputs::default())?;
        outcome
            .sim
            .ok_or_else(|| Error::Runtime("sim backend produced no timing".into()))
    }

    /// Simulate a spec and return the execution trace alongside the report
    /// (Chrome-trace / Gantt export).
    pub fn run_spec_traced(&self, spec: &Spec) -> Result<(SimReport, crate::sim::trace::Trace)> {
        let plan = self.pipeline.lower(spec)?;
        let backend = SimBackend::timing_only();
        let prepared = backend.prepare(plan)?;
        backend.execute_traced(&prepared)
    }
}

/// Deterministic per-spec numeric seed (stable across runs of the same
/// spec so cached plans see identical inputs).
fn numeric_seed(spec: &Spec) -> u64 {
    let size_mix = spec
        .routines
        .iter()
        .fold(0u64, |acc, r| acc.rotate_left(7) ^ r.size as u64);
    0xA1EB1A5 ^ size_mix
}

/// max |a - b| / (1 + |b|) over paired outputs.
fn max_rel_err(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y).abs() / (1.0 + y.abs())) as f64)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;

    fn system() -> AieBlas {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        AieBlas::new(Config { artifacts_dir: dir, cpu_samples: 2, ..Default::default() }).unwrap()
    }

    #[test]
    fn run_spec_end_to_end() {
        let sys = system();
        let spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        let rep = sys.run_spec(&spec).unwrap();
        assert!(rep.sim.makespan_s > 0.0);
        assert_eq!(rep.numerics.len(), 1);
        let (_, num) = &rep.numerics[0];
        assert!(num.max_rel_err < 1e-2, "err {}", num.max_rel_err);
        assert!(rep.cpu_time_s.unwrap() > 0.0);
        assert!(rep.summary().contains("AIE (simulated)"));
        assert!(rep.summary().contains("plan cache"));
        assert!(rep.summary().contains("eviction(s)"), "{}", rep.summary());
    }

    #[test]
    fn serve_front_end_shares_the_plan_cache() {
        let sys = system();
        let spec = Spec::single(RoutineKind::Axpy, "a", 2048, DataSource::Pl);
        let inputs = ExecInputs::random_for(&spec, 9);
        let srv = sys.serve(Arc::new(ReferenceBackend), Default::default());
        let served = srv.submit(&spec, inputs.clone()).wait().unwrap();
        drop(srv);
        // the server lowered through the system's pipeline...
        assert_eq!(sys.plan_cache().stats().misses, 1);
        let plan = sys.lower(&spec).unwrap();
        assert_eq!(sys.plan_cache().stats().hits, 1, "same plan, now warm");
        // ...and produced the same numerics as a direct execution.
        let direct = ReferenceBackend
            .execute(&ReferenceBackend.prepare(plan).unwrap(), &inputs)
            .unwrap();
        assert_eq!(served.results[0].output, direct.results[0].output);
    }

    #[test]
    fn repeated_run_spec_hits_plan_cache() {
        let sys = system();
        let spec = Spec::single(RoutineKind::Dot, "d", 8192, DataSource::Pl);
        let first = sys.run_spec(&spec).unwrap();
        assert_eq!(first.plan_cache.misses, 1);
        let second = sys.run_spec(&spec).unwrap();
        assert!(second.plan_cache.hits > 0, "warm run must hit the plan cache");
        assert_eq!(second.plan_cache.misses, 1, "warm run must not re-lower");
    }

    #[test]
    fn axpydot_dataflow_halves_runtime() {
        // Fig. 3 claim C2: "the dataflow approach doubled the performance".
        let sys = system();
        for n in [1usize << 16, 1 << 20] {
            let df = sys.run_axpydot(n, true).unwrap();
            let nodf = sys.run_axpydot(n, false).unwrap();
            let speedup = nodf.makespan_s / df.makespan_s;
            assert!(
                (1.5..3.5).contains(&speedup),
                "n={n}: DF speedup {speedup:.2} outside the paper's ~2× band"
            );
        }
    }

    #[test]
    fn cpu_beats_simulated_aie_at_large_sizes() {
        // Fig. 3 claim C3: CPU (OpenBLAS-class) is faster, up to ~10×.
        // Uses the paper-testbed roofline model (the measured series is
        // only meaningful in release builds; unit tests run unoptimized).
        let sys = system();
        let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::Pl);
        let rep = sys.run_spec(&spec).unwrap();
        let cpu = super::experiments::cpu_time_model(RoutineKind::Axpy, 1 << 20);
        let ratio = rep.sim.makespan_s / cpu;
        assert!(
            (1.0..40.0).contains(&ratio),
            "CPU advantage {ratio:.1}x outside the paper's up-to-10x band \
             (aie {} s, cpu model {cpu} s)",
            rep.sim.makespan_s
        );
    }

    #[test]
    fn composed_spec_runs() {
        let sys = system();
        let rep = sys.run_spec(&Spec::axpydot_dataflow(65536, 2.0)).unwrap();
        assert_eq!(rep.sim.kernels.len(), 2);
    }

    #[test]
    fn config_tuning_flows_into_pipeline_and_report() {
        use crate::tune::TuneMode;
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let sys = AieBlas::new(Config {
            artifacts_dir: dir,
            cpu_samples: 1,
            check_numerics: false,
            tune: TuneConfig { mode: TuneMode::Analytic, max_candidates: 4, shortlist: 2 },
            ..Default::default()
        })
        .unwrap();
        assert_eq!(sys.pipeline().tuning().mode, TuneMode::Analytic);
        // naive PL movers: the analytic tier finds the burst win, so the
        // cold lowering counts as tuned and the summary surfaces it.
        let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl);
        let rep = sys.run_spec(&spec).unwrap();
        assert_eq!(sys.plan_cache().stats().tuned, 1);
        assert!(rep.summary().contains("autotuner:"), "{}", rep.summary());
    }

    #[test]
    fn cpu_backend_covers_all_kinds() {
        let mut rng = Rng::new(3);
        for kind in RoutineKind::ALL {
            let n = 64;
            let inputs: Vec<Vec<f32>> = kind
                .inputs()
                .iter()
                .map(|p| rng.normal_vec_f32(p.ty.elements(n)))
                .collect();
            let out = CpuBackend::run_kind(kind, n, &inputs);
            assert!(!out.is_empty(), "{kind}");
            assert!(out.iter().all(|v| v.is_finite()), "{kind}");
        }
    }
}
