//! L3 coordinator: the end-to-end AIEBLAS driver.
//!
//! Ties the full pipeline together: spec → validation → graph build →
//! placement → routing → (a) cycle-approximate simulation for *timing*
//! and (b) PJRT execution of the AOT artifacts for *numerics*, plus the
//! measured CPU baseline — the three series of the paper's Fig. 3.

pub mod experiments;

use std::path::PathBuf;
use std::time::Instant;

use crate::arch::ArchConfig;
use crate::blas::RoutineKind;
use crate::graph::build::build_graph;
use crate::graph::place::place;
use crate::graph::route::{check_routing, route};
use crate::runtime::{Backend, NumericExecutor};
use crate::sim::{simulate, SimReport};
use crate::spec::{DataSource, Spec};
use crate::util::rng::Rng;
use crate::Result;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Directory holding `manifest.json` + HLO artifacts.
    pub artifacts_dir: PathBuf,
    /// Target architecture (defaults to the VCK5000).
    pub arch: ArchConfig,
    /// Samples for CPU baseline timing.
    pub cpu_samples: usize,
    /// Validate numerics against the reference implementation.
    pub check_numerics: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: PathBuf::from("artifacts"),
            arch: ArchConfig::vck5000(),
            cpu_samples: 5,
            check_numerics: true,
        }
    }
}

/// Numeric-execution outcome.
#[derive(Debug, Clone)]
pub struct NumericResult {
    pub backend: Backend,
    /// max |pjrt - reference| / (1 + |reference|) over all outputs.
    pub max_rel_err: f64,
    pub outputs: usize,
}

/// The result of running one spec end to end.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Simulated device timing.
    pub sim: SimReport,
    /// Numeric execution of each routine in the spec (when enabled).
    pub numerics: Vec<(String, NumericResult)>,
    /// Measured wallclock of the CPU baseline for the same math, seconds.
    pub cpu_time_s: Option<f64>,
}

impl RunReport {
    pub fn summary(&self) -> String {
        let mut s = format!("AIE (simulated): {}", self.sim.summary());
        if let Some(cpu) = self.cpu_time_s {
            s.push_str(&format!(
                "\nCPU baseline: {:.3} ms ({:.2}× vs AIE)",
                cpu * 1e3,
                self.sim.makespan_s / cpu
            ));
        }
        for (name, n) in &self.numerics {
            s.push_str(&format!(
                "\nnumerics[{name}]: {:?}, max rel err {:.2e} over {} outputs",
                n.backend, n.max_rel_err, n.outputs
            ));
        }
        s
    }
}

/// The AIEBLAS system handle.
pub struct AieBlas {
    pub config: Config,
    executor: NumericExecutor,
}

impl AieBlas {
    pub fn new(config: Config) -> Result<AieBlas> {
        let executor = NumericExecutor::new(&config.artifacts_dir)?;
        Ok(AieBlas { config, executor })
    }

    pub fn executor(&self) -> &NumericExecutor {
        &self.executor
    }

    /// Architecture for a spec: the spec's platform wins; the config arch
    /// backs the convenience constructors (platform "vck5000" = default).
    fn arch_for_spec(&self, spec: &Spec) -> Result<ArchConfig> {
        if spec.platform.is_empty() || spec.platform == "vck5000" {
            Ok(self.config.arch.clone())
        } else {
            crate::spec::arch_for(&spec.platform)
        }
    }

    /// Run a full spec: simulate timing + execute numerics + CPU baseline.
    pub fn run_spec(&self, spec: &Spec) -> Result<RunReport> {
        crate::spec::validate(spec)?;
        let arch = self.arch_for_spec(spec)?;
        let built = build_graph(spec)?;
        let placement = place(&built.graph, &arch)?;
        let routing = route(&built.graph, &placement, &arch)?;
        check_routing(&built.graph, &routing)?;
        let sim = simulate(&built.graph, &placement, &routing, &arch)?;

        let mut numerics = Vec::new();
        if self.config.check_numerics {
            for r in &spec.routines {
                numerics.push((r.name.clone(), self.run_numeric(r.kind, r.size)?));
            }
        }
        let cpu_time_s = self.cpu_baseline(spec);
        Ok(RunReport { sim, numerics, cpu_time_s })
    }

    /// Execute one routine numerically on random inputs; compare PJRT
    /// output against the Rust reference.
    pub fn run_numeric(&self, kind: RoutineKind, size: usize) -> Result<NumericResult> {
        let mut rng = Rng::new(0xA1EB1A5 ^ size as u64);
        let inputs: Vec<Vec<f32>> = kind
            .inputs()
            .iter()
            .map(|p| rng.normal_vec_f32(p.ty.elements(size)))
            .collect();
        let (out, backend) = self.executor.execute(kind.name(), size, &inputs)?;
        let reference = crate::runtime::reference_execute(kind.name(), size, &inputs)?;
        let mut max_rel = 0.0f64;
        for (a, b) in out.iter().zip(&reference) {
            let rel = ((a - b).abs() / (1.0 + b.abs())) as f64;
            max_rel = max_rel.max(rel);
        }
        Ok(NumericResult { backend, max_rel_err: max_rel, outputs: out.len() })
    }

    /// Measure the multithreaded CPU baseline for the spec's routines
    /// (executed sequentially, like a host would call BLAS). `None` when
    /// the spec contains routines without a CPU kernel.
    pub fn cpu_baseline(&self, spec: &Spec) -> Option<f64> {
        let mut rng = Rng::new(7);
        // pre-generate inputs outside the timed region
        let mut problems = Vec::new();
        for r in &spec.routines {
            let inputs: Vec<Vec<f32>> = r
                .kind
                .inputs()
                .iter()
                .map(|p| rng.normal_vec_f32(p.ty.elements(r.size)))
                .collect();
            problems.push((r.kind, r.size, inputs));
        }
        let mut samples = Vec::with_capacity(self.config.cpu_samples);
        for _ in 0..self.config.cpu_samples.max(1) {
            let t0 = Instant::now();
            for (kind, size, inputs) in &problems {
                std::hint::black_box(cpu_run(*kind, *size, inputs));
            }
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(samples[samples.len() / 2])
    }

    /// The paper's axpydot experiment: dataflow (single fused design) vs
    /// non-dataflow (axpy design, z through DDR, then dot design).
    pub fn run_axpydot(&self, n: usize, dataflow: bool) -> Result<SimReport> {
        if dataflow {
            let spec = Spec::axpydot_dataflow(n, 2.0);
            Ok(self.run_spec_sim_only(&spec)?)
        } else {
            // two independent designs executed back to back; z makes a
            // full DDR round trip between them.
            let axpy = self.run_spec_sim_only(&Spec::single(
                RoutineKind::Axpy,
                "axpy_stage",
                n,
                DataSource::Pl,
            ))?;
            let dot = self.run_spec_sim_only(&Spec::single(
                RoutineKind::Dot,
                "dot_stage",
                n,
                DataSource::Pl,
            ))?;
            let mut combined = axpy.clone();
            combined.makespan_s = axpy.makespan_s + dot.makespan_s;
            combined.device_bytes = axpy.device_bytes + dot.device_bytes;
            combined.interface_bytes = axpy.interface_bytes + dot.interface_bytes;
            combined.flops = axpy.flops + dot.flops;
            combined.kernels.extend(dot.kernels);
            Ok(combined)
        }
    }

    /// Simulation only (no numerics / CPU timing) — the benches' hot path.
    pub fn run_spec_sim_only(&self, spec: &Spec) -> Result<SimReport> {
        crate::spec::validate(spec)?;
        let arch = self.arch_for_spec(spec)?;
        let built = build_graph(spec)?;
        let placement = place(&built.graph, &arch)?;
        let routing = route(&built.graph, &placement, &arch)?;
        simulate(&built.graph, &placement, &routing, &arch)
    }

    /// Simulate a spec and return the execution trace alongside the report
    /// (Chrome-trace / Gantt export).
    pub fn run_spec_traced(&self, spec: &Spec) -> Result<(SimReport, crate::sim::trace::Trace)> {
        crate::spec::validate(spec)?;
        let arch = self.arch_for_spec(spec)?;
        let built = build_graph(spec)?;
        let placement = place(&built.graph, &arch)?;
        let routing = route(&built.graph, &placement, &arch)?;
        crate::sim::simulate_traced(&built.graph, &placement, &routing, &arch)
    }
}

/// Run a routine on the CPU baseline (used for Fig. 3's CPU series).
pub fn cpu_run(kind: RoutineKind, size: usize, inputs: &[Vec<f32>]) -> Vec<f32> {
    use crate::blas::cpu;
    let n = size;
    match kind {
        RoutineKind::Axpy => {
            let mut z = vec![0.0; n];
            cpu::axpy(inputs[0][0], &inputs[1], &inputs[2], &mut z);
            z
        }
        RoutineKind::Scal => {
            let mut z = vec![0.0; n];
            cpu::scal(inputs[0][0], &inputs[1], &mut z);
            z
        }
        RoutineKind::Axpby => {
            let mut z = vec![0.0; n];
            cpu::axpby(inputs[0][0], &inputs[2], inputs[1][0], &inputs[3], &mut z);
            z
        }
        RoutineKind::Rot => {
            let mut xo = vec![0.0; n];
            let mut yo = vec![0.0; n];
            cpu::rot(inputs[0][0], inputs[1][0], &inputs[2], &inputs[3], &mut xo, &mut yo);
            xo.extend(yo);
            xo
        }
        RoutineKind::Ger => {
            let mut out = vec![0.0; n * n];
            cpu::ger(inputs[0][0], &inputs[1], &inputs[2], &inputs[3], n, n, &mut out);
            out
        }
        RoutineKind::Copy => inputs[0].clone(),
        RoutineKind::Dot => vec![cpu::dot(&inputs[0], &inputs[1])],
        RoutineKind::Nrm2 => vec![cpu::nrm2(&inputs[0])],
        RoutineKind::Asum => vec![cpu::asum(&inputs[0])],
        RoutineKind::Iamax => vec![cpu::iamax(&inputs[0]) as f32],
        RoutineKind::Gemv => {
            let mut out = vec![0.0; n];
            cpu::gemv(inputs[0][0], &inputs[1], n, n, &inputs[2], inputs[3][0], &inputs[4], &mut out);
            out
        }
        RoutineKind::Gemm => {
            let mut out = vec![0.0; n * n];
            cpu::gemm(inputs[0][0], &inputs[1], &inputs[2], n, n, n, inputs[3][0], &inputs[4], &mut out);
            out
        }
        RoutineKind::Axpydot => vec![cpu::axpydot(inputs[0][0], &inputs[1], &inputs[2], &inputs[3])],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn system() -> AieBlas {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        AieBlas::new(Config { artifacts_dir: dir, cpu_samples: 2, ..Default::default() }).unwrap()
    }

    #[test]
    fn run_spec_end_to_end() {
        let sys = system();
        let spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        let rep = sys.run_spec(&spec).unwrap();
        assert!(rep.sim.makespan_s > 0.0);
        assert_eq!(rep.numerics.len(), 1);
        let (_, num) = &rep.numerics[0];
        assert!(num.max_rel_err < 1e-2, "err {}", num.max_rel_err);
        assert!(rep.cpu_time_s.unwrap() > 0.0);
        assert!(rep.summary().contains("AIE (simulated)"));
    }

    #[test]
    fn axpydot_dataflow_halves_runtime() {
        // Fig. 3 claim C2: "the dataflow approach doubled the performance".
        let sys = system();
        for n in [1usize << 16, 1 << 20] {
            let df = sys.run_axpydot(n, true).unwrap();
            let nodf = sys.run_axpydot(n, false).unwrap();
            let speedup = nodf.makespan_s / df.makespan_s;
            assert!(
                (1.5..3.5).contains(&speedup),
                "n={n}: DF speedup {speedup:.2} outside the paper's ~2× band"
            );
        }
    }

    #[test]
    fn cpu_beats_simulated_aie_at_large_sizes() {
        // Fig. 3 claim C3: CPU (OpenBLAS-class) is faster, up to ~10×.
        // Uses the paper-testbed roofline model (the measured series is
        // only meaningful in release builds; unit tests run unoptimized).
        let sys = system();
        let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::Pl);
        let rep = sys.run_spec(&spec).unwrap();
        let cpu = super::experiments::cpu_time_model(RoutineKind::Axpy, 1 << 20);
        let ratio = rep.sim.makespan_s / cpu;
        assert!(
            (1.0..40.0).contains(&ratio),
            "CPU advantage {ratio:.1}x outside the paper's up-to-10x band \
             (aie {} s, cpu model {cpu} s)",
            rep.sim.makespan_s
        );
    }

    #[test]
    fn composed_spec_runs() {
        let sys = system();
        let rep = sys.run_spec(&Spec::axpydot_dataflow(65536, 2.0)).unwrap();
        assert_eq!(rep.sim.kernels.len(), 2);
    }

    #[test]
    fn cpu_run_covers_all_kinds() {
        let mut rng = Rng::new(3);
        for kind in RoutineKind::ALL {
            let n = 64;
            let inputs: Vec<Vec<f32>> = kind
                .inputs()
                .iter()
                .map(|p| rng.normal_vec_f32(p.ty.elements(n)))
                .collect();
            let out = cpu_run(kind, n, &inputs);
            assert!(!out.is_empty(), "{kind}");
            assert!(out.iter().all(|v| v.is_finite()), "{kind}");
        }
    }
}
