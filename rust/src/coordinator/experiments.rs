//! The Fig. 3 experiment harness and §V ablations.
//!
//! One function per paper panel, each returning a [`Table`] whose rows are
//! the series the figure plots (input size × variant → execution time).
//! Shared by `cargo bench` targets, the `examples/e2e_fig3.rs` driver and
//! the `aieblas fig3` CLI subcommand.

use super::AieBlas;
use crate::blas::RoutineKind;
use crate::runtime::CpuBackend;
use crate::spec::{DataSource, Spec};
use crate::util::rng::Rng;
use crate::util::table::{fmt_time, Table};
use crate::Result;

/// Fig. 3 vector sizes (axpy / axpydot panels).
pub const VEC_SIZES: [usize; 5] = [4096, 16384, 65536, 262144, 1048576];
/// Fig. 3 matrix sizes (gemv panel).
pub const MAT_SIZES: [usize; 4] = [64, 128, 256, 512];

/// Analytic CPU time from the paper-testbed roofline model (see
/// [`crate::arch::HostConfig::blas_call_time`]): the Fig. 3 "CPU" series
/// anchored to the published 2×10-core Xeon, independent of the machine
/// running the benches.
pub fn cpu_time_model(kind: RoutineKind, size: usize) -> f64 {
    let host = crate::arch::HostConfig::default();
    host.blas_call_time(kind.flops(size), kind.offchip_bytes(size))
}

/// Median *measured* CPU time for one routine at one size (seconds) on the
/// local machine's threaded Rust BLAS (meaningful in release builds).
pub fn cpu_time(kind: RoutineKind, size: usize, samples: usize) -> f64 {
    let mut rng = Rng::new(size as u64 ^ 0xC0FFEE);
    let inputs: Vec<Vec<f32>> = kind
        .inputs()
        .iter()
        .map(|p| rng.normal_vec_f32(p.ty.elements(size)))
        .collect();
    let mut ts: Vec<f64> = (0..samples.max(1))
        .map(|_| {
            let t0 = std::time::Instant::now();
            std::hint::black_box(CpuBackend::run_kind(kind, size, &inputs));
            t0.elapsed().as_secs_f64()
        })
        .collect();
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts[ts.len() / 2]
}

/// One row of a Fig. 3 panel.
#[derive(Debug, Clone)]
pub struct PanelRow {
    pub size: usize,
    pub variant: &'static str,
    pub seconds: f64,
}

/// Fig. 3 panel for a single routine: AIE w/ PL movers vs AIE no-PL vs CPU.
pub fn single_routine_panel(
    sys: &AieBlas,
    kind: RoutineKind,
    sizes: &[usize],
) -> Result<Vec<PanelRow>> {
    let mut rows = Vec::new();
    for &n in sizes {
        let pl = sys.run_spec_sim_only(&Spec::single(kind, "k", n, DataSource::Pl))?;
        rows.push(PanelRow { size: n, variant: "aie (PL)", seconds: pl.makespan_s });
        let onchip = sys.run_spec_sim_only(&Spec::single(kind, "k", n, DataSource::OnChip))?;
        rows.push(PanelRow { size: n, variant: "aie (no PL)", seconds: onchip.makespan_s });
        rows.push(PanelRow { size: n, variant: "cpu", seconds: cpu_time_model(kind, n) });
        rows.push(PanelRow { size: n, variant: "cpu (measured)", seconds: cpu_time(kind, n, 5) });
    }
    Ok(rows)
}

/// Fig. 3 axpydot panel: dataflow vs non-dataflow vs CPU.
pub fn axpydot_panel(sys: &AieBlas, sizes: &[usize]) -> Result<Vec<PanelRow>> {
    let mut rows = Vec::new();
    for &n in sizes {
        let df = sys.run_axpydot(n, true)?;
        rows.push(PanelRow { size: n, variant: "aie (w/ DF)", seconds: df.makespan_s });
        let nodf = sys.run_axpydot(n, false)?;
        rows.push(PanelRow { size: n, variant: "aie (w/o DF)", seconds: nodf.makespan_s });
        rows.push(PanelRow {
            size: n,
            variant: "cpu",
            seconds: cpu_time_model(RoutineKind::Axpydot, n),
        });
        rows.push(PanelRow {
            size: n,
            variant: "cpu (measured)",
            seconds: cpu_time(RoutineKind::Axpydot, n, 5),
        });
    }
    Ok(rows)
}

/// Render panel rows as the table the paper's figure plots.
pub fn panel_table(title: &str, rows: &[PanelRow]) -> Table {
    let mut t = Table::new(vec!["panel", "n", "variant", "time"]);
    for r in rows {
        t.row(vec![
            title.to_string(),
            r.size.to_string(),
            r.variant.to_string(),
            fmt_time(r.seconds),
        ]);
    }
    t
}

/// Seconds for (size, variant) in a panel (test helper).
pub fn lookup(rows: &[PanelRow], size: usize, variant: &str) -> Option<f64> {
    rows.iter()
        .find(|r| r.size == size && r.variant == variant)
        .map(|r| r.seconds)
}

// ---------------------------------------------------------------------------
// §V ablations
// ---------------------------------------------------------------------------

/// A1: burst-optimized vs naive movers for one routine across sizes.
pub fn ablation_burst(sys: &AieBlas, kind: RoutineKind, sizes: &[usize]) -> Result<Table> {
    let mut t = Table::new(vec!["n", "naive", "burst", "speedup"]);
    for &n in sizes {
        let mut naive = Spec::single(kind, "k", n, DataSource::Pl);
        naive.routines[0].burst = false;
        let mut burst = naive.clone();
        burst.routines[0].burst = true;
        let tn = sys.run_spec_sim_only(&naive)?.makespan_s;
        let tb = sys.run_spec_sim_only(&burst)?.makespan_s;
        t.row(vec![
            n.to_string(),
            fmt_time(tn),
            fmt_time(tb),
            format!("{:.2}x", tn / tb),
        ]);
    }
    Ok(t)
}

/// A3 (window): window-size sweep for one routine at fixed n.
pub fn ablation_window(sys: &AieBlas, kind: RoutineKind, n: usize, windows: &[usize]) -> Result<Table> {
    let mut t = Table::new(vec!["window", "time", "windows/edge"]);
    for &w in windows {
        let mut spec = Spec::single(kind, "k", n, DataSource::Pl);
        spec.routines[0].window = Some(w);
        let r = sys.run_spec_sim_only(&spec)?;
        t.row(vec![
            w.to_string(),
            fmt_time(r.makespan_s),
            (n / spec.routines[0].effective_window()).to_string(),
        ]);
    }
    Ok(t)
}

/// A3 (vector width): vector-width sweep at fixed n.
pub fn ablation_vector_width(sys: &AieBlas, kind: RoutineKind, n: usize) -> Result<Table> {
    let mut t = Table::new(vec!["vector_bits", "time"]);
    for bits in [64usize, 128, 256, 512] {
        let mut spec = Spec::single(kind, "k", n, DataSource::OnChip);
        spec.routines[0].vector_bits = bits;
        let r = sys.run_spec_sim_only(&spec)?;
        t.row(vec![bits.to_string(), fmt_time(r.makespan_s)]);
    }
    Ok(t)
}

/// A2: multi-AIE split — the first-class `split` spec field partitions the
/// routine across k kernels, each with its own PL ports (the paper's
/// "exploit the several AIE-PL interfaces" future work), with an on-chip
/// combiner for reductions.
pub fn ablation_multi_port(sys: &AieBlas, n: usize, splits: &[usize]) -> Result<Table> {
    let mut t = Table::new(vec!["kernels", "time", "speedup_vs_1"]);
    let mut base = None;
    for &k in splits {
        let mut spec = Spec::single(RoutineKind::Axpy, "k", n, DataSource::Pl);
        spec.routines[0].split = k;
        let r = sys.run_spec_sim_only(&spec)?;
        let b = *base.get_or_insert(r.makespan_s);
        t.row(vec![
            k.to_string(),
            fmt_time(r.makespan_s),
            format!("{:.2}x", b / r.makespan_s),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Config;

    fn system() -> AieBlas {
        AieBlas::new(Config {
            artifacts_dir: "/nonexistent".into(),
            cpu_samples: 1,
            check_numerics: false,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn axpy_panel_has_paper_shape() {
        let sys = system();
        let sizes = [1usize << 14, 1 << 18];
        let rows = single_routine_panel(&sys, RoutineKind::Axpy, &sizes).unwrap();
        assert_eq!(rows.len(), sizes.len() * 4);
        for &n in &sizes {
            let pl = lookup(&rows, n, "aie (PL)").unwrap();
            let nopl = lookup(&rows, n, "aie (no PL)").unwrap();
            let cpu = lookup(&rows, n, "cpu").unwrap();
            assert!(nopl < pl, "n={n}: no-PL should beat PL");
            assert!(cpu < pl, "n={n}: cpu should beat AIE-PL");
            // paper: "up to 10x" — the gap stays within an order of
            // magnitude band, not orders beyond it.
            assert!(pl / cpu < 40.0, "n={n}: CPU advantage {:.1}x implausibly large", pl / cpu);
        }
    }

    #[test]
    fn axpydot_df_beats_nodf_about_2x() {
        let sys = system();
        let rows = axpydot_panel(&sys, &[1 << 18]).unwrap();
        let df = lookup(&rows, 1 << 18, "aie (w/ DF)").unwrap();
        let nodf = lookup(&rows, 1 << 18, "aie (w/o DF)").unwrap();
        let speedup = nodf / df;
        assert!((1.5..3.5).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn burst_ablation_speedup_above_one() {
        let sys = system();
        let t = ablation_burst(&sys, RoutineKind::Axpy, &[1 << 16]).unwrap();
        let rendered = t.to_csv();
        let speedup: f64 = rendered
            .lines()
            .nth(1)
            .unwrap()
            .split(',')
            .nth(3)
            .unwrap()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(speedup > 1.0, "{rendered}");
    }

    #[test]
    fn multi_port_scales() {
        let sys = system();
        let t = ablation_multi_port(&sys, 1 << 20, &[1, 4]).unwrap();
        let csv = t.to_csv();
        let last = csv.lines().last().unwrap();
        let speedup: f64 = last.split(',').nth(2).unwrap().trim_end_matches('x').parse().unwrap();
        assert!(speedup > 1.5, "4-way split should speed up: {csv}");
    }

    #[test]
    fn panel_table_renders() {
        let rows = vec![PanelRow { size: 4096, variant: "cpu", seconds: 1e-4 }];
        let t = panel_table("axpy", &rows);
        assert!(t.render().contains("axpy"));
    }
}
