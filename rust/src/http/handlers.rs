//! The `/v1` route handlers: HTTP frames in, versioned `crate::api` JSON
//! out. Handlers never touch sockets — they map a parsed request to
//! `(status, Json)`, which keeps every route unit-testable without a
//! listener and guarantees the error invariant the tests pin down: every
//! failure path produces a structured [`ApiError`] body.
//!
//! Cross-shard requests route through [`route_remote`]: the owner's
//! breaker gates the proxy hop, and both an open breaker and a failed
//! hop fall back to serving the request **locally** from the shared
//! plan store (DESIGN.md §14). The store's write-through makes the
//! failover answer bit-identical to the owner's — a dead shard costs
//! duplicate lowering work, never availability or correctness.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::api::{self, ApiError, ErrorCode, RunRequest};
use crate::pipeline::PlanKey;
use crate::runtime::ExecInputs;
use crate::serve::{RoutineServer, SubmitOutcome, Ticket};
use crate::util::json::{obj, Json};

use super::framing::HttpRequest;
use super::router::{shards_json, ShardRouter, FORWARDED_HEADER};
use super::server::HttpConfig;

/// Fleet failover accounting, surfaced on `/v1/statsz` (overlaid into
/// `ServeMetrics` — the serving core never sees the HTTP fleet).
#[derive(Debug, Default)]
pub struct FleetCounters {
    /// Requests owned by another shard but served locally because the
    /// owner was unavailable (breaker open or the proxy hop failed).
    pub failover_served: AtomicU64,
    /// The subset that had to lower locally (plan not already memory-
    /// warm here) — the duplicate-work cost of failover.
    pub failover_lowerings: AtomicU64,
}

/// Everything a handler needs, shared across connection threads.
pub struct Ctx {
    pub server: Arc<RoutineServer>,
    pub router: Option<ShardRouter>,
    pub cfg: HttpConfig,
    /// Set by `/v1/drain` (and server shutdown) so `/v1/healthz` reports
    /// the instance as draining before the balancer's next probe.
    pub draining: AtomicBool,
    pub fleet: FleetCounters,
}

impl Ctx {
    pub fn new(server: Arc<RoutineServer>, router: Option<ShardRouter>, cfg: HttpConfig) -> Ctx {
        Ctx {
            server,
            router,
            cfg,
            draining: AtomicBool::new(false),
            fleet: FleetCounters::default(),
        }
    }
}

fn err(e: ApiError) -> (u16, Json) {
    (e.http_status(), e.to_json())
}

/// Dispatch one framed request. Total: every input maps to a response.
pub fn handle(ctx: &Ctx, req: &HttpRequest) -> (u16, Json) {
    let forwarded = req.header(FORWARDED_HEADER).is_some();
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/v1/healthz") => healthz(ctx),
        ("GET", "/v1/statsz") => statsz(ctx),
        ("POST", "/v1/run") => match parse_body(&req.body) {
            Err(e) => err(e),
            Ok(json) => run_one(ctx, &json, forwarded),
        },
        ("POST", "/v1/batch") => match parse_body(&req.body) {
            Err(e) => err(e),
            Ok(json) => run_batch(ctx, &json, forwarded),
        },
        ("POST", "/v1/drain") => drain(ctx, &req.body),
        // known routes with the wrong method get 405, not 404, so a
        // misdirected client learns which mistake it made.
        (_, "/v1/healthz" | "/v1/statsz" | "/v1/run" | "/v1/batch" | "/v1/drain") => err(
            ApiError::new(
                ErrorCode::MethodNotAllowed,
                format!("{} not allowed on {}", req.method, req.path),
            ),
        ),
        _ => err(ApiError::new(ErrorCode::NotFound, format!("no route {}", req.path))),
    }
}

fn parse_body(body: &[u8]) -> Result<Json, ApiError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ApiError::new(ErrorCode::BadRequest, "request body is not utf-8"))?;
    Json::parse(text).map_err(|e| ApiError::new(ErrorCode::BadRequest, e.to_string()))
}

fn healthz(ctx: &Ctx) -> (u16, Json) {
    let mut pairs = vec![
        ("v", (api::API_VERSION as f64).into()),
        ("status", "ok".into()),
        ("draining", ctx.draining.load(Ordering::SeqCst).into()),
        ("shards", shards_json(ctx.router.as_ref())),
    ];
    if let Some(faults) = ctx.cfg.faults.as_ref().filter(|f| f.is_active()) {
        pairs.push(("faults", faults.to_json()));
    }
    (200, obj(pairs))
}

/// `/v1/statsz`: the serving report with the HTTP fleet's failover and
/// breaker counters overlaid (the serving core's `build_report` leaves
/// them zero — they are front-door facts).
fn statsz(ctx: &Ctx) -> (u16, Json) {
    let mut report = ctx.server.report();
    report.metrics.failover_served = ctx.fleet.failover_served.load(Ordering::Relaxed);
    report.metrics.failover_lowerings = ctx.fleet.failover_lowerings.load(Ordering::Relaxed);
    if let Some(router) = &ctx.router {
        let (trips, closes) = router.breaker_counters();
        report.metrics.breaker_trips = trips;
        report.metrics.breaker_closes = closes;
    }
    (200, api::report_json(&report))
}

/// `/v1/run`: parse, route to the owning shard, execute locally or relay
/// the owner's response verbatim.
fn run_one(ctx: &Ctx, body: &Json, forwarded: bool) -> (u16, Json) {
    let req = match RunRequest::from_json(body) {
        Ok(r) => r,
        Err(e) => return err(e),
    };
    let key = PlanKey::of(&req.spec);
    if !forwarded {
        if let Some(router) = &ctx.router {
            let shard = router.shard_of(&key);
            if shard != router.self_index() {
                return route_remote(ctx, router, shard, body, &req, &key);
            }
        }
    }
    serve_local(ctx, &req)
}

/// Submit + wait on this process — the terminal step of both the owner
/// path and the failover path.
fn serve_local(ctx: &Ctx, req: &RunRequest) -> (u16, Json) {
    let ticket = match submit(ctx, req) {
        Ok(t) => t,
        Err(e) => return err(e),
    };
    finish(ctx, req, ticket)
}

fn submit(ctx: &Ctx, req: &RunRequest) -> Result<Ticket, ApiError> {
    let inputs = ExecInputs::random_for(&req.spec, req.seed);
    match ctx.server.try_submit(&req.spec, inputs, req.opts()) {
        SubmitOutcome::Accepted(t) => Ok(t),
        SubmitOutcome::Shed(reason) => Err(ApiError::from_shed(reason)),
    }
}

fn finish(ctx: &Ctx, req: &RunRequest, ticket: Ticket) -> (u16, Json) {
    match ticket.wait_timeout(ctx.cfg.request_timeout) {
        Ok(outcome) => {
            let cache = ctx.server.pipeline().cache().stats();
            (200, api::run_response(req, &outcome, &cache))
        }
        Err(e) => err(ApiError::from_error(&e)),
    }
}

/// Route a request owned by another shard: proxy when the owner's
/// breaker admits it, otherwise (or when the hop fails at the
/// transport layer) serve locally via failover. The classified
/// transport code is only logged — the caller sees a successful
/// response either way, which is the §14 availability contract.
fn route_remote(
    ctx: &Ctx,
    router: &ShardRouter,
    shard: usize,
    body: &Json,
    req: &RunRequest,
    key: &PlanKey,
) -> (u16, Json) {
    if router.peer_available(shard) {
        let bytes = body.to_compact().into_bytes();
        match router.forward(shard, "/v1/run", &bytes) {
            Ok(resp) => {
                return match std::str::from_utf8(&resp.body)
                    .ok()
                    .and_then(|t| Json::parse(t).ok())
                {
                    Some(json) => (resp.status, json),
                    // The peer answered garbage: it is alive but broken,
                    // so failover would mask a real bug. Name it.
                    None => err(ApiError::new(
                        ErrorCode::Upstream,
                        format!("shard {shard} returned an unparseable body"),
                    )),
                };
            }
            Err(e) => {
                crate::log_warn!(
                    "shard {shard} unreachable ({}: {e}); serving locally via failover",
                    e.code().name()
                );
            }
        }
    }
    failover_local(ctx, req, key, shard)
}

/// Serve another shard's key here. Counts the request, and counts a
/// lowering when the plan is not already memory-warm locally (it will
/// be found disk-warm or cold-lowered through the shared store — both
/// produce the bit-identical plan the owner would have served).
fn failover_local(ctx: &Ctx, req: &RunRequest, key: &PlanKey, _shard: usize) -> (u16, Json) {
    ctx.fleet.failover_served.fetch_add(1, Ordering::Relaxed);
    if !ctx.server.pipeline().cache().contains(key) {
        ctx.fleet.failover_lowerings.fetch_add(1, Ordering::Relaxed);
    }
    serve_local(ctx, req)
}

/// `/v1/batch`: `{"requests": [...]}` or a bare array. Local requests are
/// all submitted before any wait (so the batcher can coalesce them);
/// remote ones are proxied (with the same breaker-gated failover as
/// `/v1/run`). The response is 200 with per-item bodies in request order
/// — each either a run response or a structured error.
fn run_batch(ctx: &Ctx, body: &Json, forwarded: bool) -> (u16, Json) {
    let items = match body.get("requests").and_then(Json::as_arr).or_else(|| body.as_arr()) {
        Some(items) => items,
        None => {
            return err(ApiError::new(
                ErrorCode::BadRequest,
                "batch body must be {\"requests\": [...]} or a JSON array",
            ))
        }
    };
    if items.len() > ctx.cfg.max_batch_items {
        return err(ApiError::new(
            ErrorCode::PayloadTooLarge,
            format!("batch of {} exceeds the {}-item limit", items.len(), ctx.cfg.max_batch_items),
        ));
    }

    // Pass 1: parse + submit everything local so same-plan requests
    // coalesce in the server's batcher.
    enum Pending {
        Done(Json),
        Local(RunRequest, Ticket),
        Remote(usize, RunRequest, Json),
    }
    let mut pending = Vec::with_capacity(items.len());
    for item in items {
        match RunRequest::from_json(item) {
            Err(e) => pending.push(Pending::Done(e.to_json())),
            Ok(req) => {
                let key = PlanKey::of(&req.spec);
                let remote = (!forwarded)
                    .then_some(ctx.router.as_ref())
                    .flatten()
                    .and_then(|r| {
                        let shard = r.shard_of(&key);
                        (shard != r.self_index()).then_some(shard)
                    });
                match remote {
                    Some(shard) => pending.push(Pending::Remote(shard, req, item.clone())),
                    None => match submit(ctx, &req) {
                        Ok(t) => pending.push(Pending::Local(req, t)),
                        Err(e) => pending.push(Pending::Done(e.to_json())),
                    },
                }
            }
        }
    }

    // Pass 2: resolve in order.
    let results: Vec<Json> = pending
        .into_iter()
        .map(|p| match p {
            Pending::Done(json) => json,
            Pending::Local(req, ticket) => finish(ctx, &req, ticket).1,
            Pending::Remote(shard, req, item) => {
                let router = ctx.router.as_ref().expect("remote implies router");
                let key = PlanKey::of(&req.spec);
                route_remote(ctx, router, shard, &item, &req, &key).1
            }
        })
        .collect();
    (
        200,
        obj(vec![
            ("v", (api::API_VERSION as f64).into()),
            ("results", Json::Arr(results)),
        ]),
    )
}

/// `/v1/drain`: stop admissions and wait (bounded) for in-flight work.
/// Optional body `{"timeout_ms": n}` overrides the configured default.
fn drain(ctx: &Ctx, body: &[u8]) -> (u16, Json) {
    let timeout = if body.is_empty() {
        ctx.cfg.drain_timeout
    } else {
        let json = match parse_body(body) {
            Ok(j) => j,
            Err(e) => return err(e),
        };
        match json.get("timeout_ms") {
            None => ctx.cfg.drain_timeout,
            Some(t) => match t.as_u64() {
                Some(ms) => Duration::from_millis(ms),
                None => {
                    return err(ApiError::new(
                        ErrorCode::BadRequest,
                        "\"timeout_ms\" must be a non-negative integer",
                    ))
                }
            },
        }
    };
    ctx.draining.store(true, Ordering::SeqCst);
    let drained = ctx.server.drain(timeout);
    (
        200,
        obj(vec![("v", (api::API_VERSION as f64).into()), ("drained", drained.into())]),
    )
}
