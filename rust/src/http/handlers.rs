//! The `/v1` route handlers: HTTP frames in, versioned `crate::api` JSON
//! out. Handlers never touch sockets — they map a parsed request to
//! `(status, Json)`, which keeps every route unit-testable without a
//! listener and guarantees the error invariant the tests pin down: every
//! failure path produces a structured [`ApiError`] body.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::api::{self, ApiError, ErrorCode, RunRequest};
use crate::pipeline::PlanKey;
use crate::runtime::ExecInputs;
use crate::serve::{RoutineServer, SubmitOutcome, Ticket};
use crate::util::json::{obj, Json};

use super::framing::HttpRequest;
use super::router::{shards_json, ShardRouter, FORWARDED_HEADER};
use super::server::HttpConfig;

/// Everything a handler needs, shared across connection threads.
pub struct Ctx {
    pub server: Arc<RoutineServer>,
    pub router: Option<ShardRouter>,
    pub cfg: HttpConfig,
    /// Set by `/v1/drain` (and server shutdown) so `/v1/healthz` reports
    /// the instance as draining before the balancer's next probe.
    pub draining: AtomicBool,
}

impl Ctx {
    pub fn new(server: Arc<RoutineServer>, router: Option<ShardRouter>, cfg: HttpConfig) -> Ctx {
        Ctx { server, router, cfg, draining: AtomicBool::new(false) }
    }
}

fn err(e: ApiError) -> (u16, Json) {
    (e.http_status(), e.to_json())
}

/// Dispatch one framed request. Total: every input maps to a response.
pub fn handle(ctx: &Ctx, req: &HttpRequest) -> (u16, Json) {
    let forwarded = req.header(FORWARDED_HEADER).is_some();
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/v1/healthz") => healthz(ctx),
        ("GET", "/v1/statsz") => (200, api::report_json(&ctx.server.report())),
        ("POST", "/v1/run") => match parse_body(&req.body) {
            Err(e) => err(e),
            Ok(json) => run_one(ctx, &json, forwarded),
        },
        ("POST", "/v1/batch") => match parse_body(&req.body) {
            Err(e) => err(e),
            Ok(json) => run_batch(ctx, &json, forwarded),
        },
        ("POST", "/v1/drain") => drain(ctx, &req.body),
        // known routes with the wrong method get 405, not 404, so a
        // misdirected client learns which mistake it made.
        (_, "/v1/healthz" | "/v1/statsz" | "/v1/run" | "/v1/batch" | "/v1/drain") => err(
            ApiError::new(
                ErrorCode::MethodNotAllowed,
                format!("{} not allowed on {}", req.method, req.path),
            ),
        ),
        _ => err(ApiError::new(ErrorCode::NotFound, format!("no route {}", req.path))),
    }
}

fn parse_body(body: &[u8]) -> Result<Json, ApiError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ApiError::new(ErrorCode::BadRequest, "request body is not utf-8"))?;
    Json::parse(text).map_err(|e| ApiError::new(ErrorCode::BadRequest, e.to_string()))
}

fn healthz(ctx: &Ctx) -> (u16, Json) {
    (
        200,
        obj(vec![
            ("v", (api::API_VERSION as f64).into()),
            ("status", "ok".into()),
            ("draining", ctx.draining.load(Ordering::SeqCst).into()),
            ("shards", shards_json(ctx.router.as_ref())),
        ]),
    )
}

/// `/v1/run`: parse, route to the owning shard, execute locally or relay
/// the owner's response verbatim.
fn run_one(ctx: &Ctx, body: &Json, forwarded: bool) -> (u16, Json) {
    let req = match RunRequest::from_json(body) {
        Ok(r) => r,
        Err(e) => return err(e),
    };
    let key = PlanKey::of(&req.spec);
    if !forwarded {
        if let Some(router) = &ctx.router {
            let shard = router.shard_of(&key);
            if shard != router.self_index() {
                return proxy(router, shard, "/v1/run", body);
            }
        }
    }
    let ticket = match submit(ctx, &req) {
        Ok(t) => t,
        Err(e) => return err(e),
    };
    finish(ctx, &req, ticket)
}

fn submit(ctx: &Ctx, req: &RunRequest) -> Result<Ticket, ApiError> {
    let inputs = ExecInputs::random_for(&req.spec, req.seed);
    match ctx.server.try_submit(&req.spec, inputs, req.opts()) {
        SubmitOutcome::Accepted(t) => Ok(t),
        SubmitOutcome::Shed(reason) => Err(ApiError::from_shed(reason)),
    }
}

fn finish(ctx: &Ctx, req: &RunRequest, ticket: Ticket) -> (u16, Json) {
    match ticket.wait_timeout(ctx.cfg.request_timeout) {
        Ok(outcome) => {
            let cache = ctx.server.pipeline().cache().stats();
            (200, api::run_response(req, &outcome, &cache))
        }
        Err(e) => err(ApiError::from_error(&e)),
    }
}

/// Relay to the owning shard. Transport failures become `upstream`; a
/// non-JSON body from a peer is also `upstream` (the peer is broken).
fn proxy(router: &ShardRouter, shard: usize, path: &str, body: &Json) -> (u16, Json) {
    let bytes = body.to_compact().into_bytes();
    match router.forward(shard, path, &bytes) {
        Ok(resp) => match std::str::from_utf8(&resp.body).ok().and_then(|t| Json::parse(t).ok()) {
            Some(json) => (resp.status, json),
            None => err(ApiError::new(
                ErrorCode::Upstream,
                format!("shard {shard} returned an unparseable body"),
            )),
        },
        Err(e) => err(ApiError::new(ErrorCode::Upstream, format!("shard {shard}: {e}"))),
    }
}

/// `/v1/batch`: `{"requests": [...]}` or a bare array. Local requests are
/// all submitted before any wait (so the batcher can coalesce them);
/// remote ones are proxied. The response is 200 with per-item bodies in
/// request order — each either a run response or a structured error.
fn run_batch(ctx: &Ctx, body: &Json, forwarded: bool) -> (u16, Json) {
    let items = match body.get("requests").and_then(Json::as_arr).or_else(|| body.as_arr()) {
        Some(items) => items,
        None => {
            return err(ApiError::new(
                ErrorCode::BadRequest,
                "batch body must be {\"requests\": [...]} or a JSON array",
            ))
        }
    };
    if items.len() > ctx.cfg.max_batch_items {
        return err(ApiError::new(
            ErrorCode::PayloadTooLarge,
            format!("batch of {} exceeds the {}-item limit", items.len(), ctx.cfg.max_batch_items),
        ));
    }

    // Pass 1: parse + submit everything local so same-plan requests
    // coalesce in the server's batcher.
    enum Pending {
        Done(Json),
        Local(RunRequest, Ticket),
        Remote(usize, Json),
    }
    let mut pending = Vec::with_capacity(items.len());
    for item in items {
        match RunRequest::from_json(item) {
            Err(e) => pending.push(Pending::Done(e.to_json())),
            Ok(req) => {
                let key = PlanKey::of(&req.spec);
                let remote = (!forwarded)
                    .then_some(ctx.router.as_ref())
                    .flatten()
                    .and_then(|r| {
                        let shard = r.shard_of(&key);
                        (shard != r.self_index()).then_some(shard)
                    });
                match remote {
                    Some(shard) => pending.push(Pending::Remote(shard, item.clone())),
                    None => match submit(ctx, &req) {
                        Ok(t) => pending.push(Pending::Local(req, t)),
                        Err(e) => pending.push(Pending::Done(e.to_json())),
                    },
                }
            }
        }
    }

    // Pass 2: resolve in order.
    let results: Vec<Json> = pending
        .into_iter()
        .map(|p| match p {
            Pending::Done(json) => json,
            Pending::Local(req, ticket) => finish(ctx, &req, ticket).1,
            Pending::Remote(shard, item) => {
                let router = ctx.router.as_ref().expect("remote implies router");
                proxy(router, shard, "/v1/run", &item).1
            }
        })
        .collect();
    (
        200,
        obj(vec![
            ("v", (api::API_VERSION as f64).into()),
            ("results", Json::Arr(results)),
        ]),
    )
}

/// `/v1/drain`: stop admissions and wait (bounded) for in-flight work.
/// Optional body `{"timeout_ms": n}` overrides the configured default.
fn drain(ctx: &Ctx, body: &[u8]) -> (u16, Json) {
    let timeout = if body.is_empty() {
        ctx.cfg.drain_timeout
    } else {
        let json = match parse_body(body) {
            Ok(j) => j,
            Err(e) => return err(e),
        };
        match json.get("timeout_ms") {
            None => ctx.cfg.drain_timeout,
            Some(t) => match t.as_u64() {
                Some(ms) => Duration::from_millis(ms),
                None => {
                    return err(ApiError::new(
                        ErrorCode::BadRequest,
                        "\"timeout_ms\" must be a non-negative integer",
                    ))
                }
            },
        }
    };
    ctx.draining.store(true, Ordering::SeqCst);
    let drained = ctx.server.drain(timeout);
    (
        200,
        obj(vec![("v", (api::API_VERSION as f64).into()), ("drained", drained.into())]),
    )
}
