//! The HTTP/1.1 front door (DESIGN.md §13).
//!
//! A dependency-free `std::net` server exposing `serve::RoutineServer`
//! over the versioned v1 wire API (`crate::api`):
//!
//! | route          | method | body                                     |
//! |----------------|--------|------------------------------------------|
//! | `/v1/run`      | POST   | `RunRequest` → run response or `ApiError`|
//! | `/v1/batch`    | POST   | `{"requests": [...]}` → per-item results |
//! | `/v1/healthz`  | GET    | liveness + draining flag + shard map     |
//! | `/v1/statsz`   | GET    | `ServeReport` (cache + serve metrics)    |
//! | `/v1/drain`    | POST   | stop admissions, settle in-flight work   |
//!
//! Layering, bottom up: [`framing`] turns byte streams into requests and
//! responses (Content-Length only, bounded head/body, keep-alive);
//! [`handlers`] maps parsed requests to `(status, Json)` pure-functionally;
//! [`server`] owns the listener, connection threads and graceful
//! shutdown; [`router`] adds the multi-process dimension — a
//! [`ShardRouter`] consistent-hashes each spec's `PlanKey` across N peer
//! processes sharing one `--cache-dir`, proxying misdirected requests one
//! hop to the owner, so every plan is lowered once per fleet and read
//! disk-warm everywhere else ([`crate::pipeline::store`]).
//!
//! Fleet fault tolerance rides the same layers (DESIGN.md §14): the
//! client classifies transport failures ([`TransportError`]) and retries
//! retryable ones under a budgeted [`RetryPolicy`]; the router keeps a
//! per-peer circuit breaker ([`BreakerState`]) fed by a background
//! `/v1/healthz` probe thread; and the handlers fail over to local
//! serving from the shared store when an owner shard is down, so peer
//! death degrades throughput, never availability.

pub mod client;
pub mod framing;
pub mod handlers;
pub mod router;
pub mod server;

pub use client::{ClientConfig, RetryPolicy, TransportError};
pub use framing::{HttpRequest, HttpResponse};
pub use router::{BreakerState, HealthConfig, PeerState, ShardRouter, FORWARDED_HEADER};
pub use server::{HttpConfig, HttpServer};
