//! Shard-aware request routing (DESIGN.md §13).
//!
//! N serving processes share one persistent plan store (`--cache-dir`);
//! each owns a deterministic slice of the spec space so a given plan is
//! lowered (and tuned) by exactly one process, then read disk-warm by
//! the rest through the store's atomic write-through. The routing rule
//! is one line and must stay identical in every process and in offline
//! tooling:
//!
//! ```text
//! shard(spec) = PlanKey::of(spec).hash64() % peers.len()
//! ```
//!
//! `PlanKey.hash64()` is the same FNV-1a the plan cache stripes and the
//! store's filenames derive from, so routing, caching and persistence
//! all agree on identity. A request landing on the wrong process is
//! proxied one hop to the owner over plain TCP; the proxied request
//! carries [`FORWARDED_HEADER`] so the owner always handles it locally —
//! a disagreement about shard maps degrades to one extra hop, never a
//! proxy loop.

use crate::pipeline::PlanKey;
use crate::util::json::Json;
use crate::{Error, Result};

use super::client::{self, ClientConfig};
use super::framing::HttpResponse;

/// Marks a proxied request; the receiving shard must handle it locally.
pub const FORWARDED_HEADER: &str = "x-aieblas-forwarded";

/// The static shard map: every process runs the same peer list in the
/// same order, differing only in `self_index`.
#[derive(Debug, Clone)]
pub struct ShardRouter {
    peers: Vec<String>,
    self_index: usize,
    client: ClientConfig,
}

impl ShardRouter {
    pub fn new(peers: Vec<String>, self_index: usize) -> Result<ShardRouter> {
        if peers.is_empty() {
            return Err(Error::Runtime("shard router needs at least one peer".into()));
        }
        if self_index >= peers.len() {
            return Err(Error::Runtime(format!(
                "shard index {self_index} out of range for {} peer(s)",
                peers.len()
            )));
        }
        Ok(ShardRouter { peers, self_index, client: ClientConfig::default() })
    }

    pub fn peers(&self) -> &[String] {
        &self.peers
    }

    pub fn self_index(&self) -> usize {
        self.self_index
    }

    /// The routing rule. Must match DESIGN.md §13 and `tools/http_smoke.py`.
    pub fn shard_of(&self, key: &PlanKey) -> usize {
        (key.hash64() % self.peers.len() as u64) as usize
    }

    pub fn is_local(&self, key: &PlanKey) -> bool {
        self.shard_of(key) == self.self_index
    }

    /// Proxy a request body one hop to `shard`, tagging it forwarded.
    pub fn forward(&self, shard: usize, path: &str, body: &[u8]) -> Result<HttpResponse> {
        let addr = &self.peers[shard];
        client::request(addr, "POST", path, Some(body), &[(FORWARDED_HEADER, "1")], &self.client)
    }
}

/// Shard-map summary for `/v1/healthz`.
pub fn shards_json(router: Option<&ShardRouter>) -> Json {
    match router {
        None => crate::util::json::obj(vec![
            ("peers", Json::Arr(vec![])),
            ("self_index", 0usize.into()),
        ]),
        Some(r) => crate::util::json::obj(vec![
            (
                "peers",
                Json::Arr(r.peers().iter().map(|p| Json::Str(p.clone())).collect()),
            ),
            ("self_index", r.self_index().into()),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_validates_peers_and_index() {
        assert!(ShardRouter::new(vec![], 0).is_err());
        assert!(ShardRouter::new(vec!["a:1".into()], 1).is_err());
        let r = ShardRouter::new(vec!["a:1".into(), "b:2".into()], 1).unwrap();
        assert_eq!(r.self_index(), 1);
        assert_eq!(r.peers().len(), 2);
    }

    #[test]
    fn routing_is_deterministic_and_covers_both_shards() {
        let r = ShardRouter::new(vec!["a:1".into(), "b:2".into()], 0).unwrap();
        let mut seen = [false, false];
        for size in [64usize, 128, 256, 512, 1024, 2048, 4096, 8192] {
            let key = PlanKey::new(format!("spec-{size}"));
            let s = r.shard_of(&key);
            assert_eq!(s, r.shard_of(&key), "stable per key");
            assert!(s < 2);
            seen[s] = true;
        }
        // FNV-1a over distinct keys must not collapse onto one shard.
        assert!(seen[0] && seen[1], "8 distinct keys all hashed to one shard");
    }

    #[test]
    fn single_peer_owns_everything() {
        let r = ShardRouter::new(vec!["only:1".into()], 0).unwrap();
        assert!(r.is_local(&PlanKey::new("anything")));
    }
}
