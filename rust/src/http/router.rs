//! Shard-aware request routing with per-peer health (DESIGN.md §13–§14).
//!
//! N serving processes share one persistent plan store (`--cache-dir`);
//! each owns a deterministic slice of the spec space so a given plan is
//! lowered (and tuned) by exactly one process, then read disk-warm by
//! the rest through the store's atomic write-through. The routing rule
//! is one line and must stay identical in every process and in offline
//! tooling:
//!
//! ```text
//! shard(spec) = PlanKey::of(spec).hash64() % peers.len()
//! ```
//!
//! `PlanKey.hash64()` is the same FNV-1a the plan cache stripes and the
//! store's filenames derive from, so routing, caching and persistence
//! all agree on identity. A request landing on the wrong process is
//! proxied one hop to the owner over plain TCP; the proxied request
//! carries [`FORWARDED_HEADER`] so the owner always handles it locally —
//! a disagreement about shard maps degrades to one extra hop, never a
//! proxy loop.
//!
//! # Peer health and circuit breakers (§14)
//!
//! The static shard map says who *should* serve a key; the per-peer
//! [`BreakerState`] says who *can* right now. Every peer starts
//! `Closed`. [`HealthConfig::trip_threshold`] consecutive transport
//! failures (from proxy hops or the background `/v1/healthz` probe)
//! trip it `Open`: the peer is not dialed at all and its keys are
//! served locally via failover (`http::handlers`). After
//! [`HealthConfig::cooldown`] the breaker admits exactly one trial
//! request (`HalfOpen`); success closes it, failure re-opens it and
//! restarts the cooldown. Shedding 429/503s from a live peer do NOT
//! count as failures — an overloaded peer is alive, and failing over
//! onto it from here would only move the overload around.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::pipeline::PlanKey;
use crate::util::json::Json;
use crate::{Error, Result};

use super::client::{self, ClientConfig, RetryPolicy, TransportError};
use super::framing::HttpResponse;

/// Marks a proxied request; the receiving shard must handle it locally.
pub const FORWARDED_HEADER: &str = "x-aieblas-forwarded";

/// Circuit-breaker state of one peer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BreakerState {
    /// Normal: requests flow.
    #[default]
    Closed,
    /// Tripped: the peer is not dialed until the cooldown elapses.
    Open,
    /// Cooldown elapsed: exactly one trial request is in flight.
    HalfOpen,
}

impl BreakerState {
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }
}

/// Coarse peer condition derived from the breaker, for operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerState {
    /// Breaker closed, no recent failures.
    Up,
    /// Failures accumulating or a half-open trial under way.
    Degraded,
    /// Breaker open: traffic fails over.
    Down,
}

impl PeerState {
    pub fn name(self) -> &'static str {
        match self {
            PeerState::Up => "up",
            PeerState::Degraded => "degraded",
            PeerState::Down => "down",
        }
    }
}

/// Breaker tuning; `normalized()` clamps hostile values.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// Consecutive transport failures that trip the breaker.
    pub trip_threshold: u32,
    /// How long an open breaker waits before admitting a trial.
    pub cooldown: Duration,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig { trip_threshold: 3, cooldown: Duration::from_millis(500) }
    }
}

impl HealthConfig {
    pub fn normalized(&self) -> HealthConfig {
        HealthConfig {
            trip_threshold: self.trip_threshold.clamp(1, 1024),
            cooldown: self.cooldown.clamp(Duration::from_millis(10), Duration::from_secs(60)),
        }
    }
}

#[derive(Debug, Default)]
struct PeerHealth {
    consecutive_failures: u32,
    breaker: BreakerState,
    opened_at: Option<Instant>,
    /// True while the half-open trial is outstanding; other callers see
    /// the peer as unavailable so one slow trial cannot become many.
    trial_in_flight: bool,
}

/// Shared, lock-per-peer health state. Lives behind an `Arc` so every
/// `ShardRouter` clone (handler contexts, the probe thread) observes
/// one fleet view.
#[derive(Debug)]
struct HealthTable {
    peers: Vec<Mutex<PeerHealth>>,
    cfg: HealthConfig,
    trips: AtomicU64,
    closes: AtomicU64,
}

impl HealthTable {
    fn new(n: usize, cfg: HealthConfig) -> HealthTable {
        HealthTable {
            peers: (0..n).map(|_| Mutex::new(PeerHealth::default())).collect(),
            cfg: cfg.normalized(),
            trips: AtomicU64::new(0),
            closes: AtomicU64::new(0),
        }
    }

    fn lock(&self, shard: usize) -> std::sync::MutexGuard<'_, PeerHealth> {
        self.peers[shard].lock().expect("peer health poisoned")
    }
}

/// One peer's health, snapshotted for `/v1/healthz`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeerSnapshot {
    pub state: PeerState,
    pub breaker: BreakerState,
    pub consecutive_failures: u32,
}

/// The static shard map: every process runs the same peer list in the
/// same order, differing only in `self_index`.
#[derive(Debug, Clone)]
pub struct ShardRouter {
    peers: Vec<String>,
    self_index: usize,
    client: ClientConfig,
    retry: RetryPolicy,
    health: Arc<HealthTable>,
}

impl ShardRouter {
    pub fn new(peers: Vec<String>, self_index: usize) -> Result<ShardRouter> {
        if peers.is_empty() {
            return Err(Error::Runtime("shard router needs at least one peer".into()));
        }
        if self_index >= peers.len() {
            return Err(Error::Runtime(format!(
                "shard index {self_index} out of range for {} peer(s)",
                peers.len()
            )));
        }
        let health = Arc::new(HealthTable::new(peers.len(), HealthConfig::default()));
        Ok(ShardRouter {
            peers,
            self_index,
            client: ClientConfig::default(),
            retry: RetryPolicy::default(),
            health,
        })
    }

    /// Replace the breaker tuning (fresh table; call before serving).
    pub fn with_health(mut self, cfg: HealthConfig) -> ShardRouter {
        self.health = Arc::new(HealthTable::new(self.peers.len(), cfg));
        self
    }

    /// Replace the proxy-hop client config (timeouts, fault plan).
    pub fn with_client(mut self, client: ClientConfig) -> ShardRouter {
        self.client = client;
        self
    }

    /// Replace the proxy-hop retry policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> ShardRouter {
        self.retry = retry;
        self
    }

    pub fn peers(&self) -> &[String] {
        &self.peers
    }

    pub fn self_index(&self) -> usize {
        self.self_index
    }

    /// The routing rule. Must match DESIGN.md §13 and `tools/http_smoke.py`.
    pub fn shard_of(&self, key: &PlanKey) -> usize {
        (key.hash64() % self.peers.len() as u64) as usize
    }

    pub fn is_local(&self, key: &PlanKey) -> bool {
        self.shard_of(key) == self.self_index
    }

    /// Whether `shard` should be dialed right now. Open breakers say no
    /// until their cooldown elapses, then admit exactly one half-open
    /// trial; the caller that got `true` must report the outcome via
    /// [`record_success`] / [`record_failure`] or the trial slot leaks
    /// until the next probe resolves it.
    ///
    /// [`record_success`]: ShardRouter::record_success
    /// [`record_failure`]: ShardRouter::record_failure
    pub fn peer_available(&self, shard: usize) -> bool {
        let mut p = self.health.lock(shard);
        match p.breaker {
            BreakerState::Closed => true,
            BreakerState::Open => {
                let cooled =
                    p.opened_at.map(|t| t.elapsed() >= self.health.cfg.cooldown).unwrap_or(true);
                if cooled {
                    p.breaker = BreakerState::HalfOpen;
                    p.trial_in_flight = true;
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => {
                if p.trial_in_flight {
                    false
                } else {
                    p.trial_in_flight = true;
                    true
                }
            }
        }
    }

    /// A dial of `shard` reached the application layer.
    pub fn record_success(&self, shard: usize) {
        let mut p = self.health.lock(shard);
        p.consecutive_failures = 0;
        p.trial_in_flight = false;
        if p.breaker != BreakerState::Closed {
            p.breaker = BreakerState::Closed;
            p.opened_at = None;
            self.health.closes.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A dial of `shard` failed at the transport layer.
    pub fn record_failure(&self, shard: usize) {
        let mut p = self.health.lock(shard);
        p.consecutive_failures = p.consecutive_failures.saturating_add(1);
        p.trial_in_flight = false;
        let trip = match p.breaker {
            BreakerState::Closed => p.consecutive_failures >= self.health.cfg.trip_threshold,
            BreakerState::HalfOpen => true,
            BreakerState::Open => false,
        };
        if trip {
            p.breaker = BreakerState::Open;
            p.opened_at = Some(Instant::now());
            self.health.trips.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Health snapshot of one peer.
    pub fn peer_snapshot(&self, shard: usize) -> PeerSnapshot {
        let p = self.health.lock(shard);
        let state = match p.breaker {
            BreakerState::Open => PeerState::Down,
            BreakerState::HalfOpen => PeerState::Degraded,
            BreakerState::Closed if p.consecutive_failures > 0 => PeerState::Degraded,
            BreakerState::Closed => PeerState::Up,
        };
        PeerSnapshot { state, breaker: p.breaker, consecutive_failures: p.consecutive_failures }
    }

    /// Lifetime `(trips, closes)` across all peers.
    pub fn breaker_counters(&self) -> (u64, u64) {
        (self.health.trips.load(Ordering::Relaxed), self.health.closes.load(Ordering::Relaxed))
    }

    /// One background health probe of `shard`: GET `/v1/healthz` with
    /// tight timeouts, recording the outcome. Probes dial even an open
    /// breaker — they *are* the recovery path that closes it — and skip
    /// the fault plan (chaos targets serving traffic; a probe made
    /// flaky by injection would fight the determinism it exists for).
    pub fn probe(&self, shard: usize) -> bool {
        if shard == self.self_index {
            return true;
        }
        let cfg = ClientConfig {
            connect_timeout: self.client.connect_timeout.min(Duration::from_secs(1)),
            io_timeout: self.client.io_timeout.min(Duration::from_secs(2)),
            faults: None,
            ..self.client.clone()
        };
        let ok = matches!(
            client::request(&self.peers[shard], "GET", "/v1/healthz", None, &[], &cfg),
            Ok(resp) if resp.status == 200
        );
        if ok {
            self.record_success(shard);
        } else {
            self.record_failure(shard);
        }
        ok
    }

    /// Proxy a request body one hop to `shard`, tagging it forwarded,
    /// with retries (proxied runs are deterministic server-side — a
    /// duplicate execution costs duplicate work, never a wrong answer —
    /// so the hop is idempotent). Records breaker health: any parsed
    /// HTTP response proves the peer alive; transport failures count
    /// toward the trip threshold.
    pub fn forward(
        &self,
        shard: usize,
        path: &str,
        body: &[u8],
    ) -> std::result::Result<HttpResponse, TransportError> {
        let addr = &self.peers[shard];
        let result = client::request_with_retry(
            addr,
            "POST",
            path,
            Some(body),
            &[(FORWARDED_HEADER, "1")],
            &self.client,
            &self.retry,
            true,
        );
        match &result {
            Ok(_) => self.record_success(shard),
            Err(_) => self.record_failure(shard),
        }
        result
    }
}

/// Shard-map summary for `/v1/healthz`: the peer list with per-peer
/// breaker state, plus this process's index.
pub fn shards_json(router: Option<&ShardRouter>) -> Json {
    use crate::util::json::obj;
    match router {
        None => obj(vec![("peers", Json::Arr(vec![])), ("self_index", 0usize.into())]),
        Some(r) => obj(vec![
            (
                "peers",
                Json::Arr(
                    r.peers()
                        .iter()
                        .enumerate()
                        .map(|(i, p)| {
                            let snap = r.peer_snapshot(i);
                            obj(vec![
                                ("addr", Json::Str(p.clone())),
                                ("self", (i == r.self_index()).into()),
                                ("state", snap.state.name().into()),
                                ("breaker", snap.breaker.name().into()),
                                (
                                    "consecutive_failures",
                                    (snap.consecutive_failures as f64).into(),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("self_index", r.self_index().into()),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_validates_peers_and_index() {
        assert!(ShardRouter::new(vec![], 0).is_err());
        assert!(ShardRouter::new(vec!["a:1".into()], 1).is_err());
        let r = ShardRouter::new(vec!["a:1".into(), "b:2".into()], 1).unwrap();
        assert_eq!(r.self_index(), 1);
        assert_eq!(r.peers().len(), 2);
    }

    #[test]
    fn routing_is_deterministic_and_covers_both_shards() {
        let r = ShardRouter::new(vec!["a:1".into(), "b:2".into()], 0).unwrap();
        let mut seen = [false, false];
        for size in [64usize, 128, 256, 512, 1024, 2048, 4096, 8192] {
            let key = PlanKey::new(format!("spec-{size}"));
            let s = r.shard_of(&key);
            assert_eq!(s, r.shard_of(&key), "stable per key");
            assert!(s < 2);
            seen[s] = true;
        }
        // FNV-1a over distinct keys must not collapse onto one shard.
        assert!(seen[0] && seen[1], "8 distinct keys all hashed to one shard");
    }

    #[test]
    fn single_peer_owns_everything() {
        let r = ShardRouter::new(vec!["only:1".into()], 0).unwrap();
        assert!(r.is_local(&PlanKey::new("anything")));
    }

    #[test]
    fn breaker_trips_after_threshold_and_half_opens_after_cooldown() {
        let r = ShardRouter::new(vec!["a:1".into(), "b:2".into()], 0)
            .unwrap()
            .with_health(HealthConfig {
                trip_threshold: 2,
                cooldown: Duration::from_millis(20),
            });
        assert!(r.peer_available(1));
        assert_eq!(r.peer_snapshot(1).state, PeerState::Up);

        r.record_failure(1);
        assert_eq!(r.peer_snapshot(1).breaker, BreakerState::Closed);
        assert_eq!(r.peer_snapshot(1).state, PeerState::Degraded);
        assert!(r.peer_available(1), "one failure under threshold keeps flowing");

        r.record_failure(1);
        assert_eq!(r.peer_snapshot(1).breaker, BreakerState::Open);
        assert_eq!(r.peer_snapshot(1).state, PeerState::Down);
        assert!(!r.peer_available(1), "open breaker blocks immediately");
        assert_eq!(r.breaker_counters().0, 1);

        std::thread::sleep(Duration::from_millis(30));
        assert!(r.peer_available(1), "cooldown admits the half-open trial");
        assert_eq!(r.peer_snapshot(1).breaker, BreakerState::HalfOpen);
        assert!(!r.peer_available(1), "only one trial at a time");

        // Trial fails: straight back to Open, no threshold needed.
        r.record_failure(1);
        assert_eq!(r.peer_snapshot(1).breaker, BreakerState::Open);
        assert_eq!(r.breaker_counters().0, 2);

        // Next trial succeeds: breaker closes and counts it.
        std::thread::sleep(Duration::from_millis(30));
        assert!(r.peer_available(1));
        r.record_success(1);
        let snap = r.peer_snapshot(1);
        assert_eq!(snap.breaker, BreakerState::Closed);
        assert_eq!(snap.state, PeerState::Up);
        assert_eq!(snap.consecutive_failures, 0);
        assert_eq!(r.breaker_counters(), (2, 1));
    }

    #[test]
    fn clones_share_one_health_table() {
        let r = ShardRouter::new(vec!["a:1".into(), "b:2".into()], 0)
            .unwrap()
            .with_health(HealthConfig {
                trip_threshold: 1,
                cooldown: Duration::from_secs(60),
            });
        let clone = r.clone();
        r.record_failure(1);
        assert_eq!(clone.peer_snapshot(1).breaker, BreakerState::Open);
        assert!(!clone.peer_available(1));
    }

    #[test]
    fn health_config_clamps_hostile_values() {
        let cfg = HealthConfig { trip_threshold: 0, cooldown: Duration::ZERO }.normalized();
        assert_eq!(cfg.trip_threshold, 1);
        assert!(cfg.cooldown >= Duration::from_millis(10));
        let cfg = HealthConfig {
            trip_threshold: u32::MAX,
            cooldown: Duration::from_secs(1 << 20),
        }
        .normalized();
        assert_eq!(cfg.trip_threshold, 1024);
        assert!(cfg.cooldown <= Duration::from_secs(60));
    }

    #[test]
    fn failed_probe_of_a_dead_peer_counts_toward_the_breaker() {
        // 203.0.113.0/24 is TEST-NET-3; nothing listens there, but to
        // keep the test offline-fast we point at a loopback port we
        // just closed: connect refuses immediately.
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        let r = ShardRouter::new(vec!["self:1".into(), addr], 0)
            .unwrap()
            .with_health(HealthConfig {
                trip_threshold: 2,
                cooldown: Duration::from_secs(60),
            });
        assert!(r.probe(0), "self-probe is a no-op success");
        assert!(!r.probe(1));
        assert!(!r.probe(1));
        assert_eq!(r.peer_snapshot(1).breaker, BreakerState::Open);
        assert_eq!(r.breaker_counters().0, 1);
    }

    #[test]
    fn shards_json_reports_breaker_per_peer() {
        let r = ShardRouter::new(vec!["a:1".into(), "b:2".into()], 0)
            .unwrap()
            .with_health(HealthConfig {
                trip_threshold: 1,
                cooldown: Duration::from_secs(60),
            });
        r.record_failure(1);
        let j = shards_json(Some(&r));
        let peers = match j.get("peers") {
            Some(Json::Arr(p)) => p,
            other => panic!("peers not an array: {other:?}"),
        };
        assert_eq!(peers.len(), 2);
        assert_eq!(peers[0].get("state").and_then(|s| s.as_str()), Some("up"));
        assert_eq!(peers[1].get("breaker").and_then(|s| s.as_str()), Some("open"));
        assert_eq!(peers[1].get("state").and_then(|s| s.as_str()), Some("down"));
    }
}
