//! A tiny blocking HTTP/1.1 client over `std::net::TcpStream`.
//!
//! Serves three callers: the shard router's proxy hop, the e2e tests,
//! and the loopback bench. One request per connection (`Connection:
//! close`) keeps it trivially correct; the proxy hop is a loopback or
//! rack-local connection where setup cost is noise next to a lowering.

use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::framing::{read_response, FrameError, HttpResponse};
use crate::util::json::Json;
use crate::{Error, Result};

/// Client-side limits, deliberately mirroring the server's defaults.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    pub connect_timeout: Duration,
    pub io_timeout: Duration,
    pub max_body: usize,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(60),
            max_body: 64 * 1024 * 1024,
        }
    }
}

fn transport(msg: String) -> Error {
    Error::Runtime(format!("http transport: {msg}"))
}

/// Issue one request and read the full response. `body: None` sends no
/// body (GET); `Some` sends it with a `Content-Length`.
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
    extra_headers: &[(&str, &str)],
    cfg: &ClientConfig,
) -> Result<HttpResponse> {
    let sock_addr = addr
        .to_socket_addrs()
        .map_err(|e| transport(format!("bad address {addr:?}: {e}")))?
        .next()
        .ok_or_else(|| transport(format!("address {addr:?} resolved to nothing")))?;
    let stream = TcpStream::connect_timeout(&sock_addr, cfg.connect_timeout)
        .map_err(|e| transport(format!("connect {addr}: {e}")))?;
    stream.set_read_timeout(Some(cfg.io_timeout)).map_err(|e| transport(e.to_string()))?;
    stream.set_write_timeout(Some(cfg.io_timeout)).map_err(|e| transport(e.to_string()))?;
    stream.set_nodelay(true).ok();

    let mut head = format!("{method} {path} HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n");
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    let body = body.unwrap_or(&[]);
    if !body.is_empty() || method == "POST" {
        head.push_str("content-type: application/json\r\n");
        head.push_str(&format!("content-length: {}\r\n", body.len()));
    }
    head.push_str("\r\n");

    let mut stream = stream;
    stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body))
        .and_then(|_| stream.flush())
        .map_err(|e| transport(format!("send to {addr}: {e}")))?;

    let mut reader = BufReader::new(stream);
    read_response(&mut reader, cfg.max_body).map_err(|e| match e {
        FrameError::Io(io) => transport(format!("read from {addr}: {io}")),
        other => transport(format!("response from {addr}: {other}")),
    })
}

/// GET `path`, parsing the body as JSON. Returns `(status, json)`.
pub fn get(addr: &str, path: &str, cfg: &ClientConfig) -> Result<(u16, Json)> {
    let resp = request(addr, "GET", path, None, &[], cfg)?;
    parse_body(addr, resp)
}

/// POST a JSON document to `path`. Returns `(status, json)`.
pub fn post_json(addr: &str, path: &str, body: &Json, cfg: &ClientConfig) -> Result<(u16, Json)> {
    let bytes = body.to_compact().into_bytes();
    let resp = request(addr, "POST", path, Some(&bytes), &[], cfg)?;
    parse_body(addr, resp)
}

fn parse_body(addr: &str, resp: HttpResponse) -> Result<(u16, Json)> {
    let text = std::str::from_utf8(&resp.body)
        .map_err(|_| transport(format!("non-utf8 response body from {addr}")))?;
    let json = Json::parse(text)
        .map_err(|e| transport(format!("non-json response body from {addr}: {e}")))?;
    Ok((resp.status, json))
}
