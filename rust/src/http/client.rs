//! A tiny blocking HTTP/1.1 client over `std::net::TcpStream`.
//!
//! Serves three callers: the shard router's proxy hop, the e2e tests,
//! and the loopback bench. One request per connection (`Connection:
//! close`) keeps it trivially correct; the proxy hop is a loopback or
//! rack-local connection where setup cost is noise next to a lowering.
//!
//! Fleet fault tolerance (DESIGN.md §14) needs two things from the
//! transport edge:
//!
//! * **classified failures** — [`TransportError`] distinguishes refused
//!   vs timed-out vs reset vs truncated, each mapped to a distinct
//!   [`ErrorCode`] so a proxy can put the real failure mode on the wire
//!   instead of one opaque string;
//! * **bounded retries** — [`request_with_retry`] retries only failures
//!   that are safe to retry (a refused connect never delivered bytes;
//!   anything after the request may have been acked is retried only for
//!   idempotent requests), under capped exponential backoff with
//!   deterministic jitter and a hard wall-clock budget so retries can
//!   never amplify an outage.

use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::framing::{read_response, FrameError, HttpResponse};
use crate::api::{ApiError, ErrorCode};
use crate::util::faults::{FaultPlan, FaultSite};
use crate::util::fnv1a64;
use crate::util::json::Json;
use crate::{Error, Result};

/// Client-side limits, deliberately mirroring the server's defaults.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    pub connect_timeout: Duration,
    pub io_timeout: Duration,
    pub max_body: usize,
    /// Chaos hook: when set, `ConnectRefuse` faults fire before any
    /// socket work, as if the peer refused the connection.
    pub faults: Option<FaultPlan>,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(60),
            max_body: 64 * 1024 * 1024,
            faults: None,
        }
    }
}

/// A classified transport failure. `Refused` is known to have happened
/// before any request byte left this process; the other variants may
/// have raced a request the peer already accepted, so only idempotent
/// requests retry them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// TCP connect refused (or address unusable): nothing was sent.
    Refused(String),
    /// Connect or I/O deadline elapsed.
    Timeout(String),
    /// Peer reset/aborted the connection mid-exchange.
    Reset(String),
    /// Response frame ended before its declared length.
    Truncated(String),
    /// Anything else (resolution failure, protocol violation, …).
    Other(String),
}

impl TransportError {
    /// The wire [`ErrorCode`] a proxy should report for this failure.
    pub fn code(&self) -> ErrorCode {
        match self {
            TransportError::Refused(_) => ErrorCode::UpstreamConnect,
            TransportError::Timeout(_) => ErrorCode::UpstreamTimeout,
            TransportError::Reset(_) => ErrorCode::UpstreamReset,
            TransportError::Truncated(_) => ErrorCode::UpstreamTruncated,
            TransportError::Other(_) => ErrorCode::Upstream,
        }
    }

    /// Whether a retry can reasonably succeed (transport failures are
    /// transient by nature; `Other` covers config mistakes too, so it
    /// does not retry).
    pub fn retryable(&self) -> bool {
        !matches!(self, TransportError::Other(_))
    }

    /// True when the failure provably happened before any request byte
    /// was sent, making a retry safe even for non-idempotent requests.
    pub fn before_send(&self) -> bool {
        matches!(self, TransportError::Refused(_))
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Refused(m) => write!(f, "connection refused: {m}"),
            TransportError::Timeout(m) => write!(f, "timed out: {m}"),
            TransportError::Reset(m) => write!(f, "connection reset: {m}"),
            TransportError::Truncated(m) => write!(f, "truncated response: {m}"),
            TransportError::Other(m) => write!(f, "{m}"),
        }
    }
}

impl From<TransportError> for Error {
    fn from(e: TransportError) -> Error {
        Error::Runtime(format!("http transport: {e}"))
    }
}

/// Classify a socket-level error by `io::ErrorKind`.
fn classify_io(context: &str, e: &std::io::Error) -> TransportError {
    use std::io::ErrorKind;
    let msg = format!("{context}: {e}");
    match e.kind() {
        ErrorKind::ConnectionRefused => TransportError::Refused(msg),
        ErrorKind::TimedOut | ErrorKind::WouldBlock => TransportError::Timeout(msg),
        ErrorKind::ConnectionReset | ErrorKind::ConnectionAborted | ErrorKind::BrokenPipe => {
            TransportError::Reset(msg)
        }
        ErrorKind::UnexpectedEof => TransportError::Truncated(msg),
        _ => TransportError::Other(msg),
    }
}

/// Classify a response-framing failure. A close before or inside the
/// declared body is truncation (the 502-worthy kind a proxy must name);
/// other malformations are protocol violations.
fn classify_frame(addr: &str, e: FrameError) -> TransportError {
    match e {
        FrameError::Io(io) => classify_io(&format!("read from {addr}"), &io),
        FrameError::Eof => {
            TransportError::Truncated(format!("read from {addr}: closed before a status line"))
        }
        FrameError::Malformed(m)
            if m.contains("body shorter than content-length")
                || m.contains("unexpected end of stream") =>
        {
            TransportError::Truncated(format!("response from {addr}: {m}"))
        }
        other => TransportError::Other(format!("response from {addr}: {other}")),
    }
}

/// Issue one request and read the full response. `body: None` sends no
/// body (GET); `Some` sends it with a `Content-Length`.
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
    extra_headers: &[(&str, &str)],
    cfg: &ClientConfig,
) -> std::result::Result<HttpResponse, TransportError> {
    if let Some(faults) = &cfg.faults {
        if faults.fire(FaultSite::ConnectRefuse) {
            return Err(TransportError::Refused(format!("connect {addr}: injected fault")));
        }
    }
    let sock_addr = addr
        .to_socket_addrs()
        .map_err(|e| TransportError::Other(format!("bad address {addr:?}: {e}")))?
        .next()
        .ok_or_else(|| TransportError::Other(format!("address {addr:?} resolved to nothing")))?;
    let stream = TcpStream::connect_timeout(&sock_addr, cfg.connect_timeout)
        .map_err(|e| match classify_io(&format!("connect {addr}"), &e) {
            // A connect that timed out never delivered the request
            // either; fold it into the before-send class.
            TransportError::Timeout(m) => TransportError::Refused(m),
            other => other,
        })?;
    stream
        .set_read_timeout(Some(cfg.io_timeout))
        .and_then(|_| stream.set_write_timeout(Some(cfg.io_timeout)))
        .map_err(|e| TransportError::Other(format!("socket setup for {addr}: {e}")))?;
    stream.set_nodelay(true).ok();

    let mut head = format!("{method} {path} HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n");
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    let body = body.unwrap_or(&[]);
    if !body.is_empty() || method == "POST" {
        head.push_str("content-type: application/json\r\n");
        head.push_str(&format!("content-length: {}\r\n", body.len()));
    }
    head.push_str("\r\n");

    let mut stream = stream;
    stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body))
        .and_then(|_| stream.flush())
        .map_err(|e| classify_io(&format!("send to {addr}"), &e))?;

    let mut reader = BufReader::new(stream);
    read_response(&mut reader, cfg.max_body).map_err(|e| classify_frame(addr, e))
}

/// Retry schedule for [`request_with_retry`]: capped exponential
/// backoff with deterministic jitter under a total wall-clock budget.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts including the first (1 = no retry).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles each retry.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Hard wall-clock budget for attempts *and* sleeps; a retry whose
    /// backoff would cross the budget is not taken. Retries can delay a
    /// request by at most this much — they cannot amplify an outage.
    pub budget: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_millis(20),
            max_backoff: Duration::from_millis(200),
            budget: Duration::from_secs(1),
        }
    }
}

impl RetryPolicy {
    /// Clamp hostile values to workable ranges (mirrors
    /// `HttpConfig::normalized`).
    pub fn normalized(&self) -> RetryPolicy {
        let base = self.base_backoff.clamp(Duration::from_millis(1), Duration::from_secs(10));
        RetryPolicy {
            max_attempts: self.max_attempts.clamp(1, 16),
            base_backoff: base,
            max_backoff: self.max_backoff.clamp(base, Duration::from_secs(30)),
            budget: self.budget.clamp(Duration::from_millis(10), Duration::from_secs(60)),
        }
    }

    /// Backoff before retry number `retry` (1-based): `base * 2^(retry-1)`
    /// capped at `max_backoff`, scaled by a deterministic jitter in
    /// `[0.5, 1.0)` derived from `(site, retry)` — no global RNG, so
    /// identical runs back off identically while distinct callers spread
    /// out instead of thundering back in lockstep.
    pub fn backoff(&self, site: &str, retry: u32) -> Duration {
        let exp = self.base_backoff.saturating_mul(1u32 << (retry - 1).min(16));
        let capped = exp.min(self.max_backoff);
        let h = fnv1a64(format!("{site}#{retry}").as_bytes());
        let jitter = 0.5 + (h % 1024) as f64 / 2048.0;
        capped.mul_f64(jitter)
    }
}

/// Whether a response status + body says "retrying may succeed". Only
/// 502/503/504 qualify, and only when the structured error agrees (an
/// unparseable body on those statuses is assumed retryable — it usually
/// means an intermediary, not the serving layer, answered).
fn response_retryable(resp: &HttpResponse) -> bool {
    if !matches!(resp.status, 502 | 503 | 504) {
        return false;
    }
    match std::str::from_utf8(&resp.body).ok().and_then(|t| Json::parse(t).ok()) {
        Some(json) => ApiError::from_json(&json).map(|e| e.retryable).unwrap_or(true),
        None => true,
    }
}

/// [`request`] with bounded retries. `idempotent` declares that the peer
/// executing the request twice is acceptable; without it only failures
/// that provably happened before any byte was sent (refused connects)
/// are retried, and 5xx responses — which prove the request was acked by
/// the application layer — never are.
#[allow(clippy::too_many_arguments)]
pub fn request_with_retry(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
    extra_headers: &[(&str, &str)],
    cfg: &ClientConfig,
    policy: &RetryPolicy,
    idempotent: bool,
) -> std::result::Result<HttpResponse, TransportError> {
    let policy = policy.normalized();
    let start = Instant::now();
    let mut retry = 0u32;
    loop {
        let result = request(addr, method, path, body, extra_headers, cfg);
        let should_retry = match &result {
            Ok(resp) => idempotent && response_retryable(resp),
            Err(e) => e.retryable() && (e.before_send() || idempotent),
        };
        retry += 1;
        if !should_retry || retry >= policy.max_attempts {
            return result;
        }
        let backoff = policy.backoff(&format!("{addr}{path}"), retry);
        if start.elapsed() + backoff > policy.budget {
            return result;
        }
        std::thread::sleep(backoff);
    }
}

/// GET `path`, parsing the body as JSON. Returns `(status, json)`.
pub fn get(addr: &str, path: &str, cfg: &ClientConfig) -> Result<(u16, Json)> {
    let resp = request(addr, "GET", path, None, &[], cfg)?;
    parse_body(addr, resp)
}

/// POST a JSON document to `path`. Returns `(status, json)`.
pub fn post_json(addr: &str, path: &str, body: &Json, cfg: &ClientConfig) -> Result<(u16, Json)> {
    let bytes = body.to_compact().into_bytes();
    let resp = request(addr, "POST", path, Some(&bytes), &[], cfg)?;
    parse_body(addr, resp)
}

fn parse_body(addr: &str, resp: HttpResponse) -> Result<(u16, Json)> {
    let text = std::str::from_utf8(&resp.body)
        .map_err(|_| Error::Runtime(format!("http transport: non-utf8 body from {addr}")))?;
    let json = Json::parse(text).map_err(|e| {
        Error::Runtime(format!("http transport: non-json body from {addr}: {e}"))
    })?;
    Ok((resp.status, json))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_error_kinds_classify() {
        use std::io::{Error as IoError, ErrorKind};
        let cases = [
            (ErrorKind::ConnectionRefused, ErrorCode::UpstreamConnect),
            (ErrorKind::TimedOut, ErrorCode::UpstreamTimeout),
            (ErrorKind::WouldBlock, ErrorCode::UpstreamTimeout),
            (ErrorKind::ConnectionReset, ErrorCode::UpstreamReset),
            (ErrorKind::BrokenPipe, ErrorCode::UpstreamReset),
            (ErrorKind::UnexpectedEof, ErrorCode::UpstreamTruncated),
            (ErrorKind::PermissionDenied, ErrorCode::Upstream),
        ];
        for (kind, want) in cases {
            let te = classify_io("test", &IoError::new(kind, "boom"));
            assert_eq!(te.code(), want, "{kind:?}");
        }
    }

    #[test]
    fn truncation_markers_classify_as_truncated() {
        for e in [
            FrameError::Eof,
            FrameError::Malformed("body shorter than content-length".into()),
            FrameError::Malformed("unexpected end of stream".into()),
        ] {
            let te = classify_frame("127.0.0.1:1", e);
            assert_eq!(te.code(), ErrorCode::UpstreamTruncated);
            assert!(te.retryable());
        }
        let other = classify_frame("127.0.0.1:1", FrameError::Malformed("bad header".into()));
        assert_eq!(other.code(), ErrorCode::Upstream);
        assert!(!other.retryable());
    }

    #[test]
    fn only_refused_is_safe_before_send() {
        assert!(TransportError::Refused("x".into()).before_send());
        assert!(!TransportError::Timeout("x".into()).before_send());
        assert!(!TransportError::Reset("x".into()).before_send());
        assert!(!TransportError::Truncated("x".into()).before_send());
    }

    #[test]
    fn backoff_grows_caps_and_is_deterministic() {
        let p = RetryPolicy::default().normalized();
        let b1 = p.backoff("a:1/v1/run", 1);
        let b2 = p.backoff("a:1/v1/run", 2);
        let b9 = p.backoff("a:1/v1/run", 9);
        assert!(b1 < b2, "{b1:?} !< {b2:?}");
        // jitter is in [0.5, 1.0): the cap bounds every backoff.
        assert!(b9 <= p.max_backoff);
        assert!(b9 >= p.max_backoff.mul_f64(0.5));
        assert_eq!(b1, p.backoff("a:1/v1/run", 1), "same site+retry, same jitter");
        assert_ne!(
            p.backoff("a:1/v1/run", 1),
            p.backoff("b:2/v1/run", 1),
            "different sites spread out"
        );
    }

    #[test]
    fn policy_normalization_clamps_hostile_values() {
        let p = RetryPolicy {
            max_attempts: 0,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
            budget: Duration::from_secs(1 << 30),
        }
        .normalized();
        assert_eq!(p.max_attempts, 1);
        assert!(p.base_backoff >= Duration::from_millis(1));
        assert!(p.max_backoff >= p.base_backoff);
        assert!(p.budget <= Duration::from_secs(60));
    }

    #[test]
    fn injected_connect_refusal_needs_no_listener() {
        let cfg = ClientConfig {
            faults: Some(crate::util::faults::FaultPlan::parse("connect_refuse=1").unwrap()),
            ..Default::default()
        };
        // Address is never dialed: the fault fires first.
        let err = request("203.0.113.1:9", "GET", "/v1/healthz", None, &[], &cfg).unwrap_err();
        assert!(matches!(err, TransportError::Refused(_)), "{err:?}");
        assert!(err.before_send());
    }

    #[test]
    fn retry_policy_gives_up_within_budget() {
        let cfg = ClientConfig {
            connect_timeout: Duration::from_millis(200),
            faults: Some(crate::util::faults::FaultPlan::parse("connect_refuse=1").unwrap()),
            ..Default::default()
        };
        let policy = RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(10),
            budget: Duration::from_millis(500),
        };
        let t0 = Instant::now();
        let err = request_with_retry(
            "203.0.113.1:9",
            "POST",
            "/v1/run",
            Some(b"{}"),
            &[],
            &cfg,
            &policy,
            false,
        )
        .unwrap_err();
        assert!(matches!(err, TransportError::Refused(_)));
        // 4 attempts (refused is before-send, so even non-idempotent
        // requests retried), all faster than the budget.
        assert_eq!(cfg.faults.as_ref().unwrap().injected(FaultSite::ConnectRefuse), 4);
        assert!(t0.elapsed() < Duration::from_secs(2));
    }
}
