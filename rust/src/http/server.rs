//! The listener: accept loop, per-connection threads, keep-alive, and
//! graceful shutdown through `RoutineServer::drain`.
//!
//! Thread-per-connection is deliberate: the expensive work (lowering,
//! backend execution) already runs on the `RoutineServer`'s dispatcher
//! pool, so connection threads spend their lives parked in blocking
//! reads. The connection count is capped ([`HttpConfig::max_connections`])
//! and every socket carries a read timeout, so a slow-loris peer costs
//! one bounded thread, not the listener.
//!
//! In fleet mode (a router with >1 peers) the server also runs a
//! background probe thread that GETs every peer's `/v1/healthz` each
//! [`HttpConfig::probe_interval`], feeding the router's circuit
//! breakers so a dead peer is detected even when no traffic routes to
//! it (DESIGN.md §14). A configured [`FaultPlan`] injects 503 bursts at
//! accept, read stalls and truncated responses per connection — the
//! chaos suite's server-side failure modes.

use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{ApiError, ErrorCode, RETRY_AFTER_SECS};
use crate::serve::RoutineServer;
use crate::util::faults::{FaultPlan, FaultSite};
use crate::{Error, Result};

use super::framing::{read_request, write_response, write_response_with, FrameError};
use super::handlers::{handle, Ctx};
use super::router::ShardRouter;

/// `retry-after` header attached to every 429/503 (DESIGN.md §14).
const RETRY_AFTER_HEADER: &[(&str, &str)] = &[("retry-after", "1")];
// The literal must track the API constant; a const assert keeps them
// honest without a runtime format.
const _: () = assert!(RETRY_AFTER_SECS == 1);

/// HTTP-layer limits. All clamped in [`HttpConfig::normalized`]; hostile
/// values degrade to the envelope instead of erroring, matching the
/// serving layer's PR 7 posture.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Largest request body we will buffer.
    pub max_body: usize,
    /// Most items one `/v1/batch` may carry.
    pub max_batch_items: usize,
    /// Socket read timeout: a peer silent this long is dropped.
    pub read_timeout: Duration,
    /// Bound on one request's end-to-end wait for the serving layer.
    pub request_timeout: Duration,
    /// Default `/v1/drain` (and shutdown) drain bound.
    pub drain_timeout: Duration,
    /// Concurrent-connection cap; excess connections get a 503 and close.
    pub max_connections: usize,
    /// Period of the background peer-health probe (fleet mode only).
    pub probe_interval: Duration,
    /// Server-side chaos hook: 503 bursts at accept, read stalls and
    /// response truncation per connection. `None` in production.
    pub faults: Option<FaultPlan>,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            max_body: 4 * 1024 * 1024,
            max_batch_items: 256,
            read_timeout: Duration::from_secs(10),
            request_timeout: Duration::from_secs(60),
            drain_timeout: Duration::from_secs(5),
            max_connections: 1024,
            probe_interval: Duration::from_millis(500),
            faults: None,
        }
    }
}

impl HttpConfig {
    /// Clamp hostile values to the workable envelope (pub so the CLI
    /// and the failure-injection suite share one clamping story).
    pub fn normalized(self) -> HttpConfig {
        HttpConfig {
            max_body: self.max_body.max(1024),
            max_batch_items: self.max_batch_items.max(1),
            read_timeout: self.read_timeout.max(Duration::from_millis(10)),
            request_timeout: self.request_timeout.max(Duration::from_millis(10)),
            // zero means "purge immediately", which drain supports; only
            // cap nothing here.
            drain_timeout: self.drain_timeout,
            max_connections: self.max_connections.max(1),
            probe_interval: self
                .probe_interval
                .clamp(Duration::from_millis(10), Duration::from_secs(60)),
            faults: self.faults,
        }
    }
}

/// A running HTTP front door over one [`RoutineServer`].
pub struct HttpServer {
    ctx: Arc<Ctx>,
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    probe_thread: Option<JoinHandle<()>>,
    conns: Arc<ConnTracker>,
}

/// Live-connection bookkeeping: a counter for the cap and the join
/// handles so shutdown can wait for in-flight responses to flush.
struct ConnTracker {
    live: AtomicUsize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl HttpServer {
    /// Bind `addr` and start serving. `addr` may use port 0 to let the
    /// OS pick (see [`HttpServer::local_addr`]) — tests rely on this.
    pub fn bind(
        addr: &str,
        server: Arc<RoutineServer>,
        router: Option<ShardRouter>,
        cfg: HttpConfig,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Runtime(format!("bind {addr}: {e}")))?;
        let local = listener.local_addr().map_err(Error::Io)?;
        let cfg = cfg.normalized();
        let probe_interval = cfg.probe_interval;
        // The probe thread needs its own router handle; clones share
        // one health table, so probe results and proxy results land in
        // the same breakers.
        let probe_router = router.clone();
        let ctx = Arc::new(Ctx::new(server, router, cfg));
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(ConnTracker {
            live: AtomicUsize::new(0),
            handles: Mutex::new(Vec::new()),
        });

        let accept_ctx = ctx.clone();
        let accept_stop = stop.clone();
        let accept_conns = conns.clone();
        let accept_thread = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || accept_loop(listener, accept_ctx, accept_stop, accept_conns))
            .map_err(Error::Io)?;

        let probe_thread = match probe_router {
            Some(router) if router.peers().len() > 1 => {
                let probe_stop = stop.clone();
                Some(
                    std::thread::Builder::new()
                        .name("http-probe".into())
                        .spawn(move || probe_loop(router, probe_stop, probe_interval))
                        .map_err(Error::Io)?,
                )
            }
            _ => None,
        };

        Ok(HttpServer {
            ctx,
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            probe_thread,
            conns,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Shared handler context (tests poke `draining`; `shutdown` uses it).
    pub fn routine_server(&self) -> &Arc<RoutineServer> {
        &self.ctx.server
    }

    /// Whether `/v1/drain` has been requested (the CLI's exit signal).
    pub fn is_draining(&self) -> bool {
        self.ctx.draining.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: stop accepting, finish in-flight connections,
    /// then drain the serving layer. Returns whether the drain completed
    /// inside the configured bound.
    pub fn shutdown(mut self) -> bool {
        self.stop_listener();
        self.ctx.draining.store(true, Ordering::SeqCst);
        self.ctx.server.drain(self.ctx.cfg.drain_timeout)
    }

    fn stop_listener(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Poke the blocking accept() awake so the loop observes `stop`.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(500));
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.probe_thread.take() {
            let _ = t.join();
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.conns.handles.lock().expect("conn handles poisoned"));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_listener();
    }
}

/// Background peer-health loop: probe every non-self peer once per
/// interval, sleeping in short slices so shutdown never waits out a
/// long interval.
fn probe_loop(router: ShardRouter, stop: Arc<AtomicBool>, interval: Duration) {
    while !stop.load(Ordering::SeqCst) {
        for shard in 0..router.peers().len() {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            if shard != router.self_index() {
                router.probe(shard);
            }
        }
        let mut slept = Duration::ZERO;
        while slept < interval {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let step = Duration::from_millis(20).min(interval - slept);
            std::thread::sleep(step);
            slept += step;
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    ctx: Arc<Ctx>,
    stop: Arc<AtomicBool>,
    conns: Arc<ConnTracker>,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        if let Some(faults) = &ctx.cfg.faults {
            if faults.fire(FaultSite::Http503Burst) {
                let e = ApiError::new(ErrorCode::ShedDraining, "injected 503 burst");
                let mut s = stream;
                let _ = write_response_with(
                    &mut s,
                    503,
                    e.to_json().to_compact().as_bytes(),
                    false,
                    RETRY_AFTER_HEADER,
                );
                continue;
            }
        }
        if conns.live.load(Ordering::SeqCst) >= ctx.cfg.max_connections {
            let e = ApiError::new(ErrorCode::ShedDraining, "connection limit reached");
            let mut s = stream;
            let _ = write_response_with(
                &mut s,
                503,
                e.to_json().to_compact().as_bytes(),
                false,
                RETRY_AFTER_HEADER,
            );
            continue;
        }
        conns.live.fetch_add(1, Ordering::SeqCst);
        let conn_ctx = ctx.clone();
        let conn_stop = stop.clone();
        let conn_conns = conns.clone();
        let handle = std::thread::Builder::new().name("http-conn".into()).spawn(move || {
            serve_connection(stream, &conn_ctx, &conn_stop);
            conn_conns.live.fetch_sub(1, Ordering::SeqCst);
        });
        match handle {
            Ok(h) => {
                let mut guard = conns.handles.lock().expect("conn handles poisoned");
                // prune finished threads so the vec tracks live
                // connections, not connection history.
                guard.retain(|h| !h.is_finished());
                guard.push(h);
            }
            Err(_) => {
                conns.live.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// One connection's request loop: frame, handle, respond, repeat while
/// keep-alive holds. Framing failures answer with a structured error
/// where the stream is still coherent (oversized body, malformed head)
/// and close either way. Every 429/503 carries `retry-after` so
/// well-behaved clients back off instead of hammering.
fn serve_connection(stream: TcpStream, ctx: &Ctx, stop: &AtomicBool) {
    let _ = stream.set_read_timeout(Some(ctx.cfg.read_timeout));
    let _ = stream.set_write_timeout(Some(ctx.cfg.read_timeout));
    let _ = stream.set_nodelay(true);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);

    loop {
        match read_request(&mut reader, ctx.cfg.max_body) {
            Ok(req) => {
                let keep_alive = req.keep_alive() && !stop.load(Ordering::SeqCst);
                if let Some(faults) = &ctx.cfg.faults {
                    if faults.fire(FaultSite::ReadStall) {
                        // Injected slow peer: hold the parsed request
                        // before handling it.
                        std::thread::sleep(faults.stall());
                    }
                }
                let (status, body) = handle(ctx, &req);
                let bytes = body.to_compact().into_bytes();
                if let Some(faults) = &ctx.cfg.faults {
                    if faults.fire(FaultSite::ResponseTruncate) {
                        // Serialize the full frame, send half, close:
                        // the client must classify this as truncation.
                        let mut frame = Vec::new();
                        let _ = write_response(&mut frame, status, &bytes, false);
                        let _ = writer.write_all(&frame[..frame.len() / 2]);
                        let _ = writer.flush();
                        return;
                    }
                }
                let extra: &[(&str, &str)] = if status == 429 || status == 503 {
                    RETRY_AFTER_HEADER
                } else {
                    &[]
                };
                if write_response_with(&mut writer, status, &bytes, keep_alive, extra).is_err() {
                    return;
                }
                if !keep_alive {
                    return;
                }
            }
            Err(FrameError::BodyTooLarge { limit }) => {
                // well-formed frame, oversized declaration: answer 413.
                // The unread body leaves the stream out of sync, so close.
                let e = ApiError::new(
                    ErrorCode::PayloadTooLarge,
                    format!("request body exceeds the {limit}-byte limit"),
                );
                let body = e.to_json().to_compact();
                let _ = write_response(&mut writer, 413, body.as_bytes(), false);
                return;
            }
            Err(FrameError::Malformed(msg)) => {
                let e = ApiError::new(ErrorCode::BadRequest, format!("malformed request: {msg}"));
                let body = e.to_json().to_compact();
                let _ = write_response(&mut writer, 400, body.as_bytes(), false);
                return;
            }
            // clean close between requests, or a dead/timed-out peer:
            // nothing sensible to send.
            Err(FrameError::Eof) | Err(FrameError::Io(_)) => return,
        }
    }
}
