//! Minimal HTTP/1.1 framing over blocking streams (DESIGN.md §13).
//!
//! Just enough of RFC 9112 for a JSON API behind a trusted load balancer:
//! `Content-Length` bodies only (no chunked transfer coding), header
//! names lowercased at parse, a hard cap on header-block and body size so
//! a hostile peer cannot balloon memory, and explicit keep-alive
//! semantics (HTTP/1.1 defaults on, `Connection: close` or HTTP/1.0
//! turns it off). Everything reads through `BufRead`, so the server
//! wraps each connection in one `BufReader` and repeated keep-alive
//! requests reuse its buffer.

use std::io::{BufRead, Read, Write};

/// Cap on the request line + header block. 16 KiB fits any sane client;
/// past it we assume abuse and drop the connection.
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Cap on a single header/request line within the head.
const MAX_LINE_BYTES: usize = 8 * 1024;

/// Why a request could not be framed. The server maps each variant to a
/// status (or silence, for a clean EOF between keep-alive requests).
#[derive(Debug)]
pub enum FrameError {
    /// Clean end of stream before any request byte — the peer closed a
    /// keep-alive connection. Not an error; just stop serving it.
    Eof,
    /// Transport failure (includes read timeouts).
    Io(std::io::Error),
    /// The bytes were not a parseable HTTP/1.x request.
    Malformed(String),
    /// `Content-Length` exceeded the configured limit. The request is a
    /// well-formed frame, so the server can still answer 413.
    BodyTooLarge { limit: usize },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Eof => write!(f, "connection closed"),
            FrameError::Io(e) => write!(f, "i/o error: {e}"),
            FrameError::Malformed(m) => write!(f, "malformed request: {m}"),
            FrameError::BodyTooLarge { limit } => {
                write!(f, "request body exceeds the {limit}-byte limit")
            }
        }
    }
}

/// One parsed request. Header names are lowercased; values are trimmed.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// `HTTP/1.1` or `HTTP/1.0` (anything else is rejected at parse).
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First value of `name` (callers pass lowercase).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the connection should stay open after this exchange.
    /// HTTP/1.1 defaults to keep-alive unless `Connection: close`;
    /// HTTP/1.0 defaults to close unless `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        let conn = self.header("connection").map(|v| v.to_ascii_lowercase());
        match self.version.as_str() {
            "HTTP/1.0" => conn.as_deref() == Some("keep-alive"),
            _ => conn.as_deref() != Some("close"),
        }
    }
}

/// Read one `\r\n`- (or `\n`-) terminated line, refusing to buffer more
/// than `MAX_LINE_BYTES`. Returns the line without its terminator.
fn read_line(r: &mut impl BufRead, first: bool) -> Result<String, FrameError> {
    let mut line = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                // EOF with nothing read on the request line = peer closed
                // between keep-alive requests; mid-line EOF is malformed.
                if first && line.is_empty() {
                    return Err(FrameError::Eof);
                }
                return Err(FrameError::Malformed("unexpected end of stream".into()));
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    return String::from_utf8(line)
                        .map_err(|_| FrameError::Malformed("non-utf8 header bytes".into()));
                }
                line.push(byte[0]);
                if line.len() > MAX_LINE_BYTES {
                    return Err(FrameError::Malformed("header line too long".into()));
                }
            }
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
}

/// Frame one request off the stream. `max_body` bounds the body we will
/// buffer; a larger declared `Content-Length` yields
/// [`FrameError::BodyTooLarge`] *without* reading the body (the server
/// answers 413 and closes, since the stream is no longer in sync).
pub fn read_request(r: &mut impl BufRead, max_body: usize) -> Result<HttpRequest, FrameError> {
    let request_line = read_line(r, true)?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| FrameError::Malformed("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| FrameError::Malformed("request line missing path".into()))?
        .to_string();
    let version = parts
        .next()
        .ok_or_else(|| FrameError::Malformed("request line missing version".into()))?
        .to_string();
    if parts.next().is_some() {
        return Err(FrameError::Malformed("request line has trailing tokens".into()));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(FrameError::Malformed(format!("unsupported version {version:?}")));
    }

    let mut headers = Vec::new();
    let mut head_bytes = request_line.len();
    loop {
        let line = read_line(r, false)?;
        if line.is_empty() {
            break;
        }
        head_bytes += line.len();
        if head_bytes > MAX_HEAD_BYTES {
            return Err(FrameError::Malformed("header block too large".into()));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| FrameError::Malformed(format!("header without colon: {line:?}")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let req = HttpRequest { method, path, version, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        return Err(FrameError::Malformed("transfer-encoding is not supported".into()));
    }
    let len = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| FrameError::Malformed(format!("bad content-length {v:?}")))?,
    };
    if len > max_body {
        return Err(FrameError::BodyTooLarge { limit: max_body });
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        r.read_exact(&mut body).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                FrameError::Malformed("body shorter than content-length".into())
            } else {
                FrameError::Io(e)
            }
        })?;
    }
    Ok(HttpRequest { body, ..req })
}

/// One response, as the client side parses it.
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// Frame one response off the stream (the proxy/client side).
pub fn read_response(r: &mut impl BufRead, max_body: usize) -> Result<HttpResponse, FrameError> {
    let status_line = read_line(r, true)?;
    let mut parts = status_line.split_whitespace();
    let version = parts.next().unwrap_or_default();
    if !version.starts_with("HTTP/1.") {
        return Err(FrameError::Malformed(format!("bad status line {status_line:?}")));
    }
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| FrameError::Malformed("status line missing code".into()))?;

    let mut headers = Vec::new();
    loop {
        let line = read_line(r, false)?;
        if line.is_empty() {
            break;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| FrameError::Malformed(format!("header without colon: {line:?}")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let resp = HttpResponse { status, headers, body: Vec::new() };
    let len = match resp.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| FrameError::Malformed(format!("bad content-length {v:?}")))?,
    };
    if len > max_body {
        return Err(FrameError::BodyTooLarge { limit: max_body });
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        r.read_exact(&mut body).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                FrameError::Malformed("body shorter than content-length".into())
            } else {
                FrameError::Io(e)
            }
        })?;
    }
    Ok(HttpResponse { body, ..resp })
}

/// Standard reason phrases for the statuses this API emits.
pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write one `application/json` response frame.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write_response_with(w, status, body, keep_alive, &[])
}

/// [`write_response`] plus extra headers (e.g. `retry-after` on every
/// 429/503 — DESIGN.md §14). Callers own header validity; names and
/// values must be token/field-safe.
pub fn write_response_with(
    w: &mut impl Write,
    status: u16,
    body: &[u8],
    keep_alive: bool,
    extra_headers: &[(&str, &str)],
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: {}\r\n",
        reason_phrase(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(bytes: &[u8], max_body: usize) -> Result<HttpRequest, FrameError> {
        read_request(&mut BufReader::new(bytes), max_body)
    }

    #[test]
    fn parses_post_with_body_and_keep_alive_defaults() {
        let req = parse(
            b"POST /v1/run HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd",
            1024,
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/run");
        assert_eq!(req.body, b"abcd");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.keep_alive());
    }

    #[test]
    fn connection_close_and_http10_semantics() {
        let close = parse(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n", 64).unwrap();
        assert!(!close.keep_alive());
        let old = parse(b"GET / HTTP/1.0\r\n\r\n", 64).unwrap();
        assert!(!old.keep_alive());
        let old_ka = parse(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n", 64).unwrap();
        assert!(old_ka.keep_alive());
    }

    #[test]
    fn rejects_oversized_body_without_reading_it() {
        let err = parse(b"POST /v1/run HTTP/1.1\r\nContent-Length: 999\r\n\r\n", 100).unwrap_err();
        match err {
            FrameError::BodyTooLarge { limit } => assert_eq!(limit, 100),
            other => panic!("expected BodyTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn rejects_malformed_frames() {
        for bytes in [
            &b"NOT A REQUEST\r\n\r\n"[..],
            &b"GET /x HTTP/2\r\n\r\n"[..],
            &b"GET /x HTTP/1.1\r\nbadheader\r\n\r\n"[..],
            &b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n"[..],
            &b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"[..],
            &b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort"[..],
        ] {
            assert!(
                matches!(parse(bytes, 1024), Err(FrameError::Malformed(_))),
                "{:?}",
                String::from_utf8_lossy(bytes)
            );
        }
    }

    #[test]
    fn eof_before_any_byte_is_eof_not_malformed() {
        assert!(matches!(parse(b"", 64), Err(FrameError::Eof)));
        assert!(matches!(parse(b"GET", 64), Err(FrameError::Malformed(_))));
    }

    #[test]
    fn response_round_trips_through_writer_and_reader() {
        let mut wire = Vec::new();
        write_response(&mut wire, 429, br#"{"e":1}"#, true).unwrap();
        let resp = read_response(&mut BufReader::new(&wire[..]), 1024).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.body, br#"{"e":1}"#);
        assert_eq!(resp.header("connection"), Some("keep-alive"));
        assert_eq!(resp.header("content-type"), Some("application/json"));
    }

    #[test]
    fn extra_headers_ride_the_response_frame() {
        let mut wire = Vec::new();
        write_response_with(&mut wire, 503, br#"{"e":1}"#, false, &[("retry-after", "1")])
            .unwrap();
        let resp = read_response(&mut BufReader::new(&wire[..]), 1024).unwrap();
        assert_eq!(resp.status, 503);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.header("connection"), Some("close"));
        assert_eq!(resp.body, br#"{"e":1}"#);
    }

    #[test]
    fn bare_lf_line_endings_are_tolerated() {
        let req = parse(b"GET /v1/healthz HTTP/1.1\nHost: x\n\n", 64).unwrap();
        assert_eq!(req.path, "/v1/healthz");
        assert_eq!(req.header("host"), Some("x"));
    }
}
