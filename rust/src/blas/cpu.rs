//! Optimized multithreaded CPU BLAS — the OpenBLAS stand-in for Fig. 3.
//!
//! The paper's CPU baseline is OpenBLAS 0.3.27 on a dual 10-core Xeon;
//! level-1/2 BLAS there is memory-bandwidth-bound, so a blocked + threaded
//! implementation reaches the same roofline regime (DESIGN.md §1). The hot
//! loops are written so LLVM auto-vectorizes them (verified: disassembly
//! shows packed `vmulps`/`vfmadd` on x86-64).
//!
//! Parallelisation thresholds: spawning threads costs ~10 µs; level-1 ops
//! under ~64 K elements run single-threaded (mirrors OpenBLAS's own
//! threshold behaviour).

use crate::util::threadpool::{parallel_chunks, parallel_reduce};

/// Below this many elements, run level-1 routines inline.
const PAR_THRESHOLD: usize = 1 << 16;
/// Minimum per-thread chunk for level-1 routines.
const MIN_CHUNK: usize = 1 << 14;

/// z = alpha*x + y.
pub fn axpy(alpha: f32, x: &[f32], y: &[f32], z: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), z.len());
    let n = x.len();
    if n < PAR_THRESHOLD {
        axpy_serial(alpha, x, y, z);
        return;
    }
    // SAFETY-free parallel mutation: chunks are disjoint; expose the output
    // as a raw pointer wrapped in a Sync carrier.
    let zp = SyncPtr(z.as_mut_ptr());
    parallel_chunks(n, MIN_CHUNK, |_, s, e| {
        let zs = unsafe { std::slice::from_raw_parts_mut(zp.get().add(s), e - s) };
        axpy_serial(alpha, &x[s..e], &y[s..e], zs);
    });
}

fn axpy_serial(alpha: f32, x: &[f32], y: &[f32], z: &mut [f32]) {
    // Simple indexed loop: bounds are hoisted, LLVM vectorizes + unrolls.
    for i in 0..z.len() {
        z[i] = alpha.mul_add(x[i], y[i]);
    }
}

/// z = alpha*x.
pub fn scal(alpha: f32, x: &[f32], z: &mut [f32]) {
    assert_eq!(x.len(), z.len());
    let n = x.len();
    if n < PAR_THRESHOLD {
        for i in 0..n {
            z[i] = alpha * x[i];
        }
        return;
    }
    let zp = SyncPtr(z.as_mut_ptr());
    parallel_chunks(n, MIN_CHUNK, |_, s, e| {
        let zs = unsafe { std::slice::from_raw_parts_mut(zp.get().add(s), e - s) };
        for (zi, &xi) in zs.iter_mut().zip(&x[s..e]) {
            *zi = alpha * xi;
        }
    });
}

/// xᵀy with 8-way unrolled partial sums (independent accumulator chains let
/// the FMA units pipeline; a single chain is latency-bound at ~4 cycles).
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n < PAR_THRESHOLD {
        return dot_serial(x, y);
    }
    parallel_reduce(
        n,
        MIN_CHUNK,
        0.0f64,
        |s, e| dot_serial(&x[s..e], &y[s..e]) as f64,
        |a, b| a + b,
    ) as f32
}

fn dot_serial(x: &[f32], y: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let chunks = x.len() / 8;
    for c in 0..chunks {
        let b = c * 8;
        for l in 0..8 {
            acc[l] = x[b + l].mul_add(y[b + l], acc[l]);
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..x.len() {
        tail = x[i].mul_add(y[i], tail);
    }
    acc.iter().sum::<f32>() + tail
}

/// ||x||₂ (scaled to avoid overflow like reference LAPACK snrm2 would is
/// unnecessary at our test magnitudes; plain sum-of-squares in f64).
pub fn nrm2(x: &[f32]) -> f32 {
    let ss = if x.len() < PAR_THRESHOLD {
        x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
    } else {
        parallel_reduce(
            x.len(),
            MIN_CHUNK,
            0.0f64,
            |s, e| x[s..e].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>(),
            |a, b| a + b,
        )
    };
    (ss.sqrt()) as f32
}

/// Σ|xᵢ|.
pub fn asum(x: &[f32]) -> f32 {
    if x.len() < PAR_THRESHOLD {
        return x.iter().map(|v| v.abs()).sum();
    }
    parallel_reduce(
        x.len(),
        MIN_CHUNK,
        0.0f64,
        |s, e| x[s..e].iter().map(|&v| v.abs() as f64).sum::<f64>(),
        |a, b| a + b,
    ) as f32
}

/// First index of max |xᵢ|.
pub fn iamax(x: &[f32]) -> usize {
    if x.is_empty() {
        return 0;
    }
    if x.len() < PAR_THRESHOLD {
        return iamax_serial(x, 0);
    }
    let best = parallel_reduce(
        x.len(),
        MIN_CHUNK,
        (f32::MIN, usize::MAX),
        |s, e| {
            let i = iamax_serial(&x[s..e], s);
            (x[i].abs(), i)
        },
        // strictly-greater keeps the FIRST maximal index (combination is
        // left-to-right and deterministic).
        |a, b| if b.0 > a.0 { b } else { a },
    );
    best.1
}

fn iamax_serial(x: &[f32], offset: usize) -> usize {
    let mut bi = 0usize;
    let mut bv = f32::MIN;
    for (i, &v) in x.iter().enumerate() {
        let a = v.abs();
        if a > bv {
            bv = a;
            bi = i;
        }
    }
    offset + bi
}

/// y' = alpha*A@x + beta*y, row-major (m,n); rows are parallelised.
pub fn gemv(alpha: f32, a: &[f32], m: usize, n: usize, x: &[f32], beta: f32, y: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    assert_eq!(out.len(), m);
    let op = SyncPtr(out.as_mut_ptr());
    // one row is n MACs; parallelise when the whole problem is big enough.
    let min_rows = (PAR_THRESHOLD / n.max(1)).max(1);
    parallel_chunks(m, min_rows, |_, rs, re| {
        let os = unsafe { std::slice::from_raw_parts_mut(op.get().add(rs), re - rs) };
        for (local, i) in (rs..re).enumerate() {
            let row = &a[i * n..(i + 1) * n];
            os[local] = alpha * dot_serial(row, x) + beta * y[i];
        }
    });
}

/// C' = alpha*A@B + beta*C, blocked i-k-j loop order (B rows stream through
/// cache; the j-innermost loop is contiguous in both B and C so it
/// vectorizes), row blocks parallelised.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    alpha: f32,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    beta: f32,
    c: &[f32],
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    assert_eq!(out.len(), m * n);
    let op = SyncPtr(out.as_mut_ptr());
    let min_rows = (PAR_THRESHOLD / (k * n).max(1)).max(1);
    parallel_chunks(m, min_rows, |_, rs, re| {
        let os = unsafe { std::slice::from_raw_parts_mut(op.get().add(rs * n), (re - rs) * n) };
        // init with beta*C
        for (local, i) in (rs..re).enumerate() {
            let orow = &mut os[local * n..(local + 1) * n];
            let crow = &c[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] = beta * crow[j];
            }
        }
        // accumulate alpha * A[i,l] * B[l,:]
        for (local, i) in (rs..re).enumerate() {
            let orow = &mut os[local * n..(local + 1) * n];
            for l in 0..k {
                let ail = alpha * a[i * k + l];
                let brow = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    orow[j] = ail.mul_add(brow[j], orow[j]);
                }
            }
        }
    });
}

/// z = alpha·x + beta·y, threaded.
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &[f32], z: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), z.len());
    let n = x.len();
    let zp = SyncPtr(z.as_mut_ptr());
    parallel_chunks(n, MIN_CHUNK, |_, s_, e| {
        let zs = unsafe { std::slice::from_raw_parts_mut(zp.get().add(s_), e - s_) };
        for (i, zi) in zs.iter_mut().enumerate() {
            *zi = alpha.mul_add(x[s_ + i], beta * y[s_ + i]);
        }
    });
}

/// Givens rotation, threaded over disjoint chunks of both outputs.
pub fn rot(c: f32, s: f32, x: &[f32], y: &[f32], xo: &mut [f32], yo: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), xo.len());
    assert_eq!(x.len(), yo.len());
    let n = x.len();
    let xp = SyncPtr(xo.as_mut_ptr());
    let yp = SyncPtr(yo.as_mut_ptr());
    parallel_chunks(n, MIN_CHUNK, |_, s_, e| {
        let xs = unsafe { std::slice::from_raw_parts_mut(xp.get().add(s_), e - s_) };
        let ys = unsafe { std::slice::from_raw_parts_mut(yp.get().add(s_), e - s_) };
        for i in 0..(e - s_) {
            let (xi, yi) = (x[s_ + i], y[s_ + i]);
            xs[i] = c.mul_add(xi, s * yi);
            ys[i] = c.mul_add(yi, -(s * xi));
        }
    });
}

/// A' = A + alpha·x·yᵀ, rows threaded.
pub fn ger(alpha: f32, x: &[f32], y: &[f32], a: &[f32], m: usize, n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m);
    assert_eq!(y.len(), n);
    assert_eq!(a.len(), m * n);
    assert_eq!(out.len(), m * n);
    let op = SyncPtr(out.as_mut_ptr());
    let min_rows = (PAR_THRESHOLD / n.max(1)).max(1);
    parallel_chunks(m, min_rows, |_, rs, re| {
        let os = unsafe { std::slice::from_raw_parts_mut(op.get().add(rs * n), (re - rs) * n) };
        for (local, i) in (rs..re).enumerate() {
            let ax = alpha * x[i];
            let arow = &a[i * n..(i + 1) * n];
            let orow = &mut os[local * n..(local + 1) * n];
            for j in 0..n {
                orow[j] = ax.mul_add(y[j], arow[j]);
            }
        }
    });
}

/// β = (w − alpha·v)ᵀu, fused single pass (no z materialization — the CPU
/// analog of the dataflow composition).
pub fn axpydot(alpha: f32, w: &[f32], v: &[f32], u: &[f32]) -> f32 {
    assert_eq!(w.len(), v.len());
    assert_eq!(w.len(), u.len());
    let n = w.len();
    // 8 independent accumulator chains — a single chain is FMA-latency
    // bound (measured 6 GB/s vs 25 GB/s; EXPERIMENTS.md §Perf iter 4).
    let body = |s: usize, e: usize| -> f64 {
        let (ws, vs, us) = (&w[s..e], &v[s..e], &u[s..e]);
        let mut acc = [0.0f32; 8];
        let chunks = ws.len() / 8;
        for c in 0..chunks {
            let b = c * 8;
            for l in 0..8 {
                acc[l] = (ws[b + l] - alpha * vs[b + l]).mul_add(us[b + l], acc[l]);
            }
        }
        let mut tail = 0.0f32;
        for i in chunks * 8..ws.len() {
            tail = (ws[i] - alpha * vs[i]).mul_add(us[i], tail);
        }
        (acc.iter().sum::<f32>() + tail) as f64
    };
    if n < PAR_THRESHOLD {
        return body(0, n) as f32;
    }
    parallel_reduce(n, MIN_CHUNK, 0.0f64, body, |a, b| a + b) as f32
}

/// Send+Sync raw-pointer carrier for disjoint-chunk parallel writes.
///
/// Closures must capture the *struct* (via [`SyncPtr::get`]); capturing the
/// `.0` field directly would disjoint-capture the raw pointer, which is not
/// `Sync`.
struct SyncPtr(*mut f32);
unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

impl SyncPtr {
    fn get(&self) -> *mut f32 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::reference as ref_;
    use crate::util::rng::Rng;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    #[test]
    fn matches_reference_small_and_large() {
        let mut rng = Rng::new(1);
        for n in [0usize, 1, 7, 1024, (1 << 16) + 13] {
            let x = rng.normal_vec_f32(n);
            let y = rng.normal_vec_f32(n);
            let alpha = 1.25f32;

            let mut z1 = vec![0.0; n];
            let mut z2 = vec![0.0; n];
            axpy(alpha, &x, &y, &mut z1);
            ref_::axpy(alpha, &x, &y, &mut z2);
            assert_eq!(z1.len(), z2.len());
            for i in 0..n {
                assert!(close(z1[i], z2[i], 1e-6), "axpy n={n} i={i}");
            }

            assert!(close(dot(&x, &y), ref_::dot(&x, &y), 2e-3), "dot n={n}");
            assert!(close(nrm2(&x), ref_::nrm2(&x), 1e-4), "nrm2 n={n}");
            assert!(close(asum(&x), ref_::asum(&x), 1e-4), "asum n={n}");
            if n > 0 {
                // equality of values, not indexes (ties broken identically
                // because both keep the first maximum).
                assert_eq!(x[iamax(&x)].abs(), x[ref_::iamax(&x)].abs(), "iamax n={n}");
            }
            assert!(
                close(axpydot(alpha, &x, &y, &x), ref_::axpydot(alpha, &x, &y, &x), 2e-3),
                "axpydot n={n}"
            );
        }
    }

    #[test]
    fn gemv_matches_reference() {
        let mut rng = Rng::new(2);
        for (m, n) in [(1, 1), (3, 5), (64, 64), (200, 333)] {
            let a = rng.normal_vec_f32(m * n);
            let x = rng.normal_vec_f32(n);
            let y = rng.normal_vec_f32(m);
            let mut o1 = vec![0.0; m];
            let mut o2 = vec![0.0; m];
            gemv(0.7, &a, m, n, &x, -1.3, &y, &mut o1);
            ref_::gemv(0.7, &a, m, n, &x, -1.3, &y, &mut o2);
            for i in 0..m {
                assert!(close(o1[i], o2[i], 1e-3), "gemv ({m},{n}) row {i}: {} vs {}", o1[i], o2[i]);
            }
        }
    }

    #[test]
    fn gemm_matches_reference() {
        let mut rng = Rng::new(3);
        for (m, k, n) in [(1, 1, 1), (4, 7, 3), (32, 32, 32), (65, 33, 48)] {
            let a = rng.normal_vec_f32(m * k);
            let b = rng.normal_vec_f32(k * n);
            let c = rng.normal_vec_f32(m * n);
            let mut o1 = vec![0.0; m * n];
            let mut o2 = vec![0.0; m * n];
            gemm(1.1, &a, &b, m, k, n, 0.4, &c, &mut o1);
            ref_::gemm(1.1, &a, &b, m, k, n, 0.4, &c, &mut o2);
            for i in 0..m * n {
                assert!(close(o1[i], o2[i], 1e-3), "gemm ({m},{k},{n}) at {i}");
            }
        }
    }

    #[test]
    fn scal_parallel_path() {
        let n = (1 << 16) + 5;
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut z = vec![0.0; n];
        scal(2.0, &x, &mut z);
        assert_eq!(z[0], 0.0);
        assert_eq!(z[n - 1], 2.0 * (n - 1) as f32);
    }

    #[test]
    fn iamax_parallel_first_max() {
        // two equal maxima straddling a likely chunk boundary: first wins.
        let n = 1 << 17;
        let mut x = vec![0.5f32; n];
        x[100] = -9.0;
        x[n - 100] = 9.0;
        assert_eq!(iamax(&x), 100);
    }
}
