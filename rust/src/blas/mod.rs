//! BLAS routine registry: kinds, signatures and cost models.
//!
//! Mirrors the L2 registry in `python/compile/model.py`; the artifact
//! manifest keeps the two sides in sync. Each routine declares its
//! input/output *ports* — the unit the dataflow-graph builder composes
//! (paper §III: scalars travel on streams, vectors/matrices on windows).

pub mod cpu;
pub mod reference;

use std::fmt;

/// Data carried on one routine port.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PortType {
    /// A scalar (travels on an AXI stream in AIEBLAS).
    Scalar,
    /// A length-`n` vector (travels window-by-window).
    Vector,
    /// An `n×n` matrix (travels as 2-D windows).
    Matrix,
}

impl PortType {
    /// Number of f32 elements for problem size `n`.
    pub fn elements(self, n: usize) -> usize {
        match self {
            PortType::Scalar => 1,
            PortType::Vector => n,
            PortType::Matrix => n * n,
        }
    }
}

/// A named input or output port of a routine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Port {
    pub name: &'static str,
    pub ty: PortType,
}

const fn port(name: &'static str, ty: PortType) -> Port {
    Port { name, ty }
}

/// Every routine AIEBLAS knows how to generate.
///
/// `Axpydot` is the paper's composed example (β = zᵀu, z = w − αv); in a
/// *dataflow* build it is a two-kernel subgraph connected on-chip, in a
/// *non-dataflow* build two independent designs bouncing z through DDR.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoutineKind {
    Axpy,
    Axpby,
    Rot,
    Scal,
    Copy,
    Dot,
    Nrm2,
    Asum,
    Iamax,
    Gemv,
    Ger,
    Gemm,
    Axpydot,
}

impl RoutineKind {
    pub const ALL: [RoutineKind; 13] = [
        RoutineKind::Axpy,
        RoutineKind::Axpby,
        RoutineKind::Rot,
        RoutineKind::Scal,
        RoutineKind::Copy,
        RoutineKind::Dot,
        RoutineKind::Nrm2,
        RoutineKind::Asum,
        RoutineKind::Iamax,
        RoutineKind::Gemv,
        RoutineKind::Ger,
        RoutineKind::Gemm,
        RoutineKind::Axpydot,
    ];

    /// Registry name (matches the JSON spec and the python registry).
    pub fn name(self) -> &'static str {
        match self {
            RoutineKind::Axpy => "axpy",
            RoutineKind::Axpby => "axpby",
            RoutineKind::Rot => "rot",
            RoutineKind::Scal => "scal",
            RoutineKind::Copy => "copy",
            RoutineKind::Dot => "dot",
            RoutineKind::Nrm2 => "nrm2",
            RoutineKind::Asum => "asum",
            RoutineKind::Iamax => "iamax",
            RoutineKind::Gemv => "gemv",
            RoutineKind::Ger => "ger",
            RoutineKind::Gemm => "gemm",
            RoutineKind::Axpydot => "axpydot",
        }
    }

    pub fn from_name(name: &str) -> Option<RoutineKind> {
        RoutineKind::ALL.iter().copied().find(|k| k.name() == name)
    }

    /// BLAS level (axpydot is a level-1 composition).
    pub fn level(self) -> u8 {
        match self {
            RoutineKind::Gemv | RoutineKind::Ger => 2,
            RoutineKind::Gemm => 3,
            _ => 1,
        }
    }

    /// Is this a composite routine lowered to a multi-kernel subgraph?
    pub fn is_composite(self) -> bool {
        matches!(self, RoutineKind::Axpydot)
    }

    /// Input ports, in artifact parameter order.
    pub fn inputs(self) -> &'static [Port] {
        use PortType::*;
        macro_rules! ports {
            ($($p:expr),* $(,)?) => {{
                const P: &[Port] = &[$($p),*];
                P
            }};
        }
        match self {
            RoutineKind::Axpy => ports![port("alpha", Scalar), port("x", Vector), port("y", Vector)],
            RoutineKind::Axpby => ports![
                port("alpha", Scalar),
                port("beta", Scalar),
                port("x", Vector),
                port("y", Vector),
            ],
            RoutineKind::Rot => ports![
                port("c", Scalar),
                port("s", Scalar),
                port("x", Vector),
                port("y", Vector),
            ],
            RoutineKind::Scal => ports![port("alpha", Scalar), port("x", Vector)],
            RoutineKind::Copy => ports![port("x", Vector)],
            RoutineKind::Dot => ports![port("x", Vector), port("y", Vector)],
            RoutineKind::Nrm2 => ports![port("x", Vector)],
            RoutineKind::Asum => ports![port("x", Vector)],
            RoutineKind::Iamax => ports![port("x", Vector)],
            RoutineKind::Gemv => ports![
                port("alpha", Scalar),
                port("a", Matrix),
                port("x", Vector),
                port("beta", Scalar),
                port("y", Vector),
            ],
            RoutineKind::Ger => ports![
                port("alpha", Scalar),
                port("x", Vector),
                port("y", Vector),
                port("a", Matrix),
            ],
            RoutineKind::Gemm => ports![
                port("alpha", Scalar),
                port("a", Matrix),
                port("b", Matrix),
                port("beta", Scalar),
                port("c", Matrix),
            ],
            RoutineKind::Axpydot => ports![
                port("alpha", Scalar),
                port("w", Vector),
                port("v", Vector),
                port("u", Vector),
            ],
        }
    }

    /// Output ports.
    pub fn outputs(self) -> &'static [Port] {
        use PortType::*;
        macro_rules! ports {
            ($($p:expr),* $(,)?) => {{
                const P: &[Port] = &[$($p),*];
                P
            }};
        }
        match self {
            RoutineKind::Axpy | RoutineKind::Axpby | RoutineKind::Scal | RoutineKind::Copy => {
                ports![port("z", Vector)]
            }
            RoutineKind::Rot => ports![port("x_out", Vector), port("y_out", Vector)],
            RoutineKind::Dot => ports![port("result", Scalar)],
            RoutineKind::Nrm2 | RoutineKind::Asum => ports![port("result", Scalar)],
            RoutineKind::Iamax => ports![port("index", Scalar)],
            RoutineKind::Gemv => ports![port("y_out", Vector)],
            RoutineKind::Ger => ports![port("a_out", Matrix)],
            RoutineKind::Gemm => ports![port("c_out", Matrix)],
            RoutineKind::Axpydot => ports![port("beta_out", Scalar)],
        }
    }

    /// Floating-point operations for problem size `n` (square matrices).
    pub fn flops(self, n: usize) -> u64 {
        let n = n as u64;
        match self {
            RoutineKind::Axpy => 2 * n,
            RoutineKind::Axpby => 3 * n,
            RoutineKind::Rot => 6 * n,
            RoutineKind::Scal => n,
            RoutineKind::Copy => 0,
            RoutineKind::Dot => 2 * n,
            RoutineKind::Nrm2 => 2 * n + 1,
            RoutineKind::Asum => 2 * n,
            RoutineKind::Iamax => 2 * n,
            RoutineKind::Gemv => 2 * n * n + 3 * n,
            RoutineKind::Ger => 2 * n * n,
            RoutineKind::Gemm => 2 * n * n * n + 3 * n * n,
            RoutineKind::Axpydot => 4 * n,
        }
    }

    /// Bytes moved to/from off-chip memory for size `n` (f32), assuming all
    /// unconnected ports go through PL movers (the Fig. 3 "PL" variant).
    pub fn offchip_bytes(self, n: usize) -> u64 {
        let io: usize = self
            .inputs()
            .iter()
            .chain(self.outputs())
            .map(|p| p.ty.elements(n))
            .sum();
        (io * crate::arch::F32_BYTES) as u64
    }

    /// Arithmetic intensity (flops per off-chip byte) — classifies the
    /// routine as memory- or compute-bound, the axis the paper's analysis
    /// (§IV) hinges on.
    pub fn arithmetic_intensity(self, n: usize) -> f64 {
        let b = self.offchip_bytes(n);
        if b == 0 {
            return 0.0;
        }
        self.flops(n) as f64 / b as f64
    }
}

impl fmt::Display for RoutineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for k in RoutineKind::ALL {
            assert_eq!(RoutineKind::from_name(k.name()), Some(k));
        }
        assert_eq!(RoutineKind::from_name("nope"), None);
    }

    #[test]
    fn levels() {
        assert_eq!(RoutineKind::Axpy.level(), 1);
        assert_eq!(RoutineKind::Gemv.level(), 2);
        assert_eq!(RoutineKind::Gemm.level(), 3);
    }

    #[test]
    fn axpy_signature() {
        let k = RoutineKind::Axpy;
        assert_eq!(k.inputs().len(), 3);
        assert_eq!(k.inputs()[0].ty, PortType::Scalar);
        assert_eq!(k.outputs()[0].ty, PortType::Vector);
    }

    #[test]
    fn flop_counts() {
        assert_eq!(RoutineKind::Axpy.flops(1000), 2000);
        assert_eq!(RoutineKind::Dot.flops(1000), 2000);
        assert_eq!(RoutineKind::Gemv.flops(100), 2 * 100 * 100 + 300);
        assert_eq!(RoutineKind::Axpydot.flops(1000), 4000);
    }

    #[test]
    fn level1_is_memory_bound() {
        // Level-1 BLAS: O(1) flops per byte — the regime where Fig. 3 shows
        // off-chip access dominating.
        for k in [RoutineKind::Axpy, RoutineKind::Dot, RoutineKind::Axpydot] {
            assert!(k.arithmetic_intensity(1 << 20) < 1.0, "{k}");
        }
        // Level-3 is compute-bound at scale.
        assert!(RoutineKind::Gemm.arithmetic_intensity(512) > 10.0);
    }

    #[test]
    fn offchip_bytes_axpy() {
        // alpha(1) + x(n) + y(n) + z(n) floats
        assert_eq!(RoutineKind::Axpy.offchip_bytes(1024), (3 * 1024 + 1) as u64 * 4);
    }

    #[test]
    fn port_type_elements() {
        assert_eq!(PortType::Scalar.elements(99), 1);
        assert_eq!(PortType::Vector.elements(99), 99);
        assert_eq!(PortType::Matrix.elements(8), 64);
    }
}
