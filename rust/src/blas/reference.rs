//! Scalar reference implementations of every routine.
//!
//! These are the Rust-side ground truth: the CPU baseline, the simulator's
//! numeric sanity checks and the PJRT artifacts are all validated against
//! them (and the artifacts are in turn validated against the pure-jnp
//! oracles in python, closing the loop across the language boundary).
//!
//! Deliberately naive: clarity over speed. Speed lives in [`super::cpu`].

/// z = alpha*x + y (out of place, like AIEBLAS routines).
pub fn axpy(alpha: f32, x: &[f32], y: &[f32], z: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), z.len());
    for i in 0..x.len() {
        z[i] = alpha * x[i] + y[i];
    }
}

/// z = alpha*x.
pub fn scal(alpha: f32, x: &[f32], z: &mut [f32]) {
    assert_eq!(x.len(), z.len());
    for i in 0..x.len() {
        z[i] = alpha * x[i];
    }
}

/// z = x.
pub fn copy(x: &[f32], z: &mut [f32]) {
    z.copy_from_slice(x);
}

/// xᵀy.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    let mut acc = 0.0f32;
    for i in 0..x.len() {
        acc += x[i] * y[i];
    }
    acc
}

/// ||x||₂.
pub fn nrm2(x: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &v in x {
        acc += v * v;
    }
    acc.sqrt()
}

/// Σ|xᵢ|.
pub fn asum(x: &[f32]) -> f32 {
    x.iter().map(|v| v.abs()).sum()
}

/// First index of the maximum-magnitude element (BLAS ixamax). Returns 0
/// for an empty slice, matching the BLAS convention of 1-based 0 meaning
/// "invalid" shifted to 0-based.
pub fn iamax(x: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_val = f32::MIN;
    for (i, &v) in x.iter().enumerate() {
        let a = v.abs();
        if a > best_val {
            best_val = a;
            best = i;
        }
    }
    best
}

/// y' = alpha*A@x + beta*y for row-major `a` of shape (m, n).
pub fn gemv(alpha: f32, a: &[f32], m: usize, n: usize, x: &[f32], beta: f32, y: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    assert_eq!(out.len(), m);
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        let mut acc = 0.0f32;
        for j in 0..n {
            acc += row[j] * x[j];
        }
        out[i] = alpha * acc + beta * y[i];
    }
}

/// C' = alpha*A@B + beta*C for row-major (m,k)·(k,n).
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    alpha: f32,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    beta: f32,
    c: &[f32],
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += a[i * k + l] * b[l * n + j];
            }
            out[i * n + j] = alpha * acc + beta * c[i * n + j];
        }
    }
}

/// z = alpha·x + beta·y (extended-BLAS axpby).
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &[f32], z: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), z.len());
    for i in 0..x.len() {
        z[i] = alpha * x[i] + beta * y[i];
    }
}

/// Givens rotation: (x', y') = (c·x + s·y, c·y − s·x).
pub fn rot(c: f32, s: f32, x: &[f32], y: &[f32], xo: &mut [f32], yo: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), xo.len());
    assert_eq!(x.len(), yo.len());
    for i in 0..x.len() {
        xo[i] = c * x[i] + s * y[i];
        yo[i] = c * y[i] - s * x[i];
    }
}

/// A' = A + alpha·x·yᵀ (rank-1 update, row-major (m,n)).
pub fn ger(alpha: f32, x: &[f32], y: &[f32], a: &[f32], m: usize, n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m);
    assert_eq!(y.len(), n);
    assert_eq!(a.len(), m * n);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            out[i * n + j] = a[i * n + j] + alpha * x[i] * y[j];
        }
    }
}

/// β = zᵀu with z = w − alpha·v (the paper's composed axpydot).
pub fn axpydot(alpha: f32, w: &[f32], v: &[f32], u: &[f32]) -> f32 {
    assert_eq!(w.len(), v.len());
    assert_eq!(w.len(), u.len());
    let mut acc = 0.0f32;
    for i in 0..w.len() {
        acc += (w[i] - alpha * v[i]) * u[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() <= tol * (1.0 + b.abs()), "{a} vs {b}");
    }

    #[test]
    fn axpy_basic() {
        let mut z = vec![0.0; 3];
        axpy(2.0, &[1.0, 2.0, 3.0], &[10.0, 20.0, 30.0], &mut z);
        assert_eq!(z, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn dot_and_norms() {
        let x = [3.0, 4.0];
        assert_eq!(dot(&x, &x), 25.0);
        assert_eq!(nrm2(&x), 5.0);
        assert_eq!(asum(&[-1.0, 2.0, -3.0]), 6.0);
    }

    #[test]
    fn iamax_first_on_tie() {
        assert_eq!(iamax(&[1.0, -3.0, 3.0]), 1);
        assert_eq!(iamax(&[]), 0);
    }

    #[test]
    fn gemv_identity() {
        // 2x2 identity
        let a = [1.0, 0.0, 0.0, 1.0];
        let mut out = vec![0.0; 2];
        gemv(1.0, &a, 2, 2, &[5.0, 7.0], 0.0, &[0.0, 0.0], &mut out);
        assert_eq!(out, vec![5.0, 7.0]);
    }

    #[test]
    fn gemv_alpha_beta() {
        let a = [1.0, 2.0, 3.0, 4.0]; // [[1,2],[3,4]]
        let mut out = vec![0.0; 2];
        gemv(2.0, &a, 2, 2, &[1.0, 1.0], -1.0, &[1.0, 2.0], &mut out);
        // 2*[3,7] - [1,2] = [5,12]
        assert_eq!(out, vec![5.0, 12.0]);
    }

    #[test]
    fn gemm_small() {
        let a = [1.0, 2.0, 3.0, 4.0]; // 2x2
        let b = [5.0, 6.0, 7.0, 8.0];
        let c = [1.0; 4];
        let mut out = vec![0.0; 4];
        gemm(1.0, &a, &b, 2, 2, 2, 1.0, &c, &mut out);
        // A@B = [[19,22],[43,50]] + 1
        assert_eq!(out, vec![20.0, 23.0, 44.0, 51.0]);
    }

    #[test]
    fn axpydot_matches_manual_composition() {
        let w = [1.0, 2.0, 3.0];
        let v = [0.5, 0.5, 0.5];
        let u = [2.0, 2.0, 2.0];
        let alpha = 2.0;
        let mut z = vec![0.0; 3];
        axpy(-alpha, &v, &w, &mut z); // z = w - alpha*v
        assert_close(axpydot(alpha, &w, &v, &u), dot(&z, &u), 1e-6);
    }

    #[test]
    #[should_panic]
    fn axpy_length_mismatch_panics() {
        let mut z = vec![0.0; 2];
        axpy(1.0, &[1.0], &[1.0, 2.0], &mut z);
    }
}
