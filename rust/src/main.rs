//! `aieblas` — command-line interface to the AIEBLAS system.
//!
//! Subcommands:
//! * `validate <spec.json>` — parse + validate a routine specification;
//! * `generate <spec.json> --out <dir>` — emit the Vitis design (Fig. 1);
//! * `run <spec.json>` — lower through the staged pipeline (plan-cached)
//!   → simulate → numerics; `--repeat N` re-runs the spec to demonstrate
//!   warm plan-cache hits;
//! * `fig3 [--panel …]` — reproduce the paper's Fig. 3 series;
//! * `ablations` — the §V ablation sweeps;
//! * `serve` — run the HTTP/1.1 front door (DESIGN.md §13): `/v1/run`,
//!   `/v1/batch`, `/v1/healthz`, `/v1/statsz`, `/v1/drain`; `--peers` +
//!   `--shard-index` make this process one shard of a fleet sharing one
//!   `--cache-dir` plan store (requests consistent-hash by `PlanKey`);
//! * `serve-bench` — drive the concurrent serving layer (queue → batcher →
//!   backend pool) with a synthetic workload, batched vs unbatched;
//!   `--cache-dir` persists lowered plans and `--assert-warm` turns the
//!   run into a pass/fail warm-start check (zero lowerings, disk hits);
//! * `cache` — manage the persistent plan store (DESIGN.md §10):
//!   `stats`, `clear`, and `prewarm <spec.json>` to lower + persist ahead
//!   of serving;
//! * `tune <spec.json>` — run the placement autotuner (DESIGN.md §11) and
//!   print the candidate table (predicted + simulated makespans, winner);
//! * `info` — architecture + artifact inventory.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{experiments, AieBlas};
use aieblas::spec::Spec;
use aieblas::util::cli::{App, Command, Matches, Parsed};

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn app() -> App {
    App::new("aieblas", "BLAS library + code generator + simulator for the AMD AI Engine")
        .command(
            Command::new("validate", "validate a JSON routine specification")
                .positional("spec", "path to spec.json", true),
        )
        .command(
            Command::new("generate", "generate the Vitis design from a spec")
                .positional("spec", "path to spec.json", true)
                .opt_default("out", "generated", "output directory"),
        )
        .command(
            Command::new("run", "simulate a spec end-to-end and check numerics")
                .positional("spec", "path to spec.json", true)
                .opt_default("artifacts", "artifacts", "AOT artifact directory")
                .opt_default("repeat", "1", "run the spec N times (warm runs hit the plan cache)")
                .opt("cache-dir", "persistent plan-store directory (warm starts across processes)")
                .flag("no-numerics", "skip numeric validation")
                .flag("kernels", "print per-kernel utilization"),
        )
        .command(
            Command::new("fig3", "reproduce the paper's Fig. 3")
                .opt_default("panel", "all", "axpy | gemv | axpydot | all")
                .opt_default("artifacts", "artifacts", "AOT artifact directory")
                .flag("csv", "emit CSV instead of a table"),
        )
        .command(
            Command::new("ablations", "run the §V ablation sweeps (A1–A3)")
                .opt_default("artifacts", "artifacts", "AOT artifact directory"),
        )
        .command(
            Command::new("serve", "run the HTTP front door over the serving layer")
                .opt_required("listen", "address to bind, e.g. 127.0.0.1:8080")
                .opt("peers", "comma-separated shard addresses (same list everywhere)")
                .opt_default("shard-index", "0", "this process's index into --peers")
                .opt_default("workers", "2", "server dispatcher threads")
                .opt_default("batch", "8", "max coalesced batch size")
                .opt_default("queue", "256", "bounded request-queue depth")
                .opt_default("policy", "block", "admission policy: block | reject | watermark:<n>")
                .opt_default("backend", "cpu", "cpu | reference | sim")
                .opt("cache-dir", "persistent plan-store directory shared across the fleet")
                .opt_default("max-body-kib", "4096", "largest request body accepted, KiB")
                .opt_default("read-timeout-ms", "10000", "per-socket read timeout")
                .opt_default("request-timeout-ms", "60000", "bound on one request's serving wait")
                .opt_default("drain-timeout-ms", "5000", "default /v1/drain (and shutdown) bound")
                .opt_default("probe-interval-ms", "500", "peer health-probe cadence (fleets only)")
                .opt(
                    "fault-plan",
                    "deterministic fault injection, e.g. seed=42,connect_refuse=0.1 \
                     (overrides AIEBLAS_FAULT_PLAN)",
                ),
        )
        .command(
            Command::new("serve-bench", "drive the serving layer with a synthetic workload")
                .opt_default("requests", "256", "total requests to submit")
                .opt_default("distinct", "4", "distinct specs in the workload")
                .opt_default("size", "4096", "vector length per routine")
                .opt_default("batch", "8", "max coalesced batch size")
                .opt_default("workers", "2", "server dispatcher threads")
                .opt_default("shards", "1", "sharded-backend fan-out per batch")
                .opt_default("linger-us", "200", "batching linger, microseconds")
                .opt_default("clients", "4", "client submitter threads")
                .opt_default("backend", "cpu", "cpu | reference | sim")
                .opt_default("policy", "block", "admission policy: block | reject | watermark:<n>")
                .opt("metrics-json", "write the batched run's ServeReport JSON to this path")
                .opt("cache-dir", "persistent plan-store directory shared across runs")
                .opt(
                    "fault-plan",
                    "deterministic fault injection for the plan store, e.g. \
                     seed=42,store_write_fail=0.2 (overrides AIEBLAS_FAULT_PLAN)",
                )
                .flag(
                    "assert-warm",
                    "fail unless every run was served warm (zero lowerings, >0 disk hits)",
                ),
        )
        .command(
            Command::new("cache", "manage the persistent plan store")
                .positional("action", "stats | clear | prewarm", true)
                .positional("spec", "spec.json to prewarm (lower + persist)", false)
                .opt_default("cache-dir", ".aieblas-plan-cache", "plan-store directory"),
        )
        .command(
            Command::new("tune", "autotune a spec's placement and print the candidate table")
                .positional("spec", "path to spec.json", true)
                .opt_default("mode", "full", "analytic | full (analytic prune + DES shortlist)")
                .opt_default("candidates", "12", "max placement candidates per graph variant")
                .opt_default("shortlist", "4", "candidates DES-simulated in full mode"),
        )
        .command(Command::new("info", "print architecture and artifact inventory"))
}

fn main() -> ExitCode {
    aieblas::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match app().parse(&args) {
        Ok(Parsed::Help(h)) => {
            println!("{h}");
            ExitCode::SUCCESS
        }
        Ok(Parsed::Matches(m)) => match dispatch(&m) {
            Ok(()) => ExitCode::SUCCESS,
            Err(e) => {
                eprintln!("error: {e}");
                ExitCode::FAILURE
            }
        },
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", app().top_usage());
            ExitCode::FAILURE
        }
    }
}

fn dispatch(m: &Matches) -> CliResult {
    match m.command.as_str() {
        "validate" => {
            let spec = Spec::from_file(Path::new(&m.positionals[0]))?;
            println!(
                "OK: {} routine(s), {} connection(s), data_source = {}",
                spec.routines.len(),
                spec.connections.len(),
                spec.data_source.name()
            );
            Ok(())
        }
        "generate" => {
            let spec = Spec::from_file(Path::new(&m.positionals[0]))?;
            let out = PathBuf::from(m.get("out").unwrap());
            let proj = aieblas::codegen::generate(&spec)?;
            proj.write_to(&out)?;
            println!(
                "generated {} files ({} lines) under {}",
                proj.files.len(),
                proj.total_lines(),
                out.display()
            );
            for f in proj.files.keys() {
                println!("  {f}");
            }
            Ok(())
        }
        "run" => {
            let spec = Spec::from_file(Path::new(&m.positionals[0]))?;
            let mut builder = AieBlas::builder()
                .artifacts_dir(m.get("artifacts").unwrap())
                .check_numerics(!m.has_flag("no-numerics"));
            if let Some(dir) = m.get("cache-dir") {
                builder = builder.cache_dir(dir);
            }
            let sys = builder.build()?;
            let repeat = m.usize("repeat")?.max(1);
            let mut report = sys.run_spec(&spec)?;
            for _ in 1..repeat {
                report = sys.run_spec(&spec)?;
            }
            println!("{}", report.summary());
            if m.has_flag("kernels") {
                for k in &report.sim.kernels {
                    println!(
                        "  kernel {:24} @ {:10} {:6} iters  busy {:8.3} ms  util {:5.1}%",
                        k.name,
                        k.location,
                        k.iterations,
                        k.busy_s * 1e3,
                        k.utilization * 100.0
                    );
                }
            }
            Ok(())
        }
        "fig3" => {
            let sys = AieBlas::builder()
                .artifacts_dir(m.get("artifacts").unwrap())
                .check_numerics(false)
                .build()?;
            let panel = m.get("panel").unwrap();
            let mut tables = Vec::new();
            if panel == "axpy" || panel == "all" {
                let rows = experiments::single_routine_panel(
                    &sys,
                    RoutineKind::Axpy,
                    &experiments::VEC_SIZES,
                )?;
                tables.push(experiments::panel_table("axpy", &rows));
            }
            if panel == "gemv" || panel == "all" {
                let rows = experiments::single_routine_panel(
                    &sys,
                    RoutineKind::Gemv,
                    &experiments::MAT_SIZES,
                )?;
                tables.push(experiments::panel_table("gemv", &rows));
            }
            if panel == "axpydot" || panel == "all" {
                let rows = experiments::axpydot_panel(&sys, &experiments::VEC_SIZES)?;
                tables.push(experiments::panel_table("axpydot", &rows));
            }
            if tables.is_empty() {
                return Err(format!("unknown panel {panel:?} (axpy | gemv | axpydot | all)").into());
            }
            for t in tables {
                if m.has_flag("csv") {
                    print!("{}", t.to_csv());
                } else {
                    println!("{}", t.render());
                }
            }
            Ok(())
        }
        "ablations" => {
            let sys = AieBlas::builder()
                .artifacts_dir(m.get("artifacts").unwrap())
                .check_numerics(false)
                .build()?;
            println!("== A1: burst-optimized movers (axpy) ==");
            println!(
                "{}",
                experiments::ablation_burst(&sys, RoutineKind::Axpy, &[1 << 16, 1 << 20])?.render()
            );
            println!("== A2: multi-AIE split (axpy, n = 2^20) ==");
            println!(
                "{}",
                experiments::ablation_multi_port(&sys, 1 << 20, &[1, 2, 4, 8])?.render()
            );
            println!("== A3a: window-size sweep (axpy, n = 2^20) ==");
            println!(
                "{}",
                experiments::ablation_window(&sys, RoutineKind::Axpy, 1 << 20, &[64, 256, 1024])?
                    .render()
            );
            println!("== A3b: vector-width sweep (axpy, n = 2^20, on-chip) ==");
            println!(
                "{}",
                experiments::ablation_vector_width(&sys, RoutineKind::Axpy, 1 << 20)?.render()
            );
            Ok(())
        }
        "serve" => serve_cmd(m),
        "serve-bench" => serve_bench(m),
        "cache" => cache_cmd(m),
        "tune" => tune_cmd(m),
        "info" => {
            let arch = aieblas::arch::ArchConfig::vck5000();
            println!("platform: vck5000");
            println!("  AIE array: {}×{} = {} tiles", arch.rows, arch.cols, arch.num_tiles());
            println!("  tile-local memory: {} KB", arch.local_mem_bytes / 1024);
            println!(
                "  AIE clock: {:.2} GHz | PL clock: {:.0} MHz",
                arch.aie_clock_hz / 1e9,
                arch.pl_clock_hz / 1e6
            );
            println!(
                "  PL↔AIE: {}+{} channels @ {:.0} GB/s",
                arch.pl_to_aie_channels,
                arch.aie_to_pl_channels,
                arch.pl_aie_channel_bw / 1e9
            );
            let manifest = aieblas::runtime::Manifest::load(Path::new("artifacts"))?;
            println!("artifacts: {} precompiled", manifest.len());
            for kind in RoutineKind::ALL {
                let sizes = manifest.sizes_for(kind.name());
                if !sizes.is_empty() {
                    println!("  {:8} {:?}", kind.name(), sizes);
                }
            }
            Ok(())
        }
        other => Err(format!("unhandled command {other:?}").into()),
    }
}

/// `serve --listen <addr>` — run the HTTP front door until drained.
///
/// With `--peers a,b,c --shard-index i` this process serves shard `i` of
/// the fleet: requests whose `PlanKey` hashes elsewhere are proxied one
/// hop to the owner, and every process shares the `--cache-dir` plan
/// store, so each plan is lowered exactly once fleet-wide. The process
/// exits cleanly after `POST /v1/drain` settles in-flight work.
fn serve_cmd(m: &Matches) -> CliResult {
    use std::sync::Arc;
    use std::time::Duration;

    use aieblas::arch::ArchConfig;
    use aieblas::http::{HttpConfig, HttpServer, ShardRouter};
    use aieblas::pipeline::{Pipeline, PlanStore};
    use aieblas::runtime::{Backend, CpuBackend, ReferenceBackend, SimBackend};
    use aieblas::serve::{AdmissionPolicy, RoutineServer, ServeConfig};
    use aieblas::util::faults::FaultPlan;

    let listen = m.get("listen").unwrap().to_string();
    let policy_str = m.get("policy").unwrap().to_string();
    let policy = AdmissionPolicy::parse(&policy_str)
        .ok_or_else(|| format!("bad --policy {policy_str:?} (block | reject | watermark:<n>)"))?;
    let backend: Arc<dyn Backend> = match m.get("backend").unwrap() {
        "cpu" => Arc::new(CpuBackend),
        "reference" => Arc::new(ReferenceBackend),
        "sim" => Arc::new(SimBackend::timing_only()),
        other => return Err(format!("unknown backend {other:?} (cpu | reference | sim)").into()),
    };
    let router = match m.get("peers") {
        None => None,
        Some(peers) => {
            let peers: Vec<String> = peers
                .split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(String::from)
                .collect();
            Some(ShardRouter::new(peers, m.usize("shard-index")?)?)
        }
    };

    // flag wins over AIEBLAS_FAULT_PLAN; a present-but-invalid plan is an
    // error either way (silently serving un-faulted would defeat a chaos run).
    let faults = match m.get("fault-plan") {
        Some(spec) => Some(FaultPlan::parse(spec)?),
        None => FaultPlan::from_env()?,
    };
    if let Some(f) = &faults {
        eprintln!("fault injection active: {}", f.to_json().to_compact());
    }

    let mut pipeline = Pipeline::new(ArchConfig::vck5000());
    if let Some(dir) = m.get("cache-dir") {
        let mut store = PlanStore::open(Path::new(dir));
        if let Some(f) = &faults {
            store = store.with_faults(f.clone());
        }
        pipeline = pipeline.with_store(store);
    }
    let serve_cfg = ServeConfig::builder()
        .max_batch(m.usize("batch")?)
        .workers(m.usize("workers")?)
        .queue_capacity(m.usize("queue")?)
        .policy(policy)
        .build();
    let server = Arc::new(RoutineServer::new(Arc::new(pipeline), backend, serve_cfg));

    let http_cfg = HttpConfig {
        max_body: m.usize("max-body-kib")?.saturating_mul(1024),
        read_timeout: Duration::from_millis(m.usize("read-timeout-ms")? as u64),
        request_timeout: Duration::from_millis(m.usize("request-timeout-ms")? as u64),
        drain_timeout: Duration::from_millis(m.usize("drain-timeout-ms")? as u64),
        probe_interval: Duration::from_millis(m.usize("probe-interval-ms")? as u64),
        faults,
        ..Default::default()
    };
    let http = HttpServer::bind(&listen, server, router, http_cfg)?;
    // the smoke driver greps this line for the resolved address (port 0).
    println!("aieblas serving on http://{}", http.local_addr());
    if let Some(shard) = m.get("peers").map(|_| m.usize("shard-index")).transpose()? {
        println!("shard {shard} of the configured peer fleet");
    }

    while !http.is_draining() {
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("drain requested; shutting down");
    http.shutdown();
    Ok(())
}

/// `cache stats|clear|prewarm <spec.json>` — inspect, empty, or pre-fill
/// the persistent plan store (DESIGN.md §10).
fn cache_cmd(m: &Matches) -> CliResult {
    use aieblas::arch::ArchConfig;
    use aieblas::pipeline::{Pipeline, PlanStore};

    let dir = PathBuf::from(m.get("cache-dir").unwrap());
    let store = PlanStore::new(&dir);
    match m.positionals[0].as_str() {
        "stats" => {
            let s = store.stats();
            println!(
                "plan store {}: {} entr{} ({} bytes)",
                dir.display(),
                s.entries,
                if s.entries == 1 { "y" } else { "ies" },
                s.bytes
            );
            Ok(())
        }
        "clear" => {
            let removed = store.clear()?;
            println!(
                "plan store {}: removed {removed} entr{}",
                dir.display(),
                if removed == 1 { "y" } else { "ies" }
            );
            Ok(())
        }
        "prewarm" => {
            let spec_path = m
                .positionals
                .get(1)
                .ok_or("prewarm needs a spec: aieblas cache prewarm <spec.json>")?;
            let spec = Spec::from_file(Path::new(spec_path))?;
            let pipeline = Pipeline::new(ArchConfig::vck5000()).with_disk_store(&dir);
            pipeline.lower(&spec)?;
            let s = pipeline.cache().stats();
            if s.disk_hits > 0 {
                println!("{spec_path}: already warm (served from {})", dir.display());
            } else {
                println!(
                    "{spec_path}: lowered and persisted to {} ({} rejected stale entr{})",
                    dir.display(),
                    s.rejected,
                    if s.rejected == 1 { "y" } else { "ies" }
                );
            }
            Ok(())
        }
        other => Err(format!("unknown cache action {other:?} (stats | clear | prewarm)").into()),
    }
}

/// `tune <spec.json>` — run the placement autotuner on one spec and print
/// the full candidate table plus the winning plan's makespans.
fn tune_cmd(m: &Matches) -> CliResult {
    use aieblas::arch::ArchConfig;
    use aieblas::tune::{tune_spec, TuneConfig, TuneMode};
    use aieblas::util::table::{fmt_time, Table};

    let spec = Spec::from_file(Path::new(&m.positionals[0]))?;
    let mode = TuneMode::parse(m.get("mode").unwrap())?;
    if mode == TuneMode::Off {
        return Err("tune mode \"off\" runs no search; pick analytic or full".into());
    }
    let cfg = TuneConfig {
        mode,
        max_candidates: m.usize("candidates")?.max(1),
        shortlist: m.usize("shortlist")?.max(1),
    };
    let outcome = tune_spec(&spec, &ArchConfig::vck5000(), &cfg)?;
    let report = &outcome.report;

    let fmt_opt = |v: Option<f64>| v.map_or_else(|| "-".to_string(), fmt_time);
    let mut table =
        Table::new(vec!["#", "candidate", "hops", "chans", "predicted", "simulated", "chosen"]);
    for (i, c) in report.candidates.iter().enumerate() {
        table.row(vec![
            i.to_string(),
            c.label.clone(),
            c.route_cost.total_hops.to_string(),
            c.route_cost.interface_channels.to_string(),
            fmt_opt(c.predicted_s),
            fmt_opt(c.simulated_s),
            if c.chosen { "*".to_string() } else { String::new() },
        ]);
    }
    println!("== tune ({} mode, {} candidate(s)) ==", report.mode.name(), report.candidates.len());
    println!("{}", table.render());
    println!("search time: {}", fmt_time(report.search_s));
    let untuned = report.candidates.first();
    let chosen = report.chosen_candidate();
    if let (Some(u), Some(c)) = (untuned, chosen) {
        let pick = |cand: &aieblas::tune::CandidateReport| cand.simulated_s.or(cand.predicted_s);
        if let (Some(base), Some(best)) = (pick(u), pick(c)) {
            if report.improved() && best > 0.0 {
                println!(
                    "tuned plan: {} ({:.2}× vs untuned {})",
                    fmt_time(best),
                    base / best,
                    fmt_time(base)
                );
            } else {
                println!("tuned plan: default placement already optimal ({})", fmt_time(base));
            }
        }
    }
    Ok(())
}

/// Synthetic serving workload: `clients` submitter threads round-robin
/// `requests` requests over `distinct` specs into a `RoutineServer`, first
/// unbatched (max_batch = 1) and then batched, and print both reports.
fn serve_bench(m: &Matches) -> CliResult {
    use std::sync::Arc;
    use std::time::Duration;

    use aieblas::arch::ArchConfig;
    use aieblas::pipeline::{Pipeline, PlanStore};
    use aieblas::runtime::{
        Backend, CpuBackend, ExecInputs, ReferenceBackend, ShardedBackend, SimBackend,
    };
    use aieblas::util::faults::FaultPlan;
    use aieblas::serve::{AdmissionPolicy, RoutineServer, ServeConfig, ServeReport};
    use aieblas::spec::DataSource;

    let requests = m.usize("requests")?.max(1);
    let distinct = m.usize("distinct")?.max(1);
    let size = m.usize("size")?.max(16);
    let batch = m.usize("batch")?.max(1);
    let workers = m.usize("workers")?.max(1);
    let shards = m.usize("shards")?.max(1);
    let linger = Duration::from_micros(m.usize("linger-us")? as u64);
    let clients = m.usize("clients")?.max(1);
    let backend_name = m.get("backend").unwrap().to_string();
    let policy_str = m.get("policy").unwrap().to_string();
    let policy = AdmissionPolicy::parse(&policy_str)
        .ok_or_else(|| format!("bad --policy {policy_str:?} (block | reject | watermark:<n>)"))?;
    let metrics_json = m.get("metrics-json").map(PathBuf::from);
    let cache_dir = m.get("cache-dir").map(PathBuf::from);
    let assert_warm = m.has_flag("assert-warm");
    if assert_warm && cache_dir.is_none() {
        return Err("--assert-warm needs --cache-dir".into());
    }
    let faults = match m.get("fault-plan") {
        Some(spec) => Some(FaultPlan::parse(spec)?),
        None => FaultPlan::from_env()?,
    };

    let specs: Vec<Spec> = (0..distinct)
        .map(|i| Spec::single(RoutineKind::Axpy, &format!("r{i}"), size, DataSource::Pl))
        .collect();

    let make_backend = |shards: usize| -> Result<Arc<dyn Backend>, String> {
        Ok(match backend_name.as_str() {
            "cpu" => Arc::new(ShardedBackend::new(CpuBackend, shards)),
            "reference" => Arc::new(ShardedBackend::new(ReferenceBackend, shards)),
            // never sharded: SimBackend::execute_batch runs the DES once
            // for the whole batch; slicing the batch would re-run the
            // identical simulation once per shard.
            "sim" => Arc::new(SimBackend::timing_only()),
            other => return Err(format!("unknown backend {other:?} (cpu | reference | sim)")),
        })
    };
    if backend_name == "sim" && shards > 1 {
        eprintln!("note: --shards ignored for the sim backend (one DES run serves the batch)");
    }

    let run = |max_batch: usize, linger: Duration| -> Result<ServeReport, String> {
        let mut pipeline = Pipeline::new(ArchConfig::vck5000());
        if let Some(dir) = &cache_dir {
            let mut store = PlanStore::open(dir);
            if let Some(f) = &faults {
                store = store.with_faults(f.clone());
            }
            pipeline = pipeline.with_store(store);
        }
        let server = RoutineServer::new(
            Arc::new(pipeline),
            make_backend(shards)?,
            ServeConfig::builder()
                .max_batch(max_batch)
                .linger(linger)
                .workers(workers)
                .policy(policy)
                .build(),
        );
        std::thread::scope(|s| {
            for c in 0..clients {
                let server = &server;
                let specs = &specs;
                s.spawn(move || {
                    let mut tickets = Vec::new();
                    for r in (c..requests).step_by(clients) {
                        let spec = &specs[r % specs.len()];
                        tickets.push(server.submit(spec, ExecInputs::random_for(spec, r as u64)));
                    }
                    for t in tickets {
                        // non-block policies legitimately shed under load;
                        // anything else is a real serving failure.
                        if let Err(e) = t.wait() {
                            let msg = e.to_string();
                            assert!(
                                msg.contains("shed at admission"),
                                "serve request failed: {msg}"
                            );
                        }
                    }
                });
            }
        });
        Ok(server.join())
    };

    println!(
        "== serve-bench: {requests} request(s), {distinct} distinct spec(s), axpy n={size}, \
         backend {backend_name} ({workers} worker(s), {shards} shard(s)) =="
    );
    let unbatched = run(1, Duration::ZERO)?;
    println!("-- unbatched (max_batch = 1) --\n{}", unbatched.summary());
    let batched = run(batch, linger)?;
    println!(
        "-- batched (max_batch = {batch}, linger {} µs) --\n{}",
        linger.as_micros(),
        batched.summary()
    );
    println!(
        "batched vs unbatched throughput: {:.2}x",
        batched.throughput_rps / unbatched.throughput_rps.max(1e-9)
    );
    if let Some(path) = &metrics_json {
        // the versioned v1 envelope (crate::api), same shape /v1/statsz
        // serves, so offline tooling parses one format either way.
        std::fs::write(path, aieblas::api::report_json(&batched).to_pretty() + "\n")
            .map_err(|e| format!("could not write {}: {e}", path.display()))?;
        println!("wrote serve metrics to {}", path.display());
    }
    if assert_warm {
        // CI warm-start gate: a run against a prewarmed --cache-dir must
        // never lower (every cold lookup is a disk hit).
        for (phase, report) in [("unbatched", &unbatched), ("batched", &batched)] {
            if report.cache.misses != 0 || report.cache.disk_hits == 0 {
                return Err(format!(
                    "warm-start assertion failed ({phase}): {} lowering(s), {} disk hit(s) \
                     (want 0 lowerings and >0 disk hits)",
                    report.cache.misses, report.cache.disk_hits
                )
                .into());
            }
        }
        println!("warm-start assertion passed: zero lowerings, all plans served from disk");
    }
    Ok(())
}
