//! `aieblas` — command-line interface to the AIEBLAS system.
//!
//! Subcommands:
//! * `validate <spec.json>` — parse + validate a routine specification;
//! * `generate <spec.json> --out <dir>` — emit the Vitis design (Fig. 1);
//! * `run <spec.json>` — lower through the staged pipeline (plan-cached)
//!   → simulate → numerics; `--repeat N` re-runs the spec to demonstrate
//!   warm plan-cache hits;
//! * `fig3 [--panel …]` — reproduce the paper's Fig. 3 series;
//! * `ablations` — the §V ablation sweeps;
//! * `info` — architecture + artifact inventory.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use aieblas::blas::RoutineKind;
use aieblas::coordinator::{experiments, AieBlas, Config};
use aieblas::spec::Spec;
use aieblas::util::cli::{App, Command, Matches, Parsed};

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn app() -> App {
    App::new("aieblas", "BLAS library + code generator + simulator for the AMD AI Engine")
        .command(
            Command::new("validate", "validate a JSON routine specification")
                .positional("spec", "path to spec.json", true),
        )
        .command(
            Command::new("generate", "generate the Vitis design from a spec")
                .positional("spec", "path to spec.json", true)
                .opt_default("out", "generated", "output directory"),
        )
        .command(
            Command::new("run", "simulate a spec end-to-end and check numerics")
                .positional("spec", "path to spec.json", true)
                .opt_default("artifacts", "artifacts", "AOT artifact directory")
                .opt_default("repeat", "1", "run the spec N times (warm runs hit the plan cache)")
                .flag("no-numerics", "skip numeric validation")
                .flag("kernels", "print per-kernel utilization"),
        )
        .command(
            Command::new("fig3", "reproduce the paper's Fig. 3")
                .opt_default("panel", "all", "axpy | gemv | axpydot | all")
                .opt_default("artifacts", "artifacts", "AOT artifact directory")
                .flag("csv", "emit CSV instead of a table"),
        )
        .command(
            Command::new("ablations", "run the §V ablation sweeps (A1–A3)")
                .opt_default("artifacts", "artifacts", "AOT artifact directory"),
        )
        .command(Command::new("info", "print architecture and artifact inventory"))
}

fn main() -> ExitCode {
    aieblas::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match app().parse(&args) {
        Ok(Parsed::Help(h)) => {
            println!("{h}");
            ExitCode::SUCCESS
        }
        Ok(Parsed::Matches(m)) => match dispatch(&m) {
            Ok(()) => ExitCode::SUCCESS,
            Err(e) => {
                eprintln!("error: {e}");
                ExitCode::FAILURE
            }
        },
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", app().top_usage());
            ExitCode::FAILURE
        }
    }
}

fn dispatch(m: &Matches) -> CliResult {
    match m.command.as_str() {
        "validate" => {
            let spec = Spec::from_file(Path::new(&m.positionals[0]))?;
            println!(
                "OK: {} routine(s), {} connection(s), data_source = {}",
                spec.routines.len(),
                spec.connections.len(),
                spec.data_source.name()
            );
            Ok(())
        }
        "generate" => {
            let spec = Spec::from_file(Path::new(&m.positionals[0]))?;
            let out = PathBuf::from(m.get("out").unwrap());
            let proj = aieblas::codegen::generate(&spec)?;
            proj.write_to(&out)?;
            println!(
                "generated {} files ({} lines) under {}",
                proj.files.len(),
                proj.total_lines(),
                out.display()
            );
            for f in proj.files.keys() {
                println!("  {f}");
            }
            Ok(())
        }
        "run" => {
            let spec = Spec::from_file(Path::new(&m.positionals[0]))?;
            let sys = AieBlas::new(Config {
                artifacts_dir: PathBuf::from(m.get("artifacts").unwrap()),
                check_numerics: !m.has_flag("no-numerics"),
                ..Default::default()
            })?;
            let repeat = m.usize("repeat")?.max(1);
            let mut report = sys.run_spec(&spec)?;
            for _ in 1..repeat {
                report = sys.run_spec(&spec)?;
            }
            println!("{}", report.summary());
            if m.has_flag("kernels") {
                for k in &report.sim.kernels {
                    println!(
                        "  kernel {:24} @ {:10} {:6} iters  busy {:8.3} ms  util {:5.1}%",
                        k.name,
                        k.location,
                        k.iterations,
                        k.busy_s * 1e3,
                        k.utilization * 100.0
                    );
                }
            }
            Ok(())
        }
        "fig3" => {
            let sys = AieBlas::new(Config {
                artifacts_dir: PathBuf::from(m.get("artifacts").unwrap()),
                check_numerics: false,
                ..Default::default()
            })?;
            let panel = m.get("panel").unwrap();
            let mut tables = Vec::new();
            if panel == "axpy" || panel == "all" {
                let rows = experiments::single_routine_panel(
                    &sys,
                    RoutineKind::Axpy,
                    &experiments::VEC_SIZES,
                )?;
                tables.push(experiments::panel_table("axpy", &rows));
            }
            if panel == "gemv" || panel == "all" {
                let rows = experiments::single_routine_panel(
                    &sys,
                    RoutineKind::Gemv,
                    &experiments::MAT_SIZES,
                )?;
                tables.push(experiments::panel_table("gemv", &rows));
            }
            if panel == "axpydot" || panel == "all" {
                let rows = experiments::axpydot_panel(&sys, &experiments::VEC_SIZES)?;
                tables.push(experiments::panel_table("axpydot", &rows));
            }
            if tables.is_empty() {
                return Err(format!("unknown panel {panel:?} (axpy | gemv | axpydot | all)").into());
            }
            for t in tables {
                if m.has_flag("csv") {
                    print!("{}", t.to_csv());
                } else {
                    println!("{}", t.render());
                }
            }
            Ok(())
        }
        "ablations" => {
            let sys = AieBlas::new(Config {
                artifacts_dir: PathBuf::from(m.get("artifacts").unwrap()),
                check_numerics: false,
                ..Default::default()
            })?;
            println!("== A1: burst-optimized movers (axpy) ==");
            println!(
                "{}",
                experiments::ablation_burst(&sys, RoutineKind::Axpy, &[1 << 16, 1 << 20])?.render()
            );
            println!("== A2: multi-AIE split (axpy, n = 2^20) ==");
            println!(
                "{}",
                experiments::ablation_multi_port(&sys, 1 << 20, &[1, 2, 4, 8])?.render()
            );
            println!("== A3a: window-size sweep (axpy, n = 2^20) ==");
            println!(
                "{}",
                experiments::ablation_window(&sys, RoutineKind::Axpy, 1 << 20, &[64, 256, 1024])?
                    .render()
            );
            println!("== A3b: vector-width sweep (axpy, n = 2^20, on-chip) ==");
            println!(
                "{}",
                experiments::ablation_vector_width(&sys, RoutineKind::Axpy, 1 << 20)?.render()
            );
            Ok(())
        }
        "info" => {
            let arch = aieblas::arch::ArchConfig::vck5000();
            println!("platform: vck5000");
            println!("  AIE array: {}×{} = {} tiles", arch.rows, arch.cols, arch.num_tiles());
            println!("  tile-local memory: {} KB", arch.local_mem_bytes / 1024);
            println!(
                "  AIE clock: {:.2} GHz | PL clock: {:.0} MHz",
                arch.aie_clock_hz / 1e9,
                arch.pl_clock_hz / 1e6
            );
            println!(
                "  PL↔AIE: {}+{} channels @ {:.0} GB/s",
                arch.pl_to_aie_channels,
                arch.aie_to_pl_channels,
                arch.pl_aie_channel_bw / 1e9
            );
            let manifest = aieblas::runtime::Manifest::load(Path::new("artifacts"))?;
            println!("artifacts: {} precompiled", manifest.len());
            for kind in RoutineKind::ALL {
                let sizes = manifest.sizes_for(kind.name());
                if !sizes.is_empty() {
                    println!("  {:8} {:?}", kind.name(), sizes);
                }
            }
            Ok(())
        }
        other => Err(format!("unhandled command {other:?}").into()),
    }
}
