//! Support substrates implemented in-tree (the offline registry only has
//! the `xla` crate closure — see DESIGN.md §1): JSON, PRNG, CLI parsing,
//! thread pool, property testing, benchmarking, tables, logging.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod table;
pub mod threadpool;
