//! Support substrates implemented in-tree — the default build has zero
//! external dependencies; the offline registry carries only the optional
//! `xla` crate closure behind the `pjrt` feature (DESIGN.md §1): JSON,
//! PRNG, CLI parsing, thread pool, property testing, benchmarking,
//! tables, logging.

pub mod bench;
pub mod cli;
pub mod faults;
pub mod json;
pub mod logging;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod table;
pub mod threadpool;

/// FNV-1a 64-bit hash (dependency-free, stable across processes) — shared
/// by plan-key interning (`pipeline::cache::PlanKey`), the persistent
/// store's entry filenames and the architecture fingerprint.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod fnv_tests {
    #[test]
    fn fnv1a64_reference_vectors() {
        // published FNV-1a test vectors
        assert_eq!(super::fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(super::fnv1a64(b"ab"), super::fnv1a64(b"ba"));
    }
}
