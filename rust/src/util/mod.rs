//! Support substrates implemented in-tree — the default build has zero
//! external dependencies; the offline registry carries only the optional
//! `xla` crate closure behind the `pjrt` feature (DESIGN.md §1): JSON,
//! PRNG, CLI parsing, thread pool, property testing, benchmarking,
//! tables, logging.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod table;
pub mod threadpool;
