//! Text-table and CSV rendering for benchmark reports (the Fig. 3 harness
//! prints the same rows the paper's figure plots; see rust/benches/).

/// A simple column-aligned text table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Column-aligned plain-text rendering with a separator under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
                .trim_end()
                .to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// RFC-4180-ish CSV rendering (quotes cells containing , " or newline).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| -> String {
            if c.contains([',', '"', '\n']) {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds human-readably (ns/µs/ms/s) for bench output.
pub fn fmt_time(seconds: f64) -> String {
    if seconds < 1e-6 {
        format!("{:.1} ns", seconds * 1e9)
    } else if seconds < 1e-3 {
        format!("{:.2} µs", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.3} ms", seconds * 1e3)
    } else {
        format!("{seconds:.3} s")
    }
}

/// Format a rate in GB/s or GFLOP/s.
pub fn fmt_giga(v: f64) -> String {
    format!("{:.2}", v / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["n", "variant", "time"]);
        t.row(vec!["4096", "aie-pl", "1.2 ms"]);
        t.row(vec!["1048576", "cpu", "0.5 ms"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("n"));
        assert!(lines[1].chars().all(|c| c == '-'));
        // columns aligned: "variant" column starts at same offset in all rows
        let col = lines[0].find("variant").unwrap();
        assert_eq!(&lines[2][col..col + 6], "aie-pl");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["k", "v"]);
        t.row(vec!["a,b", "say \"hi\""]);
        let csv = t.to_csv();
        assert_eq!(csv, "k,v\n\"a,b\",\"say \"\"hi\"\"\"\n");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(3.2e-9), "3.2 ns");
        assert_eq!(fmt_time(4.5e-5), "45.00 µs");
        assert_eq!(fmt_time(0.0123), "12.300 ms");
        assert_eq!(fmt_time(2.5), "2.500 s");
    }
}
