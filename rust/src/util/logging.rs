//! Tiny in-tree logging facade writing to stderr (no `log` crate in the
//! offline registry — DESIGN.md §1).
//!
//! Level comes from `AIEBLAS_LOG` (error|warn|info|debug|trace), default
//! `info`. Installed once by `aieblas::init()`; call sites use the
//! crate-root macros `log_error!` / `log_warn!` / `log_info!` /
//! `log_debug!`.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Install the logger level from the environment (idempotent).
pub fn init() {
    let level = match std::env::var("AIEBLAS_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Is `level` currently emitted?
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record. Prefer the `log_*!` macros, which fill in the target.
pub fn log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {target}: {args}", level.tag());
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        crate::log_info!("logging smoke test");
    }

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        assert!(enabled(Level::Error));
        if std::env::var("AIEBLAS_LOG").is_err() {
            assert!(!enabled(Level::Trace));
        }
    }
}
