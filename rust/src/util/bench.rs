//! Hand-rolled benchmark harness (no `criterion` in the offline registry).
//!
//! Every target under `rust/benches/` is a `harness = false` binary built
//! on this module: warmup, N timed samples, median/mean/min/max/stddev, and
//! table/CSV reporting. Deterministic sample counts keep `cargo bench`
//! runtimes bounded; set `AIEBLAS_BENCH_SAMPLES` / `AIEBLAS_BENCH_WARMUP`
//! to override.

use std::time::Instant;

use super::table::{fmt_time, Table};

/// Summary statistics over the timed samples (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub samples: usize,
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let median = if n % 2 == 1 {
            xs[n / 2]
        } else {
            0.5 * (xs[n / 2 - 1] + xs[n / 2])
        };
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats { samples: n, median, mean, min: xs[0], max: xs[n - 1], stddev: var.sqrt() }
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Time `f` (which returns an opaque value to defeat dead-code elimination).
pub fn run<T>(warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut xs = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        xs.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_samples(xs)
}

/// A named benchmark group that accumulates rows and prints a report.
pub struct Bench {
    name: &'static str,
    warmup: usize,
    samples: usize,
    table: Table,
    csv_extra: Vec<(String, Stats)>,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        Bench {
            name,
            warmup: env_usize("AIEBLAS_BENCH_WARMUP", 3),
            samples: env_usize("AIEBLAS_BENCH_SAMPLES", 10),
            table: Table::new(vec!["benchmark", "median", "mean", "min", "max", "stddev"]),
            csv_extra: Vec::new(),
        }
    }

    /// Benchmark a closure under `label`; returns the stats for assertions.
    pub fn bench<T>(&mut self, label: &str, f: impl FnMut() -> T) -> Stats {
        let stats = run(self.warmup, self.samples, f);
        self.record(label, stats);
        stats
    }

    /// Record an externally measured stat (e.g. simulated device time).
    pub fn record(&mut self, label: &str, stats: Stats) {
        self.table.row(vec![
            label.to_string(),
            fmt_time(stats.median),
            fmt_time(stats.mean),
            fmt_time(stats.min),
            fmt_time(stats.max),
            fmt_time(stats.stddev),
        ]);
        self.csv_extra.push((label.to_string(), stats));
    }

    /// Print the report; optionally write CSV next to the binary when
    /// `AIEBLAS_BENCH_CSV_DIR` is set.
    pub fn finish(self) {
        println!("\n== bench: {} ({} samples, {} warmup) ==", self.name, self.samples, self.warmup);
        print!("{}", self.table.render());
        if let Ok(dir) = std::env::var("AIEBLAS_BENCH_CSV_DIR") {
            let mut csv = String::from("benchmark,median_s,mean_s,min_s,max_s,stddev_s\n");
            for (label, s) in &self.csv_extra {
                csv.push_str(&format!(
                    "{label},{},{},{},{},{}\n",
                    s.median, s.mean, s.min, s.max, s.stddev
                ));
            }
            let path = format!("{dir}/{}.csv", self.name);
            if let Err(e) = std::fs::create_dir_all(&dir).and_then(|_| std::fs::write(&path, csv)) {
                eprintln!("warning: could not write {path}: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_median_odd_even() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        let s = Stats::from_samples(vec![4.0, 1.0, 2.0, 3.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn stats_constant_series() {
        let s = Stats::from_samples(vec![2.0; 8]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn run_executes_workload() {
        let mut count = 0u64;
        let stats = run(2, 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 7); // 2 warmup + 5 samples
        assert_eq!(stats.samples, 5);
        assert!(stats.min >= 0.0);
    }

    #[test]
    #[should_panic]
    fn stats_empty_panics() {
        Stats::from_samples(vec![]);
    }
}
