//! Scoped thread pool for data-parallel loops (no `rayon` in the offline
//! registry). Used by the CPU BLAS baseline (rust/src/blas/cpu.rs) — the
//! OpenBLAS stand-in for the Fig. 3 comparison — where the parallel shape is
//! always "split a range into contiguous chunks".

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of worker threads to use: respects `AIEBLAS_THREADS`, defaults to
/// the available parallelism (the paper's CPU baseline uses all 20 cores).
///
/// Memoized behind a once-initialized static: the env var is read and
/// parsed exactly once per process, so hot callers (sharded batch
/// execution asks per batch) pay one atomic load, not a getenv + parse.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("AIEBLAS_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Run `f(chunk_index, start, end)` over `nchunks` contiguous chunks of
/// `0..len` on up to [`num_threads`] scoped threads. Chunks are balanced to
/// within one element. Falls back to inline execution for small inputs —
/// thread spawn costs ~10 µs, pointless below ~64 KiB of work.
pub fn parallel_chunks<F>(len: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if len == 0 {
        return;
    }
    let threads = num_threads().max(1);
    parallel_chunks_with(len, (len / min_chunk.max(1)).clamp(1, threads), f);
}

/// [`parallel_chunks`] with an explicit chunk/worker count instead of the
/// global [`num_threads`] heuristic — the sharded backend uses this to make
/// its fan-out width configurable (and benchmarkable at 1/2/4 workers).
/// `nchunks` is clamped to `1..=len`; one chunk runs inline.
pub fn parallel_chunks_with<F>(len: usize, nchunks: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if len == 0 {
        return;
    }
    let nchunks = nchunks.clamp(1, len);
    if nchunks == 1 {
        f(0, 0, len);
        return;
    }
    let base = len / nchunks;
    let rem = len % nchunks;
    std::thread::scope(|scope| {
        let mut start = 0;
        for i in 0..nchunks {
            let this = base + usize::from(i < rem);
            let end = start + this;
            let fref = &f;
            scope.spawn(move || fref(i, start, end));
            start = end;
        }
    });
}

/// Parallel indexed map: `f(i)` for every `i in 0..len`, fanned over up to
/// `nchunks` scoped workers, results returned **in index order** (so a
/// caller merging them is deterministic regardless of scheduling). Thin
/// equal-weight wrapper over [`parallel_map_weighted`] (one shared
/// implementation — the slot/panic semantics cannot drift); for items of
/// very uneven cost pass real weights instead, as the simulator does for
/// its dataflow components.
pub fn parallel_map<T, F>(len: usize, nchunks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_weighted(len, nchunks, &vec![1; len], f)
}

/// [`parallel_map`] with per-item weights: items are distributed over up
/// to `nchunks` workers by longest-processing-time-first greedy binning
/// (heaviest item into the currently lightest bin), so one dominant item
/// — e.g. a simulation component holding most of a graph's iterations —
/// does not serialize behind same-chunk neighbours the way contiguous
/// index chunking would. Results are still returned **in index order**;
/// the binning only decides which worker computes what.
pub fn parallel_map_weighted<T, F>(len: usize, nchunks: usize, weights: &[usize], f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert_eq!(weights.len(), len, "one weight per item");
    if len == 0 {
        return Vec::new();
    }
    let nchunks = nchunks.clamp(1, len);
    if nchunks == 1 {
        return (0..len).map(f).collect();
    }
    let mut order: Vec<usize> = (0..len).collect();
    order.sort_by(|&a, &b| weights[b].cmp(&weights[a]).then(a.cmp(&b)));
    let mut bins: Vec<Vec<usize>> = vec![Vec::new(); nchunks];
    let mut load = vec![0u64; nchunks];
    for &i in &order {
        let lightest = (0..nchunks).min_by_key(|&b| load[b]).expect("nchunks >= 1");
        bins[lightest].push(i);
        load[lightest] += weights[i].max(1) as u64;
    }
    let slots: Vec<std::sync::Mutex<Option<T>>> =
        (0..len).map(|_| std::sync::Mutex::new(None)).collect();
    parallel_chunks_with(nchunks, nchunks, |b, _, _| {
        for &i in &bins[b] {
            *slots[i].lock().expect("map slot poisoned") = Some(f(i));
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("map slot poisoned").expect("worker panicked"))
        .collect()
}

/// Parallel map-reduce over contiguous chunks: each chunk computes a partial
/// with `map(start, end)`, partials are combined left-to-right with
/// `reduce`. Deterministic combination order (important for reproducible
/// floating-point reductions in tests).
pub fn parallel_reduce<T, M, R>(len: usize, min_chunk: usize, identity: T, map: M, reduce: R) -> T
where
    T: Send + Clone,
    M: Fn(usize, usize) -> T + Sync,
    R: Fn(T, T) -> T,
{
    if len == 0 {
        return identity;
    }
    let threads = num_threads().max(1);
    let nchunks = (len / min_chunk.max(1)).clamp(1, threads);
    if nchunks == 1 {
        return reduce(identity, map(0, len));
    }
    let mut partials: Vec<Option<T>> = vec![None; nchunks];
    let base = len / nchunks;
    let rem = len % nchunks;
    std::thread::scope(|scope| {
        let mut start = 0;
        for (i, slot) in partials.iter_mut().enumerate() {
            let this = base + usize::from(i < rem);
            let end = start + this;
            let mref = &map;
            scope.spawn(move || {
                *slot = Some(mref(start, end));
            });
            start = end;
        }
    });
    partials
        .into_iter()
        .map(|p| p.expect("worker panicked"))
        .fold(identity, |acc, p| reduce(acc, p))
}

/// Monotonic counter for unique ids (graph nodes, sim events).
pub struct IdGen(AtomicUsize);

impl IdGen {
    pub const fn new() -> Self {
        IdGen(AtomicUsize::new(0))
    }

    pub fn next(&self) -> usize {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
}

impl Default for IdGen {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let len = 10_007; // prime, exercises remainder balancing
        let hits: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(len, 1, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_len_is_noop() {
        parallel_chunks(0, 1, |_, _, _| panic!("should not run"));
        parallel_chunks_with(0, 4, |_, _, _| panic!("should not run"));
    }

    #[test]
    fn num_threads_is_stable_across_calls() {
        // memoized behind a OnceLock: every call must return the value the
        // first call resolved. (Deliberately no env mutation here — setenv
        // concurrent with other tests' getenv is UB on glibc.)
        let first = num_threads();
        assert!(first >= 1);
        for _ in 0..4 {
            assert_eq!(num_threads(), first);
        }
    }

    #[test]
    fn explicit_chunk_count_covers_range() {
        let len = 1001;
        let hits: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
        let max_idx = AtomicUsize::new(0);
        parallel_chunks_with(len, 4, |i, s, e| {
            max_idx.fetch_max(i, Ordering::Relaxed);
            for h in &hits[s..e] {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(max_idx.load(Ordering::Relaxed), 3, "four chunks requested");
        // over-subscription clamps to len (one index per element)
        parallel_chunks_with(3, 100, |i, s, e| {
            assert!(i < 3);
            assert_eq!(e - s, 1);
        });
    }

    #[test]
    fn small_input_runs_inline() {
        // min_chunk larger than len -> single chunk, chunk index 0.
        let mut seen = Vec::new();
        let seen_ptr = std::sync::Mutex::new(&mut seen);
        parallel_chunks(8, 1024, |i, s, e| {
            seen_ptr.lock().unwrap().push((i, s, e));
        });
        assert_eq!(seen, vec![(0, 0, 8)]);
    }

    #[test]
    fn map_returns_results_in_index_order() {
        for chunks in [1, 3, 8] {
            let out = parallel_map(17, chunks, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>(), "chunks={chunks}");
        }
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn weighted_map_returns_results_in_index_order() {
        // one dominant item plus many light ones — the exact shape LPT
        // binning exists for; results must stay index-ordered regardless.
        let weights: Vec<usize> = (0..17).map(|i| if i == 5 { 10_000 } else { i }).collect();
        for chunks in [1, 2, 4, 17] {
            let out = parallel_map_weighted(17, chunks, &weights, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>(), "chunks={chunks}");
        }
        assert!(parallel_map_weighted(0, 4, &[], |i| i).is_empty());
    }

    #[test]
    fn reduce_sums_correctly() {
        let data: Vec<u64> = (0..100_000).collect();
        let total = parallel_reduce(
            data.len(),
            1024,
            0u64,
            |s, e| data[s..e].iter().sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(total, 100_000u64 * 99_999 / 2);
    }

    #[test]
    fn reduce_identity_on_empty() {
        let v = parallel_reduce(0, 1, 42u64, |_, _| panic!("no chunks"), |a, b| a + b);
        assert_eq!(v, 42);
    }

    #[test]
    fn idgen_unique_across_threads() {
        let gen = IdGen::new();
        let ids = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let mut local = Vec::new();
                    for _ in 0..100 {
                        local.push(gen.next());
                    }
                    ids.lock().unwrap().extend(local);
                });
            }
        });
        let mut all = ids.into_inner().unwrap();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 800);
    }
}
