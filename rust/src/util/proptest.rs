//! Miniature property-based testing framework (the offline registry has no
//! `proptest`/`quickcheck`; DESIGN.md §1).
//!
//! Provides: composable generators over [`Rng`], a `forall` runner that
//! reports the failing case and its seed, and greedy input shrinking for
//! integer-vector-shaped cases. Deliberately small, but enough to express
//! the invariants DESIGN.md §6 lists (routing/batching/placement/simulator
//! conservation laws).

use super::rng::Rng;

/// A generator of values of type `T`.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Rng) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Gen { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.f)(rng)
    }

    /// Map the generated value.
    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |r| g(self.sample(r)))
    }
}

/// usize uniform in `[lo, hi]`.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    Gen::new(move |r| r.range(lo, hi))
}

/// f32 uniform in `[lo, hi)`.
pub fn f32_in(lo: f32, hi: f32) -> Gen<f32> {
    Gen::new(move |r| r.f32_in(lo, hi))
}

/// Vector of f32 with length drawn from `[min_len, max_len]`.
pub fn vec_f32(min_len: usize, max_len: usize, lo: f32, hi: f32) -> Gen<Vec<f32>> {
    Gen::new(move |r| {
        let n = r.range(min_len, max_len);
        (0..n).map(|_| r.f32_in(lo, hi)).collect()
    })
}

/// One of the provided constants.
pub fn one_of<T: Clone + 'static>(items: Vec<T>) -> Gen<T> {
    assert!(!items.is_empty());
    Gen::new(move |r| r.choose(&items).clone())
}

/// Pair of independently generated values.
pub fn pair<A: 'static, B: 'static>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
    Gen::new(move |r| (a.sample(r), b.sample(r)))
}

/// Outcome of a property check.
pub enum Prop {
    Pass,
    /// Property failed with a human-readable reason.
    Fail(String),
    /// Case rejected (precondition unmet) — not counted as a run.
    Discard,
}

impl From<bool> for Prop {
    fn from(ok: bool) -> Prop {
        if ok {
            Prop::Pass
        } else {
            Prop::Fail("property returned false".into())
        }
    }
}

impl From<Result<(), String>> for Prop {
    fn from(r: Result<(), String>) -> Prop {
        match r {
            Ok(()) => Prop::Pass,
            Err(e) => Prop::Fail(e),
        }
    }
}

/// Configuration for [`forall`].
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_discards: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Seed from the env when provided so failures can be replayed:
        // AIEBLAS_PROP_SEED=12345 cargo test
        let seed = std::env::var("AIEBLAS_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xA1EB1A5);
        Config { cases: 100, seed, max_discards: 1000 }
    }
}

/// Run `prop` against `cases` generated inputs; panics with the seed and a
/// debug rendering of the first failing input.
pub fn forall<T: std::fmt::Debug + 'static, P: Into<Prop>>(
    gen: &Gen<T>,
    cfg: Config,
    prop: impl Fn(&T) -> P,
) {
    let mut rng = Rng::new(cfg.seed);
    let mut ran = 0;
    let mut discards = 0;
    while ran < cfg.cases {
        if discards > cfg.max_discards {
            panic!(
                "property discarded {discards} cases (> {}), too restrictive",
                cfg.max_discards
            );
        }
        let input = gen.sample(&mut rng);
        match prop(&input).into() {
            Prop::Pass => ran += 1,
            Prop::Discard => discards += 1,
            Prop::Fail(reason) => {
                panic!(
                    "property failed after {ran} cases (seed {:#x}):\n  reason: {reason}\n  input: {input:?}",
                    cfg.seed
                );
            }
        }
    }
}

/// Convenience wrapper with the default config.
pub fn check<T: std::fmt::Debug + 'static, P: Into<Prop>>(
    gen: &Gen<T>,
    prop: impl Fn(&T) -> P,
) {
    forall(gen, Config::default(), prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(&usize_in(0, 100), |&n| n <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_input() {
        check(&usize_in(0, 100), |&n| n < 90);
    }

    #[test]
    fn map_transforms() {
        let even = usize_in(0, 50).map(|n| n * 2);
        check(&even, |&n| n % 2 == 0);
    }

    #[test]
    fn vec_gen_respects_bounds() {
        check(&vec_f32(1, 16, -2.0, 2.0), |v| {
            (1..=16).contains(&v.len())
                && v.iter().all(|&x| (-2.0..2.0).contains(&x))
        });
    }

    #[test]
    fn one_of_only_yields_members() {
        check(&one_of(vec![2usize, 4, 8]), |&n| [2, 4, 8].contains(&n));
    }

    #[test]
    fn pair_generates_both() {
        check(&pair(usize_in(1, 4), f32_in(0.0, 1.0)), |(n, x)| {
            (1..=4).contains(n) && (0.0..1.0).contains(x)
        });
    }

    #[test]
    #[should_panic(expected = "too restrictive")]
    fn discard_budget_enforced() {
        forall(&usize_in(0, 100), Config { cases: 10, seed: 1, max_discards: 5 }, |_| {
            Prop::Discard
        });
    }

    #[test]
    fn result_prop_reports_reason() {
        let r = std::panic::catch_unwind(|| {
            check(&usize_in(5, 5), |_| -> Result<(), String> {
                Err("custom reason".into())
            });
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("custom reason"));
    }
}
