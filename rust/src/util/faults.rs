//! Deterministic fault injection for the serving fleet (DESIGN.md §14).
//!
//! A [`FaultPlan`] is a seeded, shareable source of "should this site
//! fail now?" decisions, threaded through the HTTP client (connect
//! refusal), the server accept loop (503 bursts), the per-connection
//! path (read stalls, truncated responses) and [`PlanStore`] writes.
//! Decisions come from the crate's deterministic [`Rng`], so a fixed
//! seed replays the exact same fault sequence — the chaos suite and
//! `tools/http_smoke.py` rely on that to make outage tests reproducible
//! instead of flaky.
//!
//! Plans are parsed from a compact spec string (CLI `--fault-plan` or
//! the `AIEBLAS_FAULT_PLAN` env var):
//!
//! ```text
//! seed=42,connect_refuse=0.1,read_stall_ms=50,response_truncate=0.05,
//! http_503=0.2,store_write_fail=0.5
//! ```
//!
//! Unknown keys and non-numeric values are hard errors (a typo silently
//! disabling chaos would defeat the point); out-of-range numbers are
//! clamped (probabilities to `[0, 1]`, the stall to at most
//! [`MAX_STALL`]) so hostile values degrade to the nearest sane plan.
//!
//! [`PlanStore`]: crate::pipeline::PlanStore
//! [`Rng`]: crate::util::rng::Rng

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::rng::Rng;
use crate::{Error, Result};

/// Environment variable consulted by [`FaultPlan::from_env`].
pub const FAULT_PLAN_ENV: &str = "AIEBLAS_FAULT_PLAN";

/// Ceiling for `read_stall_ms` (hostile-value clamp): long enough to
/// trip any sane read timeout, short enough that a test can wait it out.
pub const MAX_STALL: Duration = Duration::from_secs(5);

/// Where a fault can be injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Client side: fail the connect as if the peer refused it.
    ConnectRefuse,
    /// Server side: stall before handling a parsed request.
    ReadStall,
    /// Server side: write only half the response frame, then close.
    ResponseTruncate,
    /// Accept loop: answer the connection with a bare 503 burst.
    Http503Burst,
    /// Plan store: fail the write-through before touching disk.
    StoreWriteFail,
}

impl FaultSite {
    pub const ALL: [FaultSite; 5] = [
        FaultSite::ConnectRefuse,
        FaultSite::ReadStall,
        FaultSite::ResponseTruncate,
        FaultSite::Http503Burst,
        FaultSite::StoreWriteFail,
    ];

    /// Spec-string key (also the wire name in `to_json`).
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::ConnectRefuse => "connect_refuse",
            FaultSite::ReadStall => "read_stall",
            FaultSite::ResponseTruncate => "response_truncate",
            FaultSite::Http503Burst => "http_503",
            FaultSite::StoreWriteFail => "store_write_fail",
        }
    }

    fn index(self) -> usize {
        match self {
            FaultSite::ConnectRefuse => 0,
            FaultSite::ReadStall => 1,
            FaultSite::ResponseTruncate => 2,
            FaultSite::Http503Burst => 3,
            FaultSite::StoreWriteFail => 4,
        }
    }
}

#[derive(Debug)]
struct Inner {
    seed: u64,
    /// Per-site injection probability, clamped to `[0, 1]`.
    probs: [f64; 5],
    /// Sleep applied when a `ReadStall` fires.
    stall: Duration,
    rng: Mutex<Rng>,
    /// Faults actually injected, per site (observability; surfaced on
    /// `/v1/healthz` when a plan is active).
    injected: [AtomicU64; 5],
}

/// A shared, seeded fault schedule. Cloning shares the underlying RNG
/// and counters, so one plan threaded through client + server + store
/// draws a single deterministic decision sequence.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    inner: Arc<Inner>,
}

impl FaultPlan {
    /// Parse a `key=value,key=value` spec. See the module docs for the
    /// grammar; an empty spec yields an inert plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut seed = 0u64;
        let mut probs = [0.0f64; 5];
        let mut stall = Duration::from_millis(50);
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part.split_once('=').ok_or_else(|| {
                Error::Runtime(format!("fault plan: expected key=value, got {part:?}"))
            })?;
            let (key, value) = (key.trim(), value.trim());
            if key == "seed" {
                seed = value.parse().map_err(|_| {
                    Error::Runtime(format!("fault plan: seed must be a u64, got {value:?}"))
                })?;
                continue;
            }
            let num: f64 = value.parse().map_err(|_| {
                Error::Runtime(format!("fault plan: {key} must be numeric, got {value:?}"))
            })?;
            if !num.is_finite() {
                return Err(Error::Runtime(format!("fault plan: {key} must be finite")));
            }
            if key == "read_stall_ms" {
                // Clamp, don't reject: a hostile 10^12 ms stall becomes
                // the max testable stall rather than a wedged server.
                let ms = num.clamp(0.0, MAX_STALL.as_millis() as f64);
                stall = Duration::from_millis(ms as u64);
                continue;
            }
            let site = FaultSite::ALL
                .iter()
                .find(|s| s.name() == key)
                .ok_or_else(|| Error::Runtime(format!("fault plan: unknown key {key:?}")))?;
            probs[site.index()] = num.clamp(0.0, 1.0);
        }
        Ok(FaultPlan {
            inner: Arc::new(Inner {
                seed,
                probs,
                stall,
                rng: Mutex::new(Rng::new(seed)),
                injected: Default::default(),
            }),
        })
    }

    /// Plan from `AIEBLAS_FAULT_PLAN`, if set. A present-but-invalid
    /// spec is an error — silently ignoring it would un-inject the
    /// chaos a test asked for.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var(FAULT_PLAN_ENV) {
            Ok(spec) => Self::parse(&spec).map(Some),
            Err(_) => Ok(None),
        }
    }

    /// Decide whether `site` fails now. Draws from the shared RNG only
    /// when the site has a nonzero rate, so inert sites never perturb
    /// the decision sequence of active ones.
    pub fn fire(&self, site: FaultSite) -> bool {
        let p = self.inner.probs[site.index()];
        if p <= 0.0 {
            return false;
        }
        let hit = p >= 1.0
            || self.inner.rng.lock().expect("fault plan rng poisoned").f64() < p;
        if hit {
            self.inner.injected[site.index()].fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// True when any site has a nonzero rate.
    pub fn is_active(&self) -> bool {
        self.inner.probs.iter().any(|&p| p > 0.0)
    }

    /// Configured rate for `site` (post-clamp).
    pub fn rate(&self, site: FaultSite) -> f64 {
        self.inner.probs[site.index()]
    }

    /// Sleep applied when a read stall fires.
    pub fn stall(&self) -> Duration {
        self.inner.stall
    }

    /// How many times `site` has actually fired.
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.inner.injected[site.index()].load(Ordering::Relaxed)
    }

    /// Wire summary for `/v1/healthz`: seed, stall and, per active
    /// site, the configured rate and the injected-so-far count.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let sites: Vec<(&str, Json)> = FaultSite::ALL
            .iter()
            .filter(|s| self.rate(**s) > 0.0)
            .map(|s| {
                (
                    s.name(),
                    obj(vec![
                        ("rate", self.rate(*s).into()),
                        ("injected", (self.injected(*s) as f64).into()),
                    ]),
                )
            })
            .collect();
        obj(vec![
            ("seed", (self.inner.seed as f64).into()),
            ("stall_ms", (self.inner.stall.as_millis() as f64).into()),
            ("sites", obj(sites)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_decision_sequence() {
        let a = FaultPlan::parse("seed=42,http_503=0.3").unwrap();
        let b = FaultPlan::parse("seed=42,http_503=0.3").unwrap();
        let seq_a: Vec<bool> = (0..4096).map(|_| a.fire(FaultSite::Http503Burst)).collect();
        let seq_b: Vec<bool> = (0..4096).map(|_| b.fire(FaultSite::Http503Burst)).collect();
        assert_eq!(seq_a, seq_b);
        assert_eq!(
            a.injected(FaultSite::Http503Burst),
            b.injected(FaultSite::Http503Burst)
        );
        let hits = a.injected(FaultSite::Http503Burst) as f64 / 4096.0;
        assert!((hits - 0.3).abs() < 0.05, "rate {hits} too far from 0.3");
    }

    #[test]
    fn different_seeds_differ() {
        let a = FaultPlan::parse("seed=1,connect_refuse=0.5").unwrap();
        let b = FaultPlan::parse("seed=2,connect_refuse=0.5").unwrap();
        let seq_a: Vec<bool> = (0..512).map(|_| a.fire(FaultSite::ConnectRefuse)).collect();
        let seq_b: Vec<bool> = (0..512).map(|_| b.fire(FaultSite::ConnectRefuse)).collect();
        assert_ne!(seq_a, seq_b);
    }

    #[test]
    fn zero_rate_sites_never_fire_and_never_draw() {
        let with_inert = FaultPlan::parse("seed=9,http_503=0.4,connect_refuse=0").unwrap();
        let without = FaultPlan::parse("seed=9,http_503=0.4").unwrap();
        for _ in 0..256 {
            assert!(!with_inert.fire(FaultSite::ConnectRefuse));
            // Interleaving inert draws must not shift the active site's
            // sequence.
            assert_eq!(
                with_inert.fire(FaultSite::Http503Burst),
                without.fire(FaultSite::Http503Burst)
            );
        }
    }

    #[test]
    fn rate_one_always_fires() {
        let plan = FaultPlan::parse("store_write_fail=1").unwrap();
        for _ in 0..32 {
            assert!(plan.fire(FaultSite::StoreWriteFail));
        }
        assert_eq!(plan.injected(FaultSite::StoreWriteFail), 32);
    }

    #[test]
    fn hostile_values_clamp_and_typos_reject() {
        let plan =
            FaultPlan::parse("seed=7,connect_refuse=99.5,http_503=-3,read_stall_ms=1e18")
                .unwrap();
        assert_eq!(plan.rate(FaultSite::ConnectRefuse), 1.0);
        assert_eq!(plan.rate(FaultSite::Http503Burst), 0.0);
        assert_eq!(plan.stall(), MAX_STALL);

        assert!(FaultPlan::parse("bogus_site=0.5").is_err());
        assert!(FaultPlan::parse("connect_refuse=lots").is_err());
        assert!(FaultPlan::parse("connect_refuse").is_err());
        assert!(FaultPlan::parse("seed=minus-one").is_err());
        assert!(FaultPlan::parse("read_stall_ms=nan").is_err());
    }

    #[test]
    fn empty_spec_is_inert() {
        let plan = FaultPlan::parse("").unwrap();
        assert!(!plan.is_active());
        for site in FaultSite::ALL {
            assert!(!plan.fire(site));
        }
    }

    #[test]
    fn clones_share_rng_and_counters() {
        let a = FaultPlan::parse("seed=5,http_503=1").unwrap();
        let b = a.clone();
        assert!(a.fire(FaultSite::Http503Burst));
        assert!(b.fire(FaultSite::Http503Burst));
        assert_eq!(a.injected(FaultSite::Http503Burst), 2);
        assert_eq!(b.injected(FaultSite::Http503Burst), 2);
    }

    #[test]
    fn to_json_lists_only_active_sites() {
        let plan = FaultPlan::parse("seed=3,store_write_fail=0.25").unwrap();
        let j = plan.to_json();
        assert_eq!(j.get("seed").and_then(|v| v.as_u64()), Some(3));
        let sites = j.get("sites").expect("sites object");
        assert!(sites.get("store_write_fail").is_some());
        assert!(sites.get("connect_refuse").is_none());
    }
}
