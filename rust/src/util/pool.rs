//! Thread-local `f32` buffer pool for the dispatch hot path (DESIGN.md §12).
//!
//! Warm serving traffic used to allocate every output vector
//! (`vec![0.0; n]` in the Cpu/Reference kernel walks) and drop every
//! request's input vectors per dispatch. The pool closes that loop on
//! each thread: backends draw zeroed buffers from their thread's pool and
//! the serve dispatcher recycles consumed input vectors back into its
//! own, so steady-state dispatch on one thread reuses the same handful of
//! allocations instead of round-tripping the global allocator per
//! request.
//!
//! Lifetime rules (the reasons this is safe and bounded):
//! * `take_*` transfers **ownership** out of the pool — a taken buffer is
//!   an ordinary `Vec<f32>` that may outlive the pool, the thread, or be
//!   handed to another thread (outcome vectors leave the server with the
//!   response; they are simply never recycled in that case).
//! * `recycle` is best-effort: retention is capped per thread (count and
//!   bytes), so recycling on a thread that never takes — or taking on a
//!   thread that never recycles — degrades to plain allocation, never to
//!   unbounded growth.
//! * Buffers are re-zeroed (`take_zeroed`) or fully overwritten
//!   (`take_copied`) on the way out, so pooling is invisible to numerics:
//!   outputs are bit-identical to freshly allocated ones.

use std::cell::RefCell;

/// Buffers retained per thread.
const MAX_POOLED: usize = 32;

/// Bytes retained per thread (16 MiB: a few level-3 `n×n` outputs).
const MAX_POOLED_BYTES: usize = 16 << 20;

struct Pool {
    bufs: Vec<Vec<f32>>,
    bytes: usize,
}

impl Pool {
    const fn new() -> Pool {
        Pool { bufs: Vec::new(), bytes: 0 }
    }

    fn take(&mut self, min_capacity: usize) -> Option<Vec<f32>> {
        // newest-first: the most recently recycled buffer is the most
        // likely to still be cache-warm and the right size.
        for i in (0..self.bufs.len()).rev() {
            if self.bufs[i].capacity() >= min_capacity {
                let buf = self.bufs.swap_remove(i);
                self.bytes -= buf.capacity() * std::mem::size_of::<f32>();
                return Some(buf);
            }
        }
        None
    }

    fn recycle(&mut self, buf: Vec<f32>) {
        let bytes = buf.capacity() * std::mem::size_of::<f32>();
        if bytes == 0 || self.bufs.len() >= MAX_POOLED || self.bytes + bytes > MAX_POOLED_BYTES {
            return; // dropped: retention stays bounded
        }
        self.bytes += bytes;
        self.bufs.push(buf);
    }
}

thread_local! {
    static POOL: RefCell<Pool> = const { RefCell::new(Pool::new()) };
}

/// An all-zeros length-`n` vector, reusing a pooled allocation when one
/// with enough capacity is available. Numerically identical to
/// `vec![0.0; n]`.
pub fn take_zeroed(n: usize) -> Vec<f32> {
    match POOL.with(|p| p.borrow_mut().take(n)) {
        Some(mut buf) => {
            buf.clear();
            buf.resize(n, 0.0);
            buf
        }
        None => vec![0.0; n],
    }
}

/// A copy of `src`, reusing a pooled allocation when possible.
/// Numerically identical to `src.to_vec()`.
pub fn take_copied(src: &[f32]) -> Vec<f32> {
    match POOL.with(|p| p.borrow_mut().take(src.len())) {
        Some(mut buf) => {
            buf.clear();
            buf.extend_from_slice(src);
            buf
        }
        None => src.to_vec(),
    }
}

/// Return a buffer to this thread's pool (best-effort; see module docs).
pub fn recycle(buf: Vec<f32>) {
    POOL.with(|p| p.borrow_mut().recycle(buf));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_zeroed_matches_fresh_allocation() {
        let mut buf = take_zeroed(16);
        for (i, v) in buf.iter_mut().enumerate() {
            *v = i as f32 + 1.0;
        }
        recycle(buf);
        // the recycled (dirty) buffer must come back fully zeroed.
        let again = take_zeroed(16);
        assert_eq!(again, vec![0.0; 16]);
        // shrinking reuse zeroes exactly n elements.
        recycle(again);
        let small = take_zeroed(4);
        assert_eq!(small, vec![0.0; 4]);
    }

    #[test]
    fn take_copied_matches_to_vec() {
        recycle(vec![9.0; 32]);
        let src = [1.0f32, 2.0, 3.0];
        assert_eq!(take_copied(&src), src.to_vec());
    }

    #[test]
    fn reuse_actually_happens_on_one_thread() {
        let buf = take_zeroed(1024);
        let ptr = buf.as_ptr();
        recycle(buf);
        let again = take_zeroed(512);
        assert_eq!(again.as_ptr(), ptr, "pooled allocation must be reused");
    }

    #[test]
    fn retention_is_bounded() {
        // over-recycle: the pool must cap its retained count...
        for _ in 0..(MAX_POOLED * 2) {
            recycle(vec![0.0; 8]);
        }
        let retained = POOL.with(|p| p.borrow().bufs.len());
        assert!(retained <= MAX_POOLED);
        // ...and its retained bytes (one buffer over the byte cap drops).
        recycle(vec![0.0; MAX_POOLED_BYTES / std::mem::size_of::<f32>() + 1]);
        let bytes = POOL.with(|p| p.borrow().bytes);
        assert!(bytes <= MAX_POOLED_BYTES);
    }

    #[test]
    fn zero_capacity_buffers_are_not_retained() {
        let before = POOL.with(|p| p.borrow().bufs.len());
        recycle(Vec::new());
        let after = POOL.with(|p| p.borrow().bufs.len());
        assert_eq!(before, after);
    }
}
