//! Deterministic PRNG (xoshiro256++) and the distributions the test-suite,
//! property-testing framework and workload generators need.
//!
//! In-tree because the offline registry has no `rand` crate (DESIGN.md §1).
//! xoshiro256++ is the same generator family `rand_xoshiro` uses; it is
//! fast, passes BigCrush, and — crucially for reproducible experiments —
//! fully determined by its 64-bit seed.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64, per the xoshiro reference implementation (a
    /// zero state would be a fixed point, SplitMix avoids it).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's nearly-divisionless method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Standard normal via Box–Muller (workload generators).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > f64::EPSILON {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Random f32 vector with standard-normal entries (test inputs).
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(17);
        for _ in 0..100 {
            let v = r.range(5, 7);
            assert!((5..=7).contains(&v));
        }
        assert_eq!(r.range(4, 4), 4);
    }
}
