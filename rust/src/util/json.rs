//! Minimal but complete JSON implementation (RFC 8259).
//!
//! The build environment's cargo registry is offline (only the `xla` crate
//! closure is cached), so `serde_json` is unavailable; AIEBLAS needs JSON in
//! two places — the user-facing routine specification (paper §III, Fig. 1)
//! and the artifact manifest emitted by `python/compile/aot.py` — so we
//! implement the format in-tree. Supports parsing, pretty/compact printing,
//! typed accessors and JSON-pointer-style path lookup.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a `BTreeMap` so printing is
/// deterministic (stable golden files in codegen tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with 1-based line/column and a short message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    line: usize,
    col: usize,
    depth: usize,
}

const MAX_DEPTH: usize = 128;

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { bytes: s.as_bytes(), pos: 0, line: 1, col: 1, depth: 0 }
    }

    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { line: self.line, col: self.col, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.bump();
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        match self.bump() {
            Some(got) if got == b => Ok(()),
            Some(got) => self.err(format!("expected '{}', got '{}'", b as char, got as char)),
            None => self.err(format!("expected '{}', got end of input", b as char)),
        }
    }

    fn parse_value(&mut self) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return self.err("nesting too deep");
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_object(),
            Some(b'[') => self.parse_array(),
            Some(b'"') => Ok(Json::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Json::Bool(true)),
            Some(b'f') => self.parse_lit("false", Json::Bool(false)),
            Some(b'n') => self.parse_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_number(),
            Some(c) => self.err(format!("unexpected character '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        for &b in lit.as_bytes() {
            match self.bump() {
                Some(got) if got == b => {}
                _ => return self.err(format!("invalid literal, expected '{lit}'")),
            }
        }
        Ok(v)
    }

    fn parse_object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.depth += 1;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.bump();
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.parse_value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => break,
                Some(c) => return self.err(format!("expected ',' or '}}', got '{}'", c as char)),
                None => return self.err("unterminated object"),
            }
        }
        self.depth -= 1;
        Ok(Json::Obj(map))
    }

    fn parse_array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.depth += 1;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.bump();
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => break,
                Some(c) => return self.err(format!("expected ',' or ']', got '{}'", c as char)),
                None => return self.err("unterminated array"),
            }
        }
        self.depth -= 1;
        Ok(Json::Arr(items))
    }

    fn parse_string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.parse_hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("unpaired high surrogate");
                            }
                            let lo = self.parse_hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            match char::from_u32(c) {
                                Some(ch) => out.push(ch),
                                None => return self.err("invalid surrogate pair"),
                            }
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return self.err("unpaired low surrogate");
                        } else {
                            match char::from_u32(cp) {
                                Some(ch) => out.push(ch),
                                None => return self.err("invalid \\u escape"),
                            }
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(b) if b < 0x20 => return self.err("control character in string"),
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // multi-byte UTF-8: copy continuation bytes verbatim.
                    let len = UTF8_LEN[(b >> 3) as usize] as usize;
                    if len == 0 {
                        return self.err("invalid utf-8");
                    }
                    let start = self.pos - 1;
                    for _ in 1..len {
                        match self.bump() {
                            Some(c) if c & 0xC0 == 0x80 => {}
                            _ => return self.err("invalid utf-8 continuation"),
                        }
                    }
                    match std::str::from_utf8(&self.bytes[start..self.pos]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return self.err("invalid utf-8 sequence"),
                    }
                }
            }
        }
    }

    fn parse_hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.bump() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => return self.err("invalid hex digit in \\u escape"),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn parse_number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.bump();
        }
        match self.peek() {
            Some(b'0') => {
                self.bump();
            }
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.bump();
                }
            }
            _ => return self.err("invalid number"),
        }
        if self.peek() == Some(b'.') {
            self.bump();
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return self.err("expected digit after decimal point");
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.bump();
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.bump();
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return self.err("expected digit in exponent");
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(Json::Num(v)),
            _ => self.err("number out of range"),
        }
    }
}

// len-by-leading-5-bits table for UTF-8 sequences (index = byte >> 3).
const UTF8_LEN: [u8; 32] = [
    1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, // ASCII
    0, 0, 0, 0, 0, 0, 0, 0, // continuation bytes (invalid as start)
    2, 2, 2, 2, // 110xxxxx
    3, 3, // 1110xxxx
    4, // 11110xxx
    0,
];

impl Json {
    /// Parse a JSON document. The whole input must be consumed.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser::new(s);
        let v = p.parse_value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return p.err("trailing characters after value");
        }
        Ok(v)
    }

    /// Typed accessors ------------------------------------------------------
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; returns `None` for non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `a.b.0.c`-style path lookup (segments separated by '.'; numeric
    /// segments index arrays).
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = match cur {
                Json::Obj(m) => m.get(seg)?,
                Json::Arr(v) => v.get(seg.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Compact single-line rendering.
    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..width * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders used by codegen and reports.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path("a.1.b"), Some(&Json::Null));
        assert_eq!(v.path("c").and_then(Json::as_str), Some("x"));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "01", "1.", "1e", "\"\\x\"",
            "\"unterminated", "nul", "[1 2]", "{\"a\" 1}", "1 2",
            "\"\\ud800\"", // unpaired surrogate
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn rejects_deep_nesting() {
        let s = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&s).is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"axpy","n":4096,"window":2048,"pl":true,"hints":[1,2,3],"nested":{"x":null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_compact()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(4096.0).to_compact(), "4096");
        assert_eq!(Json::Num(0.5).to_compact(), "0.5");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 8, "f": 1.5, "s": "x", "b": true, "a": []}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(8));
        assert_eq!(v.get("f").unwrap().as_u64(), None); // not integral
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn obj_builder() {
        let v = obj(vec![("a", 1usize.into()), ("b", "x".into())]);
        assert_eq!(v.to_compact(), r#"{"a":1,"b":"x"}"#);
    }

    #[test]
    fn error_position_is_reported() {
        let e = Json::parse("{\n  \"a\": oops\n}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.col > 1);
    }
}
