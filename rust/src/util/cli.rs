//! Declarative command-line parser (no `clap` in the offline registry).
//!
//! Supports subcommands, `--flag`, `--opt value` / `--opt=value`,
//! positional arguments, defaults, required options, and generated
//! `--help` text — the subset `aieblas`' CLI (rust/src/main.rs) needs,
//! including the plan-cache demo surface (`run --repeat N` re-runs a
//! spec so warm lowerings hit the cache).

use std::collections::BTreeMap;
use std::fmt;

/// Argument specification for one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub required: bool,
    pub default: Option<&'static str>,
}

/// A (sub)command: options + positionals.
#[derive(Debug, Clone, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
    positionals: Vec<(&'static str, &'static str, bool)>, // (name, help, required)
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    /// `--name <value>` option.
    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, required: false, default: None });
        self
    }

    /// `--name <value>` with a default.
    pub fn opt_default(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            required: false,
            default: Some(default),
        });
        self
    }

    /// Required `--name <value>`.
    pub fn opt_required(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, required: true, default: None });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, required: false, default: None });
        self
    }

    /// Positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str, required: bool) -> Self {
        self.positionals.push((name, help, required));
        self
    }

    fn usage(&self, prog: &str) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {prog} {}", self.name, self.about, self.name);
        for (p, _, req) in &self.positionals {
            if *req {
                s.push_str(&format!(" <{p}>"));
            } else {
                s.push_str(&format!(" [{p}]"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str(" [OPTIONS]\n\nOPTIONS:\n");
            for o in &self.opts {
                let mut left = format!("  --{}", o.name);
                if o.takes_value {
                    left.push_str(" <v>");
                }
                let mut help = o.help.to_string();
                if let Some(d) = o.default {
                    help.push_str(&format!(" [default: {d}]"));
                }
                if o.required {
                    help.push_str(" (required)");
                }
                s.push_str(&format!("{left:28}{help}\n"));
            }
        } else {
            s.push('\n');
        }
        s
    }
}

/// Parsed arguments for the matched subcommand.
#[derive(Debug, Clone, Default)]
pub struct Matches {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|_| {
                CliError(format!("invalid value {v:?} for --{name}"))
            }),
        }
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.get_parsed::<usize>(name)?
            .ok_or_else(|| CliError(format!("missing --{name}")))
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.get_parsed::<f64>(name)?
            .ok_or_else(|| CliError(format!("missing --{name}")))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Top-level application: a set of subcommands.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    commands: Vec<Command>,
}

/// Result of parsing: either matches, or help text to print (not an error).
pub enum Parsed {
    Matches(Matches),
    Help(String),
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        App { name, about, commands: Vec::new() }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    pub fn top_usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <COMMAND>\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:16}{}\n", c.name, c.about));
        }
        s.push_str("\nRun with <COMMAND> --help for command options.\n");
        s
    }

    /// Parse argv (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        let Some(first) = args.first() else {
            return Ok(Parsed::Help(self.top_usage()));
        };
        if first == "--help" || first == "-h" || first == "help" {
            return Ok(Parsed::Help(self.top_usage()));
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == first.as_str())
            .ok_or_else(|| CliError(format!("unknown command {first:?}; try --help")))?;

        let mut m = Matches { command: cmd.name.to_string(), ..Default::default() };
        // seed defaults
        for o in &cmd.opts {
            if let Some(d) = o.default {
                m.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Ok(Parsed::Help(cmd.usage(self.name)));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError(format!("unknown option --{name} for {}", cmd.name)))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    m.values.insert(name.to_string(), v);
                } else {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} does not take a value")));
                    }
                    m.flags.push(name.to_string());
                }
            } else {
                m.positionals.push(a.clone());
            }
            i += 1;
        }
        // validate
        for o in &cmd.opts {
            if o.required && !m.values.contains_key(o.name) {
                return Err(CliError(format!("missing required option --{}", o.name)));
            }
        }
        let required_pos = cmd.positionals.iter().filter(|(_, _, r)| *r).count();
        if m.positionals.len() < required_pos {
            return Err(CliError(format!(
                "{} requires {} positional argument(s)",
                cmd.name, required_pos
            )));
        }
        // Surplus positionals are as much a usage error as unknown
        // options: `cache stats extra` or a typo'd bare word must fail
        // loudly, not run with the junk silently ignored.
        if m.positionals.len() > cmd.positionals.len() {
            return Err(CliError(format!(
                "unexpected positional argument {:?} for {}",
                m.positionals[cmd.positionals.len()],
                cmd.name
            )));
        }
        Ok(Parsed::Matches(m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("aieblas", "test app")
            .command(
                Command::new("run", "run a spec")
                    .positional("spec", "spec file", true)
                    .opt_default("size", "4096", "problem size")
                    .opt_required("routine", "routine name")
                    .flag("verbose", "chatty"),
            )
            .command(Command::new("info", "print info"))
    }

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_with_options() {
        let p = app()
            .parse(&args(&["run", "spec.json", "--routine", "axpy", "--verbose"]))
            .unwrap();
        let Parsed::Matches(m) = p else { panic!("expected matches") };
        assert_eq!(m.command, "run");
        assert_eq!(m.positionals, vec!["spec.json"]);
        assert_eq!(m.get("routine"), Some("axpy"));
        assert_eq!(m.usize("size").unwrap(), 4096); // default
        assert!(m.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let p = app()
            .parse(&args(&["run", "s.json", "--routine=dot", "--size=99"]))
            .unwrap();
        let Parsed::Matches(m) = p else { panic!() };
        assert_eq!(m.get("routine"), Some("dot"));
        assert_eq!(m.usize("size").unwrap(), 99);
    }

    #[test]
    fn missing_required_option_is_error() {
        assert!(app().parse(&args(&["run", "s.json"])).is_err());
    }

    #[test]
    fn missing_positional_is_error() {
        assert!(app().parse(&args(&["run", "--routine", "axpy"])).is_err());
    }

    #[test]
    fn unknown_command_and_option() {
        assert!(app().parse(&args(&["nope"])).is_err());
        assert!(app()
            .parse(&args(&["run", "s.json", "--routine", "axpy", "--bogus"]))
            .is_err());
    }

    #[test]
    fn surplus_positional_is_error() {
        let err = app()
            .parse(&args(&["run", "s.json", "stray", "--routine", "axpy"]))
            .unwrap_err();
        assert!(err.0.contains("unexpected positional"), "{err}");
        assert!(err.0.contains("stray"), "{err}");
        // zero-positional commands reject any bare word.
        assert!(app().parse(&args(&["info", "huh"])).is_err());
    }

    #[test]
    fn help_paths() {
        assert!(matches!(app().parse(&args(&[])), Ok(Parsed::Help(_))));
        assert!(matches!(app().parse(&args(&["--help"])), Ok(Parsed::Help(_))));
        let Ok(Parsed::Help(h)) = app().parse(&args(&["run", "--help"])) else {
            panic!()
        };
        assert!(h.contains("--routine"));
        assert!(h.contains("[default: 4096]"));
    }

    #[test]
    fn invalid_numeric_value() {
        let Parsed::Matches(m) = app()
            .parse(&args(&["run", "s.json", "--routine", "x", "--size", "abc"]))
            .unwrap()
        else {
            panic!()
        };
        assert!(m.usize("size").is_err());
    }
}
