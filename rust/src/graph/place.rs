//! Placement engine: map graph nodes onto the AIE array (Fig. 1 ③).
//!
//! The paper: "By default, AIEBLAS relies on the AIE compiler for the
//! kernel placements. However, for larger designs, it may be necessary to
//! provide placement hints … users can set an optional field in the JSON
//! configuration specifying a placement constraint for each kernel."
//!
//! Our stand-in for the AIE compiler's floorplanner: user hints are
//! honored verbatim (errors on conflicts); remaining AIE kernels are
//! placed greedily next to their already-placed neighbours (minimising
//! Manhattan wire length), then improved with a local-search pass. PL
//! movers occupy *shim* columns — the PL↔AIE interface row below the
//! array — balanced across columns to spread interface load.

use std::collections::BTreeMap;

use super::{Graph, NodeId, NodeKind};
use crate::arch::ArchConfig;
use crate::{Error, Result};

/// Where a node physically sits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Location {
    /// An AIE tile at (col, row).
    Tile { col: usize, row: usize },
    /// A PL kernel reaching the array through the shim at `col`.
    Shim { col: usize },
    /// Host/DDR side (not on the array) — unused today but kept so the
    /// router can model host-mapped endpoints.
    OffChip,
}

impl Location {
    pub fn coords(&self) -> (isize, isize) {
        match *self {
            Location::Tile { col, row } => (col as isize, row as isize),
            Location::Shim { col } => (col as isize, -1),
            Location::OffChip => (-1, -2),
        }
    }
}

/// A complete placement of a graph.
#[derive(Debug, Clone)]
pub struct Placement {
    pub locations: Vec<Location>,
}

impl Placement {
    pub fn of(&self, id: NodeId) -> Location {
        self.locations[id]
    }

    /// Manhattan distance between two placed nodes (hop estimate).
    pub fn distance(&self, a: NodeId, b: NodeId) -> usize {
        let (ax, ay) = self.of(a).coords();
        let (bx, by) = self.of(b).coords();
        (ax.abs_diff(bx) + ay.abs_diff(by)) as usize
    }

    /// Total wire length over all edges (the placement objective).
    pub fn wirelength(&self, g: &Graph) -> usize {
        g.edges.iter().map(|e| self.distance(e.src, e.dst)).sum()
    }
}

/// Tile scan order for the greedy placement step. Ties in the greedy cost
/// are broken by whichever free tile is visited first, so the scan order is
/// a genuine placement knob: column-major packs chains vertically up a
/// column, row-major spreads them along the (shim-adjacent) bottom row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScanOrder {
    /// `for col { for row }` — the historical default.
    ColMajor,
    /// `for row { for col }`.
    RowMajor,
}

/// Tunable knobs of the placement heuristic. [`PlaceParams::default`]
/// reproduces [`place`] exactly (byte-identical placements), so the tuner's
/// candidate 0 is always the untuned plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlaceParams {
    /// Weight of the bottom-row bias in the greedy cost (`+ row * row_bias`).
    /// 0 ignores shim proximity; larger values pull kernels toward the PL
    /// interface at the cost of wirelength between kernels.
    pub row_bias: usize,
    /// Free-tile scan order (tie-break direction) for the greedy step.
    pub scan: ScanOrder,
    /// Bound on local-search improvement passes (0 disables the search).
    pub swap_passes: usize,
}

impl Default for PlaceParams {
    fn default() -> Self {
        PlaceParams { row_bias: 1, scan: ScanOrder::ColMajor, swap_passes: 4 }
    }
}

impl PlaceParams {
    /// Stable one-line rendering for candidate tables and store metadata.
    pub fn describe(&self) -> String {
        format!(
            "bias={} scan={} passes={}",
            self.row_bias,
            match self.scan {
                ScanOrder::ColMajor => "col",
                ScanOrder::RowMajor => "row",
            },
            self.swap_passes
        )
    }
}

/// Deterministic bounded enumeration of placement-parameter candidates for
/// the autotuner: the cross product of row-bias weights, scan orders and
/// local-search budgets, with the default parameters always first (so a
/// tuner that keeps candidate 0 degrades gracefully to the untuned plan).
/// Truncated to `limit` entries.
pub fn candidate_params(limit: usize) -> Vec<PlaceParams> {
    let mut out = vec![PlaceParams::default()];
    for &row_bias in &[1usize, 0, 2, 4] {
        for &scan in &[ScanOrder::ColMajor, ScanOrder::RowMajor] {
            for &swap_passes in &[4usize, 0, 8] {
                let p = PlaceParams { row_bias, scan, swap_passes };
                if !out.contains(&p) {
                    out.push(p);
                }
            }
        }
    }
    out.truncate(limit.max(1));
    out
}

/// Place `graph` on `arch` with the default heuristic parameters.
/// Deterministic for a given input.
pub fn place(graph: &Graph, arch: &ArchConfig) -> Result<Placement> {
    place_with(graph, arch, &PlaceParams::default())
}

/// Place `graph` on `arch` under explicit heuristic parameters (the
/// autotuner's candidate-enumeration entry point). Hints are always honored
/// regardless of parameters; every returned placement satisfies the same
/// invariants as [`place`].
pub fn place_with(graph: &Graph, arch: &ArchConfig, params: &PlaceParams) -> Result<Placement> {
    let n = graph.nodes.len();
    let mut locations = vec![Location::OffChip; n];
    let mut occupied: BTreeMap<(usize, usize), NodeId> = BTreeMap::new();

    let aie_kernels: Vec<NodeId> = graph
        .nodes
        .iter()
        .filter(|nd| matches!(nd.kind, NodeKind::AieKernel { .. }))
        .map(|nd| nd.id)
        .collect();
    if aie_kernels.len() > arch.num_tiles() {
        return Err(Error::Placement(format!(
            "{} kernels exceed the {}-tile array",
            aie_kernels.len(),
            arch.num_tiles()
        )));
    }

    // 1. pin hinted kernels.
    for &id in &aie_kernels {
        if let NodeKind::AieKernel { hint: Some((col, row)), .. } = graph.node(id).kind {
            if col >= arch.cols || row >= arch.rows {
                return Err(Error::Placement(format!(
                    "{}: hint ({col},{row}) outside {}×{} grid",
                    graph.node(id).name,
                    arch.cols,
                    arch.rows
                )));
            }
            if let Some(prev) = occupied.insert((col, row), id) {
                return Err(Error::Placement(format!(
                    "hint collision at ({col},{row}) between {} and {}",
                    graph.node(prev).name,
                    graph.node(id).name
                )));
            }
            locations[id] = Location::Tile { col, row };
        }
    }

    // 2. greedy: process unhinted kernels in topological order; place each
    //    at the free tile minimising distance to already-placed neighbours
    //    (ties → lowest col,row: deterministic).
    let topo = graph.topo_order()?;
    for &id in &topo {
        if !matches!(graph.node(id).kind, NodeKind::AieKernel { .. })
            || !matches!(locations[id], Location::OffChip)
        {
            continue;
        }
        let neighbours: Vec<NodeId> = graph
            .in_edges(id)
            .map(|e| e.src)
            .chain(graph.out_edges(id).map(|e| e.dst))
            .filter(|&o| matches!(locations[o], Location::Tile { .. }))
            .collect();
        let mut best: Option<((usize, usize), usize)> = None;
        let consider = |col: usize, row: usize, best: &mut Option<((usize, usize), usize)>| {
            if occupied.contains_key(&(col, row)) {
                return;
            }
            let cost: usize = neighbours
                .iter()
                .map(|&o| {
                    let (ox, oy) = locations[o].coords();
                    (ox.abs_diff(col as isize) + oy.abs_diff(row as isize)) as usize
                })
                .sum::<usize>()
                // bias: prefer the bottom row (nearer the shim/PL).
                + row * params.row_bias;
            if best.is_none() || cost < best.unwrap().1 {
                *best = Some(((col, row), cost));
            }
        };
        match params.scan {
            ScanOrder::ColMajor => {
                for col in 0..arch.cols {
                    for row in 0..arch.rows {
                        consider(col, row, &mut best);
                    }
                }
            }
            ScanOrder::RowMajor => {
                for row in 0..arch.rows {
                    for col in 0..arch.cols {
                        consider(col, row, &mut best);
                    }
                }
            }
        }
        let ((col, row), _) = best.expect("array not exhausted");
        occupied.insert((col, row), id);
        locations[id] = Location::Tile { col, row };
    }

    // 3. on-chip generators/sinks co-locate with their kernel's tile
    //    neighbourhood (they run on the same or an adjacent tile).
    for nd in &graph.nodes {
        match nd.kind {
            NodeKind::Combine { .. } => {
                let producer = graph.in_edges(nd.id).next().map(|e| e.src);
                locations[nd.id] = neighbour_tile(producer, &locations, &mut occupied, arch)
                    .unwrap_or(Location::Tile { col: 0, row: 0 });
            }
            NodeKind::OnChipSource => {
                let consumer = graph.out_edges(nd.id).next().map(|e| e.dst);
                locations[nd.id] = neighbour_tile(consumer, &locations, &mut occupied, arch)
                    .unwrap_or(Location::Tile { col: 0, row: 0 });
            }
            NodeKind::OnChipSink => {
                let producer = graph.in_edges(nd.id).next().map(|e| e.src);
                locations[nd.id] = neighbour_tile(producer, &locations, &mut occupied, arch)
                    .unwrap_or(Location::Tile { col: 0, row: 0 });
            }
            _ => {}
        }
    }

    // 4. PL movers: shim column nearest their AIE endpoint, load-balanced
    //    (at most `ceil(movers/cols)` per column).
    let mut shim_load = vec![0usize; arch.cols];
    let movers: Vec<NodeId> = graph
        .nodes
        .iter()
        .filter(|nd| nd.kind.is_pl())
        .map(|nd| nd.id)
        .collect();
    let max_per_col = movers.len().div_ceil(arch.cols).max(1);
    for &id in &movers {
        let endpoint = graph
            .out_edges(id)
            .map(|e| e.dst)
            .chain(graph.in_edges(id).map(|e| e.src))
            .next();
        let want_col = match endpoint.map(|e| locations[e]) {
            Some(Location::Tile { col, .. }) => col,
            _ => 0,
        };
        // nearest column with capacity
        let col = (0..arch.cols)
            .min_by_key(|&c| {
                let over = shim_load[c] >= max_per_col;
                (over as usize, c.abs_diff(want_col), c)
            })
            .unwrap();
        shim_load[col] += 1;
        locations[id] = Location::Shim { col };
    }

    // 5. local search: try swapping pairs of unhinted kernels to reduce
    //    wirelength (first-improvement, bounded passes).
    let mut placement = Placement { locations };
    let unhinted: Vec<NodeId> = aie_kernels
        .iter()
        .copied()
        .filter(|&id| !matches!(graph.node(id).kind, NodeKind::AieKernel { hint: Some(_), .. }))
        .collect();
    let mut improved = true;
    let mut passes = 0;
    while improved && passes < params.swap_passes {
        improved = false;
        passes += 1;
        let before = placement.wirelength(graph);
        for i in 0..unhinted.len() {
            for j in i + 1..unhinted.len() {
                let (a, b) = (unhinted[i], unhinted[j]);
                placement.locations.swap(a, b);
                if placement.wirelength(graph) < before {
                    improved = true;
                } else {
                    placement.locations.swap(a, b);
                }
            }
        }
    }

    Ok(placement)
}

fn neighbour_tile(
    anchor: Option<NodeId>,
    locations: &[Location],
    occupied: &mut BTreeMap<(usize, usize), NodeId>,
    arch: &ArchConfig,
) -> Option<Location> {
    let (ac, ar) = match anchor.map(|a| locations[a]) {
        Some(Location::Tile { col, row }) => (col as isize, row as isize),
        _ => return None,
    };
    // nearest free tile by Manhattan radius (including the anchor's own
    // tile being busy, generators can share: fall back to the anchor tile).
    for radius in 1..(arch.cols + arch.rows) as isize {
        for dc in -radius..=radius {
            let dr = radius - dc.abs();
            for &(c, r) in &[(ac + dc, ar + dr), (ac + dc, ar - dr)] {
                if c < 0 || r < 0 || c >= arch.cols as isize || r >= arch.rows as isize {
                    continue;
                }
                let key = (c as usize, r as usize);
                if !occupied.contains_key(&key) {
                    // generators don't exclude kernels from the tile, but
                    // mark it to spread multiple generators out.
                    occupied.insert(key, usize::MAX);
                    return Some(Location::Tile { col: key.0, row: key.1 });
                }
            }
        }
    }
    Some(Location::Tile { col: ac as usize, row: ar as usize })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::graph::build::build_graph;
    use crate::spec::{DataSource, Spec};

    fn arch() -> ArchConfig {
        ArchConfig::vck5000()
    }

    #[test]
    fn places_single_routine() {
        let g = build_graph(&Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl))
            .unwrap()
            .graph;
        let p = place(&g, &arch()).unwrap();
        let kernel = g.node_by_name("a").unwrap();
        assert!(matches!(p.of(kernel.id), Location::Tile { .. }));
        for nd in &g.nodes {
            if nd.kind.is_pl() {
                assert!(matches!(p.of(nd.id), Location::Shim { .. }), "{}", nd.name);
            }
        }
    }

    #[test]
    fn honors_hints() {
        let mut spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        spec.routines[0].placement = Some(crate::spec::Placement { col: 7, row: 3 });
        let g = build_graph(&spec).unwrap().graph;
        let p = place(&g, &arch()).unwrap();
        let kernel = g.node_by_name("a").unwrap();
        assert_eq!(p.of(kernel.id), Location::Tile { col: 7, row: 3 });
    }

    #[test]
    fn connected_kernels_placed_adjacent() {
        let g = build_graph(&Spec::axpydot_dataflow(4096, 2.0)).unwrap().graph;
        let p = place(&g, &arch()).unwrap();
        let a = g.node_by_name("axpy_stage").unwrap().id;
        let d = g.node_by_name("dot_stage").unwrap().id;
        assert!(
            p.distance(a, d) <= 2,
            "dataflow stages should be near-adjacent, got {}",
            p.distance(a, d)
        );
    }

    #[test]
    fn no_two_kernels_share_a_tile() {
        // a chain of many kernels
        let mut spec = Spec::default();
        spec.platform = "vck5000".into();
        for i in 0..20 {
            spec.routines.push(crate::spec::RoutineSpec {
                kind: RoutineKind::Scal,
                name: format!("k{i}"),
                size: 1024,
                window: None,
                vector_bits: 512,
                placement: None,
                burst: false,
                alpha: Some(1.5),
                beta: None,
                split: 1,
            });
        }
        let g = build_graph(&spec).unwrap().graph;
        let p = place(&g, &arch()).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for nd in &g.nodes {
            if matches!(nd.kind, NodeKind::AieKernel { .. }) {
                let Location::Tile { col, row } = p.of(nd.id) else {
                    panic!("kernel off-array")
                };
                assert!(seen.insert((col, row)), "tile ({col},{row}) reused");
            }
        }
    }

    #[test]
    fn default_params_reproduce_place_exactly() {
        for spec in [
            Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl),
            Spec::axpydot_dataflow(4096, 2.0),
            Spec::chain(RoutineKind::Scal, 3, 1024),
        ] {
            let g = build_graph(&spec).unwrap().graph;
            let default = place(&g, &arch()).unwrap();
            let explicit = place_with(&g, &arch(), &PlaceParams::default()).unwrap();
            assert_eq!(default.locations, explicit.locations);
        }
    }

    #[test]
    fn candidate_enumeration_is_bounded_deterministic_and_default_first() {
        let all = candidate_params(usize::MAX);
        assert_eq!(all[0], PlaceParams::default(), "candidate 0 must be the untuned default");
        assert!(all.len() <= 24, "candidate space must stay bounded, got {}", all.len());
        // no duplicates, and a second enumeration is identical.
        for (i, a) in all.iter().enumerate() {
            assert!(!all[i + 1..].contains(a), "duplicate candidate {a:?}");
        }
        assert_eq!(all, candidate_params(usize::MAX));
        assert_eq!(candidate_params(3).len(), 3);
        assert_eq!(candidate_params(0).len(), 1, "limit 0 still yields the default");
    }

    #[test]
    fn every_candidate_yields_a_valid_placement() {
        let g = build_graph(&Spec::axpydot_dataflow(4096, 2.0)).unwrap().graph;
        for params in candidate_params(usize::MAX) {
            let p = place_with(&g, &arch(), &params).unwrap();
            let mut seen = std::collections::BTreeSet::new();
            for nd in &g.nodes {
                if matches!(nd.kind, NodeKind::AieKernel { .. }) {
                    let Location::Tile { col, row } = p.of(nd.id) else {
                        panic!("{}: kernel off-array under {params:?}", nd.name)
                    };
                    assert!(col < arch().cols && row < arch().rows);
                    assert!(seen.insert((col, row)), "tile reuse under {params:?}");
                }
            }
        }
    }

    #[test]
    fn hints_honored_under_all_candidates() {
        let mut spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        spec.routines[0].placement = Some(crate::spec::Placement { col: 7, row: 3 });
        let g = build_graph(&spec).unwrap().graph;
        let kernel = g.node_by_name("a").unwrap().id;
        for params in candidate_params(usize::MAX) {
            let p = place_with(&g, &arch(), &params).unwrap();
            assert_eq!(p.of(kernel), Location::Tile { col: 7, row: 3 }, "{params:?}");
        }
    }

    #[test]
    fn too_many_kernels_rejected() {
        let mut g = Graph::default();
        for i in 0..401 {
            g.add_node(
                format!("k{i}"),
                NodeKind::AieKernel {
                    kind: RoutineKind::Scal,
                    size: 64,
                    window: 64,
                    vector_bits: 512,
                    hint: None,
                },
            );
        }
        assert!(place(&g, &arch()).is_err());
    }

    #[test]
    fn hint_collision_rejected_at_placement() {
        let mut g = Graph::default();
        for name in ["a", "b"] {
            g.add_node(
                name,
                NodeKind::AieKernel {
                    kind: RoutineKind::Scal,
                    size: 64,
                    window: 64,
                    vector_bits: 512,
                    hint: Some((1, 1)),
                },
            );
        }
        assert!(place(&g, &arch()).is_err());
    }
}
