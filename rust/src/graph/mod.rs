//! ADF-style dataflow graph IR (paper §III, Fig. 1 ③).
//!
//! The generator lowers a validated [`Spec`](crate::spec::Spec) to this
//! graph: one node per AIE kernel, plus PL data-mover nodes for every
//! routine port not connected to another routine (the paper: "If a routine
//! input/output is not connected to another routine, AIEBLAS will create a
//! PL kernel to load/store the data from off-chip memory"). Composite
//! routines (axpydot) are expanded into their kernel pipeline here.

pub mod build;
pub mod place;
pub mod route;

use std::collections::BTreeMap;

use crate::blas::{PortType, RoutineKind};
use crate::{Error, Result};

/// Node identifier (index into [`Graph::nodes`]).
pub type NodeId = usize;
/// Edge identifier (index into [`Graph::edges`]).
pub type EdgeId = usize;

/// What a node is.
#[derive(Debug, Clone, PartialEq)]
pub enum NodeKind {
    /// A compute kernel scheduled on one AIE tile.
    AieKernel {
        kind: RoutineKind,
        /// Problem size `n` of the originating routine.
        size: usize,
        /// Window size in elements.
        window: usize,
        /// Vector datapath width in bits.
        vector_bits: usize,
        /// Optional placement hint (col,row) from the spec.
        hint: Option<(usize, usize)>,
    },
    /// PL kernel streaming DDR → AIE (mm2s).
    PlMm2s { burst: bool },
    /// PL kernel streaming AIE → DDR (s2mm).
    PlS2mm { burst: bool },
    /// On-chip combiner summing the partial results of a multi-AIE split
    /// reduction (paper §V future work 2).
    Combine { parts: usize },
    /// Synthetic on-chip data generator (the Fig. 3 "no PL" variant).
    OnChipSource,
    /// On-chip sink (result kept in local memory / discarded).
    OnChipSink,
}

impl NodeKind {
    pub fn is_aie(&self) -> bool {
        matches!(
            self,
            NodeKind::AieKernel { .. }
                | NodeKind::Combine { .. }
                | NodeKind::OnChipSource
                | NodeKind::OnChipSink
        )
    }

    pub fn is_pl(&self) -> bool {
        matches!(self, NodeKind::PlMm2s { .. } | NodeKind::PlS2mm { .. })
    }
}

/// A graph node.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    /// Unique name (kernel name from the spec, or generated mover name).
    pub name: String,
    pub kind: NodeKind,
}

/// How data travels on an edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// Block transfer into tile-local memory (ADF *window*).
    Window,
    /// Element-by-element AXI4 stream (ADF *stream*).
    Stream,
}

/// A directed dataflow edge carrying `total_elements` f32 values in
/// `window_elements`-sized chunks from `src`'s output port to `dst`'s
/// input port.
#[derive(Debug, Clone, PartialEq)]
pub struct Edge {
    pub id: EdgeId,
    pub src: NodeId,
    pub src_port: String,
    pub dst: NodeId,
    pub dst_port: String,
    pub ty: PortType,
    pub kind: EdgeKind,
    pub total_elements: usize,
    pub window_elements: usize,
}

impl Edge {
    /// Number of window transfers needed to move all elements.
    pub fn num_windows(&self) -> usize {
        if self.total_elements == 0 {
            0
        } else {
            self.total_elements.div_ceil(self.window_elements.max(1))
        }
    }

    pub fn window_bytes(&self) -> usize {
        self.window_elements * crate::arch::F32_BYTES
    }

    pub fn total_bytes(&self) -> usize {
        self.total_elements * crate::arch::F32_BYTES
    }
}

/// The dataflow graph.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
}

impl Graph {
    pub fn add_node(&mut self, name: impl Into<String>, kind: NodeKind) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, name: name.into(), kind });
        id
    }

    #[allow(clippy::too_many_arguments)]
    pub fn add_edge(
        &mut self,
        src: NodeId,
        src_port: impl Into<String>,
        dst: NodeId,
        dst_port: impl Into<String>,
        ty: PortType,
        kind: EdgeKind,
        total_elements: usize,
        window_elements: usize,
    ) -> EdgeId {
        let id = self.edges.len();
        self.edges.push(Edge {
            id,
            src,
            src_port: src_port.into(),
            dst,
            dst_port: dst_port.into(),
            ty,
            kind,
            total_elements,
            window_elements,
        });
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn node_by_name(&self, name: &str) -> Option<&Node> {
        self.nodes.iter().find(|n| n.name == name)
    }

    /// Edges entering `id`.
    pub fn in_edges(&self, id: NodeId) -> impl Iterator<Item = &Edge> {
        self.edges.iter().filter(move |e| e.dst == id)
    }

    /// Edges leaving `id`.
    pub fn out_edges(&self, id: NodeId) -> impl Iterator<Item = &Edge> {
        self.edges.iter().filter(move |e| e.src == id)
    }

    /// Number of AIE-mapped kernel nodes.
    pub fn num_aie_kernels(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::AieKernel { .. }))
            .count()
    }

    /// Number of PL mover nodes.
    pub fn num_pl_movers(&self) -> usize {
        self.nodes.iter().filter(|n| n.kind.is_pl()).count()
    }

    /// Topological order of node ids; errors on cycles.
    pub fn topo_order(&self) -> Result<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        for e in &self.edges {
            indeg[e.dst] += 1;
        }
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = queue.pop() {
            order.push(u);
            for e in self.out_edges(u) {
                indeg[e.dst] -= 1;
                if indeg[e.dst] == 0 {
                    queue.push(e.dst);
                }
            }
        }
        if order.len() != n {
            return Err(Error::Graph("graph contains a cycle".into()));
        }
        Ok(order)
    }

    /// Structural invariants the builder must uphold (property-tested):
    /// unique names, valid endpoints, windows dividing totals, every AIE
    /// kernel input driven, acyclicity.
    pub fn check_invariants(&self) -> Result<()> {
        let mut names = BTreeMap::new();
        for node in &self.nodes {
            if let Some(prev) = names.insert(node.name.as_str(), node.id) {
                return Err(Error::Graph(format!(
                    "duplicate node name {:?} (ids {} and {})",
                    node.name, prev, node.id
                )));
            }
        }
        for e in &self.edges {
            if e.src >= self.nodes.len() || e.dst >= self.nodes.len() {
                return Err(Error::Graph(format!("edge {} has dangling endpoint", e.id)));
            }
            if e.src == e.dst {
                return Err(Error::Graph(format!("edge {} is a self-loop", e.id)));
            }
            if e.window_elements == 0 || e.total_elements == 0 {
                return Err(Error::Graph(format!("edge {} moves zero data", e.id)));
            }
            if e.total_elements % e.window_elements != 0 {
                return Err(Error::Graph(format!(
                    "edge {}: window {} does not divide total {}",
                    e.id, e.window_elements, e.total_elements
                )));
            }
        }
        // every AIE kernel input port must be driven exactly once
        for node in &self.nodes {
            if let NodeKind::AieKernel { kind, .. } = &node.kind {
                for p in kind.inputs() {
                    let drivers = self
                        .in_edges(node.id)
                        .filter(|e| e.dst_port == p.name)
                        .count();
                    if drivers != 1 {
                        return Err(Error::Graph(format!(
                            "kernel {} input {} has {} drivers (want 1)",
                            node.name, p.name, drivers
                        )));
                    }
                }
                for p in kind.outputs() {
                    let consumers = self
                        .out_edges(node.id)
                        .filter(|e| e.src_port == p.name)
                        .count();
                    if consumers != 1 {
                        return Err(Error::Graph(format!(
                            "kernel {} output {} has {} consumers (want 1)",
                            node.name, p.name, consumers
                        )));
                    }
                }
            }
        }
        self.topo_order()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut g = Graph::default();
        let src = g.add_node("src", NodeKind::PlMm2s { burst: false });
        let k = g.add_node(
            "k",
            NodeKind::AieKernel {
                kind: RoutineKind::Scal,
                size: 64,
                window: 16,
                vector_bits: 512,
                hint: None,
            },
        );
        let sink = g.add_node("sink", NodeKind::PlS2mm { burst: false });
        let alpha_src = g.add_node("alpha_src", NodeKind::PlMm2s { burst: false });
        g.add_edge(alpha_src, "out", k, "alpha", PortType::Scalar, EdgeKind::Stream, 1, 1);
        g.add_edge(src, "out", k, "x", PortType::Vector, EdgeKind::Window, 64, 16);
        g.add_edge(k, "z", sink, "in", PortType::Vector, EdgeKind::Window, 64, 16);
        g
    }

    #[test]
    fn tiny_graph_invariants_hold() {
        tiny().check_invariants().unwrap();
    }

    #[test]
    fn topo_order_is_consistent() {
        let g = tiny();
        let order = g.topo_order().unwrap();
        let pos: BTreeMap<NodeId, usize> =
            order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
        for e in &g.edges {
            assert!(pos[&e.src] < pos[&e.dst], "edge {} -> {}", e.src, e.dst);
        }
    }

    #[test]
    fn num_windows() {
        let g = tiny();
        assert_eq!(g.edges[1].num_windows(), 4);
        assert_eq!(g.edges[0].num_windows(), 1);
    }

    #[test]
    fn invariants_catch_undriven_input() {
        let mut g = tiny();
        g.edges.remove(1); // drop the x edge
        assert!(g.check_invariants().is_err());
    }

    #[test]
    fn invariants_catch_window_not_dividing() {
        let mut g = tiny();
        g.edges[1].window_elements = 7;
        assert!(g.check_invariants().is_err());
    }

    #[test]
    fn invariants_catch_duplicate_names() {
        let mut g = tiny();
        g.nodes[2].name = "src".into();
        assert!(g.check_invariants().is_err());
    }

    #[test]
    fn invariants_catch_cycle() {
        let mut g = tiny();
        // add a bogus back edge sink -> src
        g.add_edge(2, "out", 0, "in", PortType::Vector, EdgeKind::Window, 64, 16);
        assert!(g.topo_order().is_err());
    }
}
