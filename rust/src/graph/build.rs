//! Spec → Graph lowering (Fig. 1 ②③: insert PL movers, expand composites,
//! wire dataflow connections).
//!
//! Rules (paper §III):
//! * every routine becomes one AIE kernel node (composites expand to their
//!   pipeline: axpydot → axpy kernel + dot kernel with an on-chip edge);
//! * a connection in the spec becomes a direct AIE→AIE *window* edge
//!   (scalars would use streams);
//! * every unconnected vector/matrix input gets a PL mm2s mover (or an
//!   on-chip generator in the "no PL" configuration), every unconnected
//!   output a PL s2mm mover (or on-chip sink);
//! * scalar inputs ride a stream from the host/PL unless a compile-time
//!   constant (alpha/beta in the spec) bakes them into the kernel.

use super::{EdgeKind, Graph, NodeId, NodeKind};
use crate::blas::{PortType, RoutineKind};
use crate::spec::{DataSource, RoutineSpec, Spec};
use crate::Result;

/// A kernel node together with the spec routine it implements (composites
/// produce several kernels per routine). Used by placement and codegen to
/// recover spec-level options (burst, placement hints).
#[derive(Debug, Clone)]
pub struct BuildOutput {
    pub graph: Graph,
    /// For each graph node: the index of the originating routine in the
    /// spec, if any.
    pub node_routine: Vec<Option<usize>>,
}

/// Lower a *validated* spec into a dataflow graph.
pub fn build_graph(spec: &Spec) -> Result<BuildOutput> {
    let mut b = Builder {
        graph: Graph::default(),
        node_routine: Vec::new(),
        source: spec.data_source,
    };

    // kernel nodes (expanding composites)
    let mut kernel_nodes: Vec<Vec<(NodeId, RoutineKind)>> = Vec::new();
    for (ri, r) in spec.routines.iter().enumerate() {
        let nodes = if r.kind.is_composite() {
            b.expand_composite(r, ri)
        } else if r.split > 1 {
            b.expand_split(r, ri)
        } else {
            vec![(b.add_kernel(&r.name, r.kind, r, ri), r.kind)]
        };
        kernel_nodes.push(nodes);
    }

    // spec-level connections: window edge between the producing kernel's
    // output port and the consuming kernel's input port.
    let mut connected_in: Vec<(usize, String)> = Vec::new();
    let mut connected_out: Vec<(usize, String)> = Vec::new();
    for c in &spec.connections {
        let (fi, from) = find_routine(spec, &c.from_kernel);
        let (ti, to) = find_routine(spec, &c.to_kernel);
        // composites expose their boundary kernels' ports
        let src_node = kernel_nodes[fi].last().unwrap().0;
        let dst_node = kernel_nodes[ti].first().unwrap().0;
        let ty = port_ty(from.kind.outputs(), &c.from_port);
        let window = from.effective_window().min(to.effective_window());
        b.graph.add_edge(
            src_node,
            c.from_port.clone(),
            dst_node,
            c.to_port.clone(),
            ty,
            edge_kind(ty),
            elements(ty, from.size),
            window_elements(ty, from.size, window),
        );
        connected_out.push((fi, c.from_port.clone()));
        connected_in.push((ti, c.to_port.clone()));
    }

    // movers / generators for unconnected ports
    for (ri, r) in spec.routines.iter().enumerate() {
        let nodes = &kernel_nodes[ri];
        if r.kind.is_composite() {
            b.wire_composite_io(r, ri, nodes, &connected_in, &connected_out);
            continue;
        }
        if r.split > 1 {
            // already fully wired (movers per part + combiner) in
            // expand_split; validation guarantees no spec connections.
            continue;
        }
        let (node, kind) = nodes[0];
        for p in kind.inputs() {
            if connected_in.contains(&(ri, p.name.to_string())) {
                continue;
            }
            // compile-time constants need no edge-feeding kernel... except
            // the graph invariant wants every input driven; model baked
            // scalars as zero-cost on-chip sources.
            b.drive_input(node, r, ri, p.name, p.ty);
        }
        for p in kind.outputs() {
            if connected_out.contains(&(ri, p.name.to_string())) {
                continue;
            }
            b.consume_output(node, r, ri, p.name, p.ty);
        }
    }

    b.graph.check_invariants()?;
    Ok(BuildOutput { graph: b.graph, node_routine: b.node_routine })
}

fn find_routine<'s>(spec: &'s Spec, name: &str) -> (usize, &'s RoutineSpec) {
    spec.routines
        .iter()
        .enumerate()
        .find(|(_, r)| r.name == name)
        .expect("validated spec has the kernel")
}

fn port_ty(ports: &[crate::blas::Port], name: &str) -> PortType {
    ports.iter().find(|p| p.name == name).expect("validated port").ty
}

fn elements(ty: PortType, n: usize) -> usize {
    ty.elements(n)
}

fn window_elements(ty: PortType, n: usize, window: usize) -> usize {
    match ty {
        PortType::Scalar => 1,
        // Matrix windows stage `rb` rows × `window` columns; rb is 16
        // shrunk to a divisor of n so whole blocks tile the matrix exactly
        // ((n/rb)·(n/w) windows, both factors integral).
        PortType::Matrix => {
            let mut rb = 16.min(n).max(1);
            while n % rb != 0 {
                rb -= 1;
            }
            rb * window.min(n)
        }
        PortType::Vector => window.min(n),
    }
}

fn edge_kind(ty: PortType) -> EdgeKind {
    match ty {
        PortType::Scalar => EdgeKind::Stream,
        _ => EdgeKind::Window,
    }
}

struct Builder {
    graph: Graph,
    node_routine: Vec<Option<usize>>,
    source: DataSource,
}

impl Builder {
    fn add_kernel(&mut self, name: &str, kind: RoutineKind, r: &RoutineSpec, ri: usize) -> NodeId {
        let id = self.graph.add_node(
            name,
            NodeKind::AieKernel {
                kind,
                size: r.size,
                window: r.effective_window(),
                vector_bits: r.vector_bits,
                hint: r.placement.map(|p| (p.col, p.row)),
            },
        );
        self.node_routine.push(Some(ri));
        id
    }

    fn add_aux(&mut self, name: String, kind: NodeKind, ri: usize) -> NodeId {
        let id = self.graph.add_node(name, kind);
        self.node_routine.push(Some(ri));
        id
    }

    /// Expand a split routine into `split` part kernels over `size/split`
    /// elements each, every part with its own PL ports (leveraging the
    /// multiple PL↔AIE interfaces, §V), plus an on-chip combiner when the
    /// routine reduces to a scalar.
    fn expand_split(&mut self, r: &RoutineSpec, ri: usize) -> Vec<(NodeId, RoutineKind)> {
        let k = r.split;
        let part_size = r.size / k;
        let mut part_spec = r.clone();
        part_spec.size = part_size;
        part_spec.split = 1;
        let mut parts = Vec::with_capacity(k);
        let reduces = r
            .kind
            .outputs()
            .iter()
            .all(|p| p.ty == PortType::Scalar);
        for i in 0..k {
            part_spec.name = format!("{}_p{i}", r.name);
            let node = self.add_kernel(&part_spec.name.clone(), r.kind, &part_spec, ri);
            // per-part inputs from their own movers/generators
            for p in r.kind.inputs() {
                self.drive_input(node, &part_spec, ri, p.name, p.ty);
            }
            if !reduces {
                // striped vector/matrix outputs: each part writes its slice
                for p in r.kind.outputs() {
                    self.consume_output(node, &part_spec, ri, p.name, p.ty);
                }
            }
            parts.push((node, r.kind));
        }
        if reduces {
            // additive combine of the k scalar partials (dot/asum).
            let combine = self.add_aux(format!("{}_combine", r.name), NodeKind::Combine { parts: k }, ri);
            for (i, &(node, _)) in parts.iter().enumerate() {
                let out_port = r.kind.outputs()[0].name;
                self.graph.add_edge(
                    node,
                    out_port,
                    combine,
                    format!("in{i}"),
                    PortType::Scalar,
                    EdgeKind::Stream,
                    1,
                    1,
                );
            }
            self.consume_output(combine, &part_spec, ri, "out", PortType::Scalar);
        }
        parts
    }

    /// Expand axpydot into axpy(z = w − αv) → dot(z·u): the paper's Fig. 1
    /// dataflow composition as a prebuilt subgraph.
    fn expand_composite(&mut self, r: &RoutineSpec, ri: usize) -> Vec<(NodeId, RoutineKind)> {
        assert_eq!(r.kind, RoutineKind::Axpydot);
        let axpy = self.add_kernel(&format!("{}_axpy", r.name), RoutineKind::Axpy, r, ri);
        let dot = self.add_kernel(&format!("{}_dot", r.name), RoutineKind::Dot, r, ri);
        let w = r.effective_window();
        self.graph.add_edge(
            axpy,
            "z",
            dot,
            "x",
            PortType::Vector,
            EdgeKind::Window,
            r.size,
            w.min(r.size),
        );
        vec![(axpy, RoutineKind::Axpy), (dot, RoutineKind::Dot)]
    }

    /// Wire the unbound ports of an expanded composite:
    /// axpy gets alpha, x(=v), y(=w); dot gets y(=u); dot.result exits.
    fn wire_composite_io(
        &mut self,
        r: &RoutineSpec,
        ri: usize,
        nodes: &[(NodeId, RoutineKind)],
        connected_in: &[(usize, String)],
        connected_out: &[(usize, String)],
    ) {
        let (axpy, _) = nodes[0];
        let (dot, _) = nodes[1];
        for (node, port, ty) in [
            (axpy, "alpha", PortType::Scalar),
            (axpy, "x", PortType::Vector),
            (axpy, "y", PortType::Vector),
            (dot, "y", PortType::Vector),
        ] {
            if !connected_in.contains(&(ri, port.to_string())) {
                self.drive_input(node, r, ri, port, ty);
            }
        }
        if !connected_out.contains(&(ri, "result".to_string())) {
            self.consume_output(dot, r, ri, "result", PortType::Scalar);
        }
    }

    fn drive_input(&mut self, node: NodeId, r: &RoutineSpec, ri: usize, port: &str, ty: PortType) {
        let kernel_name = self.graph.node(node).name.clone();
        let w = r.effective_window();
        let baked_scalar = ty == PortType::Scalar
            && ((port == "alpha" && r.alpha.is_some()) || (port == "beta" && r.beta.is_some()));
        let src_kind = if baked_scalar || self.source == DataSource::OnChip {
            // on-chip generation (or a compile-time constant): no PL mover.
            NodeKind::OnChipSource
        } else {
            NodeKind::PlMm2s { burst: r.burst }
        };
        let label = match src_kind {
            NodeKind::OnChipSource => format!("{kernel_name}_{port}_gen"),
            _ => format!("{kernel_name}_{port}_mm2s"),
        };
        let src = self.add_aux(label, src_kind, ri);
        self.graph.add_edge(
            src,
            "out",
            node,
            port,
            ty,
            edge_kind(ty),
            elements(ty, r.size),
            window_elements(ty, r.size, w),
        );
    }

    fn consume_output(&mut self, node: NodeId, r: &RoutineSpec, ri: usize, port: &str, ty: PortType) {
        let kernel_name = self.graph.node(node).name.clone();
        let w = r.effective_window();
        let dst_kind = if self.source == DataSource::OnChip {
            NodeKind::OnChipSink
        } else {
            NodeKind::PlS2mm { burst: r.burst }
        };
        let label = match dst_kind {
            NodeKind::OnChipSink => format!("{kernel_name}_{port}_sink"),
            _ => format!("{kernel_name}_{port}_s2mm"),
        };
        let dst = self.add_aux(label, dst_kind, ri);
        self.graph.add_edge(
            node,
            port,
            dst,
            "in",
            ty,
            edge_kind(ty),
            elements(ty, r.size),
            window_elements(ty, r.size, w),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::{DataSource, Spec};

    #[test]
    fn single_axpy_pl_gets_movers() {
        let spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        let out = build_graph(&spec).unwrap();
        let g = &out.graph;
        g.check_invariants().unwrap();
        assert_eq!(g.num_aie_kernels(), 1);
        // alpha, x, y movers in + z mover out
        assert_eq!(g.num_pl_movers(), 4);
        let kernel = g.node_by_name("a").unwrap();
        assert_eq!(g.in_edges(kernel.id).count(), 3);
        assert_eq!(g.out_edges(kernel.id).count(), 1);
    }

    #[test]
    fn single_axpy_onchip_has_no_pl() {
        let spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::OnChip);
        let g = build_graph(&spec).unwrap().graph;
        assert_eq!(g.num_pl_movers(), 0);
        assert!(g
            .nodes
            .iter()
            .any(|n| matches!(n.kind, NodeKind::OnChipSource)));
    }

    #[test]
    fn baked_alpha_skips_scalar_mover() {
        let mut spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        spec.routines[0].alpha = Some(2.0);
        let g = build_graph(&spec).unwrap().graph;
        // x, y, z movers; alpha is an on-chip constant source
        assert_eq!(g.num_pl_movers(), 3);
    }

    #[test]
    fn connection_becomes_direct_edge() {
        let spec = Spec::axpydot_dataflow(4096, 2.0);
        let g = build_graph(&spec).unwrap().graph;
        g.check_invariants().unwrap();
        let axpy = g.node_by_name("axpy_stage").unwrap();
        let dot = g.node_by_name("dot_stage").unwrap();
        let direct: Vec<_> = g
            .edges
            .iter()
            .filter(|e| e.src == axpy.id && e.dst == dot.id)
            .collect();
        assert_eq!(direct.len(), 1);
        assert_eq!(direct[0].kind, EdgeKind::Window);
        // dot.x is fed on-chip, so no mover for it
        assert!(g.node_by_name("dot_stage_x_mm2s").is_none());
    }

    #[test]
    fn composite_axpydot_expands() {
        let spec = Spec::single(RoutineKind::Axpydot, "ad", 4096, DataSource::Pl);
        let out = build_graph(&spec).unwrap();
        let g = &out.graph;
        g.check_invariants().unwrap();
        assert_eq!(g.num_aie_kernels(), 2);
        assert!(g.node_by_name("ad_axpy").is_some());
        assert!(g.node_by_name("ad_dot").is_some());
        // internal z edge is AIE->AIE
        let axpy = g.node_by_name("ad_axpy").unwrap();
        let dot = g.node_by_name("ad_dot").unwrap();
        assert!(g.edges.iter().any(|e| e.src == axpy.id && e.dst == dot.id));
        // movers: axpy alpha/x/y + dot y in, dot result out = 5
        assert_eq!(g.num_pl_movers(), 5);
    }

    #[test]
    fn gemv_matrix_edge_windows() {
        let spec = Spec::single(RoutineKind::Gemv, "g", 256, DataSource::Pl);
        let g = build_graph(&spec).unwrap().graph;
        g.check_invariants().unwrap();
        let kernel = g.node_by_name("g").unwrap();
        let a_edge = g
            .in_edges(kernel.id)
            .find(|e| e.dst_port == "a")
            .unwrap();
        assert_eq!(a_edge.ty, PortType::Matrix);
        assert_eq!(a_edge.total_elements, 256 * 256);
        assert_eq!(a_edge.total_elements % a_edge.window_elements, 0);
    }

    #[test]
    fn node_routine_mapping_covers_all_nodes() {
        let spec = Spec::axpydot_dataflow(1024, 1.0);
        let out = build_graph(&spec).unwrap();
        assert_eq!(out.node_routine.len(), out.graph.nodes.len());
        assert!(out.node_routine.iter().all(|r| r.is_some()));
    }
}
