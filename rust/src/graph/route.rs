//! Stream routing: assign every edge a physical path and check interface
//! capacity (paper §II: AXI4-stream NoC; 312 PL→AIE and 234 AIE→PL
//! channels).
//!
//! Adjacent tiles share local memory, so a window edge between neighbours
//! costs zero NoC hops (the AIE "can share data with the adjacent AIEs by
//! reading/writing directly from/to their local memory"); anything else
//! rides the stream network with per-hop latency. Edges crossing the
//! PL↔AIE boundary consume interface channels, which are a counted,
//! capacity-checked resource.

use super::place::{Location, Placement};
use super::{EdgeId, Graph};
use crate::arch::ArchConfig;
use crate::{Error, Result};

/// How one edge is physically realised.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutedEdge {
    pub edge: EdgeId,
    /// NoC hops (0 = neighbour local-memory sharing).
    pub hops: usize,
    /// Crosses PL→AIE interface (consumes one of the 312 channels).
    pub uses_pl_to_aie: bool,
    /// Crosses AIE→PL interface (consumes one of the 234 channels).
    pub uses_aie_to_pl: bool,
    /// True when the transfer is tile-local-memory sharing.
    pub neighbour: bool,
}

/// Routing result for a placed graph.
#[derive(Debug, Clone)]
pub struct Routing {
    pub routed: Vec<RoutedEdge>,
    pub pl_to_aie_used: usize,
    pub aie_to_pl_used: usize,
}

impl Routing {
    pub fn of(&self, edge: EdgeId) -> &RoutedEdge {
        &self.routed[edge]
    }

    /// Total hop count (congestion proxy used by ablation A2).
    pub fn total_hops(&self) -> usize {
        self.routed.iter().map(|r| r.hops).sum()
    }

    /// Static route-quality summary for the placement autotuner: cheap,
    /// simulation-free figures the tuner uses to break ties between
    /// candidates whose predicted makespans are equal (fewer hops, then
    /// fewer interface channels, then more neighbour edges).
    pub fn cost_summary(&self) -> RouteCost {
        RouteCost {
            total_hops: self.total_hops(),
            interface_channels: self.pl_to_aie_used + self.aie_to_pl_used,
            neighbour_edges: self.routed.iter().filter(|r| r.neighbour).count(),
        }
    }
}

/// Simulation-free route cost used for candidate tie-breaking; see
/// [`Routing::cost_summary`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteCost {
    pub total_hops: usize,
    pub interface_channels: usize,
    pub neighbour_edges: usize,
}

impl RouteCost {
    /// Ordering key: lower is better. Neighbour edges are negated (more
    /// local-memory edges are better), after hops and channel pressure.
    pub fn key(&self) -> (usize, usize, isize) {
        (self.total_hops, self.interface_channels, -(self.neighbour_edges as isize))
    }
}

/// Route every edge of a placed graph, enforcing interface capacity.
pub fn route(graph: &Graph, placement: &Placement, arch: &ArchConfig) -> Result<Routing> {
    let mut routed = Vec::with_capacity(graph.edges.len());
    let mut pl_to_aie = 0usize;
    let mut aie_to_pl = 0usize;

    for e in &graph.edges {
        let src_loc = placement.of(e.src);
        let dst_loc = placement.of(e.dst);
        let src_pl = graph.node(e.src).kind.is_pl();
        let dst_pl = graph.node(e.dst).kind.is_pl();

        let hops = manhattan(src_loc, dst_loc);
        let neighbour = !src_pl && !dst_pl && hops <= 1;
        let uses_pl_to_aie = src_pl && !dst_pl;
        let uses_aie_to_pl = !src_pl && dst_pl;
        if uses_pl_to_aie {
            pl_to_aie += 1;
        }
        if uses_aie_to_pl {
            aie_to_pl += 1;
        }
        routed.push(RoutedEdge {
            edge: e.id,
            hops: if neighbour { 0 } else { hops },
            uses_pl_to_aie,
            uses_aie_to_pl,
            neighbour,
        });
    }

    if pl_to_aie > arch.pl_to_aie_channels {
        return Err(Error::Routing(format!(
            "{pl_to_aie} PL→AIE channels needed, device has {}",
            arch.pl_to_aie_channels
        )));
    }
    if aie_to_pl > arch.aie_to_pl_channels {
        return Err(Error::Routing(format!(
            "{aie_to_pl} AIE→PL channels needed, device has {}",
            arch.aie_to_pl_channels
        )));
    }

    Ok(Routing { routed, pl_to_aie_used: pl_to_aie, aie_to_pl_used: aie_to_pl })
}

fn manhattan(a: Location, b: Location) -> usize {
    let (ax, ay) = a.coords();
    let (bx, by) = b.coords();
    (ax.abs_diff(bx) + ay.abs_diff(by)) as usize
}

/// Check conservation: every edge routed exactly once, channel counts match
/// the per-edge flags (property-tested invariant).
pub fn check_routing(graph: &Graph, routing: &Routing) -> Result<()> {
    if routing.routed.len() != graph.edges.len() {
        return Err(Error::Routing(format!(
            "{} edges but {} routes",
            graph.edges.len(),
            routing.routed.len()
        )));
    }
    let p2a = routing.routed.iter().filter(|r| r.uses_pl_to_aie).count();
    let a2p = routing.routed.iter().filter(|r| r.uses_aie_to_pl).count();
    if p2a != routing.pl_to_aie_used || a2p != routing.aie_to_pl_used {
        return Err(Error::Routing("channel accounting mismatch".into()));
    }
    for r in &routing.routed {
        let e = &graph.edges[r.edge];
        let src_pl = graph.node(e.src).kind.is_pl();
        let dst_pl = graph.node(e.dst).kind.is_pl();
        if r.uses_pl_to_aie != (src_pl && !dst_pl) || r.uses_aie_to_pl != (!src_pl && dst_pl) {
            return Err(Error::Routing(format!("edge {} flags inconsistent", r.edge)));
        }
        if r.neighbour && r.hops != 0 {
            return Err(Error::Routing(format!("edge {} neighbour but hops>0", r.edge)));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::graph::build::build_graph;
    use crate::graph::place::place;
    use crate::spec::{DataSource, Spec};

    fn routed(spec: &Spec) -> (Graph, Routing) {
        let g = build_graph(spec).unwrap().graph;
        let arch = ArchConfig::vck5000();
        let p = place(&g, &arch).unwrap();
        let r = route(&g, &p, &arch).unwrap();
        check_routing(&g, &r).unwrap();
        (g, r)
    }

    #[test]
    fn axpy_pl_consumes_interface_channels() {
        let (_, r) = routed(&Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl));
        // alpha, x, y in; z out
        assert_eq!(r.pl_to_aie_used, 3);
        assert_eq!(r.aie_to_pl_used, 1);
    }

    #[test]
    fn onchip_uses_no_interface() {
        let (_, r) = routed(&Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::OnChip));
        assert_eq!(r.pl_to_aie_used, 0);
        assert_eq!(r.aie_to_pl_used, 0);
    }

    #[test]
    fn dataflow_edge_is_neighbour_local_memory() {
        let (g, r) = routed(&Spec::axpydot_dataflow(4096, 2.0));
        let a = g.node_by_name("axpy_stage").unwrap().id;
        let d = g.node_by_name("dot_stage").unwrap().id;
        let e = g.edges.iter().find(|e| e.src == a && e.dst == d).unwrap();
        assert!(r.of(e.id).neighbour, "DF edge should use neighbour memory sharing");
        assert_eq!(r.of(e.id).hops, 0);
    }

    #[test]
    fn cost_summary_matches_route_counts() {
        let (_, r) = routed(&Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl));
        let c = r.cost_summary();
        assert_eq!(c.total_hops, r.total_hops());
        assert_eq!(c.interface_channels, r.pl_to_aie_used + r.aie_to_pl_used);
        assert_eq!(c.neighbour_edges, r.routed.iter().filter(|e| e.neighbour).count());
        // fewer hops always orders strictly better.
        let worse = RouteCost { total_hops: c.total_hops + 1, ..c };
        assert!(c.key() < worse.key());
    }

    #[test]
    fn capacity_overflow_rejected() {
        let mut arch = ArchConfig::vck5000();
        arch.pl_to_aie_channels = 2; // artificially tiny
        let g = build_graph(&Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl))
            .unwrap()
            .graph;
        let p = place(&g, &arch).unwrap();
        assert!(route(&g, &p, &arch).is_err());
    }
}
