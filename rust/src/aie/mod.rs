//! AIE tile compute model: cycles a kernel spends per window.
//!
//! The AIE1 core is a VLIW vector processor that can issue two vector
//! loads, one vector store and one vector arithmetic op per cycle, with an
//! fp32 datapath retiring 8 MACs/cycle (paper §II; AM009 AIE architecture
//! manual). Our per-window cost model:
//!
//! * MAC-bound kernels (axpy, dot, nrm2, asum, gemv, gemm): one MAC per
//!   element pair → `elements / fp32_macs_per_cycle` cycles, scaled by the
//!   configured vector width (a 256-bit kernel does half the MACs/cycle);
//! * move/scale kernels (copy, scal, iamax compare): lane-bound →
//!   `elements / lanes` cycles;
//! * every window acquisition pays `window_overhead_cycles` (DMA + lock),
//!   and every kernel invocation pays `kernel_call_cycles` once.
//!
//! This is a *structural* model — it deliberately ignores pipeline stalls
//! and models only what the paper's analysis depends on: vectorization
//! width, window amortization, and compute-vs-transfer balance.

use crate::arch::ArchConfig;
use crate::blas::RoutineKind;

/// Cycles one kernel invocation spends computing on one window of
/// `window_elements` (vector elements or matrix-window elements).
pub fn cycles_per_window(
    kind: RoutineKind,
    window_elements: usize,
    vector_bits: usize,
    arch: &ArchConfig,
) -> u64 {
    let width_scale = vector_bits as f64 / arch.vector_bits as f64;
    let macs_per_cycle = (arch.fp32_macs_per_cycle as f64 * width_scale).max(1.0);
    let lanes = (arch.f32_lanes(vector_bits)) as f64;
    let e = window_elements as f64;
    let compute = match kind {
        // one MAC per element
        RoutineKind::Axpy
        | RoutineKind::Axpby
        | RoutineKind::Dot
        | RoutineKind::Nrm2
        | RoutineKind::Asum
        | RoutineKind::Axpydot => e / macs_per_cycle,
        // rot: two MACs per element pair (both outputs)
        RoutineKind::Rot => 2.0 * e / macs_per_cycle,
        // matrix windows: one MAC per matrix element
        RoutineKind::Gemv | RoutineKind::Ger | RoutineKind::Gemm => e / macs_per_cycle,
        // pure data movement / single vector op per element
        RoutineKind::Scal | RoutineKind::Copy | RoutineKind::Iamax => e / lanes,
    };
    compute.ceil() as u64 + arch.window_overhead_cycles
}

/// Seconds one kernel invocation spends on one window.
pub fn seconds_per_window(
    kind: RoutineKind,
    window_elements: usize,
    vector_bits: usize,
    arch: &ArchConfig,
) -> f64 {
    cycles_per_window(kind, window_elements, vector_bits, arch) as f64 * arch.aie_cycle_s()
}

/// Peak-achievable fraction of the tile's MAC throughput for a routine at
/// a given window size — the roofline-style efficiency figure DESIGN.md §8
/// reports (window overhead amortization).
pub fn window_efficiency(kind: RoutineKind, window_elements: usize, arch: &ArchConfig) -> f64 {
    let ideal = window_elements as f64 / arch.fp32_macs_per_cycle as f64;
    let actual = cycles_per_window(kind, window_elements, arch.vector_bits, arch) as f64;
    (ideal / actual).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch() -> ArchConfig {
        ArchConfig::vck5000()
    }

    #[test]
    fn axpy_window_cost_scales_with_elements() {
        let a = arch();
        let c1 = cycles_per_window(RoutineKind::Axpy, 1024, 512, &a);
        let c2 = cycles_per_window(RoutineKind::Axpy, 2048, 512, &a);
        assert!(c2 > c1);
        // 1024 elements at 8 MACs/cycle = 128 cycles + overhead
        assert_eq!(c1, 128 + a.window_overhead_cycles);
    }

    #[test]
    fn narrower_vectors_cost_more() {
        let a = arch();
        let wide = cycles_per_window(RoutineKind::Axpy, 1024, 512, &a);
        let narrow = cycles_per_window(RoutineKind::Axpy, 1024, 128, &a);
        assert!(narrow > wide, "{narrow} vs {wide}");
    }

    #[test]
    fn copy_is_lane_bound() {
        let a = arch();
        // 1024/16 lanes = 64 cycles + overhead
        assert_eq!(
            cycles_per_window(RoutineKind::Copy, 1024, 512, &a),
            64 + a.window_overhead_cycles
        );
    }

    #[test]
    fn larger_windows_amortize_overhead() {
        let a = arch();
        let small = window_efficiency(RoutineKind::Axpy, 64, &a);
        let large = window_efficiency(RoutineKind::Axpy, 2048, &a);
        assert!(large > small);
        assert!(large > 0.7, "2048-element window should amortize: {large}");
    }

    #[test]
    fn seconds_match_cycles() {
        let a = arch();
        let c = cycles_per_window(RoutineKind::Dot, 512, 512, &a) as f64;
        let s = seconds_per_window(RoutineKind::Dot, 512, 512, &a);
        assert!((s - c / a.aie_clock_hz).abs() < 1e-15);
    }
}
