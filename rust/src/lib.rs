//! # AIEBLAS-RS
//!
//! Reproduction of *"Developing a BLAS library for the AMD AI Engine"*
//! (Laan & De Matteis, 2024): an expandable BLAS library for the AMD AI
//! Engine spatial architecture, built as a three-layer Rust + JAX + Pallas
//! stack with the VCK5000 hardware replaced by a cycle-approximate
//! simulator (see DESIGN.md §1 for the substitution argument).
//!
//! Layer map:
//! * **L3 (this crate)** — the AIEBLAS system: JSON spec → code generation →
//!   dataflow-graph construction → placement/routing → simulation, plus the
//!   PJRT runtime executing AOT-compiled numerics and the experiment
//!   harness reproducing the paper's Fig. 3.
//! * **L2 (`python/compile/model.py`)** — JAX routine graphs.
//! * **L1 (`python/compile/kernels/`)** — window-tiled Pallas kernels.
//!
//! ## Quickstart
//! ```no_run
//! use aieblas::spec::Spec;
//! use aieblas::coordinator::AieBlas;
//!
//! let spec = Spec::from_json_str(r#"{
//!   "platform": "vck5000",
//!   "routines": [
//!     {"routine": "axpy", "name": "my_axpy", "size": 65536}
//!   ]
//! }"#).unwrap();
//! let system = AieBlas::new(Default::default()).unwrap();
//! let report = system.run_spec(&spec).unwrap();
//! println!("{}", report.summary());
//! ```

pub mod aie;
pub mod arch;
pub mod blas;
pub mod codegen;
pub mod coordinator;
pub mod error;
pub mod graph;
pub mod pl;
pub mod runtime;
pub mod sim;
pub mod spec;
pub mod util;

pub use error::{Error, Result};

/// Initialize process-level facilities (logging). Idempotent.
pub fn init() {
    util::logging::init();
}
