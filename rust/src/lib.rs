//! # AIEBLAS-RS
//!
//! Reproduction of *"Developing a BLAS library for the AMD AI Engine"*
//! (Laan & De Matteis, 2024): an expandable BLAS library for the AMD AI
//! Engine spatial architecture, built as a three-layer Rust + JAX + Pallas
//! stack with the VCK5000 hardware replaced by a cycle-approximate
//! simulator (see DESIGN.md §1 for the substitution argument).
//!
//! Layer map:
//! * **L3 (this crate)** — the AIEBLAS system: JSON spec → staged pipeline
//!   (`pipeline`: validation + code generation → placement + routing →
//!   [`pipeline::ExecutablePlan`], memoized in a thread-safe, single-flight
//!   plan cache) → execution behind the [`runtime::Backend`] trait
//!   (`SimBackend` / `CpuBackend` / `ReferenceBackend`, batched via
//!   `execute_batch`, fanned out by `ShardedBackend`) → concurrent serving
//!   via [`serve::RoutineServer`] (admission control + priority-laned
//!   bounded queue + same-plan batching + adaptive backend pool, with
//!   deadline handling and graceful drain), exposed over the network by
//!   the [`http`] front door (versioned v1 wire API in [`api`],
//!   shard-aware routing by `PlanKey` across processes sharing one plan
//!   store), plus the experiment harness reproducing the paper's Fig. 3.
//! * **L2 (`python/compile/model.py`)** — JAX routine graphs.
//! * **L1 (`python/compile/kernels/`)** — window-tiled Pallas kernels.
//!
//! ## Quickstart
//! ```no_run
//! use aieblas::coordinator::AieBlas;
//! use aieblas::spec::Spec;
//!
//! let spec = Spec::from_json_str(r#"{
//!   "platform": "vck5000",
//!   "routines": [
//!     {"routine": "axpy", "name": "my_axpy", "size": 65536}
//!   ]
//! }"#).unwrap();
//! let system = AieBlas::new(Default::default()).unwrap();
//!
//! // Cold: spec → RoutinePlan (validated + codegen'd) → PlacedGraph
//! // (placed + routed) → ExecutablePlan, then simulated + checked.
//! let report = system.run_spec(&spec).unwrap();
//! println!("{}", report.summary());
//!
//! // Warm: the same spec skips codegen/placement/routing entirely — the
//! // plan cache serves the lowered design (hit counters in the report).
//! let warm = system.run_spec(&spec).unwrap();
//! assert!(warm.plan_cache.hits >= 1);
//! ```
//!
//! ## Executing on a specific backend
//! ```no_run
//! use aieblas::runtime::{Backend, CpuBackend, ExecInputs};
//! use aieblas::spec::{DataSource, Spec};
//! use aieblas::blas::RoutineKind;
//!
//! let spec = Spec::single(RoutineKind::Dot, "d", 4096, DataSource::Pl);
//! let plan = std::sync::Arc::new(aieblas::pipeline::lower_spec(&spec).unwrap());
//! let prepared = CpuBackend.prepare(plan).unwrap();
//! let outcome = CpuBackend.execute(&prepared, &ExecInputs::random_for(&spec, 1)).unwrap();
//! println!("dot = {}", outcome.results[0].output[0]);
//! ```
//!
//! Adding a fourth backend is an ≤30-line `impl runtime::Backend` — see
//! DESIGN.md §3.

pub mod aie;
pub mod api;
pub mod arch;
pub mod blas;
pub mod codegen;
pub mod coordinator;
pub mod error;
pub mod graph;
pub mod http;
pub mod pipeline;
pub mod pl;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod spec;
pub mod tune;
pub mod util;

pub use error::{Error, Result};

/// Initialize process-level facilities (logging). Idempotent.
pub fn init() {
    util::logging::init();
}
