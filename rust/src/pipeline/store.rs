//! The persistent plan store: disk-backed warm starts (DESIGN.md §10).
//!
//! Lowering a spec — validate → build graph → codegen → place → route — is
//! the expensive cold-start path the in-memory [`PlanCache`] exists to
//! amortize; this module extends that amortization **across processes** by
//! serializing every lowered [`ExecutablePlan`] (routine graph, placement,
//! routing, generated sources, architecture) to
//! `<cache_dir>/<key_hash>.plan.json` with `util::json`, so a restarted
//! server warms from a previous process's cache instead of re-lowering.
//!
//! Entries are **versioned and fingerprinted**: each file carries the store
//! format version, the spec's full cache key, and a fingerprint of the
//! pipeline's default architecture. A reader rejects (and the pipeline
//! silently re-lowers) on *any* mismatch or corruption — truncated files,
//! garbage JSON, a bumped format version, a different arch — rather than
//! erroring; a stale cache directory can degrade warm starts but can never
//! take the serving path down or execute a plan lowered for different
//! hardware. Writes go through a temp file + rename so a crashed writer
//! leaves no half-written entry under the final name.
//!
//! [`PlanCache`]: super::PlanCache

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime};

use super::{ExecutablePlan, PlacedGraph, PlanKey, RoutinePlan};
use crate::arch::ArchConfig;
use crate::blas::{PortType, RoutineKind};
use crate::codegen::GeneratedProject;
use crate::graph::build::BuildOutput;
use crate::graph::place::{Location, Placement};
use crate::graph::route::{check_routing, RoutedEdge, Routing};
use crate::graph::{EdgeKind, Graph, NodeKind};
use crate::spec::Spec;
use crate::util::faults::{FaultPlan, FaultSite};
use crate::util::fnv1a64;
use crate::util::json::{obj, Json};
use crate::{Error, Result};

/// On-disk format version. Bump on ANY change to the serialized shape;
/// readers reject other versions and re-lower (never migrate in place).
/// v2: entries carry a `tuned` field (autotuner provenance, DESIGN.md §11).
pub const FORMAT_VERSION: u64 = 2;

/// Filename suffix for store entries.
const ENTRY_SUFFIX: &str = ".plan.json";

/// How old a leftover `.tmp` file must be before [`PlanStore::open`] sweeps
/// it. A writer crashes between `fs::write` and `fs::rename` rarely but
/// predictably under chaos testing; the grace window keeps a sweep in one
/// process from racing a *live* writer in another process sharing the
/// directory (a healthy write-then-rename completes in well under a
/// minute — usually milliseconds).
pub const TMP_SWEEP_GRACE: Duration = Duration::from_secs(60);

/// Fingerprint of a pipeline configuration: a hash of the default
/// architecture's canonical JSON. Two pipelines share plans on disk iff
/// their fingerprints match; anything else (different grid, clocks,
/// channel counts, efficiencies …) must re-lower.
pub fn arch_fingerprint(arch: &ArchConfig) -> String {
    format!("arch-{:016x}", fnv1a64(arch_to_json(arch).to_compact().as_bytes()))
}

/// Tuning provenance persisted alongside a plan: which search produced
/// it, under which tuner version, and what it predicted/measured. A
/// tuning-enabled pipeline uses the version to decide whether a warm
/// start may skip the search (same version) or must re-tune (the
/// candidate space / scoring rules changed — see `crate::tune`).
#[derive(Debug, Clone, PartialEq)]
pub struct TunedEntry {
    pub tuner_version: u32,
    /// Search mode that produced the plan (`"analytic"` / `"full"`).
    pub mode: String,
    /// Candidates the search examined.
    pub candidates: usize,
    /// Label of the installed candidate.
    pub chosen: String,
    /// True when a non-default candidate was installed.
    pub improved: bool,
    /// Analytic prediction for the installed candidate, if priced.
    pub predicted_s: Option<f64>,
    /// DES-confirmed makespan for the installed candidate, if simulated.
    pub simulated_s: Option<f64>,
}

/// Outcome of one store lookup, as seen by the pipeline.
#[derive(Debug)]
pub enum LoadOutcome {
    /// No entry on disk for this key — a plain cold start.
    Missing,
    /// A valid entry was deserialized; execution-equivalent to a fresh
    /// lowering (DESIGN.md §10 substitution argument). Carries the tuning
    /// provenance, `None` for untuned entries.
    Loaded(Box<ExecutablePlan>, Option<TunedEntry>),
    /// An entry exists but failed validation (corruption, version or
    /// fingerprint mismatch); the caller should re-lower and overwrite.
    Rejected(String),
}

/// Aggregate on-disk state, for `aieblas cache stats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// `*.plan.json` entries present.
    pub entries: usize,
    /// Total bytes across entries.
    pub bytes: u64,
}

/// A directory of serialized plans, keyed like the in-memory [`PlanCache`]
/// (the spec's canonical JSON). Thread- and process-safe for the pipeline's
/// usage: loads are single-flight per key (the lowering leader is the only
/// reader), and writes are atomic renames, so concurrent processes sharing
/// one directory at worst redo each other's work.
///
/// [`PlanCache`]: super::PlanCache
#[derive(Debug, Clone)]
pub struct PlanStore {
    dir: PathBuf,
    /// Stale temp files removed by the crash-recovery sweep at open time.
    swept: u64,
    /// Optional deterministic fault injection (chaos testing only).
    faults: Option<FaultPlan>,
}

impl PlanStore {
    /// A store handle with **no** crash-recovery sweep. Prefer
    /// [`PlanStore::open`] for long-lived stores; `new` is for short-lived
    /// handles (CLI inspection, tests) that must not race live writers.
    pub fn new(dir: impl Into<PathBuf>) -> PlanStore {
        PlanStore {
            dir: dir.into(),
            swept: 0,
            faults: None,
        }
    }

    /// Open a store for serving: like [`PlanStore::new`], plus a one-shot
    /// crash-recovery sweep that removes temp files a crashed writer left
    /// behind, provided they are at least [`TMP_SWEEP_GRACE`] old (younger
    /// temps may belong to a live writer in another process).
    pub fn open(dir: impl Into<PathBuf>) -> PlanStore {
        PlanStore::open_with_grace(dir, TMP_SWEEP_GRACE)
    }

    /// [`PlanStore::open`] with an explicit grace window (tests use
    /// `Duration::ZERO` to sweep unconditionally).
    pub fn open_with_grace(dir: impl Into<PathBuf>, grace: Duration) -> PlanStore {
        let dir = dir.into();
        let swept = sweep_stale_tmps(&dir, grace);
        PlanStore {
            dir,
            swept,
            faults: None,
        }
    }

    /// Attach a fault plan; subsequent [`PlanStore::save`] calls may fail
    /// with an injected error at the `store_write_fail` site.
    pub fn with_faults(mut self, faults: FaultPlan) -> PlanStore {
        self.faults = Some(faults);
        self
    }

    /// Stale temp files removed when this store was opened (0 for
    /// [`PlanStore::new`], which never sweeps).
    pub fn swept(&self) -> u64 {
        self.swept
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Entry path for a cache key (filename is the key's interned FNV-1a
    /// hash — no re-hash here; the full key is stored inside the entry and
    /// re-checked on load, so a hash collision degrades to a rejection,
    /// never a wrong plan).
    pub fn path_for(&self, key: &PlanKey) -> PathBuf {
        self.dir.join(format!("{:016x}{ENTRY_SUFFIX}", key.hash64()))
    }

    /// Look up `key`, validating version, key and fingerprint, and fully
    /// deserializing + invariant-checking the plan. Never errors on bad
    /// entries: anything unusable is a [`LoadOutcome::Rejected`].
    pub fn load(&self, key: &PlanKey, fingerprint: &str) -> LoadOutcome {
        let path = self.path_for(key);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return LoadOutcome::Missing,
            Err(e) => return LoadOutcome::Rejected(format!("unreadable entry: {e}")),
        };
        match decode_entry(&text, key.as_str(), fingerprint) {
            Ok((plan, tuned)) => LoadOutcome::Loaded(Box::new(plan), tuned),
            Err(e) => LoadOutcome::Rejected(e.to_string()),
        }
    }

    /// Write-through one lowered plan. I/O errors surface to the caller
    /// (which logs and carries on — persistence is an optimization, never
    /// a correctness dependency).
    pub fn save(&self, key: &PlanKey, fingerprint: &str, plan: &ExecutablePlan) -> Result<()> {
        self.save_tuned(key, fingerprint, plan, None)
    }

    /// [`PlanStore::save`] with tuning provenance (`None` = untuned; the
    /// entry's `tuned` field is then JSON null).
    pub fn save_tuned(
        &self,
        key: &PlanKey,
        fingerprint: &str,
        plan: &ExecutablePlan,
        tuned: Option<&TunedEntry>,
    ) -> Result<()> {
        if let Some(f) = &self.faults {
            if f.fire(FaultSite::StoreWriteFail) {
                return Err(Error::Runtime(
                    "plan store write failed (injected fault)".into(),
                ));
            }
        }
        std::fs::create_dir_all(&self.dir)?;
        let entry = obj(vec![
            ("format_version", (FORMAT_VERSION as usize).into()),
            ("cache_key", key.as_str().into()),
            ("fingerprint", fingerprint.into()),
            ("tuned", tuned.map_or(Json::Null, tuned_to_json)),
            ("plan", plan_to_json(plan)),
        ]);
        let path = self.path_for(key);
        // temp-then-rename keeps readers from ever seeing a partial entry
        // under the final name (rename is atomic on one filesystem).
        let tmp = self.dir.join(format!(
            ".{:016x}.{}.tmp",
            key.hash64(),
            std::process::id()
        ));
        let written = std::fs::write(&tmp, entry.to_pretty() + "\n")
            .and_then(|()| std::fs::rename(&tmp, &path));
        if let Err(e) = written {
            // never leave a half-written temp behind on failure.
            let _ = std::fs::remove_file(&tmp);
            return Err(e.into());
        }
        Ok(())
    }

    /// Count entries and bytes currently on disk.
    pub fn stats(&self) -> StoreStats {
        let mut stats = StoreStats::default();
        for path in self.entry_paths() {
            stats.entries += 1;
            stats.bytes += std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        }
        stats
    }

    /// Remove every entry (plus any stale temp files a crashed writer
    /// left); returns how many entries were deleted.
    pub fn clear(&self) -> Result<usize> {
        let mut removed = 0;
        for path in self.entry_paths() {
            std::fs::remove_file(&path)?;
            removed += 1;
        }
        let Ok(dir) = std::fs::read_dir(&self.dir) else {
            return Ok(removed);
        };
        for path in dir.filter_map(|e| e.ok()).map(|e| e.path()) {
            let stale_tmp = path
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with('.') && n.ends_with(".tmp"));
            if stale_tmp {
                let _ = std::fs::remove_file(&path);
            }
        }
        Ok(removed)
    }

    fn entry_paths(&self) -> Vec<PathBuf> {
        let Ok(dir) = std::fs::read_dir(&self.dir) else {
            return Vec::new();
        };
        let mut paths: Vec<PathBuf> = dir
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.ends_with(ENTRY_SUFFIX) && !n.starts_with('.'))
            })
            .collect();
        paths.sort();
        paths
    }
}

/// Crash recovery: remove dot-prefixed `.tmp` files at least `grace` old,
/// returning how many were deleted. Best-effort throughout — an unreadable
/// directory, missing mtime, or racing unlink just skips that file; the
/// sweep is hygiene, never a correctness dependency.
fn sweep_stale_tmps(dir: &Path, grace: Duration) -> u64 {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return 0;
    };
    let now = SystemTime::now();
    let mut swept = 0;
    for path in entries.filter_map(|e| e.ok()).map(|e| e.path()) {
        let is_tmp = path
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.starts_with('.') && n.ends_with(".tmp"));
        if !is_tmp {
            continue;
        }
        let stale = std::fs::metadata(&path)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|mtime| now.duration_since(mtime).ok())
            .is_some_and(|age| age >= grace);
        if stale && std::fs::remove_file(&path).is_ok() {
            swept += 1;
        }
    }
    swept
}

/// Parse + validate one entry document against the expected key and
/// fingerprint, returning the deserialized plan and its tuning provenance.
fn decode_entry(
    text: &str,
    key: &str,
    fingerprint: &str,
) -> Result<(ExecutablePlan, Option<TunedEntry>)> {
    let json = Json::parse(text)?;
    let version = json
        .get("format_version")
        .and_then(Json::as_u64)
        .ok_or_else(|| corrupt("missing format_version"))?;
    if version != FORMAT_VERSION {
        return Err(corrupt(&format!(
            "format version {version} (reader speaks {FORMAT_VERSION})"
        )));
    }
    let stored_key = json
        .get("cache_key")
        .and_then(Json::as_str)
        .ok_or_else(|| corrupt("missing cache_key"))?;
    if stored_key != key {
        return Err(corrupt("cache key mismatch (filename hash collision?)"));
    }
    let stored_fp = json
        .get("fingerprint")
        .and_then(Json::as_str)
        .ok_or_else(|| corrupt("missing fingerprint"))?;
    if stored_fp != fingerprint {
        return Err(corrupt(&format!(
            "arch fingerprint {stored_fp} does not match pipeline {fingerprint}"
        )));
    }
    // missing or null = untuned; a present-but-malformed field is a
    // rejection like any other corruption (never silently dropped — a
    // tuning-enabled reader keys its skip-the-search decision off it).
    let tuned = match json.get("tuned") {
        None | Some(Json::Null) => None,
        Some(j) => Some(tuned_from_json(j)?),
    };
    let plan = plan_from_json(json.get("plan").ok_or_else(|| corrupt("missing plan"))?)?;
    // a deserialized plan must satisfy the same invariants a fresh
    // lowering does before any backend may execute it (DESIGN.md §6/§10).
    plan.plan.built.graph.check_invariants()?;
    if plan.placed.placement.locations.len() != plan.plan.built.graph.nodes.len() {
        return Err(corrupt("placement arity does not match graph"));
    }
    if plan.plan.built.node_routine.len() != plan.plan.built.graph.nodes.len() {
        return Err(corrupt("node_routine arity does not match graph"));
    }
    let num_edges = plan.plan.built.graph.edges.len();
    if plan.placed.routing.routed.iter().any(|r| r.edge >= num_edges) {
        return Err(corrupt("routing references an unknown edge"));
    }
    check_routing(&plan.plan.built.graph, &plan.placed.routing)?;
    Ok((plan, tuned))
}

fn corrupt(msg: &str) -> Error {
    Error::Runtime(format!("plan store entry rejected: {msg}"))
}

fn tuned_to_json(t: &TunedEntry) -> Json {
    obj(vec![
        ("tuner_version", (t.tuner_version as usize).into()),
        ("mode", t.mode.as_str().into()),
        ("candidates", t.candidates.into()),
        ("chosen", t.chosen.as_str().into()),
        ("improved", t.improved.into()),
        ("predicted_s", t.predicted_s.map_or(Json::Null, Json::Num)),
        ("simulated_s", t.simulated_s.map_or(Json::Null, Json::Num)),
    ])
}

fn tuned_from_json(j: &Json) -> Result<TunedEntry> {
    let us = |name: &str| {
        j.get(name)
            .and_then(Json::as_usize)
            .ok_or_else(|| corrupt(&format!("tuned missing {name}")))
    };
    let s = |name: &str| {
        j.get(name)
            .and_then(Json::as_str)
            .ok_or_else(|| corrupt(&format!("tuned missing {name}")))
    };
    let opt_f = |name: &str| -> Result<Option<f64>> {
        match j.get(name) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => v.as_f64().map(Some).ok_or_else(|| corrupt(&format!("bad tuned {name}"))),
        }
    };
    let improved = j
        .get("improved")
        .and_then(Json::as_bool)
        .ok_or_else(|| corrupt("tuned missing improved"))?;
    Ok(TunedEntry {
        tuner_version: us("tuner_version")? as u32,
        mode: s("mode")?.to_string(),
        candidates: us("candidates")?,
        chosen: s("chosen")?.to_string(),
        improved,
        predicted_s: opt_f("predicted_s")?,
        simulated_s: opt_f("simulated_s")?,
    })
}

// ---------------------------------------------------------------------------
// ExecutablePlan ⇄ Json round-trip serializers
// ---------------------------------------------------------------------------

/// Serialize a lowered plan (graph + placement + routing + generated
/// sources + architecture) to pure data. Inverse of [`plan_from_json`];
/// the round trip is property-tested in `rust/tests/persistence.rs`.
pub fn plan_to_json(plan: &ExecutablePlan) -> Json {
    obj(vec![
        ("spec", plan.plan.spec.to_json()),
        ("arch", arch_to_json(&plan.plan.arch)),
        ("graph", graph_to_json(&plan.plan.built.graph)),
        (
            "node_routine",
            Json::Arr(
                plan.plan
                    .built
                    .node_routine
                    .iter()
                    .map(|r| r.map_or(Json::Null, Json::from))
                    .collect(),
            ),
        ),
        (
            "project",
            Json::Obj(
                plan.plan
                    .project
                    .files
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                    .collect(),
            ),
        ),
        ("placement", placement_to_json(&plan.placed.placement)),
        ("routing", routing_to_json(&plan.placed.routing)),
    ])
}

/// Deserialize a plan previously written by [`plan_to_json`].
pub fn plan_from_json(json: &Json) -> Result<ExecutablePlan> {
    let spec = Spec::from_json(json.get("spec").ok_or_else(|| corrupt("missing spec"))?)?;
    let arch = arch_from_json(json.get("arch").ok_or_else(|| corrupt("missing arch"))?)?;
    let graph = graph_from_json(json.get("graph").ok_or_else(|| corrupt("missing graph"))?)?;
    let node_routine = json
        .get("node_routine")
        .and_then(Json::as_arr)
        .ok_or_else(|| corrupt("missing node_routine"))?
        .iter()
        .map(|v| match v {
            Json::Null => Ok(None),
            _ => v.as_usize().map(Some).ok_or_else(|| corrupt("bad node_routine entry")),
        })
        .collect::<Result<Vec<Option<usize>>>>()?;
    let mut files = BTreeMap::new();
    for (path, contents) in json
        .get("project")
        .and_then(Json::as_obj)
        .ok_or_else(|| corrupt("missing project"))?
    {
        let text = contents.as_str().ok_or_else(|| corrupt("non-string source file"))?;
        files.insert(path.clone(), text.to_string());
    }
    let placement =
        placement_from_json(json.get("placement").ok_or_else(|| corrupt("missing placement"))?)?;
    let routing =
        routing_from_json(json.get("routing").ok_or_else(|| corrupt("missing routing"))?)?;
    Ok(ExecutablePlan {
        plan: RoutinePlan {
            spec,
            arch,
            built: BuildOutput { graph, node_routine },
            project: GeneratedProject { files },
        },
        placed: PlacedGraph { placement, routing },
    })
}

fn arch_to_json(a: &ArchConfig) -> Json {
    obj(vec![
        ("rows", a.rows.into()),
        ("cols", a.cols.into()),
        ("local_mem_bytes", a.local_mem_bytes.into()),
        ("aie_clock_hz", a.aie_clock_hz.into()),
        ("pl_clock_hz", a.pl_clock_hz.into()),
        ("vector_bits", a.vector_bits.into()),
        ("fp32_macs_per_cycle", a.fp32_macs_per_cycle.into()),
        ("stream_bits_per_cycle", a.stream_bits_per_cycle.into()),
        ("pl_aie_channel_bw", a.pl_aie_channel_bw.into()),
        ("pl_to_aie_channels", a.pl_to_aie_channels.into()),
        ("aie_to_pl_channels", a.aie_to_pl_channels.into()),
        ("ddr_channel_bw", a.ddr_channel_bw.into()),
        ("ddr_channels", a.ddr_channels.into()),
        ("ddr_naive_efficiency", a.ddr_naive_efficiency.into()),
        ("ddr_burst_efficiency", a.ddr_burst_efficiency.into()),
        ("window_overhead_cycles", (a.window_overhead_cycles as usize).into()),
        ("noc_hop_cycles", (a.noc_hop_cycles as usize).into()),
        ("kernel_call_cycles", (a.kernel_call_cycles as usize).into()),
    ])
}

fn arch_from_json(j: &Json) -> Result<ArchConfig> {
    let field = |name: &str| j.get(name).ok_or_else(|| corrupt(&format!("arch missing {name}")));
    let us = |name: &str| {
        field(name)?.as_usize().ok_or_else(|| corrupt(&format!("bad arch {name}")))
    };
    let f = |name: &str| field(name)?.as_f64().ok_or_else(|| corrupt(&format!("bad arch {name}")));
    Ok(ArchConfig {
        rows: us("rows")?,
        cols: us("cols")?,
        local_mem_bytes: us("local_mem_bytes")?,
        aie_clock_hz: f("aie_clock_hz")?,
        pl_clock_hz: f("pl_clock_hz")?,
        vector_bits: us("vector_bits")?,
        fp32_macs_per_cycle: us("fp32_macs_per_cycle")?,
        stream_bits_per_cycle: us("stream_bits_per_cycle")?,
        pl_aie_channel_bw: f("pl_aie_channel_bw")?,
        pl_to_aie_channels: us("pl_to_aie_channels")?,
        aie_to_pl_channels: us("aie_to_pl_channels")?,
        ddr_channel_bw: f("ddr_channel_bw")?,
        ddr_channels: us("ddr_channels")?,
        ddr_naive_efficiency: f("ddr_naive_efficiency")?,
        ddr_burst_efficiency: f("ddr_burst_efficiency")?,
        window_overhead_cycles: us("window_overhead_cycles")? as u64,
        noc_hop_cycles: us("noc_hop_cycles")? as u64,
        kernel_call_cycles: us("kernel_call_cycles")? as u64,
    })
}

fn node_kind_to_json(kind: &NodeKind) -> Json {
    match kind {
        NodeKind::AieKernel { kind, size, window, vector_bits, hint } => {
            let mut fields: Vec<(&str, Json)> = vec![
                ("t", "aie".into()),
                ("routine", kind.name().into()),
                ("size", (*size).into()),
                ("window", (*window).into()),
                ("vector_bits", (*vector_bits).into()),
            ];
            if let Some((col, row)) = hint {
                fields.push(("hint", obj(vec![("col", (*col).into()), ("row", (*row).into())])));
            }
            obj(fields)
        }
        NodeKind::PlMm2s { burst } => obj(vec![("t", "mm2s".into()), ("burst", (*burst).into())]),
        NodeKind::PlS2mm { burst } => obj(vec![("t", "s2mm".into()), ("burst", (*burst).into())]),
        NodeKind::Combine { parts } => {
            obj(vec![("t", "combine".into()), ("parts", (*parts).into())])
        }
        NodeKind::OnChipSource => obj(vec![("t", "source".into())]),
        NodeKind::OnChipSink => obj(vec![("t", "sink".into())]),
    }
}

fn node_kind_from_json(j: &Json) -> Result<NodeKind> {
    let tag = j.get("t").and_then(Json::as_str).ok_or_else(|| corrupt("node missing tag"))?;
    let us = |name: &str| {
        j.get(name)
            .and_then(Json::as_usize)
            .ok_or_else(|| corrupt(&format!("node missing {name}")))
    };
    Ok(match tag {
        "aie" => {
            let routine = j
                .get("routine")
                .and_then(Json::as_str)
                .ok_or_else(|| corrupt("aie node missing routine"))?;
            let kind = RoutineKind::from_name(routine)
                .ok_or_else(|| corrupt(&format!("unknown routine {routine:?}")))?;
            let hint = match j.get("hint") {
                None => None,
                Some(h) => {
                    let col = h.get("col").and_then(Json::as_usize);
                    let row = h.get("row").and_then(Json::as_usize);
                    match (col, row) {
                        (Some(col), Some(row)) => Some((col, row)),
                        _ => return Err(corrupt("bad placement hint")),
                    }
                }
            };
            NodeKind::AieKernel {
                kind,
                size: us("size")?,
                window: us("window")?,
                vector_bits: us("vector_bits")?,
                hint,
            }
        }
        "mm2s" => NodeKind::PlMm2s { burst: mover_burst(j)? },
        "s2mm" => NodeKind::PlS2mm { burst: mover_burst(j)? },
        "combine" => NodeKind::Combine { parts: us("parts")? },
        "source" => NodeKind::OnChipSource,
        "sink" => NodeKind::OnChipSink,
        other => return Err(corrupt(&format!("unknown node tag {other:?}"))),
    })
}

/// A PL mover's `burst` flag. Mandatory: silently defaulting a corrupt
/// field would flip the DDR efficiency model instead of rejecting.
fn mover_burst(j: &Json) -> Result<bool> {
    j.get("burst").and_then(Json::as_bool).ok_or_else(|| corrupt("mover missing bool burst"))
}

fn port_ty_name(ty: PortType) -> &'static str {
    match ty {
        PortType::Scalar => "scalar",
        PortType::Vector => "vector",
        PortType::Matrix => "matrix",
    }
}

fn port_ty_from_name(s: &str) -> Result<PortType> {
    match s {
        "scalar" => Ok(PortType::Scalar),
        "vector" => Ok(PortType::Vector),
        "matrix" => Ok(PortType::Matrix),
        other => Err(corrupt(&format!("unknown port type {other:?}"))),
    }
}

fn graph_to_json(g: &Graph) -> Json {
    let nodes: Vec<Json> = g
        .nodes
        .iter()
        .map(|n| obj(vec![("name", n.name.clone().into()), ("kind", node_kind_to_json(&n.kind))]))
        .collect();
    let edges: Vec<Json> = g
        .edges
        .iter()
        .map(|e| {
            obj(vec![
                ("src", e.src.into()),
                ("src_port", e.src_port.clone().into()),
                ("dst", e.dst.into()),
                ("dst_port", e.dst_port.clone().into()),
                ("ty", port_ty_name(e.ty).into()),
                (
                    "kind",
                    match e.kind {
                        EdgeKind::Window => "window",
                        EdgeKind::Stream => "stream",
                    }
                    .into(),
                ),
                ("total", e.total_elements.into()),
                ("window", e.window_elements.into()),
            ])
        })
        .collect();
    obj(vec![("nodes", Json::Arr(nodes)), ("edges", Json::Arr(edges))])
}

fn graph_from_json(j: &Json) -> Result<Graph> {
    let mut g = Graph::default();
    for n in j.get("nodes").and_then(Json::as_arr).ok_or_else(|| corrupt("graph missing nodes"))? {
        let name =
            n.get("name").and_then(Json::as_str).ok_or_else(|| corrupt("node missing name"))?;
        let kind =
            node_kind_from_json(n.get("kind").ok_or_else(|| corrupt("node missing kind"))?)?;
        g.add_node(name, kind);
    }
    for (i, e) in j
        .get("edges")
        .and_then(Json::as_arr)
        .ok_or_else(|| corrupt("graph missing edges"))?
        .iter()
        .enumerate()
    {
        let us = |name: &str| {
            e.get(name)
                .and_then(Json::as_usize)
                .ok_or_else(|| corrupt(&format!("edge {i} missing {name}")))
        };
        let s = |name: &str| {
            e.get(name)
                .and_then(Json::as_str)
                .ok_or_else(|| corrupt(&format!("edge {i} missing {name}")))
        };
        let id = g.add_edge(
            us("src")?,
            s("src_port")?,
            us("dst")?,
            s("dst_port")?,
            port_ty_from_json_edge(e, i)?,
            match s("kind")? {
                "window" => EdgeKind::Window,
                "stream" => EdgeKind::Stream,
                other => return Err(corrupt(&format!("edge {i}: unknown kind {other:?}"))),
            },
            us("total")?,
            us("window")?,
        );
        debug_assert_eq!(id, i);
    }
    Ok(g)
}

fn port_ty_from_json_edge(e: &Json, i: usize) -> Result<PortType> {
    port_ty_from_name(
        e.get("ty")
            .and_then(Json::as_str)
            .ok_or_else(|| corrupt(&format!("edge {i} missing ty")))?,
    )
}

fn placement_to_json(p: &Placement) -> Json {
    let locs: Vec<Json> = p
        .locations
        .iter()
        .map(|l| match *l {
            Location::Tile { col, row } => {
                obj(vec![("t", "tile".into()), ("col", col.into()), ("row", row.into())])
            }
            Location::Shim { col } => obj(vec![("t", "shim".into()), ("col", col.into())]),
            Location::OffChip => obj(vec![("t", "off".into())]),
        })
        .collect();
    obj(vec![("locations", Json::Arr(locs))])
}

fn placement_from_json(j: &Json) -> Result<Placement> {
    let mut locations = Vec::new();
    for l in j
        .get("locations")
        .and_then(Json::as_arr)
        .ok_or_else(|| corrupt("placement missing locations"))?
    {
        let tag = l.get("t").and_then(Json::as_str).ok_or_else(|| corrupt("location missing tag"))?;
        let us = |name: &str| {
            l.get(name)
                .and_then(Json::as_usize)
                .ok_or_else(|| corrupt(&format!("location missing {name}")))
        };
        locations.push(match tag {
            "tile" => Location::Tile { col: us("col")?, row: us("row")? },
            "shim" => Location::Shim { col: us("col")? },
            "off" => Location::OffChip,
            other => return Err(corrupt(&format!("unknown location tag {other:?}"))),
        });
    }
    Ok(Placement { locations })
}

fn routing_to_json(r: &Routing) -> Json {
    let routed: Vec<Json> = r
        .routed
        .iter()
        .map(|e| {
            obj(vec![
                ("edge", e.edge.into()),
                ("hops", e.hops.into()),
                ("p2a", e.uses_pl_to_aie.into()),
                ("a2p", e.uses_aie_to_pl.into()),
                ("neighbour", e.neighbour.into()),
            ])
        })
        .collect();
    obj(vec![
        ("routed", Json::Arr(routed)),
        ("pl_to_aie_used", r.pl_to_aie_used.into()),
        ("aie_to_pl_used", r.aie_to_pl_used.into()),
    ])
}

fn routing_from_json(j: &Json) -> Result<Routing> {
    let mut routed = Vec::new();
    for (i, e) in j
        .get("routed")
        .and_then(Json::as_arr)
        .ok_or_else(|| corrupt("routing missing routed"))?
        .iter()
        .enumerate()
    {
        let us = |name: &str| {
            e.get(name)
                .and_then(Json::as_usize)
                .ok_or_else(|| corrupt(&format!("route {i} missing {name}")))
        };
        let b = |name: &str| {
            e.get(name)
                .and_then(Json::as_bool)
                .ok_or_else(|| corrupt(&format!("route {i} missing {name}")))
        };
        routed.push(RoutedEdge {
            edge: us("edge")?,
            hops: us("hops")?,
            uses_pl_to_aie: b("p2a")?,
            uses_aie_to_pl: b("a2p")?,
            neighbour: b("neighbour")?,
        });
    }
    let us = |name: &str| {
        j.get(name)
            .and_then(Json::as_usize)
            .ok_or_else(|| corrupt(&format!("routing missing {name}")))
    };
    Ok(Routing {
        routed,
        pl_to_aie_used: us("pl_to_aie_used")?,
        aie_to_pl_used: us("aie_to_pl_used")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::spec::DataSource;

    fn tmp_store(tag: &str) -> PlanStore {
        static NEXT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let n = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        PlanStore::new(std::env::temp_dir().join(format!(
            "aieblas-store-unit-{tag}-{}-{n}",
            std::process::id()
        )))
    }

    fn lowered(spec: &Spec) -> ExecutablePlan {
        crate::pipeline::lower_spec(spec).unwrap()
    }

    #[test]
    fn plan_round_trips_through_json() {
        for spec in [
            Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl),
            Spec::single(RoutineKind::Gemv, "g", 64, DataSource::OnChip),
            Spec::axpydot_dataflow(8192, 2.0),
            Spec::chain(RoutineKind::Scal, 4, 1024),
        ] {
            let plan = lowered(&spec);
            let back = plan_from_json(&plan_to_json(&plan)).unwrap();
            assert_eq!(back.plan.spec, plan.plan.spec);
            assert_eq!(back.plan.arch, plan.plan.arch);
            assert_eq!(back.plan.built.graph, plan.plan.built.graph);
            assert_eq!(back.plan.built.node_routine, plan.plan.built.node_routine);
            assert_eq!(back.plan.project.files, plan.plan.project.files);
            assert_eq!(back.placed.placement.locations, plan.placed.placement.locations);
            assert_eq!(back.placed.routing.routed, plan.placed.routing.routed);
            assert_eq!(back.placed.routing.pl_to_aie_used, plan.placed.routing.pl_to_aie_used);
            assert_eq!(back.placed.routing.aie_to_pl_used, plan.placed.routing.aie_to_pl_used);
        }
    }

    #[test]
    fn save_load_round_trip_on_disk() {
        let store = tmp_store("roundtrip");
        let spec = Spec::axpydot_dataflow(4096, 2.0);
        let plan = lowered(&spec);
        let fp = arch_fingerprint(&ArchConfig::vck5000());
        store.save(&PlanKey::of(&spec), &fp, &plan).unwrap();
        assert_eq!(store.stats().entries, 1);
        match store.load(&PlanKey::of(&spec), &fp) {
            LoadOutcome::Loaded(back, tuned) => {
                assert_eq!(back.plan.built.graph, plan.plan.built.graph);
                assert_eq!(tuned, None, "plain save persists no tuning provenance");
            }
            other => panic!("expected Loaded, got {other:?}"),
        }
        assert_eq!(store.clear().unwrap(), 1);
        assert_eq!(store.stats().entries, 0);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn tuned_metadata_round_trips() {
        let store = tmp_store("tuned");
        let spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        let plan = lowered(&spec);
        let fp = arch_fingerprint(&ArchConfig::vck5000());
        let tuned = TunedEntry {
            tuner_version: 1,
            mode: "full".into(),
            candidates: 14,
            chosen: "bias=1 scan=col passes=4 +burst".into(),
            improved: true,
            predicted_s: Some(1.5e-3),
            simulated_s: None,
        };
        store.save_tuned(&PlanKey::of(&spec), &fp, &plan, Some(&tuned)).unwrap();
        match store.load(&PlanKey::of(&spec), &fp) {
            LoadOutcome::Loaded(_, Some(back)) => assert_eq!(back, tuned),
            other => panic!("expected tuned Loaded, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn open_sweeps_stale_tmps_but_spares_fresh_ones() {
        let dir = tmp_store("sweep").dir().to_path_buf();
        std::fs::create_dir_all(&dir).unwrap();
        let tmp = dir.join(".00000000deadbeef.1.tmp");
        std::fs::write(&tmp, "half-written entry").unwrap();
        // just-written tmp is younger than the default grace: survives.
        let fresh = PlanStore::open(&dir);
        assert_eq!(fresh.swept(), 0);
        assert!(tmp.exists(), "fresh tmp must survive the graced sweep");
        // zero grace: the same tmp is stale by definition and is removed.
        let swept = PlanStore::open_with_grace(&dir, Duration::ZERO);
        assert_eq!(swept.swept(), 1);
        assert!(!tmp.exists(), "zero-grace sweep must remove the tmp");
        // entries and non-dot files are never touched by the sweep.
        let entry = dir.join(format!("{:016x}{ENTRY_SUFFIX}", 7u64));
        std::fs::write(&entry, "{}").unwrap();
        assert_eq!(PlanStore::open_with_grace(&dir, Duration::ZERO).swept(), 0);
        assert!(entry.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_store_write_fault_fails_save_deterministically() {
        let store = tmp_store("faulty");
        let faulty = store
            .clone()
            .with_faults(FaultPlan::parse("seed=7,store_write_fail=1.0").unwrap());
        let spec = Spec::single(RoutineKind::Scal, "s", 1024, DataSource::Pl);
        let plan = lowered(&spec);
        let fp = arch_fingerprint(&ArchConfig::vck5000());
        let err = faulty.save(&PlanKey::of(&spec), &fp, &plan).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "got: {err}");
        assert_eq!(faulty.stats().entries, 0, "injected failure writes nothing");
        // the un-faulted handle on the same directory still works.
        store.save(&PlanKey::of(&spec), &fp, &plan).unwrap();
        assert_eq!(store.stats().entries, 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn missing_entry_is_missing_not_rejected() {
        let store = tmp_store("missing");
        let fp = arch_fingerprint(&ArchConfig::vck5000());
        assert!(matches!(store.load(&PlanKey::from("no-such-key"), &fp), LoadOutcome::Missing));
    }

    #[test]
    fn wrong_fingerprint_is_rejected() {
        let store = tmp_store("fp");
        let spec = Spec::single(RoutineKind::Dot, "d", 1024, DataSource::Pl);
        let plan = lowered(&spec);
        let fp = arch_fingerprint(&ArchConfig::vck5000());
        store.save(&PlanKey::of(&spec), &fp, &plan).unwrap();
        let other_fp = arch_fingerprint(&ArchConfig::ryzen_ai());
        assert!(matches!(store.load(&PlanKey::of(&spec), &other_fp), LoadOutcome::Rejected(_)));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn fingerprints_distinguish_architectures() {
        assert_ne!(
            arch_fingerprint(&ArchConfig::vck5000()),
            arch_fingerprint(&ArchConfig::ryzen_ai())
        );
        assert_eq!(
            arch_fingerprint(&ArchConfig::vck5000()),
            arch_fingerprint(&ArchConfig::vck5000())
        );
    }
}
