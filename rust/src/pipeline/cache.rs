//! The plan cache: memoized `Spec → ExecutablePlan` lowering.
//!
//! Keyed on the spec's canonical JSON (routine set, sizes, non-functional
//! parameters, connections, platform — see [`crate::spec::Spec::cache_key`]),
//! interned as a [`PlanKey`] (an `Arc<str>` plus its precomputed FNV-1a
//! hash) so the warm serving path never clones or re-hashes the full
//! canonical-JSON `String` per request. A repeated spec skips
//! re-validation, re-codegen, re-placement and re-routing. LRU-evicting
//! with a bounded capacity; hit/miss counters are surfaced in
//! `RunReport::summary()` for serving observability.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::ExecutablePlan;
use crate::spec::Spec;

/// An interned plan-cache key: the spec's canonical JSON behind a shared
/// `Arc<str>`, with its 64-bit FNV-1a hash computed exactly once.
///
/// The hash front-loads every comparison (the serving batcher probes the
/// queue per request; the cache map hashes per lookup) and doubles as the
/// persistent store's entry filename (`pipeline::store`), so one
/// canonicalization + one hash per request covers batching, memory
/// caching and disk lookup. Cloning is an `Arc` bump.
#[derive(Debug, Clone)]
pub struct PlanKey {
    text: Arc<str>,
    hash: u64,
}

impl PlanKey {
    pub fn new(text: impl Into<Arc<str>>) -> PlanKey {
        let text = text.into();
        let hash = crate::util::fnv1a64(text.as_bytes());
        PlanKey { text, hash }
    }

    /// The canonical key of a spec (one `cache_key()` render + one hash).
    pub fn of(spec: &Spec) -> PlanKey {
        PlanKey::new(spec.cache_key())
    }

    pub fn as_str(&self) -> &str {
        &self.text
    }

    /// The precomputed FNV-1a hash (also the store entry filename stem).
    pub fn hash64(&self) -> u64 {
        self.hash
    }
}

impl PartialEq for PlanKey {
    fn eq(&self, other: &Self) -> bool {
        // hash first: a mismatch (the common case in the batcher's queue
        // scan) rejects without touching the string bytes.
        self.hash == other.hash && self.text == other.text
    }
}

impl Eq for PlanKey {}

impl std::hash::Hash for PlanKey {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        state.write_u64(self.hash);
    }
}

impl From<&str> for PlanKey {
    fn from(s: &str) -> PlanKey {
        PlanKey::new(s)
    }
}

impl std::fmt::Display for PlanKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.text)
    }
}

/// Snapshot of the cache's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lowerings served from the cache (including requests coalesced onto
    /// an in-flight lowering by the pipeline's single-flight path).
    pub hits: u64,
    /// Lowerings that ran the full pipeline.
    pub misses: u64,
    /// Plans currently resident.
    pub entries: usize,
    /// Resident plans dropped to make room (serving-pressure thrash).
    pub evictions: u64,
    /// Requests that waited on another thread's in-flight lowering
    /// instead of lowering redundantly (subset of `hits`).
    pub coalesced: u64,
    /// Cold lookups served by deserializing a persisted plan from the
    /// on-disk store instead of lowering (`pipeline::store`).
    pub disk_hits: u64,
    /// Lowered plans written through to the on-disk store.
    pub disk_writes: u64,
    /// On-disk entries rejected (corruption, format-version or
    /// arch-fingerprint mismatch) and re-lowered.
    pub rejected: u64,
    /// Cold lowerings where the autotuner installed a non-default plan
    /// (`crate::tune`; tuning enabled and the search found a win).
    pub tuned: u64,
    /// Tuned plans served from cache or disk without re-running the
    /// search (the warm-start path the persisted `tuned` field buys).
    pub tune_skipped: u64,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0 when none yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Inner {
    map: HashMap<PlanKey, Arc<ExecutablePlan>>,
    /// LRU order: front = least recently used (`PlanKey` clones are `Arc`
    /// bumps, not string copies).
    order: VecDeque<PlanKey>,
}

/// Bounded, thread-safe LRU cache of lowered plans.
pub struct PlanCache {
    inner: Mutex<Inner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    coalesced: AtomicU64,
    disk_hits: AtomicU64,
    disk_writes: AtomicU64,
    rejected: AtomicU64,
    tuned: AtomicU64,
    tune_skipped: AtomicU64,
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            inner: Mutex::new(Inner { map: HashMap::new(), order: VecDeque::new() }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            disk_writes: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            tuned: AtomicU64::new(0),
            tune_skipped: AtomicU64::new(0),
        }
    }

    /// Look up a plan, counting a hit and refreshing LRU order when
    /// present. Absence counts **nothing**: `misses` means "a full
    /// lowering ran", recorded by the single-flight leader via
    /// [`PlanCache::record_miss`] — so `misses == distinct cold specs`
    /// holds no matter how many threads probe concurrently.
    pub fn get(&self, key: &PlanKey) -> Option<Arc<ExecutablePlan>> {
        let mut inner = self.inner.lock().expect("plan cache poisoned");
        let plan = inner.map.get(key).cloned()?;
        self.hits.fetch_add(1, Ordering::Relaxed);
        if let Some(pos) = inner.order.iter().position(|k| k == key) {
            inner.order.remove(pos);
        }
        inner.order.push_back(key.clone());
        Some(plan)
    }

    /// Record one full-pipeline lowering (the single-flight leader).
    pub(crate) fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request served by waiting on another thread's in-flight
    /// lowering: a hit (the plan was shared, not re-lowered) plus the
    /// `coalesced` sub-counter.
    pub(crate) fn record_coalesced(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        self.coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a cold lookup warmed from the on-disk plan store (no
    /// lowering ran; neither a memory `hit` nor a `miss`).
    pub(crate) fn record_disk_hit(&self) {
        self.disk_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one plan written through to the on-disk store.
    pub(crate) fn record_disk_write(&self) {
        self.disk_writes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one on-disk entry rejected (and re-lowered).
    pub(crate) fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a cold lowering where the autotuner installed a non-default
    /// plan.
    pub(crate) fn record_tuned(&self) {
        self.tuned.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a tuned plan served from the on-disk store with the search
    /// skipped.
    pub(crate) fn record_tune_skipped(&self) {
        self.tune_skipped.fetch_add(1, Ordering::Relaxed);
    }

    /// Insert a freshly lowered plan, evicting the least recently used
    /// entry when at capacity.
    pub fn insert(&self, key: PlanKey, plan: Arc<ExecutablePlan>) {
        let mut inner = self.inner.lock().expect("plan cache poisoned");
        if inner.map.contains_key(&key) {
            // a concurrent lowering won the race; keep the resident plan.
            return;
        }
        while inner.map.len() >= self.capacity {
            match inner.order.pop_front() {
                Some(old) => {
                    inner.map.remove(&old);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
        inner.order.push_back(key.clone());
        inner.map.insert(key, plan);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("plan cache poisoned").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all resident plans (counters are preserved).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().expect("plan cache poisoned");
        inner.map.clear();
        inner.order.clear();
    }

    /// Zero **every** counter — hits, misses, evictions, coalesced and the
    /// disk-store trio — so a reset observation window starts consistent
    /// (previously only some counters were covered, skewing `hit_rate`
    /// and eviction-pressure readings after a reset).
    pub fn reset_stats(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.evictions.store(0, Ordering::Relaxed);
        self.coalesced.store(0, Ordering::Relaxed);
        self.disk_hits.store(0, Ordering::Relaxed);
        self.disk_writes.store(0, Ordering::Relaxed);
        self.rejected.store(0, Ordering::Relaxed);
        self.tuned.store(0, Ordering::Relaxed);
        self.tune_skipped.store(0, Ordering::Relaxed);
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len(),
            evictions: self.evictions.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            disk_writes: self.disk_writes.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            tuned: self.tuned.load(Ordering::Relaxed),
            tune_skipped: self.tune_skipped.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::spec::{DataSource, Spec};

    fn plan_for(n: usize) -> Arc<ExecutablePlan> {
        let spec = Spec::single(RoutineKind::Scal, "k", n, DataSource::OnChip);
        Arc::new(crate::pipeline::lower_spec(&spec).unwrap())
    }

    #[test]
    fn plan_key_interning_and_equality() {
        let a = PlanKey::from("spec-json");
        let b = PlanKey::from("spec-json");
        let c = PlanKey::from("other");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.hash64(), crate::util::fnv1a64(b"spec-json"));
        // clone shares the interned text: an Arc bump, not a string copy.
        let d = a.clone();
        assert!(std::ptr::eq(a.as_str(), d.as_str()));
        // spec keys are exactly the canonical JSON render.
        let spec = Spec::single(RoutineKind::Axpy, "a", 64, DataSource::Pl);
        assert_eq!(PlanKey::of(&spec).as_str(), spec.cache_key());
        assert_eq!(PlanKey::of(&spec), PlanKey::of(&spec.clone()));
    }

    #[test]
    fn hit_and_miss_counting() {
        let cache = PlanCache::new(4);
        assert!(cache.get(&"a".into()).is_none());
        assert_eq!(cache.stats().misses, 0, "absence alone is not a miss");
        cache.record_miss(); // the lowering leader ran the pipeline
        cache.insert("a".into(), plan_for(64));
        assert!(cache.get(&"a".into()).is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let cache = PlanCache::new(2);
        cache.insert("a".into(), plan_for(64));
        cache.insert("b".into(), plan_for(128));
        // touch "a" so "b" is now the LRU entry
        assert!(cache.get(&"a".into()).is_some());
        cache.insert("c".into(), plan_for(256));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&"b".into()).is_none(), "LRU entry should be evicted");
        assert!(cache.get(&"a".into()).is_some());
        assert!(cache.get(&"c".into()).is_some());
    }

    #[test]
    fn clear_preserves_counters() {
        let cache = PlanCache::new(2);
        cache.insert("a".into(), plan_for(64));
        cache.get(&"a".into());
        cache.clear();
        let s = cache.stats();
        assert_eq!(s.entries, 0);
        assert_eq!(s.hits, 1);
    }

    #[test]
    fn reset_stats_covers_every_counter() {
        let cache = PlanCache::new(1);
        // drive every counter nonzero: hit, miss, eviction, coalesced,
        // disk hit/write/reject.
        cache.insert("a".into(), plan_for(64));
        cache.get(&"a".into()); // hit
        cache.record_miss();
        cache.insert("b".into(), plan_for(128)); // evicts "a"
        cache.record_coalesced();
        cache.record_disk_hit();
        cache.record_disk_write();
        cache.record_rejected();
        cache.record_tuned();
        cache.record_tune_skipped();
        let s = cache.stats();
        assert!(
            s.hits > 0
                && s.misses > 0
                && s.evictions > 0
                && s.coalesced > 0
                && s.disk_hits > 0
                && s.disk_writes > 0
                && s.rejected > 0
                && s.tuned > 0
                && s.tune_skipped > 0,
            "precondition: every counter nonzero, got {s:?}"
        );
        cache.reset_stats();
        cache.clear();
        assert_eq!(
            cache.stats(),
            CacheStats::default(),
            "reset_stats + clear must zero every field, not just hits/misses"
        );
    }

    #[test]
    fn evictions_are_counted() {
        let cache = PlanCache::new(2);
        cache.insert("a".into(), plan_for(64));
        cache.insert("b".into(), plan_for(128));
        assert_eq!(cache.stats().evictions, 0);
        cache.insert("c".into(), plan_for(256));
        cache.insert("d".into(), plan_for(512));
        let s = cache.stats();
        assert_eq!(s.evictions, 2, "two inserts past capacity evict twice");
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn duplicate_insert_keeps_resident_plan() {
        let cache = PlanCache::new(2);
        let first = plan_for(64);
        cache.insert("a".into(), first.clone());
        cache.insert("a".into(), plan_for(64));
        assert!(Arc::ptr_eq(&cache.get(&"a".into()).unwrap(), &first));
        assert_eq!(cache.len(), 1);
    }
}
