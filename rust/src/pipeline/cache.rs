//! The plan cache: memoized `Spec → ExecutablePlan` lowering.
//!
//! Keyed on the spec's canonical JSON (routine set, sizes, non-functional
//! parameters, connections, platform — see [`crate::spec::Spec::cache_key`]),
//! interned as a [`PlanKey`] (an `Arc<str>` plus its precomputed FNV-1a
//! hash) so the warm serving path never clones or re-hashes the full
//! canonical-JSON `String` per request. A repeated spec skips
//! re-validation, re-codegen, re-placement and re-routing.
//!
//! Internally the cache is **striped** (DESIGN.md §12): the key's
//! precomputed hash selects one of a power-of-two number of lock stripes,
//! each an independent O(1) LRU (intrusive doubly-linked order through a
//! slab of slots, so a warm `get` is one `HashMap` probe plus four index
//! writes — no `VecDeque` scan). Warm hits on distinct keys therefore
//! take disjoint locks and scale with client threads, while per-stripe
//! relaxed atomic counters keep the aggregate [`CacheStats`] exact.
//! Small capacities collapse to a single stripe so exact global LRU
//! semantics (and the unit tests that rely on them) are preserved.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::ExecutablePlan;
use crate::spec::Spec;

/// An interned plan-cache key: the spec's canonical JSON behind a shared
/// `Arc<str>`, with its 64-bit FNV-1a hash computed exactly once.
///
/// The hash front-loads every comparison (the serving batcher probes the
/// queue per request; the cache map hashes per lookup) and doubles as the
/// persistent store's entry filename (`pipeline::store`), so one
/// canonicalization + one hash per request covers batching, memory
/// caching and disk lookup. Cloning is an `Arc` bump.
#[derive(Debug, Clone)]
pub struct PlanKey {
    text: Arc<str>,
    hash: u64,
}

impl PlanKey {
    pub fn new(text: impl Into<Arc<str>>) -> PlanKey {
        let text = text.into();
        let hash = crate::util::fnv1a64(text.as_bytes());
        PlanKey { text, hash }
    }

    /// The canonical key of a spec (one `cache_key()` render + one hash).
    pub fn of(spec: &Spec) -> PlanKey {
        PlanKey::new(spec.cache_key())
    }

    pub fn as_str(&self) -> &str {
        &self.text
    }

    /// The precomputed FNV-1a hash (also the store entry filename stem).
    pub fn hash64(&self) -> u64 {
        self.hash
    }
}

impl PartialEq for PlanKey {
    fn eq(&self, other: &Self) -> bool {
        // hash first: a mismatch (the common case in the batcher's queue
        // scan) rejects without touching the string bytes.
        self.hash == other.hash && self.text == other.text
    }
}

impl Eq for PlanKey {}

impl std::hash::Hash for PlanKey {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        state.write_u64(self.hash);
    }
}

impl From<&str> for PlanKey {
    fn from(s: &str) -> PlanKey {
        PlanKey::new(s)
    }
}

impl std::fmt::Display for PlanKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.text)
    }
}

/// Pick the stripe for a key hash among `stripes` (a power of two).
///
/// Uses bits 32..40 of the FNV-1a hash rather than the low bits: the
/// stripe-local `HashMap` derives its buckets from the same 64-bit value
/// (`PlanKey::hash` writes only `hash64()`), so stripe selection and
/// bucket selection must consume different bit ranges or every map in a
/// stripe would see keys agreeing in its own low bits. Shared with the
/// pipeline's single-flight in-flight map so both layers agree on which
/// lock guards a key.
pub(crate) fn select_stripe(hash: u64, stripes: usize) -> usize {
    debug_assert!(stripes.is_power_of_two());
    ((hash >> 32) as usize) & (stripes - 1)
}

/// Stripe count for a cache of `capacity` entries: the largest power of
/// two ≤ `min(64, capacity / 8)`, and at least 1. Small caches (< 16)
/// get exactly one stripe — global LRU order stays exact, which the
/// eviction unit tests (capacities 1–4) and any capacity-precise caller
/// rely on. Large caches cap at 64 stripes: past the core count more
/// stripes only fragment capacity.
pub(crate) fn stripe_count_for(capacity: usize) -> usize {
    let limit = (capacity / 8).clamp(1, 64);
    // largest power of two ≤ limit
    1 << (usize::BITS - 1 - limit.leading_zeros())
}

/// Snapshot of the cache's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lowerings served from the cache (including requests coalesced onto
    /// an in-flight lowering by the pipeline's single-flight path).
    pub hits: u64,
    /// Lowerings that ran the full pipeline.
    pub misses: u64,
    /// Plans currently resident.
    pub entries: usize,
    /// Resident plans dropped to make room (serving-pressure thrash).
    pub evictions: u64,
    /// Requests that waited on another thread's in-flight lowering
    /// instead of lowering redundantly (subset of `hits`).
    pub coalesced: u64,
    /// Cold lookups served by deserializing a persisted plan from the
    /// on-disk store instead of lowering (`pipeline::store`).
    pub disk_hits: u64,
    /// Lowered plans written through to the on-disk store.
    pub disk_writes: u64,
    /// On-disk entries rejected (corruption, format-version or
    /// arch-fingerprint mismatch) and re-lowered.
    pub rejected: u64,
    /// Cold lowerings where the autotuner installed a non-default plan
    /// (`crate::tune`; tuning enabled and the search found a win).
    pub tuned: u64,
    /// Tuned plans served from cache or disk without re-running the
    /// search (the warm-start path the persisted `tuned` field buys).
    pub tune_skipped: u64,
    /// Stale temp files swept by `PlanStore::open` — crashed-writer
    /// litter older than the sweep grace window (DESIGN.md §14).
    pub tmp_swept: u64,
    /// Store write-throughs that failed; the plan stayed memory-cached
    /// and serving continued (degraded persistence, not an error).
    pub store_fallbacks: u64,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0 when none yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Sentinel slab index: "no neighbour" in the intrusive LRU list.
const NIL: usize = usize::MAX;

struct Slot {
    /// `None` after eviction (the plan and key drop eagerly; the slot
    /// waits on the free list for reuse).
    entry: Option<(PlanKey, Arc<ExecutablePlan>)>,
    prev: usize,
    next: usize,
}

/// One stripe's resident entries: a key → slot-index map plus the slots
/// themselves, LRU-ordered by an intrusive doubly-linked list through
/// `prev`/`next` (head = least recently used, tail = most recently used).
/// Every operation — hit refresh, insert, evict — is O(1): no ordered
/// container is scanned or shifted, and slots are recycled through a free
/// list so a stripe running at capacity performs no allocation at all.
struct StripeInner {
    map: HashMap<PlanKey, usize>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl StripeInner {
    fn with_capacity(capacity: usize) -> StripeInner {
        StripeInner {
            map: HashMap::with_capacity(capacity),
            slots: Vec::with_capacity(capacity),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    /// Detach slot `i` from the LRU list (it keeps its map entry).
    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        match prev {
            NIL => self.head = next,
            p => self.slots[p].next = next,
        }
        match next {
            NIL => self.tail = prev,
            n => self.slots[n].prev = prev,
        }
        self.slots[i].prev = NIL;
        self.slots[i].next = NIL;
    }

    /// Append slot `i` at the most-recently-used end.
    fn attach_mru(&mut self, i: usize) {
        self.slots[i].prev = self.tail;
        self.slots[i].next = NIL;
        match self.tail {
            NIL => self.head = i,
            t => self.slots[t].next = i,
        }
        self.tail = i;
    }

    /// Look up `key`, refreshing its LRU position on a hit.
    fn touch(&mut self, key: &PlanKey) -> Option<Arc<ExecutablePlan>> {
        let i = *self.map.get(key)?;
        if self.tail != i {
            self.unlink(i);
            self.attach_mru(i);
        }
        Some(self.slots[i].entry.as_ref().expect("resident slot").1.clone())
    }

    /// Insert at the MRU end, evicting from the LRU end while over
    /// `capacity`; returns the number of evictions.
    fn insert(&mut self, key: PlanKey, plan: Arc<ExecutablePlan>, capacity: usize) -> u64 {
        if self.map.contains_key(&key) {
            // a concurrent lowering won the race; keep the resident plan.
            return 0;
        }
        let mut evicted = 0;
        while self.map.len() >= capacity {
            let lru = self.head;
            if lru == NIL {
                break;
            }
            self.unlink(lru);
            let (old_key, _) = self.slots[lru].entry.take().expect("LRU slot resident");
            self.map.remove(&old_key);
            self.free.push(lru);
            evicted += 1;
        }
        let i = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(Slot { entry: None, prev: NIL, next: NIL });
                self.slots.len() - 1
            }
        };
        self.slots[i].entry = Some((key.clone(), plan));
        self.map.insert(key, i);
        self.attach_mru(i);
        evicted
    }

    fn clear(&mut self) {
        self.map.clear();
        self.slots.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
    }

    /// Resident keys in LRU → MRU order (test/oracle support).
    #[cfg(test)]
    fn keys_lru_order(&self) -> Vec<PlanKey> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut i = self.head;
        while i != NIL {
            out.push(self.slots[i].entry.as_ref().expect("resident slot").0.clone());
            i = self.slots[i].next;
        }
        out
    }
}

/// One lock stripe. Padded to a cache line so neighbouring stripes'
/// mutexes and hot counters never share one (false sharing would hand
/// back the contention the striping removes).
#[repr(align(64))]
struct Stripe {
    inner: Mutex<StripeInner>,
    /// This stripe's share of the total capacity (shares sum exactly to
    /// the configured capacity).
    capacity: usize,
    /// Warm-path counters live per stripe: a hit bumps only its own
    /// stripe's cache line. Exact when summed at snapshot time.
    hits: AtomicU64,
    evictions: AtomicU64,
}

/// Bounded, thread-safe, striped LRU cache of lowered plans. Warm `get`s
/// on distinct keys take disjoint stripe locks; every operation is O(1)
/// in both capacity and stripe size.
pub struct PlanCache {
    stripes: Box<[Stripe]>,
    capacity: usize,
    // Cold-path counters stay global: they are bumped at lowering /
    // disk-store frequency, not per warm request.
    misses: AtomicU64,
    coalesced: AtomicU64,
    disk_hits: AtomicU64,
    disk_writes: AtomicU64,
    rejected: AtomicU64,
    tuned: AtomicU64,
    tune_skipped: AtomicU64,
    tmp_swept: AtomicU64,
    store_fallbacks: AtomicU64,
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        let capacity = capacity.max(1);
        let n = stripe_count_for(capacity);
        let (base, rem) = (capacity / n, capacity % n);
        let stripes = (0..n)
            .map(|i| {
                let cap = base + usize::from(i < rem);
                Stripe {
                    inner: Mutex::new(StripeInner::with_capacity(cap)),
                    capacity: cap,
                    hits: AtomicU64::new(0),
                    evictions: AtomicU64::new(0),
                }
            })
            .collect();
        PlanCache {
            stripes,
            capacity,
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            disk_writes: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            tuned: AtomicU64::new(0),
            tune_skipped: AtomicU64::new(0),
            tmp_swept: AtomicU64::new(0),
            store_fallbacks: AtomicU64::new(0),
        }
    }

    /// Number of lock stripes (1 for small caches; see
    /// [`stripe_count_for`]). The pipeline sizes its single-flight
    /// in-flight map to match.
    pub fn stripe_count(&self) -> usize {
        self.stripes.len()
    }

    /// Total configured capacity (the per-stripe shares sum to this).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn stripe(&self, key: &PlanKey) -> &Stripe {
        &self.stripes[select_stripe(key.hash64(), self.stripes.len())]
    }

    /// Look up a plan, counting a hit and refreshing LRU order when
    /// present. Absence counts **nothing**: `misses` means "a full
    /// lowering ran", recorded by the single-flight leader via
    /// [`PlanCache::record_miss`] — so `misses == distinct cold specs`
    /// holds no matter how many threads probe concurrently.
    pub fn get(&self, key: &PlanKey) -> Option<Arc<ExecutablePlan>> {
        let stripe = self.stripe(key);
        let plan = stripe.inner.lock().expect("plan cache poisoned").touch(key)?;
        stripe.hits.fetch_add(1, Ordering::Relaxed);
        Some(plan)
    }

    /// Record one full-pipeline lowering (the single-flight leader).
    pub(crate) fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request served by waiting on another thread's in-flight
    /// lowering: a hit (the plan was shared, not re-lowered) plus the
    /// `coalesced` sub-counter.
    pub(crate) fn record_coalesced(&self) {
        // attribute the hit to stripe 0: hits are reported only in
        // aggregate, and coalescing happens at cold-lowering frequency.
        self.stripes[0].hits.fetch_add(1, Ordering::Relaxed);
        self.coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a cold lookup warmed from the on-disk plan store (no
    /// lowering ran; neither a memory `hit` nor a `miss`).
    pub(crate) fn record_disk_hit(&self) {
        self.disk_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one plan written through to the on-disk store.
    pub(crate) fn record_disk_write(&self) {
        self.disk_writes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one on-disk entry rejected (and re-lowered).
    pub(crate) fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a cold lowering where the autotuner installed a non-default
    /// plan.
    pub(crate) fn record_tuned(&self) {
        self.tuned.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a tuned plan served from the on-disk store with the search
    /// skipped.
    pub(crate) fn record_tune_skipped(&self) {
        self.tune_skipped.fetch_add(1, Ordering::Relaxed);
    }

    /// Record stale temp files swept by `PlanStore::open` (attached at
    /// `Pipeline::with_store` time, once per store).
    pub(crate) fn record_tmp_swept(&self, n: u64) {
        if n > 0 {
            self.tmp_swept.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Record a failed store write-through that fell back to memory-only
    /// caching.
    pub(crate) fn record_store_fallback(&self) {
        self.store_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Non-recording membership probe: no hit counted, no LRU refresh.
    /// Observability only — the HTTP failover path uses it to classify
    /// a failover as duplicate-lowering work vs already-warm; using
    /// [`PlanCache::get`] there would skew `hits` and the LRU order.
    pub fn contains(&self, key: &PlanKey) -> bool {
        self.stripe(key).inner.lock().expect("plan cache poisoned").map.contains_key(key)
    }

    /// Insert a freshly lowered plan, evicting the least recently used
    /// entry **within the key's stripe** when that stripe is at capacity.
    pub fn insert(&self, key: PlanKey, plan: Arc<ExecutablePlan>) {
        let stripe = self.stripe(&key);
        let evicted =
            stripe.inner.lock().expect("plan cache poisoned").insert(key, plan, stripe.capacity);
        if evicted > 0 {
            stripe.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
    }

    pub fn len(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.inner.lock().expect("plan cache poisoned").map.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all resident plans (counters are preserved).
    pub fn clear(&self) {
        for stripe in self.stripes.iter() {
            stripe.inner.lock().expect("plan cache poisoned").clear();
        }
    }

    /// Zero **every** counter — the per-stripe hit/eviction atomics,
    /// misses, coalesced and the disk-store trio — so a reset observation
    /// window starts consistent (previously only some counters were
    /// covered, skewing `hit_rate` and eviction-pressure readings after a
    /// reset).
    pub fn reset_stats(&self) {
        for stripe in self.stripes.iter() {
            stripe.hits.store(0, Ordering::Relaxed);
            stripe.evictions.store(0, Ordering::Relaxed);
        }
        self.misses.store(0, Ordering::Relaxed);
        self.coalesced.store(0, Ordering::Relaxed);
        self.disk_hits.store(0, Ordering::Relaxed);
        self.disk_writes.store(0, Ordering::Relaxed);
        self.rejected.store(0, Ordering::Relaxed);
        self.tuned.store(0, Ordering::Relaxed);
        self.tune_skipped.store(0, Ordering::Relaxed);
        self.tmp_swept.store(0, Ordering::Relaxed);
        self.store_fallbacks.store(0, Ordering::Relaxed);
    }

    /// Aggregate counters: per-stripe hit/eviction atomics summed with
    /// the global cold-path counters. Relaxed loads — exact at
    /// quiescence, monotone-approximate while writers run (same contract
    /// the single-counter version had).
    pub fn stats(&self) -> CacheStats {
        let mut hits = 0;
        let mut evictions = 0;
        for stripe in self.stripes.iter() {
            hits += stripe.hits.load(Ordering::Relaxed);
            evictions += stripe.evictions.load(Ordering::Relaxed);
        }
        CacheStats {
            hits,
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len(),
            evictions,
            coalesced: self.coalesced.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            disk_writes: self.disk_writes.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            tuned: self.tuned.load(Ordering::Relaxed),
            tune_skipped: self.tune_skipped.load(Ordering::Relaxed),
            tmp_swept: self.tmp_swept.load(Ordering::Relaxed),
            store_fallbacks: self.store_fallbacks.load(Ordering::Relaxed),
        }
    }

    /// Which stripe a key lands in (oracle tests mirror eviction
    /// per-stripe).
    #[cfg(test)]
    fn stripe_of(&self, key: &PlanKey) -> usize {
        select_stripe(key.hash64(), self.stripes.len())
    }

    /// Per-stripe resident keys in LRU → MRU order (oracle tests).
    #[cfg(test)]
    fn stripe_keys(&self, stripe: usize) -> Vec<PlanKey> {
        self.stripes[stripe].inner.lock().expect("plan cache poisoned").keys_lru_order()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::spec::{DataSource, Spec};

    fn plan_for(n: usize) -> Arc<ExecutablePlan> {
        let spec = Spec::single(RoutineKind::Scal, "k", n, DataSource::OnChip);
        Arc::new(crate::pipeline::lower_spec(&spec).unwrap())
    }

    #[test]
    fn plan_key_interning_and_equality() {
        let a = PlanKey::from("spec-json");
        let b = PlanKey::from("spec-json");
        let c = PlanKey::from("other");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.hash64(), crate::util::fnv1a64(b"spec-json"));
        // clone shares the interned text: an Arc bump, not a string copy.
        let d = a.clone();
        assert!(std::ptr::eq(a.as_str(), d.as_str()));
        // spec keys are exactly the canonical JSON render.
        let spec = Spec::single(RoutineKind::Axpy, "a", 64, DataSource::Pl);
        assert_eq!(PlanKey::of(&spec).as_str(), spec.cache_key());
        assert_eq!(PlanKey::of(&spec), PlanKey::of(&spec.clone()));
    }

    #[test]
    fn stripe_counts_follow_capacity() {
        // below 16 entries: exactly one stripe (exact global LRU).
        for cap in [1, 2, 4, 8, 15] {
            assert_eq!(stripe_count_for(cap), 1, "capacity {cap}");
            assert_eq!(PlanCache::new(cap).stripe_count(), 1);
        }
        assert_eq!(stripe_count_for(16), 2);
        assert_eq!(stripe_count_for(128), 16);
        assert_eq!(stripe_count_for(1024), 64);
        assert_eq!(stripe_count_for(1 << 20), 64, "stripes cap at 64");
        // per-stripe shares sum exactly to the configured capacity.
        for cap in [1, 16, 100, 129, 1000, 16384] {
            let cache = PlanCache::new(cap);
            let total: usize = cache.stripes.iter().map(|s| s.capacity).sum();
            assert_eq!(total, cap, "capacity {cap} split across stripes");
        }
    }

    #[test]
    fn hit_and_miss_counting() {
        let cache = PlanCache::new(4);
        assert!(cache.get(&"a".into()).is_none());
        assert_eq!(cache.stats().misses, 0, "absence alone is not a miss");
        cache.record_miss(); // the lowering leader ran the pipeline
        cache.insert("a".into(), plan_for(64));
        assert!(cache.get(&"a".into()).is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let cache = PlanCache::new(2);
        cache.insert("a".into(), plan_for(64));
        cache.insert("b".into(), plan_for(128));
        // touch "a" so "b" is now the LRU entry
        assert!(cache.get(&"a".into()).is_some());
        cache.insert("c".into(), plan_for(256));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&"b".into()).is_none(), "LRU entry should be evicted");
        assert!(cache.get(&"a".into()).is_some());
        assert!(cache.get(&"c".into()).is_some());
    }

    #[test]
    fn clear_preserves_counters() {
        let cache = PlanCache::new(2);
        cache.insert("a".into(), plan_for(64));
        cache.get(&"a".into());
        cache.clear();
        let s = cache.stats();
        assert_eq!(s.entries, 0);
        assert_eq!(s.hits, 1);
    }

    #[test]
    fn reset_stats_covers_every_counter() {
        let cache = PlanCache::new(1);
        // drive every counter nonzero: hit, miss, eviction, coalesced,
        // disk hit/write/reject.
        cache.insert("a".into(), plan_for(64));
        cache.get(&"a".into()); // hit
        cache.record_miss();
        cache.insert("b".into(), plan_for(128)); // evicts "a"
        cache.record_coalesced();
        cache.record_disk_hit();
        cache.record_disk_write();
        cache.record_rejected();
        cache.record_tuned();
        cache.record_tune_skipped();
        cache.record_tmp_swept(2);
        cache.record_store_fallback();
        let s = cache.stats();
        assert!(
            s.hits > 0
                && s.misses > 0
                && s.evictions > 0
                && s.coalesced > 0
                && s.disk_hits > 0
                && s.disk_writes > 0
                && s.rejected > 0
                && s.tuned > 0
                && s.tune_skipped > 0
                && s.tmp_swept > 0
                && s.store_fallbacks > 0,
            "precondition: every counter nonzero, got {s:?}"
        );
        cache.reset_stats();
        cache.clear();
        assert_eq!(
            cache.stats(),
            CacheStats::default(),
            "reset_stats + clear must zero every field, not just hits/misses"
        );
    }

    #[test]
    fn contains_probes_without_recording() {
        let cache = PlanCache::new(4);
        cache.insert("a".into(), plan_for(64));
        let before = cache.stats();
        assert!(cache.contains(&"a".into()));
        assert!(!cache.contains(&"missing".into()));
        // neither probe moved any counter (no hit, no miss).
        assert_eq!(cache.stats(), before);
    }

    #[test]
    fn contains_does_not_refresh_lru_order() {
        // single-stripe cache of 2: inserting c must evict the true LRU
        // (a), even though contains() probed a just before.
        let cache = PlanCache::new(2);
        assert_eq!(cache.stripe_count(), 1);
        cache.insert("a".into(), plan_for(64));
        cache.insert("b".into(), plan_for(64));
        assert!(cache.contains(&"a".into()));
        cache.insert("c".into(), plan_for(64));
        assert!(!cache.contains(&"a".into()), "a stays LRU despite the probe");
        assert!(cache.contains(&"b".into()));
        assert!(cache.contains(&"c".into()));
    }

    #[test]
    fn reset_stats_covers_stripe_counters() {
        // multi-stripe cache: hits and evictions land in per-stripe
        // atomics spread across stripes; reset must zero all of them.
        let cache = PlanCache::new(64);
        assert!(cache.stripe_count() > 1);
        for i in 0..128 {
            let key: PlanKey = format!("k{i}").as_str().into();
            cache.insert(key.clone(), plan_for(64));
            cache.get(&key);
        }
        let s = cache.stats();
        assert!(s.hits >= 128 && s.evictions > 0, "precondition: {s:?}");
        cache.reset_stats();
        cache.clear();
        assert_eq!(cache.stats(), CacheStats::default());
    }

    #[test]
    fn evictions_are_counted() {
        let cache = PlanCache::new(2);
        cache.insert("a".into(), plan_for(64));
        cache.insert("b".into(), plan_for(128));
        assert_eq!(cache.stats().evictions, 0);
        cache.insert("c".into(), plan_for(256));
        cache.insert("d".into(), plan_for(512));
        let s = cache.stats();
        assert_eq!(s.evictions, 2, "two inserts past capacity evict twice");
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn duplicate_insert_keeps_resident_plan() {
        let cache = PlanCache::new(2);
        let first = plan_for(64);
        cache.insert("a".into(), first.clone());
        cache.insert("a".into(), plan_for(64));
        assert!(Arc::ptr_eq(&cache.get(&"a".into()).unwrap(), &first));
        assert_eq!(cache.len(), 1);
    }

    /// Reference LRU: the pre-stripe `HashMap` + `VecDeque` semantics,
    /// driven per stripe as the oracle for the intrusive list.
    struct OracleLru {
        resident: Vec<PlanKey>, // front = LRU
        capacity: usize,
    }

    impl OracleLru {
        fn get(&mut self, key: &PlanKey) -> bool {
            match self.resident.iter().position(|k| k == key) {
                Some(pos) => {
                    let k = self.resident.remove(pos);
                    self.resident.push(k);
                    true
                }
                None => false,
            }
        }

        fn insert(&mut self, key: PlanKey) -> u64 {
            if self.resident.contains(&key) {
                return 0;
            }
            let mut evicted = 0;
            while self.resident.len() >= self.capacity {
                self.resident.remove(0);
                evicted += 1;
            }
            self.resident.push(key);
            evicted
        }
    }

    /// Drive a random get/insert mix against the striped cache and a
    /// per-stripe unsharded oracle; residency, LRU order, hit and
    /// eviction counts must agree after every step.
    #[test]
    fn stripe_eviction_order_matches_unsharded_oracle() {
        for (capacity, seed) in [(4usize, 1u64), (16, 2), (48, 3)] {
            let cache = PlanCache::new(capacity);
            let n = cache.stripe_count();
            let mut oracles: Vec<OracleLru> = cache
                .stripes
                .iter()
                .map(|s| OracleLru { resident: Vec::new(), capacity: s.capacity })
                .collect();
            let keys: Vec<PlanKey> =
                (0..capacity * 3).map(|i| PlanKey::from(format!("k{i}").as_str())).collect();
            let plan = plan_for(64);
            let mut rng = crate::util::rng::Rng::new(seed);
            let (mut hits, mut evictions) = (0u64, 0u64);
            for _ in 0..2000 {
                let key = &keys[rng.below(keys.len() as u64) as usize];
                let stripe = cache.stripe_of(key);
                if rng.below(2) == 0 {
                    let got = cache.get(key).is_some();
                    assert_eq!(got, oracles[stripe].get(key));
                    hits += u64::from(got);
                } else {
                    cache.insert(key.clone(), plan.clone());
                    evictions += oracles[stripe].insert(key.clone());
                }
            }
            for (i, oracle) in oracles.iter().enumerate() {
                assert_eq!(
                    cache.stripe_keys(i),
                    oracle.resident,
                    "stripe {i}/{n} LRU order diverged (capacity {capacity}, seed {seed})"
                );
            }
            let s = cache.stats();
            assert_eq!(s.hits, hits);
            assert_eq!(s.evictions, evictions);
            assert_eq!(s.entries, oracles.iter().map(|o| o.resident.len()).sum::<usize>());
        }
    }

    /// Property: aggregate stats stay exact under a multithreaded hammer.
    /// Each thread tallies locally what it observed; at quiescence the
    /// summed per-stripe atomics must equal the sequential oracle
    /// (`hits == successful gets`, and since every inserted key is
    /// unique, `prefill + inserts == entries + evictions`).
    #[test]
    fn sharded_stats_exact_under_multithreaded_hammer() {
        use std::sync::atomic::AtomicU64;

        let cache = PlanCache::new(64);
        assert!(cache.stripe_count() > 1, "hammer should cross stripes");
        let plan = plan_for(64);
        let prefill = 48u64;
        let warm: Vec<PlanKey> =
            (0..prefill).map(|i| PlanKey::from(format!("warm{i}").as_str())).collect();
        for k in &warm {
            cache.insert(k.clone(), plan.clone());
        }
        let threads = 8u64;
        let inserts_per_thread = 32u64;
        let observed_hits = AtomicU64::new(0);
        std::thread::scope(|s| {
            for t in 0..threads {
                let (cache, plan, warm) = (&cache, &plan, &warm);
                let observed_hits = &observed_hits;
                s.spawn(move || {
                    let mut rng = crate::util::rng::Rng::new(0xC0FFEE + t);
                    let mut local_hits = 0u64;
                    for i in 0..inserts_per_thread {
                        for _ in 0..8 {
                            let k = &warm[rng.below(prefill) as usize];
                            local_hits += u64::from(cache.get(k).is_some());
                        }
                        // unique key per (thread, i): always a fresh insert.
                        cache.insert(PlanKey::from(format!("t{t}-{i}").as_str()), plan.clone());
                    }
                    observed_hits.fetch_add(local_hits, Ordering::Relaxed);
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.hits, observed_hits.load(Ordering::Relaxed), "hits exact: {s:?}");
        let inserted = prefill + threads * inserts_per_thread;
        assert_eq!(
            s.entries as u64 + s.evictions,
            inserted,
            "every uniquely-inserted plan is either resident or evicted: {s:?}"
        );
        assert_eq!(s.entries, cache.len());
    }
}
