//! The staged lowering pipeline (DESIGN.md §2).
//!
//! Spec execution used to be orchestrated inline by the coordinator; it is
//! now an explicit three-stage lowering with a cacheable artifact at the
//! end:
//!
//! ```text
//!   Spec ──(validate + build graph + codegen)──▶ RoutinePlan
//!        ──(place + route + capacity check)───▶ PlacedGraph
//!        ──(bundle)───────────────────────────▶ ExecutablePlan
//! ```
//!
//! An [`ExecutablePlan`] is pure data (graph, placement, routing, generated
//! sources, architecture) and is what every [`Backend`]
//! (`crate::runtime::Backend`) consumes. [`Pipeline`] memoizes lowering in
//! a [`PlanCache`] keyed on the spec's canonical JSON, so a repeated spec —
//! the serving-heavy-traffic case — skips validation, codegen, placement
//! and routing entirely and goes straight to execution. With an attached
//! [`PlanStore`] (see [`Pipeline::with_disk_store`]), lowered plans also
//! persist to disk, so a restarted process warms from its predecessor's
//! cache instead of re-lowering (DESIGN.md §10).

pub mod cache;
pub mod store;

pub use cache::{CacheStats, PlanCache, PlanKey};
pub use store::{LoadOutcome, PlanStore, StoreStats, TunedEntry};

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};

use crate::arch::ArchConfig;
use crate::codegen::GeneratedProject;
use crate::graph::build::{build_graph, BuildOutput};
use crate::graph::place::{place, Placement};
use crate::graph::route::{check_routing, route, Routing};
use crate::graph::Graph;
use crate::spec::Spec;
use crate::tune::{tune_spec, TuneConfig, TuneMode, TuneReport, TUNER_VERSION};
use crate::{Error, Result};

/// Stage-1 output: a validated spec with its dataflow graph and the
/// generated Vitis sources (paper Fig. 1 ①–④ up to placement).
#[derive(Debug, Clone)]
pub struct RoutinePlan {
    pub spec: Spec,
    pub arch: ArchConfig,
    pub built: BuildOutput,
    pub project: GeneratedProject,
}

/// Stage-2 output: the graph mapped onto the array and its edges routed,
/// with interface-capacity and conservation checks already passed.
#[derive(Debug, Clone)]
pub struct PlacedGraph {
    pub placement: Placement,
    pub routing: Routing,
}

/// Stage-3 output: everything a backend needs to execute the design.
#[derive(Debug, Clone)]
pub struct ExecutablePlan {
    pub plan: RoutinePlan,
    pub placed: PlacedGraph,
}

impl ExecutablePlan {
    pub fn spec(&self) -> &Spec {
        &self.plan.spec
    }

    pub fn arch(&self) -> &ArchConfig {
        &self.plan.arch
    }

    pub fn graph(&self) -> &Graph {
        &self.plan.built.graph
    }

    pub fn project(&self) -> &GeneratedProject {
        &self.plan.project
    }

    pub fn placement(&self) -> &Placement {
        &self.placed.placement
    }

    pub fn routing(&self) -> &Routing {
        &self.placed.routing
    }
}

/// The architecture a spec lowers against: `default_arch` backs the
/// default platform ("vck5000"/empty); named platforms resolve through
/// [`crate::spec::arch_for`].
pub fn resolve_arch(spec: &Spec, default_arch: &ArchConfig) -> Result<ArchConfig> {
    if spec.platform.is_empty() || spec.platform == "vck5000" {
        Ok(default_arch.clone())
    } else {
        crate::spec::arch_for(&spec.platform)
    }
}

/// Stage 1: validate the spec, resolve its architecture, build the
/// dataflow graph and generate the Vitis sources.
pub fn plan_routines(spec: &Spec, default_arch: &ArchConfig) -> Result<RoutinePlan> {
    crate::spec::validate(spec)?;
    let arch = resolve_arch(spec, default_arch)?;
    let built = build_graph(spec)?;
    let project = crate::codegen::generate_from_built(spec, &built)?;
    Ok(RoutinePlan { spec: spec.clone(), arch, built, project })
}

/// Stage 2: place the plan's graph on the array and route every edge,
/// verifying routing conservation.
pub fn place_and_route(plan: &RoutinePlan) -> Result<PlacedGraph> {
    let placement = place(&plan.built.graph, &plan.arch)?;
    let routing = route(&plan.built.graph, &placement, &plan.arch)?;
    check_routing(&plan.built.graph, &routing)?;
    Ok(PlacedGraph { placement, routing })
}

/// One-shot uncached lowering with an explicit default architecture.
pub fn lower_spec_with(spec: &Spec, default_arch: &ArchConfig) -> Result<ExecutablePlan> {
    let plan = plan_routines(spec, default_arch)?;
    let placed = place_and_route(&plan)?;
    Ok(ExecutablePlan { plan, placed })
}

/// One-shot uncached lowering against the stock VCK5000.
pub fn lower_spec(spec: &Spec) -> Result<ExecutablePlan> {
    lower_spec_with(spec, &ArchConfig::vck5000())
}

/// Store provenance for a tuned lowering: enough for a warm-started
/// process to decide whether the persisted search is still trustworthy.
fn tuned_entry_from(report: &TuneReport) -> TunedEntry {
    let chosen = report.chosen_candidate();
    TunedEntry {
        tuner_version: TUNER_VERSION,
        mode: report.mode.name().to_string(),
        candidates: report.candidates.len(),
        chosen: chosen.map(|c| c.label.clone()).unwrap_or_default(),
        improved: report.improved(),
        predicted_s: chosen.and_then(|c| c.predicted_s),
        simulated_s: chosen.and_then(|c| c.simulated_s),
    }
}

/// Outcome of one lowering as seen by single-flight followers; errors
/// travel as rendered strings (`Error` is not `Clone`).
type LoweredResult = std::result::Result<Arc<ExecutablePlan>, String>;

/// One in-flight lowering: the leader fills `done` and notifies; followers
/// block on the condvar and share the result.
struct LoweringSlot {
    done: Mutex<Option<LoweredResult>>,
    cv: Condvar,
}

impl LoweringSlot {
    fn new() -> Arc<LoweringSlot> {
        Arc::new(LoweringSlot { done: Mutex::new(None), cv: Condvar::new() })
    }

    fn fill(&self, result: LoweredResult) {
        let mut done = self.done.lock().expect("lowering slot poisoned");
        if done.is_none() {
            *done = Some(result);
        }
        self.cv.notify_all();
    }

    fn wait(&self) -> LoweredResult {
        let mut done = self.done.lock().expect("lowering slot poisoned");
        loop {
            if let Some(result) = done.as_ref() {
                return result.clone();
            }
            done = self.cv.wait(done).expect("lowering slot poisoned");
        }
    }
}

/// Removes the leader's slot from the in-flight map and fails any waiting
/// followers even if lowering panics (so they never block forever).
struct LeaderGuard<'p> {
    pipeline: &'p Pipeline,
    key: PlanKey,
    slot: Arc<LoweringSlot>,
}

impl Drop for LeaderGuard<'_> {
    fn drop(&mut self) {
        self.pipeline
            .in_flight_stripe(&self.key)
            .lock()
            .expect("in-flight map poisoned")
            .remove(&self.key);
        // no-op when the leader already filled the slot with its result
        self.slot.fill(Err(format!("lowering of {:?} panicked", self.key.as_str())));
    }
}

/// The memoizing pipeline front-end: `lower` returns a shared
/// [`ExecutablePlan`], reusing a cached one when the same spec (by
/// canonical JSON) was lowered before.
///
/// `Pipeline` is `Send + Sync` and designed to sit behind an `Arc` shared
/// by every serving thread: the cache is a mutex'd LRU with atomic
/// counters, and cold lowerings are **single-flight** — N concurrent
/// requests for the same uncached spec run codegen/placement/routing once
/// (one miss) while the other N−1 block and share the resulting
/// `Arc<ExecutablePlan>` (counted as coalesced hits).
pub struct Pipeline {
    default_arch: ArchConfig,
    cache: PlanCache,
    /// Cold lowerings currently running, lock-striped by the same
    /// hash→stripe rule as the cache (`cache::select_stripe`) so
    /// registering a leader for one key never serializes against an
    /// unrelated key's cold start.
    in_flight: Box<[Mutex<HashMap<PlanKey, Arc<LoweringSlot>>>]>,
    /// Optional on-disk plan store: cold lowerings first try to warm from
    /// a previous process's persisted plans and write through on success.
    store: Option<PlanStore>,
    /// Fingerprint of `default_arch`, stamped into (and checked against)
    /// every store entry.
    fingerprint: String,
    /// Autotuning policy for cold lowerings (default: off — lower the
    /// first valid plan, the historical behaviour).
    tune: TuneConfig,
}

impl Pipeline {
    /// Default plan-cache capacity (resident lowered designs).
    pub const DEFAULT_CACHE_CAPACITY: usize = 128;

    pub fn new(default_arch: ArchConfig) -> Pipeline {
        Self::with_cache_capacity(default_arch, Self::DEFAULT_CACHE_CAPACITY)
    }

    pub fn with_cache_capacity(default_arch: ArchConfig, capacity: usize) -> Pipeline {
        let fingerprint = store::arch_fingerprint(&default_arch);
        let cache = PlanCache::new(capacity);
        let in_flight =
            (0..cache.stripe_count()).map(|_| Mutex::new(HashMap::new())).collect();
        Pipeline {
            default_arch,
            cache,
            in_flight,
            store: None,
            fingerprint,
            tune: TuneConfig::default(),
        }
    }

    /// The in-flight stripe guarding `key`'s cold lowering (same
    /// selection rule as the cache stripes).
    fn in_flight_stripe(&self, key: &PlanKey) -> &Mutex<HashMap<PlanKey, Arc<LoweringSlot>>> {
        &self.in_flight[cache::select_stripe(key.hash64(), self.in_flight.len())]
    }

    /// Set the autotuning policy (builder-style). With a mode other than
    /// [`TuneMode::Off`], cold lowerings run the placement autotuner
    /// (`crate::tune`) and install the winning candidate; warm starts —
    /// memory hits, and disk entries tuned under the current
    /// [`TUNER_VERSION`] — skip the search (counted as `tune_skipped`).
    pub fn with_tuning(mut self, tune: TuneConfig) -> Pipeline {
        self.tune = tune;
        self
    }

    /// The active autotuning policy.
    pub fn tuning(&self) -> &TuneConfig {
        &self.tune
    }

    /// Attach an on-disk [`PlanStore`] under `dir` (builder-style): cold
    /// lowerings lazily load persisted plans written by earlier processes
    /// (counted as `disk_hits`) and successful lowerings write through.
    pub fn with_disk_store(self, dir: impl Into<PathBuf>) -> Pipeline {
        self.with_store(PlanStore::open(dir))
    }

    /// Attach an already-constructed [`PlanStore`] (builder-style). Lets
    /// callers pick the open mode — crash-recovery sweep, sweep grace,
    /// fault injection — before handing the store over; the store's sweep
    /// count is folded into this pipeline's cache stats as `tmp_swept`.
    pub fn with_store(mut self, store: PlanStore) -> Pipeline {
        self.cache.record_tmp_swept(store.swept());
        self.store = Some(store);
        self
    }

    /// The attached on-disk plan store, if any.
    pub fn store(&self) -> Option<&PlanStore> {
        self.store.as_ref()
    }

    /// Lower a spec to an executable plan, consulting the plan cache.
    ///
    /// Thread-safe and single-flight: concurrent calls with the same cache
    /// key either hit the cache, become the one lowering leader, or wait
    /// for the leader and share its plan.
    pub fn lower(&self, spec: &Spec) -> Result<Arc<ExecutablePlan>> {
        self.lower_keyed(&PlanKey::of(spec), spec)
    }

    /// [`Pipeline::lower`] with the spec's [`PlanKey`] already computed —
    /// the serving layer interns the key once at submit time and reuses it
    /// through batching, caching and the disk store, so the warm path
    /// renders and hashes the canonical JSON exactly once per request.
    pub fn lower_keyed(&self, key: &PlanKey, spec: &Spec) -> Result<Arc<ExecutablePlan>> {
        debug_assert_eq!(key.as_str(), spec.cache_key(), "key must belong to spec");
        if let Some(hit) = self.cache.get(key) {
            return Ok(hit);
        }
        let (slot, leader) = {
            let mut in_flight =
                self.in_flight_stripe(key).lock().expect("in-flight map poisoned");
            // re-check under the stripe lock: a leader may have completed
            // (inserted into the cache and left the map) since the peek.
            if let Some(hit) = self.cache.get(key) {
                return Ok(hit);
            }
            match in_flight.get(key) {
                Some(slot) => (slot.clone(), false),
                None => {
                    let slot = LoweringSlot::new();
                    in_flight.insert(key.clone(), slot.clone());
                    (slot, true)
                }
            }
        };
        if !leader {
            return match slot.wait() {
                Ok(plan) => {
                    self.cache.record_coalesced();
                    Ok(plan)
                }
                Err(msg) => Err(Error::Runtime(msg)),
            };
        }
        let guard = LeaderGuard { pipeline: self, key: key.clone(), slot };
        // lazy-load: before paying for a full lowering, the leader (and
        // only the leader — followers coalesce onto the slot either way)
        // tries the on-disk store. A valid persisted plan is execution-
        // equivalent to a fresh lowering (DESIGN.md §10), so it goes
        // straight into the memory cache; anything unusable is rejected
        // and falls through to a clean re-lower.
        if let Some(store) = &self.store {
            let loaded = match store.load(key, &self.fingerprint) {
                LoadOutcome::Loaded(plan, tuned) => {
                    // the fingerprint covers the *default* arch; a named
                    // platform resolves independently of it, so also require
                    // the stored arch to equal what resolution produces
                    // today — otherwise a plan lowered under old platform
                    // constants would execute a stale hardware model.
                    let arch_ok = matches!(
                        resolve_arch(spec, &self.default_arch),
                        Ok(arch) if plan.plan.arch == arch
                    );
                    // a tuning pipeline only trusts entries tuned under the
                    // current tuner version — an untuned (or stale-tuner)
                    // plan would silently pin the unsearched default. A
                    // non-tuning pipeline takes any valid plan.
                    let tuned_ok = if self.tune.mode == TuneMode::Off {
                        true
                    } else {
                        matches!(&tuned, Some(t) if t.tuner_version == TUNER_VERSION)
                    };
                    if arch_ok && tuned_ok {
                        if tuned.is_some() {
                            self.cache.record_tune_skipped();
                        }
                        Some(Arc::from(plan))
                    } else {
                        self.cache.record_rejected();
                        crate::log_warn!(
                            "plan store entry rejected, re-lowering: {}",
                            if arch_ok {
                                "entry not tuned under the current tuner version"
                            } else {
                                "stale arch for the requested platform"
                            }
                        );
                        None
                    }
                }
                LoadOutcome::Rejected(why) => {
                    self.cache.record_rejected();
                    crate::log_warn!("plan store entry rejected, re-lowering: {why}");
                    None
                }
                LoadOutcome::Missing => None,
            };
            if let Some(plan) = loaded {
                self.cache.record_disk_hit();
                self.cache.insert(key.clone(), Arc::clone(&plan));
                guard.slot.fill(Ok(Arc::clone(&plan)));
                return Ok(plan);
            }
        }
        self.cache.record_miss();
        let lowered = if self.tune.mode == TuneMode::Off {
            lower_spec_with(spec, &self.default_arch).map(|plan| (plan, None))
        } else {
            tune_spec(spec, &self.default_arch, &self.tune).map(|outcome| {
                if outcome.report.improved() {
                    self.cache.record_tuned();
                }
                let entry = tuned_entry_from(&outcome.report);
                (outcome.plan, Some(entry))
            })
        };
        match lowered {
            Ok((plan, tuned)) => {
                let plan = Arc::new(plan);
                // write-through: persistence is an optimization, so an
                // I/O failure is logged and the lowering still succeeds.
                if let Some(store) = &self.store {
                    match store.save_tuned(key, &self.fingerprint, &plan, tuned.as_ref()) {
                        Ok(()) => self.cache.record_disk_write(),
                        Err(e) => {
                            // the plan stays memory-resident either way:
                            // count the fallback so operators can see a
                            // store going dark (DESIGN.md §14).
                            self.cache.record_store_fallback();
                            crate::log_warn!("plan store write-through failed: {e}")
                        }
                    }
                }
                self.cache.insert(key.clone(), plan.clone());
                guard.slot.fill(Ok(plan.clone()));
                Ok(plan)
            }
            Err(e) => {
                guard.slot.fill(Err(e.to_string()));
                Err(e)
            }
        }
    }

    /// Drop all resident plans **and** zero every cache counter — the
    /// consistent reset `CacheStats` observers rely on (the on-disk store,
    /// if any, is left untouched; use [`PlanStore::clear`] for that).
    pub fn reset(&self) {
        self.cache.clear();
        self.cache.reset_stats();
    }

    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }
}

// the serving layer shares one Pipeline across threads; keep it that way.
const _: fn() = || {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Pipeline>();
    assert_send_sync::<PlanCache>();
    assert_send_sync::<ExecutablePlan>();
};

impl Default for Pipeline {
    fn default() -> Self {
        Pipeline::new(ArchConfig::vck5000())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::spec::DataSource;

    #[test]
    fn stages_compose_for_single_routine() {
        let spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        let plan = plan_routines(&spec, &ArchConfig::vck5000()).unwrap();
        assert_eq!(plan.built.graph.num_aie_kernels(), 1);
        assert!(plan.project.get("aie/kernels/a.cc").is_some());
        let placed = place_and_route(&plan).unwrap();
        assert_eq!(placed.routing.pl_to_aie_used, 3);
        let exec = ExecutablePlan { plan, placed };
        assert_eq!(exec.graph().nodes.len(), exec.placement().locations.len());
    }

    #[test]
    fn invalid_spec_fails_at_stage_one() {
        let spec = Spec { routines: vec![], ..Default::default() };
        assert!(plan_routines(&spec, &ArchConfig::vck5000()).is_err());
    }

    #[test]
    fn pipeline_caches_repeated_specs() {
        let pipeline = Pipeline::default();
        let spec = Spec::axpydot_dataflow(4096, 2.0);
        let a = pipeline.lower(&spec).unwrap();
        let stats = pipeline.cache().stats();
        assert_eq!((stats.hits, stats.misses), (0, 1));
        let b = pipeline.lower(&spec).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lowering must be the cached plan");
        let stats = pipeline.cache().stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
    }

    #[test]
    fn different_specs_do_not_collide() {
        let pipeline = Pipeline::default();
        let a = pipeline
            .lower(&Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl))
            .unwrap();
        let b = pipeline
            .lower(&Spec::single(RoutineKind::Axpy, "a", 8192, DataSource::Pl))
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(pipeline.cache().stats().misses, 2);
    }

    #[test]
    fn concurrent_same_key_lowering_is_single_flight() {
        let pipeline = Arc::new(Pipeline::default());
        let spec = Spec::axpydot_dataflow(8192, 2.0);
        let threads = 8;
        let barrier = Arc::new(std::sync::Barrier::new(threads));
        let plans: Vec<Arc<ExecutablePlan>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    let pipeline = pipeline.clone();
                    let spec = spec.clone();
                    let barrier = barrier.clone();
                    s.spawn(move || {
                        barrier.wait();
                        pipeline.lower(&spec).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for p in &plans[1..] {
            assert!(Arc::ptr_eq(&plans[0], p), "all threads must share one plan");
        }
        let stats = pipeline.cache().stats();
        assert_eq!(stats.misses, 1, "one cold spec lowers exactly once");
        assert_eq!(stats.hits + stats.misses, threads as u64);
    }

    #[test]
    fn failed_lowering_propagates_to_followers() {
        let pipeline = Arc::new(Pipeline::default());
        let bad = Spec { routines: vec![], ..Default::default() };
        let threads = 4;
        let barrier = Arc::new(std::sync::Barrier::new(threads));
        std::thread::scope(|s| {
            for _ in 0..threads {
                let pipeline = pipeline.clone();
                let bad = bad.clone();
                let barrier = barrier.clone();
                s.spawn(move || {
                    barrier.wait();
                    assert!(pipeline.lower(&bad).is_err());
                });
            }
        });
        assert_eq!(pipeline.cache().len(), 0, "failed lowerings are not cached");
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        static NEXT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let n = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::env::temp_dir().join(format!("aieblas-pipe-{tag}-{}-{n}", std::process::id()))
    }

    #[test]
    fn disk_store_warms_a_second_pipeline() {
        let dir = tmp_dir("warm");
        let spec = Spec::axpydot_dataflow(4096, 2.0);

        let first = Pipeline::default().with_disk_store(&dir);
        let a = first.lower(&spec).unwrap();
        let s = first.cache().stats();
        assert_eq!((s.misses, s.disk_writes, s.disk_hits), (1, 1, 0));

        // a fresh process (modeled by a fresh pipeline) warms from disk:
        // zero lowerings, one disk hit, and the same lowered artifacts.
        let second = Pipeline::default().with_disk_store(&dir);
        let b = second.lower(&spec).unwrap();
        let s = second.cache().stats();
        assert_eq!((s.misses, s.disk_hits, s.rejected), (0, 1, 0));
        assert_eq!(a.graph(), b.graph());
        assert_eq!(a.placement().locations, b.placement().locations);
        assert_eq!(a.project().files, b.project().files);

        // third lookup in the same pipeline is a plain memory hit.
        let c = second.lower(&spec).unwrap();
        assert!(Arc::ptr_eq(&b, &c));
        assert_eq!(second.cache().stats().disk_hits, 1, "disk consulted once");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn different_default_arch_rejects_and_relowers() {
        let dir = tmp_dir("arch");
        let spec = Spec::single(RoutineKind::Axpy, "a", 2048, DataSource::Pl);
        Pipeline::default().with_disk_store(&dir).lower(&spec).unwrap();

        let other = Pipeline::new(ArchConfig::ryzen_ai()).with_disk_store(&dir);
        let plan = other.lower(&spec).unwrap();
        assert_eq!(plan.arch(), &ArchConfig::ryzen_ai(), "must not execute a vck5000 plan");
        let s = other.cache().stats();
        assert_eq!((s.rejected, s.misses, s.disk_hits), (1, 1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reset_clears_plans_and_all_counters() {
        let pipeline = Pipeline::default();
        let spec = Spec::single(RoutineKind::Dot, "d", 1024, DataSource::Pl);
        pipeline.lower(&spec).unwrap();
        pipeline.lower(&spec).unwrap();
        assert_ne!(pipeline.cache().stats(), CacheStats::default());
        pipeline.reset();
        assert_eq!(pipeline.cache().stats(), CacheStats::default());
        assert_eq!(pipeline.cache().len(), 0);
    }

    #[test]
    fn tuning_pipeline_tunes_cold_and_warm_starts_from_tuned_entry() {
        let dir = tmp_dir("tune");
        // naive PL movers: the tuner's burst variant wins, so `tuned` ticks.
        let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl);
        let cfg = TuneConfig { mode: TuneMode::Full, max_candidates: 4, shortlist: 2 };

        let first = Pipeline::default().with_tuning(cfg.clone()).with_disk_store(&dir);
        let a = first.lower(&spec).unwrap();
        let s = first.cache().stats();
        assert_eq!((s.misses, s.disk_writes, s.tuned, s.tune_skipped), (1, 1, 1, 0));

        // a tuning restart trusts the persisted search: no re-tune, no miss.
        let second = Pipeline::default().with_tuning(cfg).with_disk_store(&dir);
        let b = second.lower(&spec).unwrap();
        let s = second.cache().stats();
        assert_eq!((s.misses, s.disk_hits, s.tuned, s.tune_skipped), (0, 1, 0, 1));
        assert_eq!(a.graph(), b.graph());
        assert_eq!(a.placement().locations, b.placement().locations);

        // a non-tuning reader accepts the tuned plan too — it is a valid
        // lowering like any other.
        let third = Pipeline::default().with_disk_store(&dir);
        let c = third.lower(&spec).unwrap();
        let s = third.cache().stats();
        assert_eq!((s.misses, s.disk_hits, s.rejected), (0, 1, 0));
        assert_eq!(b.graph(), c.graph());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tuning_pipeline_rejects_untuned_store_entry() {
        let dir = tmp_dir("untuned");
        let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl);
        Pipeline::default().with_disk_store(&dir).lower(&spec).unwrap();

        // an untuned entry would pin the unsearched default plan forever;
        // a tuning pipeline must reject it and run the search.
        let cfg = TuneConfig { mode: TuneMode::Analytic, max_candidates: 4, shortlist: 2 };
        let tuning = Pipeline::default().with_tuning(cfg.clone()).with_disk_store(&dir);
        tuning.lower(&spec).unwrap();
        let s = tuning.cache().stats();
        assert_eq!((s.rejected, s.misses, s.disk_hits, s.tune_skipped), (1, 1, 0, 0));

        // ...and its write-through upgrades the entry for the next restart.
        let third = Pipeline::default().with_tuning(cfg).with_disk_store(&dir);
        third.lower(&spec).unwrap();
        let s = third.cache().stats();
        assert_eq!((s.misses, s.disk_hits, s.tune_skipped), (0, 1, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn named_platform_overrides_default_arch() {
        let mut spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        spec.platform = "ryzen_ai".into();
        let plan = plan_routines(&spec, &ArchConfig::vck5000()).unwrap();
        assert_eq!(plan.arch, ArchConfig::ryzen_ai());
    }
}
