//! Versal ACAP architecture description (paper §II, Fig. 2).
//!
//! All numbers that drive the simulator live here, so experiments can vary
//! them (ablations A1–A4) and DESIGN.md can cite their provenance:
//!
//! * grid: the VCK5000's AIE array is 8 rows × 50 columns = 400 AIEs;
//! * 32 KB of tile-local memory, shared with the four neighbours;
//! * AXI4 streams carry 32 bits/cycle/channel on the NoC;
//! * 312 PL→AIE and 234 AIE→PL interface channels, 4 GB/s each;
//! * AIE clock 1.25 GHz (VCK5000 production speed grade), PL at 300 MHz
//!   (typical HLS kernel clock, paper's Vitis 2022.2 default is 300 MHz);
//! * fp32 vector datapath: 8 MAC/cycle/tile (AIE1 fp32 SIMD).

/// Floating-point element width in bytes (AIEBLAS currently targets f32, as
/// does the paper's evaluation).
pub const F32_BYTES: usize = 4;

/// Architecture parameters consumed by the simulator and cost models.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Rows in the AIE array (8 on the VCK5000).
    pub rows: usize,
    /// Columns in the AIE array (50 on the VCK5000).
    pub cols: usize,
    /// Tile-local data memory in bytes (32 KB).
    pub local_mem_bytes: usize,
    /// AIE core clock in Hz.
    pub aie_clock_hz: f64,
    /// PL fabric clock in Hz.
    pub pl_clock_hz: f64,
    /// Vector datapath width in bits (512 on AIE1; the JSON spec may lower
    /// it per-kernel, paper §III).
    pub vector_bits: usize,
    /// fp32 multiply-accumulates the vector unit retires per cycle.
    pub fp32_macs_per_cycle: usize,
    /// AXI4-stream payload per cycle per channel, in bits (32 on the AIE
    /// array NoC).
    pub stream_bits_per_cycle: usize,
    /// Per-channel PL↔AIE interface bandwidth in bytes/second (4 GB/s).
    pub pl_aie_channel_bw: f64,
    /// Number of PL→AIE interface channels (312).
    pub pl_to_aie_channels: usize,
    /// Number of AIE→PL interface channels (234).
    pub aie_to_pl_channels: usize,
    /// Off-chip DDR bandwidth per channel, bytes/second (DDR4-3200 ≈
    /// 25.6 GB/s peak; VCK5000 has 4 channels but a single PL mover
    /// saturates well below that — burst efficiency models the gap).
    pub ddr_channel_bw: f64,
    /// Number of DDR channels.
    pub ddr_channels: usize,
    /// Efficiency of non-burst (naive) DDR access; the paper's "need to
    /// optimize off-chip memory reads (e.g., via burst transfers)".
    pub ddr_naive_efficiency: f64,
    /// Efficiency of burst-optimized DDR access (ablation A1).
    pub ddr_burst_efficiency: f64,
    /// Fixed DMA/lock overhead per window acquisition, in AIE cycles.
    pub window_overhead_cycles: u64,
    /// Per-hop NoC latency in AIE cycles.
    pub noc_hop_cycles: u64,
    /// Kernel-invocation overhead (graph iteration entry), AIE cycles.
    pub kernel_call_cycles: u64,
}

impl ArchConfig {
    /// The VCK5000 development card (paper §II + §IV testbed).
    pub fn vck5000() -> Self {
        ArchConfig {
            rows: 8,
            cols: 50,
            local_mem_bytes: 32 * 1024,
            aie_clock_hz: 1.25e9,
            pl_clock_hz: 300e6,
            vector_bits: 512,
            fp32_macs_per_cycle: 8,
            stream_bits_per_cycle: 32,
            pl_aie_channel_bw: 4.0e9,
            pl_to_aie_channels: 312,
            aie_to_pl_channels: 234,
            ddr_channel_bw: 25.6e9,
            ddr_channels: 4,
            // Naive HLS movers without wide bursts reach a small fraction of
            // a DDR channel; this calibrates the paper's observation that
            // off-chip access dominates (Fig. 3 PL vs no-PL gap).
            ddr_naive_efficiency: 0.15,
            ddr_burst_efficiency: 0.70,
            window_overhead_cycles: 60,
            noc_hop_cycles: 4,
            kernel_call_cycles: 200,
        }
    }

    /// The Ryzen AI XDNA NPU (paper §I, ref [11]): the same AIE-family
    /// architecture "currently being offered in commodity CPUs" — a much
    /// smaller 4×5 array of AIE2 tiles with 64 KB local memory, shared
    /// system DDR (no dedicated device DRAM), and far fewer interface
    /// channels. Lets experiments contrast datacenter vs commodity parts.
    pub fn ryzen_ai() -> Self {
        ArchConfig {
            rows: 4,
            cols: 5,
            local_mem_bytes: 64 * 1024,
            aie_clock_hz: 1.3e9,
            pl_clock_hz: 400e6, // NPU fabric/interface clock
            vector_bits: 512,
            fp32_macs_per_cycle: 16, // AIE2-generation fp32 throughput
            stream_bits_per_cycle: 32,
            pl_aie_channel_bw: 4.0e9,
            pl_to_aie_channels: 20,
            aie_to_pl_channels: 20,
            // shares system LPDDR5 with the host
            ddr_channel_bw: 30.0e9,
            ddr_channels: 2,
            ddr_naive_efficiency: 0.25,
            ddr_burst_efficiency: 0.75,
            window_overhead_cycles: 60,
            noc_hop_cycles: 4,
            kernel_call_cycles: 200,
        }
    }

    /// Total number of AIE tiles.
    pub fn num_tiles(&self) -> usize {
        self.rows * self.cols
    }

    /// fp32 vector lanes for a given vector width.
    pub fn f32_lanes(&self, vector_bits: usize) -> usize {
        (vector_bits / 32).max(1)
    }

    /// Seconds per AIE cycle.
    pub fn aie_cycle_s(&self) -> f64 {
        1.0 / self.aie_clock_hz
    }

    /// Effective DDR bandwidth (bytes/s) for one mover, honoring burst mode.
    pub fn ddr_effective_bw(&self, burst: bool) -> f64 {
        let eff = if burst { self.ddr_burst_efficiency } else { self.ddr_naive_efficiency };
        self.ddr_channel_bw * eff
    }

    /// Stream bandwidth in bytes per AIE cycle.
    pub fn stream_bytes_per_cycle(&self) -> f64 {
        self.stream_bits_per_cycle as f64 / 8.0
    }

    /// Peak fp32 FLOP/s of a single AIE (2 flops per MAC).
    pub fn tile_peak_flops(&self) -> f64 {
        2.0 * self.fp32_macs_per_cycle as f64 * self.aie_clock_hz
    }

    /// Validate internal consistency (used by spec validation).
    pub fn validate(&self) -> crate::Result<()> {
        if self.rows == 0 || self.cols == 0 {
            return Err(crate::Error::Spec("architecture grid must be non-empty".into()));
        }
        if self.local_mem_bytes < 1024 {
            return Err(crate::Error::Spec("local memory unrealistically small".into()));
        }
        if !(self.ddr_naive_efficiency > 0.0 && self.ddr_naive_efficiency <= 1.0)
            || !(self.ddr_burst_efficiency > 0.0 && self.ddr_burst_efficiency <= 1.0)
        {
            return Err(crate::Error::Spec("DDR efficiencies must be in (0,1]".into()));
        }
        Ok(())
    }
}

impl Default for ArchConfig {
    fn default() -> Self {
        ArchConfig::vck5000()
    }
}

/// CPU-baseline machine model: the paper's host (2×10-core Xeon Silver
/// 4210R @ 2.4 GHz, 256 GB DDR4). Used by the analytic OpenBLAS model that
/// anchors Fig. 3's CPU series when measuring on different hardware.
#[derive(Debug, Clone, PartialEq)]
pub struct HostConfig {
    pub cores: usize,
    pub clock_hz: f64,
    /// Sustained aggregate memory bandwidth (bytes/s). Dual-socket
    /// six-channel DDR4-2400: ~100 GB/s aggregate STREAM-like triad.
    pub mem_bw: f64,
    /// fp32 FLOP/s per core (AVX-512 off on 4210R under load: 2×8-wide FMA
    /// = 32 flops/cycle is optimistic; use 16).
    pub flops_per_core: f64,
}

impl HostConfig {
    pub fn xeon_4210r_dual() -> Self {
        HostConfig {
            cores: 20,
            clock_hz: 2.4e9,
            // sustained (not peak) aggregate bandwidth a threaded BLAS-1
            // actually achieves across two NUMA nodes — calibrated so the
            // model reproduces the paper's "CPU up to 10× faster" band.
            mem_bw: 40e9,
            flops_per_core: 16.0 * 2.4e9,
        }
    }

    /// Roofline execution-time model for one BLAS call: the greater of the
    /// memory and compute times, plus a fixed threading/dispatch overhead.
    /// This represents the *paper's* OpenBLAS-on-Xeon baseline on any host
    /// (the measured CPU series in the benches runs on whatever machine
    /// executes them; this model anchors the Fig. 3 comparison to the
    /// published testbed).
    pub fn blas_call_time(&self, flops: u64, bytes: u64) -> f64 {
        const DISPATCH_OVERHEAD_S: f64 = 10e-6; // OpenBLAS thread wake ~10 µs
        let mem = bytes as f64 / self.mem_bw;
        let compute = flops as f64 / (self.cores as f64 * self.flops_per_core);
        DISPATCH_OVERHEAD_S + mem.max(compute)
    }
}

impl Default for HostConfig {
    fn default() -> Self {
        HostConfig::xeon_4210r_dual()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vck5000_matches_paper_section2() {
        let a = ArchConfig::vck5000();
        assert_eq!(a.num_tiles(), 400); // "8×50 grid of 400 AIEs"
        assert_eq!(a.local_mem_bytes, 32 * 1024); // "32KB of local memory"
        assert_eq!(a.pl_to_aie_channels, 312); // "312 PL→AIEs"
        assert_eq!(a.aie_to_pl_channels, 234); // "234 AIEs→PL"
        assert_eq!(a.pl_aie_channel_bw, 4.0e9); // "4 GB/s each"
        assert_eq!(a.vector_bits, 512); // "maximum supported (512 bits)"
    }

    #[test]
    fn lanes_and_rates() {
        let a = ArchConfig::vck5000();
        assert_eq!(a.f32_lanes(512), 16);
        assert_eq!(a.f32_lanes(128), 4);
        assert_eq!(a.stream_bytes_per_cycle(), 4.0);
        assert!(a.tile_peak_flops() > 1e10); // 20 GFLOP/s fp32
    }

    #[test]
    fn burst_beats_naive() {
        let a = ArchConfig::vck5000();
        assert!(a.ddr_effective_bw(true) > a.ddr_effective_bw(false));
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut a = ArchConfig::vck5000();
        a.rows = 0;
        assert!(a.validate().is_err());
        let mut b = ArchConfig::vck5000();
        b.ddr_burst_efficiency = 1.5;
        assert!(b.validate().is_err());
        assert!(ArchConfig::vck5000().validate().is_ok());
    }
}
