//! PL data-mover and off-chip memory model (Fig. 1 ②, paper §II).
//!
//! AIEBLAS generates HLS `mm2s` (memory-to-stream) and `s2mm` kernels that
//! move data between device DRAM and the AIE array through the PL↔AIE AXI
//! interfaces (4 GB/s per channel). Their effective rate is the minimum of
//!
//! * the AXI interface channel rate (4 GB/s),
//! * the mover's share of DDR bandwidth (channels × per-channel bandwidth
//!   × burst efficiency, split across concurrently active movers), and
//! * the PL kernel's own loop rate (one 32-bit word per PL clock cycle
//!   when not burst-optimized — the naive HLS mover the paper starts
//!   from; 16 bytes/cycle with wide bursts).
//!
//! The naive/burst split is the paper's §IV observation: "this emphasizes
//! the need to optimize off-chip memory reads (e.g., via burst transfers)".

use crate::arch::ArchConfig;

/// Effective sustained bandwidth (bytes/s) of one PL mover.
pub fn mover_bandwidth(arch: &ArchConfig, burst: bool, active_movers: usize) -> f64 {
    let ddr_total = arch.ddr_effective_bw(burst) * arch.ddr_channels as f64;
    let ddr_share = ddr_total / active_movers.max(1) as f64;
    let pl_word_bytes = if burst { 16.0 } else { 4.0 };
    let pl_rate = pl_word_bytes * arch.pl_clock_hz;
    arch.pl_aie_channel_bw.min(ddr_share).min(pl_rate)
}

/// Seconds to move one window of `bytes` through a mover.
pub fn window_transfer_s(arch: &ArchConfig, bytes: usize, burst: bool, active_movers: usize) -> f64 {
    bytes as f64 / mover_bandwidth(arch, burst, active_movers)
}

/// DDR round-trip cost of materialising `bytes` off-chip and reading them
/// back — the penalty the non-dataflow axpydot pays for its intermediate z
/// vector (Fig. 3 "w/o DF").
pub fn roundtrip_s(arch: &ArchConfig, bytes: usize, burst: bool) -> f64 {
    // write then read, each at single-mover rate
    2.0 * bytes as f64 / mover_bandwidth(arch, burst, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch() -> ArchConfig {
        ArchConfig::vck5000()
    }

    #[test]
    fn burst_is_faster() {
        let a = arch();
        assert!(mover_bandwidth(&a, true, 1) > mover_bandwidth(&a, false, 1));
    }

    #[test]
    fn naive_mover_is_pl_loop_bound() {
        let a = arch();
        // 4 B/cycle at 300 MHz = 1.2 GB/s < 4 GB/s channel < DDR share
        let bw = mover_bandwidth(&a, false, 1);
        assert!((bw - 1.2e9).abs() < 1e6, "naive mover ~1.2 GB/s, got {bw:e}");
    }

    #[test]
    fn burst_mover_is_channel_bound() {
        let a = arch();
        // 16 B/cycle at 300 MHz = 4.8 GB/s, capped by the 4 GB/s channel
        let bw = mover_bandwidth(&a, true, 1);
        assert!((bw - 4.0e9).abs() < 1e6, "burst mover = 4 GB/s channel cap, got {bw:e}");
    }

    #[test]
    fn contention_reduces_share() {
        let a = arch();
        // with enough movers the DDR share becomes the binding constraint
        let many = mover_bandwidth(&a, true, 64);
        assert!(many < mover_bandwidth(&a, true, 1));
    }

    #[test]
    fn transfer_time_scales_linearly() {
        let a = arch();
        let t1 = window_transfer_s(&a, 4096, false, 1);
        let t2 = window_transfer_s(&a, 8192, false, 1);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn roundtrip_is_twice_one_way() {
        let a = arch();
        let one = 1_048_576f64 / mover_bandwidth(&a, false, 1);
        assert!((roundtrip_s(&a, 1_048_576, false) - 2.0 * one).abs() < 1e-12);
    }
}
