//! Admission control for the serving layer (DESIGN.md §9).
//!
//! Everything that decides whether a request *enters* the queue — and in
//! what order it *leaves* — lives here: priority classes, per-tenant
//! in-flight quotas, deadline screening, and the configurable shed
//! policy. The dispatcher side (`mod.rs`) only sees a [`QueueState`] that
//! hands out requests priority-first; the accounting invariant the chaos
//! suite checks (`attempts == answered + shed`) is enforced by routing
//! every admission decision through [`QueueState::admit`].

use std::collections::{HashMap, VecDeque};
use std::fmt;

use super::{Request, ServeConfig, Ticket};

/// Scheduling class carried by each request. Dispatchers drain `High`
/// before `Normal` before `Background`; the watermark shed policy exempts
/// `High` so latency-critical traffic keeps headroom under overload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Background,
}

impl Priority {
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Background];

    /// Queue-lane index (0 drains first).
    pub(crate) fn lane(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Background => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Background => "background",
        }
    }

    /// Parse a CLI/config spelling (`high` / `normal` / `background`).
    pub fn parse(s: &str) -> Option<Priority> {
        match s.trim().to_ascii_lowercase().as_str() {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "background" | "bg" => Some(Priority::Background),
            _ => None,
        }
    }
}

impl fmt::Display for Priority {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-request submission options: tenant attribution, priority class and
/// an optional absolute deadline. `RequestOpts::default()` reproduces the
/// pre-hardening behavior exactly (untenanted, `Normal`, no deadline).
#[derive(Debug, Clone, Default)]
pub struct RequestOpts {
    /// Quota bucket; `None` (or an empty string) means untenanted traffic,
    /// which is never quota-limited.
    pub tenant: Option<String>,
    pub priority: Priority,
    /// Requests whose deadline has passed are shed at submit, or dropped
    /// at dequeue before wasting a backend run.
    pub deadline: Option<std::time::Instant>,
}

impl RequestOpts {
    pub fn tenant(mut self, tenant: &str) -> Self {
        self.tenant = Some(tenant.to_string());
        self
    }

    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Deadline `d` from now.
    pub fn with_deadline_in(mut self, d: std::time::Duration) -> Self {
        self.deadline = Some(std::time::Instant::now() + d);
        self
    }
}

/// What `submit` does when the queue is at capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Block the submitter until space frees (back-pressure; the
    /// pre-hardening behavior, and still the default so `serve_all`
    /// callers see identical semantics).
    #[default]
    Block,
    /// Shed with [`ShedReason::QueueFull`] instead of blocking.
    RejectWhenFull,
    /// Shed non-`High` requests once the queue holds this many entries,
    /// reserving the remaining headroom for `High` traffic. A full queue
    /// still sheds everything.
    RejectAboveWatermark(usize),
}

impl AdmissionPolicy {
    /// Parse a CLI spelling: `block`, `reject` / `reject-when-full`, or
    /// `watermark:<n>`.
    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "block" => Some(AdmissionPolicy::Block),
            "reject" | "reject-when-full" => Some(AdmissionPolicy::RejectWhenFull),
            _ => {
                let n = s.strip_prefix("watermark:")?;
                Some(AdmissionPolicy::RejectAboveWatermark(n.parse().ok()?))
            }
        }
    }
}

/// Why a request was refused at admission. Each reason has its own shed
/// counter in `ServeMetrics`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    QueueFull,
    AboveWatermark,
    TenantQuota,
    /// The server is draining or shut down; no worker will ever answer.
    Draining,
    /// The deadline had already passed at submit time.
    DeadlineExpired,
}

impl ShedReason {
    pub const ALL: [ShedReason; 5] = [
        ShedReason::QueueFull,
        ShedReason::AboveWatermark,
        ShedReason::TenantQuota,
        ShedReason::Draining,
        ShedReason::DeadlineExpired,
    ];

    pub(crate) fn index(self) -> usize {
        match self {
            ShedReason::QueueFull => 0,
            ShedReason::AboveWatermark => 1,
            ShedReason::TenantQuota => 2,
            ShedReason::Draining => 3,
            ShedReason::DeadlineExpired => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue full",
            ShedReason::AboveWatermark => "above watermark",
            ShedReason::TenantQuota => "tenant quota exceeded",
            ShedReason::Draining => "server draining",
            ShedReason::DeadlineExpired => "deadline expired",
        }
    }
}

impl fmt::Display for ShedReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Result of a non-blocking [`super::RoutineServer::try_submit`].
pub enum SubmitOutcome {
    /// The request is queued; wait on the ticket for its outcome.
    Accepted(Ticket),
    /// The request was refused and will never run.
    Shed(ShedReason),
}

impl SubmitOutcome {
    pub fn is_accepted(&self) -> bool {
        matches!(self, SubmitOutcome::Accepted(_))
    }

    pub fn ticket(self) -> Option<Ticket> {
        match self {
            SubmitOutcome::Accepted(t) => Some(t),
            SubmitOutcome::Shed(_) => None,
        }
    }

    pub fn shed_reason(&self) -> Option<ShedReason> {
        match self {
            SubmitOutcome::Accepted(_) => None,
            SubmitOutcome::Shed(r) => Some(*r),
        }
    }
}

/// Internal admission verdict: `Full` means "would block under the Block
/// policy" — the caller decides whether to wait or shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Admission {
    Admit,
    Full,
    Shed(ShedReason),
}

/// The priority-laned queue plus the tenant ledger, guarded as one unit by
/// the server's queue mutex. `accepted`/`answered` count every request
/// that ever entered a lane and every request that left with a response —
/// `is_idle` (drain's exit condition) is true only when both the lanes are
/// empty *and* nothing is in flight between dequeue and response.
#[derive(Default)]
pub(crate) struct QueueState {
    lanes: [VecDeque<Request>; 3],
    len: usize,
    /// In-flight (queued or dispatched, not yet answered) count per tenant.
    tenants: HashMap<String, usize>,
    pub(crate) accepted: u64,
    pub(crate) answered: u64,
}

impl QueueState {
    pub(crate) fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Decide whether `req` may enter the queue. Quota is checked before
    /// capacity: an over-quota tenant is shed even when the queue has
    /// room, so the reason it sees is stable across load levels.
    pub(crate) fn admit(&self, cfg: &ServeConfig, req: &Request) -> Admission {
        if cfg.max_inflight_per_tenant > 0 {
            if let Some(tenant) = &req.tenant {
                let inflight = self.tenants.get(tenant).copied().unwrap_or(0);
                if inflight >= cfg.max_inflight_per_tenant {
                    return Admission::Shed(ShedReason::TenantQuota);
                }
            }
        }
        if self.len >= cfg.queue_capacity {
            return match cfg.policy {
                AdmissionPolicy::Block => Admission::Full,
                _ => Admission::Shed(ShedReason::QueueFull),
            };
        }
        if let AdmissionPolicy::RejectAboveWatermark(w) = cfg.policy {
            // clamp: watermark 0 would shed everything, watermark above
            // capacity would never trigger before QueueFull anyway.
            let w = w.clamp(1, cfg.queue_capacity);
            if req.priority != Priority::High && self.len >= w {
                return Admission::Shed(ShedReason::AboveWatermark);
            }
        }
        Admission::Admit
    }

    /// Enqueue an admitted request (caller has already checked `admit`).
    pub(crate) fn push(&mut self, req: Request) {
        if let Some(tenant) = &req.tenant {
            *self.tenants.entry(tenant.clone()).or_insert(0) += 1;
        }
        self.accepted += 1;
        self.len += 1;
        self.lanes[req.priority.lane()].push_back(req);
    }

    /// Dequeue the oldest request from the highest non-empty lane.
    pub(crate) fn pop(&mut self) -> Option<Request> {
        for lane in &mut self.lanes {
            if let Some(req) = lane.pop_front() {
                self.len -= 1;
                return Some(req);
            }
        }
        None
    }

    /// Resumable coalesce scan: starting at `*idx` in `lane`, remove and
    /// return the next request whose plan key is `key`. Entries skipped
    /// advance `*idx`, so a linger wakeup resumes where the last scan
    /// stopped instead of rescanning the prefix under the lock.
    pub(crate) fn take_matching(
        &mut self,
        lane: usize,
        idx: &mut usize,
        key: &crate::pipeline::PlanKey,
    ) -> Option<Request> {
        while *idx < self.lanes[lane].len() {
            if self.lanes[lane][*idx].key == *key {
                let req = self.lanes[lane].remove(*idx).expect("index checked");
                self.len -= 1;
                return Some(req);
            }
            *idx += 1;
        }
        None
    }

    /// Account one dequeued request as answered (response sent, or about
    /// to be): releases its tenant quota slot.
    pub(crate) fn note_done(&mut self, req: &Request) {
        self.answered += 1;
        if let Some(tenant) = &req.tenant {
            if let Some(n) = self.tenants.get_mut(tenant) {
                *n -= 1;
                if *n == 0 {
                    self.tenants.remove(tenant);
                }
            }
        }
    }

    /// True when the lanes are empty and every accepted request has been
    /// answered — drain's exit condition.
    pub(crate) fn is_idle(&self) -> bool {
        self.len == 0 && self.accepted == self.answered
    }

    /// Empty every lane (drain timeout path); the caller answers and
    /// accounts each returned request.
    pub(crate) fn drain_all(&mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.len);
        for lane in &mut self.lanes {
            out.extend(lane.drain(..));
        }
        self.len = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_parse_and_lanes() {
        assert_eq!(Priority::parse("HIGH"), Some(Priority::High));
        assert_eq!(Priority::parse("bg"), Some(Priority::Background));
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
        let lanes: Vec<usize> = Priority::ALL.iter().map(|p| p.lane()).collect();
        assert_eq!(lanes, vec![0, 1, 2]);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(AdmissionPolicy::parse("block"), Some(AdmissionPolicy::Block));
        assert_eq!(AdmissionPolicy::parse("reject"), Some(AdmissionPolicy::RejectWhenFull));
        assert_eq!(
            AdmissionPolicy::parse("reject-when-full"),
            Some(AdmissionPolicy::RejectWhenFull)
        );
        assert_eq!(
            AdmissionPolicy::parse("watermark:12"),
            Some(AdmissionPolicy::RejectAboveWatermark(12))
        );
        assert_eq!(AdmissionPolicy::parse("watermark:lots"), None);
        assert_eq!(AdmissionPolicy::parse("drop"), None);
    }

    #[test]
    fn shed_reason_indices_cover_all() {
        for (i, r) in ShedReason::ALL.iter().enumerate() {
            assert_eq!(r.index(), i);
            assert!(!r.name().is_empty());
        }
    }
}
