//! The serving layer (DESIGN.md §9): admission → queue → batcher → backend pool.
//!
//! [`RoutineServer`] is the host-side front door the ROADMAP's
//! "heavy traffic" north-star asks for: callers submit `(Spec, ExecInputs)`
//! requests and get per-request [`ExecOutcome`]s back, while the server
//!
//! 1. **admits** requests through a configurable policy
//!    ([`AdmissionPolicy`]: block / reject-when-full / watermark) with
//!    per-tenant in-flight quotas and deadline screening — refused
//!    requests are *shed* with a [`ShedReason`] instead of queued,
//! 2. **queues** admitted requests in priority lanes ([`Priority`]:
//!    High before Normal before Background) in a bounded queue,
//! 3. **batches** them — a dispatcher that dequeues a request coalesces
//!    every queued request with the same plan-cache key into one batch (up
//!    to `max_batch`, lingering up to `linger` for stragglers), dropping
//!    requests whose deadline passed while they queued, and
//! 4. **dispatches** each batch to a shared [`Backend`] via
//!    `execute_batch` on an adaptive worker pool
//!    (`min_workers..=max_workers`, steered by a queue-wait EWMA), so
//!    per-plan setup — and for the simulator the whole DES run — is paid
//!    once per batch instead of once per request.
//!
//! Lowering goes through a shared [`Pipeline`], so cold specs are
//! single-flight across every dispatcher thread and warm specs are plan
//! cache hits. Queueing, batching, latency and hardening statistics are
//! surfaced in a [`ServeReport`] (machine-readable via
//! [`ServeReport::to_json`]). [`RoutineServer::drain`] stops admissions
//! and settles outstanding work; dropping the server still drains and
//! answers everything.

mod admission;
mod metrics;

pub use admission::{AdmissionPolicy, Priority, RequestOpts, ShedReason, SubmitOutcome};
pub use metrics::{PriorityLatency, ServeMetrics, ServeReport};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use admission::{Admission, QueueState};
use metrics::{Counters, PoolState, ShardedStats};

use crate::pipeline::{Pipeline, PlanKey};
use crate::runtime::{Backend, ExecInputs, ExecOutcome};
use crate::spec::Spec;
use crate::{Error, Result};

/// Hostile configs may ask for absurd linger values; a dispatcher must
/// never sit on a partial batch longer than this.
const LINGER_CAP: Duration = Duration::from_millis(250);

/// Floor for `target_queue_wait`: below scheduling granularity the EWMA
/// signal is pure noise.
const TARGET_WAIT_FLOOR: Duration = Duration::from_micros(50);

/// Serving-layer knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Largest batch one dispatch may coalesce (1 disables batching).
    pub max_batch: usize,
    /// How long a dispatcher waits for same-key stragglers before
    /// dispatching a non-full batch. Zero still coalesces whatever is
    /// already queued. Clamped to 250 ms.
    pub linger: Duration,
    /// Bounded queue depth; what happens when it is reached is `policy`.
    pub queue_capacity: usize,
    /// Dispatcher threads at startup (the initial backend pool width).
    pub workers: usize,
    /// What `submit` does at capacity (default: block, the pre-hardening
    /// behavior — `serve_all` callers see identical semantics).
    pub policy: AdmissionPolicy,
    /// Per-tenant in-flight (queued + dispatched) cap; 0 = unlimited.
    /// Untenanted requests are never quota-limited.
    pub max_inflight_per_tenant: usize,
    /// Adaptive-pool floor; 0 means `workers` (fixed pool).
    pub min_workers: usize,
    /// Adaptive-pool ceiling; 0 means `workers` (fixed pool).
    pub max_workers: usize,
    /// Queue-wait EWMA above this grows the pool toward `max_workers`.
    pub target_queue_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            linger: Duration::from_micros(500),
            queue_capacity: 256,
            workers: 2,
            policy: AdmissionPolicy::Block,
            max_inflight_per_tenant: 0,
            min_workers: 0,
            max_workers: 0,
            target_queue_wait: Duration::from_millis(2),
        }
    }
}

impl ServeConfig {
    /// Start a [`ServeConfigBuilder`] from the defaults. This is the
    /// preferred construction path: defaults, knob-by-knob overrides and
    /// the hostile-value clamps all live in one place, and `build()`
    /// always returns an already-normalized config. The struct's public
    /// fields remain usable for literal construction (existing tests and
    /// callers), but new code should go through the builder.
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::default() }
    }

    /// Clamp hostile values into a sane envelope (zero capacity/workers/
    /// batch, absurd linger, inverted pool bounds).
    fn normalized(self) -> ServeConfig {
        let workers = self.workers.max(1);
        let min_workers =
            if self.min_workers == 0 { workers } else { self.min_workers.clamp(1, workers) };
        let max_workers =
            if self.max_workers == 0 { workers } else { self.max_workers.max(workers) };
        ServeConfig {
            max_batch: self.max_batch.max(1),
            linger: self.linger.min(LINGER_CAP),
            queue_capacity: self.queue_capacity.max(1),
            workers,
            min_workers,
            max_workers,
            target_queue_wait: self.target_queue_wait.max(TARGET_WAIT_FLOOR),
            ..self
        }
    }
}

/// Fluent construction for [`ServeConfig`]. Every setter takes the raw
/// requested value; `build()` runs the same clamps `RoutineServer::new`
/// applies, so a builder-made config is valid by construction and the two
/// paths can never disagree about what "sane" means.
#[derive(Debug, Clone)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    pub fn linger(mut self, d: Duration) -> Self {
        self.cfg.linger = d;
        self
    }

    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.cfg.queue_capacity = n;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    pub fn policy(mut self, policy: AdmissionPolicy) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn max_inflight_per_tenant(mut self, n: usize) -> Self {
        self.cfg.max_inflight_per_tenant = n;
        self
    }

    /// Adaptive-pool bounds; `(0, 0)` keeps a fixed pool of `workers`.
    pub fn pool_bounds(mut self, min: usize, max: usize) -> Self {
        self.cfg.min_workers = min;
        self.cfg.max_workers = max;
        self
    }

    pub fn target_queue_wait(mut self, d: Duration) -> Self {
        self.cfg.target_queue_wait = d;
        self
    }

    /// Finish, applying the hostile-value clamps (PR 7 envelope).
    pub fn build(self) -> ServeConfig {
        self.cfg.normalized()
    }
}

/// One queued request. `key` is interned at submit time ([`PlanKey`]):
/// the batcher's queue scans compare hashes, and the dispatcher hands the
/// same key to the pipeline — the canonical JSON is rendered and hashed
/// exactly once per request.
pub(crate) struct Request {
    spec: Spec,
    key: PlanKey,
    inputs: ExecInputs,
    enqueued: Instant,
    priority: Priority,
    /// Normalized at submit: empty tenant strings become `None`.
    tenant: Option<String>,
    deadline: Option<Instant>,
    tx: mpsc::Sender<Result<ExecOutcome>>,
}

impl Request {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

/// A handle to one submitted request.
pub struct Ticket {
    rx: mpsc::Receiver<Result<ExecOutcome>>,
}

impl Ticket {
    /// Block until the server has executed (or failed) the request.
    pub fn wait(self) -> Result<ExecOutcome> {
        match self.rx.recv() {
            Ok(outcome) => outcome,
            Err(_) => Err(Error::Runtime("request dropped by server".into())),
        }
    }

    /// Like [`Ticket::wait`], but bound the caller's exposure: a response
    /// not ready within `timeout` returns a structured timeout error. The
    /// ticket stays usable — wait again and the response, once produced,
    /// is still delivered.
    pub fn wait_timeout(&self, timeout: Duration) -> Result<ExecOutcome> {
        match self.rx.recv_timeout(timeout) {
            Ok(outcome) => outcome,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(Error::Runtime(format!(
                "timed out after {timeout:?} waiting for server response"
            ))),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Runtime("request dropped by server".into()))
            }
        }
    }

    /// A pre-resolved ticket carrying an admission rejection, so blocking
    /// `submit` callers get a structured error instead of a hang.
    fn rejected(reason: ShedReason) -> Ticket {
        let (tx, rx) = mpsc::channel();
        let _ = tx.send(Err(Error::Runtime(format!("request shed at admission: {reason}"))));
        Ticket { rx }
    }
}

struct ServerShared {
    pipeline: Arc<Pipeline>,
    backend: Arc<dyn Backend>,
    cfg: ServeConfig,
    queue: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Signalled when the queue goes idle (empty and nothing in flight);
    /// `drain` waits on it.
    idle: Condvar,
    shutdown: AtomicBool,
    /// Admissions closed (drain or shutdown). Set under the queue lock so
    /// blocked submitters cannot miss it between their check and wait.
    draining: AtomicBool,
    /// Per-dispatcher stats shards (DESIGN.md §12): each worker records
    /// into its own shard, so the per-batch bookkeeping never serializes
    /// the pool; `report` merges all shards into one snapshot.
    stats: ShardedStats,
    counters: Counters,
    pool: PoolState,
    /// Worker handles live behind the shared state so growers can
    /// register spawned threads; `shutdown_and_join` repeatedly takes the
    /// vec (joining outside the lock) until no straggler handle remains.
    workers: Mutex<Vec<JoinHandle<()>>>,
    /// Set once by the first `submit` (lock-free afterwards); anchors the
    /// report's throughput span.
    first_submit: OnceLock<Instant>,
}

/// A thread-pooled, batching routine server over one shared [`Pipeline`]
/// and one shared [`Backend`]. Dropping the server drains the queue,
/// answers every outstanding request, and joins the worker threads.
pub struct RoutineServer {
    shared: Arc<ServerShared>,
}

impl RoutineServer {
    pub fn new(
        pipeline: Arc<Pipeline>,
        backend: Arc<dyn Backend>,
        cfg: ServeConfig,
    ) -> RoutineServer {
        let cfg = cfg.normalized();
        let shared = Arc::new(ServerShared {
            pipeline,
            backend,
            pool: PoolState::new(cfg.workers),
            cfg,
            queue: Mutex::new(QueueState::default()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            idle: Condvar::new(),
            shutdown: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            stats: ShardedStats::new(),
            counters: Counters::default(),
            workers: Mutex::new(Vec::new()),
            first_submit: OnceLock::new(),
        });
        {
            let mut handles = shared.workers.lock().expect("serve workers poisoned");
            for i in 0..shared.cfg.workers {
                handles.push(spawn_worker(&shared, i));
            }
        }
        RoutineServer { shared }
    }

    /// Enqueue one request with default options; under the `Block` policy
    /// this blocks while the queue is at capacity. On a draining/shut-down
    /// server the returned ticket resolves immediately to a structured
    /// rejection (it never hangs).
    pub fn submit(&self, spec: &Spec, inputs: ExecInputs) -> Ticket {
        self.submit_with(spec, inputs, RequestOpts::default())
    }

    /// [`RoutineServer::submit`] with tenant/priority/deadline options.
    pub fn submit_with(&self, spec: &Spec, inputs: ExecInputs, opts: RequestOpts) -> Ticket {
        match self.admit(spec, inputs, opts, true) {
            SubmitOutcome::Accepted(ticket) => ticket,
            SubmitOutcome::Shed(reason) => Ticket::rejected(reason),
        }
    }

    /// Non-blocking submit: where `submit` would block (or enqueue), this
    /// either accepts the request or tells the caller exactly why it was
    /// refused. Never waits, regardless of policy.
    pub fn try_submit(&self, spec: &Spec, inputs: ExecInputs, opts: RequestOpts) -> SubmitOutcome {
        self.admit(spec, inputs, opts, false)
    }

    fn admit(
        &self,
        spec: &Spec,
        inputs: ExecInputs,
        opts: RequestOpts,
        may_block: bool,
    ) -> SubmitOutcome {
        let now = Instant::now();
        self.shared.first_submit.get_or_init(|| now);
        if opts.deadline.is_some_and(|d| d <= now) {
            // screen pre-queue: an already-expired request would only be
            // dropped at dequeue — shed it before it occupies a slot.
            self.shared.counters.shed(ShedReason::DeadlineExpired);
            return SubmitOutcome::Shed(ShedReason::DeadlineExpired);
        }
        let (tx, rx) = mpsc::channel();
        let req = Request {
            spec: spec.clone(),
            key: PlanKey::of(spec),
            inputs,
            enqueued: now,
            priority: opts.priority,
            tenant: opts.tenant.filter(|t| !t.is_empty()),
            deadline: opts.deadline,
            tx,
        };
        {
            let mut q = self.shared.queue.lock().expect("serve queue poisoned");
            loop {
                if self.shared.draining.load(Ordering::SeqCst) {
                    self.shared.counters.shed(ShedReason::Draining);
                    return SubmitOutcome::Shed(ShedReason::Draining);
                }
                match q.admit(&self.shared.cfg, &req) {
                    Admission::Admit => break,
                    Admission::Shed(reason) => {
                        self.shared.counters.shed(reason);
                        return SubmitOutcome::Shed(reason);
                    }
                    Admission::Full if may_block => {
                        q = self.shared.not_full.wait(q).expect("serve queue poisoned");
                    }
                    Admission::Full => {
                        self.shared.counters.shed(ShedReason::QueueFull);
                        return SubmitOutcome::Shed(ShedReason::QueueFull);
                    }
                }
            }
            q.push(req);
        }
        self.shared.not_empty.notify_all();
        SubmitOutcome::Accepted(Ticket { rx })
    }

    /// Submit every request, then wait for all responses (in order).
    pub fn serve_all(&self, requests: Vec<(Spec, ExecInputs)>) -> Vec<Result<ExecOutcome>> {
        let tickets: Vec<Ticket> =
            requests.into_iter().map(|(spec, inputs)| self.submit(&spec, inputs)).collect();
        tickets.into_iter().map(Ticket::wait).collect()
    }

    /// Graceful drain: stop admissions, let the pool settle queued and
    /// in-flight work, and wait up to `timeout` for the server to go
    /// idle. Returns `true` when everything settled; on timeout the
    /// still-queued stragglers are answered with a structured error
    /// (counted as `drain_purged`) and `false` is returned. Either way
    /// the server afterwards rejects every submit with
    /// [`ShedReason::Draining`]; `join`/drop remain the shutdown path.
    pub fn drain(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut q = self.shared.queue.lock().expect("serve queue poisoned");
        self.shared.draining.store(true, Ordering::SeqCst);
        // blocked submitters must re-check the flag; workers parked on an
        // empty queue are left to their idle timeouts.
        self.shared.not_full.notify_all();
        while !q.is_idle() {
            let now = Instant::now();
            if now >= deadline {
                let stragglers = q.drain_all();
                drop(q);
                self.shared
                    .counters
                    .drain_purged
                    .fetch_add(stragglers.len() as u64, Ordering::Relaxed);
                answer_failed(&self.shared, &stragglers, "server drained before request ran", 0);
                return false;
            }
            let (guard, _) =
                self.shared.idle.wait_timeout(q, deadline - now).expect("serve queue poisoned");
            q = guard;
        }
        true
    }

    /// Snapshot the server's queueing/batching/latency/hardening
    /// statistics. Percentile sorts happen on a clone, outside the stats
    /// lock, so reporting never stalls the dispatchers.
    pub fn report(&self) -> ServeReport {
        let snap = self.shared.stats.snapshot();
        let wall_s = match (self.shared.first_submit.get(), snap.last_done) {
            (Some(t0), Some(t1)) => t1.duration_since(*t0).as_secs_f64(),
            _ => 0.0,
        };
        metrics::build_report(
            snap,
            wall_s,
            self.shared.pipeline.cache().stats(),
            &self.shared.counters,
            &self.shared.pool,
            &self.shared.cfg,
        )
    }

    /// The shared pipeline (and its plan cache) behind this server.
    pub fn pipeline(&self) -> &Arc<Pipeline> {
        &self.shared.pipeline
    }

    /// Shut down: drain the queue, answer everything, join the workers,
    /// and return the final report.
    pub fn join(self) -> ServeReport {
        self.shutdown_and_join();
        self.report()
    }

    fn shutdown_and_join(&self) {
        {
            // both flags flip under the queue lock: a submitter between
            // its draining-check and its wait, or a worker between its
            // empty-check and its wait, cannot miss them.
            let _q = self.shared.queue.lock().expect("serve queue poisoned");
            self.shared.draining.store(true, Ordering::SeqCst);
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        self.shared.idle.notify_all();
        // growers push handles under the workers lock and re-check
        // `shutdown` inside it, so looping take-then-join (join outside
        // the lock, or a grower would deadlock) catches every spawn.
        loop {
            let handles =
                std::mem::take(&mut *self.shared.workers.lock().expect("serve workers poisoned"));
            if handles.is_empty() {
                break;
            }
            for handle in handles {
                let _ = handle.join();
            }
        }
    }
}

impl Drop for RoutineServer {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

fn spawn_worker(shared: &Arc<ServerShared>, id: usize) -> JoinHandle<()> {
    let shared = shared.clone();
    std::thread::Builder::new()
        .name(format!("aieblas-serve-{id}"))
        .spawn(move || worker_loop(&shared, id))
        .expect("spawn serve worker")
}

fn worker_loop(shared: &Arc<ServerShared>, id: usize) {
    // how long an idle worker waits before considering retirement.
    let idle_window = (shared.cfg.target_queue_wait * 8).max(Duration::from_millis(20));
    // this dispatcher's stats shard: effectively private in steady state
    // (pool widths stay well under STATS_SHARDS), so per-batch stats
    // updates never serialize the pool (DESIGN.md §12).
    let shard = id % metrics::STATS_SHARDS;
    // scratch reused across iterations — warm-path dispatch allocates no
    // fresh control-plane buffers per batch.
    let mut batch: Vec<Request> = Vec::new();
    let mut expired: Vec<Request> = Vec::new();
    let mut inputs_scratch: Vec<ExecInputs> = Vec::new();
    loop {
        {
            let mut q = shared.queue.lock().expect("serve queue poisoned");
            // seed: highest-priority oldest request, diverting any whose
            // deadline passed while queued (answered below, without
            // wasting a backend run on them).
            loop {
                let now = Instant::now();
                while let Some(req) = q.pop() {
                    if req.expired(now) {
                        expired.push(req);
                    } else {
                        batch.push(req);
                        break;
                    }
                }
                if !batch.is_empty() || !expired.is_empty() {
                    shared.not_full.notify_all();
                    break;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let (guard, timeout) = shared
                    .not_empty
                    .wait_timeout(q, idle_window)
                    .expect("serve queue poisoned");
                q = guard;
                if timeout.timed_out() && q.is_empty() && try_retire(shared) {
                    return;
                }
            }

            // coalesce: pull every queued same-key request from every
            // lane (other keys stay for the other dispatchers), lingering
            // for stragglers until the batch fills or the deadline
            // passes. Each lane keeps a resume index: the scanned prefix
            // is other-key, and new arrivals only append at the back.
            // Another dispatcher removing ahead of an index while we wait
            // can shift an unscanned entry into the prefix — that entry
            // is merely coalesced into a later batch, never lost.
            if !batch.is_empty() {
                let key = batch[0].key.clone();
                let linger_deadline = Instant::now() + shared.cfg.linger;
                let mut scanned = [0usize; 3];
                loop {
                    let now = Instant::now();
                    for (lane, idx) in scanned.iter_mut().enumerate() {
                        while batch.len() < shared.cfg.max_batch {
                            match q.take_matching(lane, idx, &key) {
                                Some(req) if req.expired(now) => {
                                    expired.push(req);
                                    shared.not_full.notify_all();
                                }
                                Some(req) => {
                                    batch.push(req);
                                    shared.not_full.notify_all();
                                }
                                None => break,
                            }
                        }
                    }
                    if batch.len() >= shared.cfg.max_batch
                        || shared.shutdown.load(Ordering::SeqCst)
                        || now >= linger_deadline
                    {
                        break;
                    }
                    let (guard, _) = shared
                        .not_empty
                        .wait_timeout(q, linger_deadline - now)
                        .expect("serve queue poisoned");
                    q = guard;
                }
            }
        }
        if !expired.is_empty() {
            shared.counters.deadline_missed.fetch_add(expired.len() as u64, Ordering::Relaxed);
            answer_failed(
                shared,
                &expired,
                "deadline expired before execution; request dropped",
                shard,
            );
            expired.clear();
        }
        if !batch.is_empty() {
            dispatch_batch(shared, &mut batch, &mut inputs_scratch, shard);
            maybe_grow(shared);
        }
    }
}

/// Try to leave the pool: succeeds only while more than `min_workers`
/// dispatchers are active, so the pool shrinks back when load subsides.
fn try_retire(shared: &ServerShared) -> bool {
    let retired = shared
        .pool
        .active
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
            if n > shared.cfg.min_workers {
                Some(n - 1)
            } else {
                None
            }
        })
        .is_ok();
    if retired {
        shared.counters.pool_shrunk.fetch_add(1, Ordering::Relaxed);
    }
    retired
}

/// Grow the pool by one worker when the queue-wait EWMA says requests
/// are waiting longer than `target_queue_wait` and there is a backlog.
fn maybe_grow(shared: &Arc<ServerShared>) {
    if shared.cfg.min_workers == shared.cfg.max_workers {
        return; // fixed pool
    }
    if shared.shutdown.load(Ordering::SeqCst) || shared.draining.load(Ordering::SeqCst) {
        return;
    }
    if shared.pool.wait_ewma() <= shared.cfg.target_queue_wait.as_secs_f64() {
        return;
    }
    {
        let q = shared.queue.lock().expect("serve queue poisoned");
        if q.is_empty() {
            return;
        }
    }
    let mut handles = shared.workers.lock().expect("serve workers poisoned");
    // re-checked INSIDE the workers lock: shutdown_and_join sets the flag
    // before its first take, so a grower that sees it false here will
    // have pushed its handle before the joiner's (repeated) take.
    if shared.shutdown.load(Ordering::SeqCst) {
        return;
    }
    let grown = shared
        .pool
        .active
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
            if n < shared.cfg.max_workers {
                Some(n + 1)
            } else {
                None
            }
        })
        .is_ok();
    if grown {
        let id = handles.len();
        handles.push(spawn_worker(shared, id));
        shared.counters.pool_grown.fetch_add(1, Ordering::Relaxed);
    }
}

/// Answer every request in `reqs` with a structured runtime error,
/// recording them into stats shard `shard` as completed+failed (they
/// were admitted, so they count toward `requests`, keeping
/// `attempts == requests + shed` exact).
fn answer_failed(shared: &ServerShared, reqs: &[Request], msg: &str, shard: usize) {
    let done = Instant::now();
    {
        let mut stats = shared.stats.shard(shard).lock().expect("serve stats poisoned");
        for req in reqs {
            let elapsed = done.duration_since(req.enqueued).as_secs_f64();
            stats.record_request(req.priority, req.tenant.as_deref(), elapsed, elapsed, true, done);
        }
    }
    for req in reqs {
        let _ = req.tx.send(Err(Error::Runtime(msg.to_string())));
    }
    note_answered(shared, reqs);
}

/// Account answered requests against the queue ledger (releases tenant
/// quota slots; flips `is_idle` for `drain`).
fn note_answered(shared: &ServerShared, reqs: &[Request]) {
    let idle = {
        let mut q = shared.queue.lock().expect("serve queue poisoned");
        for req in reqs {
            q.note_done(req);
        }
        q.is_idle()
    };
    if idle {
        shared.idle.notify_all();
    }
    // freed tenant-quota slots can unblock waiting submitters.
    shared.not_full.notify_all();
}

/// Dispatch one coalesced batch and answer every request in it. `batch`
/// and `inputs_scratch` are the calling dispatcher's reusable scratch:
/// both are left empty on return, and the consumed per-request input
/// vectors are recycled into this thread's buffer pool (`util::pool`)
/// where the backend's next dispatch draws its output buffers from.
fn dispatch_batch(
    shared: &Arc<ServerShared>,
    batch: &mut Vec<Request>,
    inputs_scratch: &mut Vec<ExecInputs>,
    shard: usize,
) {
    let dequeued = Instant::now();
    let per_request_err = |msg: &str, n: usize| -> Vec<Result<ExecOutcome>> {
        (0..n).map(|_| Err(Error::Runtime(msg.to_string()))).collect()
    };
    // inputs move out of the requests before the unwind-isolated attempt
    // so the closure only borrows them immutably — they are reclaimed for
    // the pool below no matter how the attempt ends.
    inputs_scratch.clear();
    inputs_scratch.extend(batch.iter_mut().map(|r| std::mem::take(&mut r.inputs)));
    let inputs: &[ExecInputs] = inputs_scratch;
    // lower once per batch (single-flight dedups concurrent cold lowerings
    // from other dispatchers), then execute. A panicking backend must not
    // kill this dispatcher — queued requests would never be answered — so
    // the whole attempt is unwind-isolated and turned into per-request
    // errors.
    let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        shared
            .pipeline
            .lower_keyed(&batch[0].key, &batch[0].spec)
            .and_then(|plan| shared.backend.prepare(plan))
            .map(|prepared| shared.backend.execute_batch(&prepared, inputs))
    }));
    let outcomes: Vec<Result<ExecOutcome>> = match attempt {
        Ok(Ok(outcomes)) if outcomes.len() == batch.len() => outcomes,
        // a backend returning the wrong outcome count would leave zipped-
        // away requests hanging in Ticket::wait; fail the whole batch.
        Ok(Ok(outcomes)) => per_request_err(
            &format!(
                "backend returned {} outcome(s) for {} request(s)",
                outcomes.len(),
                batch.len()
            ),
            batch.len(),
        ),
        Ok(Err(e)) => per_request_err(&e.to_string(), batch.len()),
        Err(_) => per_request_err("backend panicked while executing batch", batch.len()),
    };
    let done = Instant::now();
    let mut wait_sum = 0.0;
    {
        let mut stats = shared.stats.shard(shard).lock().expect("serve stats poisoned");
        stats.batches += 1;
        stats.batch_size_sum += batch.len() as u64;
        stats.max_batch = stats.max_batch.max(batch.len());
        for (req, outcome) in batch.iter().zip(&outcomes) {
            let wait_s = dequeued.duration_since(req.enqueued).as_secs_f64();
            wait_sum += wait_s;
            stats.record_request(
                req.priority,
                req.tenant.as_deref(),
                done.duration_since(req.enqueued).as_secs_f64(),
                wait_s,
                outcome.is_err(),
                done,
            );
        }
    }
    shared.pool.observe_wait(wait_sum / batch.len() as f64);
    for (req, outcome) in batch.iter().zip(outcomes) {
        // a dropped Ticket just means the caller stopped caring.
        let _ = req.tx.send(outcome);
    }
    note_answered(shared, batch.as_slice());
    // the consumed request inputs are dead here (outputs left with the
    // responses); feed their allocations back to this thread's pool.
    for inputs in inputs_scratch.drain(..) {
        for routine_inputs in inputs.per_routine {
            for buf in routine_inputs {
                crate::util::pool::recycle(buf);
            }
        }
    }
    batch.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::blas::RoutineKind;
    use crate::runtime::{CpuBackend, SlowBackend};
    use crate::spec::DataSource;

    fn server(cfg: ServeConfig) -> RoutineServer {
        RoutineServer::new(
            Arc::new(Pipeline::new(ArchConfig::vck5000())),
            Arc::new(CpuBackend),
            cfg,
        )
    }

    fn slow_server(cfg: ServeConfig, delay: Duration) -> RoutineServer {
        RoutineServer::new(
            Arc::new(Pipeline::new(ArchConfig::vck5000())),
            Arc::new(SlowBackend::new(CpuBackend, delay)),
            cfg,
        )
    }

    #[test]
    fn single_request_round_trips() {
        let srv = server(ServeConfig::default());
        let spec = Spec::single(RoutineKind::Axpy, "a", 1024, DataSource::Pl);
        let inputs = ExecInputs::random_for(&spec, 1);
        let outcome = srv.submit(&spec, inputs).wait().unwrap();
        assert_eq!(outcome.backend, "cpu");
        assert_eq!(outcome.results.len(), 1);
        let report = srv.join();
        assert_eq!(report.requests, 1);
        assert_eq!(report.failed, 0);
        assert_eq!(report.batches, 1);
        assert_eq!(report.cache.misses, 1);
        assert_eq!(report.metrics.shed_total(), 0);
    }

    #[test]
    fn invalid_spec_fails_per_request_not_server() {
        let srv = server(ServeConfig::default());
        let bad = Spec { routines: vec![], ..Default::default() };
        let good = Spec::single(RoutineKind::Dot, "d", 256, DataSource::Pl);
        let bad_ticket = srv.submit(&bad, ExecInputs::default());
        let good_ticket = srv.submit(&good, ExecInputs::random_for(&good, 2));
        assert!(bad_ticket.wait().is_err());
        assert!(good_ticket.wait().is_ok(), "server must survive failed requests");
        let report = srv.join();
        assert_eq!((report.requests, report.failed), (2, 1));
    }

    #[test]
    fn drop_drains_outstanding_requests() {
        let spec = Spec::single(RoutineKind::Scal, "s", 512, DataSource::Pl);
        let tickets: Vec<Ticket> = {
            let srv = server(ServeConfig { workers: 1, ..Default::default() });
            (0..16).map(|i| srv.submit(&spec, ExecInputs::random_for(&spec, i))).collect()
            // server dropped here with requests possibly still queued
        };
        for t in tickets {
            assert!(t.wait().is_ok(), "drop must answer queued requests, not abandon them");
        }
    }

    #[test]
    fn panicking_backend_fails_requests_without_killing_workers() {
        struct PanicBackend;
        impl Backend for PanicBackend {
            fn name(&self) -> &'static str {
                "panic"
            }
            fn prepare(
                &self,
                plan: Arc<crate::pipeline::ExecutablePlan>,
            ) -> crate::Result<crate::runtime::Prepared> {
                Ok(crate::runtime::Prepared::new(plan, self.name()))
            }
            fn execute(
                &self,
                _prepared: &crate::runtime::Prepared,
                _inputs: &ExecInputs,
            ) -> crate::Result<ExecOutcome> {
                panic!("injected backend panic")
            }
        }

        let srv = RoutineServer::new(
            Arc::new(Pipeline::new(ArchConfig::vck5000())),
            Arc::new(PanicBackend),
            ServeConfig { workers: 1, ..Default::default() },
        );
        let spec = Spec::single(RoutineKind::Dot, "d", 128, DataSource::Pl);
        // two sequential requests: if the first panic killed the only
        // worker, the second would hang forever instead of erroring.
        for i in 0..2 {
            let err = srv.submit(&spec, ExecInputs::random_for(&spec, i)).wait();
            match err {
                Err(Error::Runtime(msg)) => assert!(msg.contains("panicked"), "{msg}"),
                other => panic!("expected runtime error, got {other:?}"),
            }
        }
        let report = srv.join();
        assert_eq!((report.requests, report.failed), (2, 2));
    }

    #[test]
    fn try_submit_sheds_when_full_and_accounting_balances() {
        let srv = slow_server(
            ServeConfig {
                max_batch: 1,
                queue_capacity: 1,
                workers: 1,
                policy: AdmissionPolicy::RejectWhenFull,
                ..Default::default()
            },
            Duration::from_millis(50),
        );
        let spec = Spec::single(RoutineKind::Axpy, "a", 256, DataSource::Pl);
        let mut tickets = Vec::new();
        let mut shed = 0u64;
        for i in 0..16 {
            let inputs = ExecInputs::random_for(&spec, i);
            match srv.try_submit(&spec, inputs, RequestOpts::default()) {
                SubmitOutcome::Accepted(t) => tickets.push(t),
                SubmitOutcome::Shed(reason) => {
                    assert_eq!(reason, ShedReason::QueueFull);
                    shed += 1;
                }
            }
        }
        assert!(shed > 0, "a 1-deep queue over a 50 ms backend must shed rapid submits");
        for t in tickets {
            assert!(t.wait().is_ok());
        }
        let report = srv.join();
        assert_eq!(report.requests + report.metrics.shed_total(), 16);
        assert_eq!(report.metrics.shed_queue_full, shed);
    }

    #[test]
    fn watermark_reserves_headroom_for_high_priority() {
        let srv = slow_server(
            ServeConfig {
                max_batch: 1,
                queue_capacity: 8,
                workers: 1,
                policy: AdmissionPolicy::RejectAboveWatermark(2),
                linger: Duration::ZERO,
                ..Default::default()
            },
            Duration::from_millis(50),
        );
        let spec = Spec::single(RoutineKind::Dot, "d", 256, DataSource::Pl);
        // first request occupies the single worker for 50 ms.
        let blocker = srv.submit(&spec, ExecInputs::random_for(&spec, 0));
        let mut tickets = vec![blocker];
        let mut normal_shed = 0;
        for i in 1..6 {
            let inputs = ExecInputs::random_for(&spec, i);
            match srv.try_submit(&spec, inputs, RequestOpts::default()) {
                SubmitOutcome::Accepted(t) => tickets.push(t),
                SubmitOutcome::Shed(reason) => {
                    assert_eq!(reason, ShedReason::AboveWatermark);
                    normal_shed += 1;
                }
            }
        }
        assert!(normal_shed > 0, "normal traffic above the watermark must shed");
        // high priority is exempt from the watermark while the queue has room.
        let high = srv.try_submit(
            &spec,
            ExecInputs::random_for(&spec, 99),
            RequestOpts::default().with_priority(Priority::High),
        );
        assert!(high.is_accepted(), "high priority must pass the watermark");
        tickets.extend(high.ticket());
        for t in tickets {
            assert!(t.wait().is_ok());
        }
        let report = srv.join();
        assert_eq!(report.metrics.shed_watermark, normal_shed);
    }

    #[test]
    fn tenant_quota_caps_inflight_requests() {
        let srv = slow_server(
            ServeConfig {
                max_batch: 1,
                workers: 1,
                max_inflight_per_tenant: 2,
                ..Default::default()
            },
            Duration::from_millis(50),
        );
        let spec = Spec::single(RoutineKind::Scal, "s", 256, DataSource::Pl);
        let mut tickets = Vec::new();
        let mut quota_shed = 0;
        for i in 0..5 {
            let inputs = ExecInputs::random_for(&spec, i);
            let opts = RequestOpts::default().tenant("greedy");
            match srv.try_submit(&spec, inputs, opts) {
                SubmitOutcome::Accepted(t) => tickets.push(t),
                SubmitOutcome::Shed(reason) => {
                    assert_eq!(reason, ShedReason::TenantQuota);
                    quota_shed += 1;
                }
            }
        }
        assert_eq!(quota_shed, 3, "only 2 of 5 greedy-tenant requests may be in flight");
        // untenanted traffic is never quota-limited.
        let free = srv.try_submit(&spec, ExecInputs::random_for(&spec, 9), RequestOpts::default());
        assert!(free.is_accepted());
        tickets.extend(free.ticket());
        for t in tickets {
            assert!(t.wait().is_ok());
        }
        let report = srv.join();
        assert_eq!(report.metrics.shed_tenant_quota, 3);
    }

    #[test]
    fn deadlines_shed_at_submit_and_drop_at_dequeue() {
        let srv = slow_server(
            ServeConfig { max_batch: 1, workers: 1, ..Default::default() },
            Duration::from_millis(50),
        );
        let spec = Spec::single(RoutineKind::Axpy, "a", 256, DataSource::Pl);
        // already expired at submit: shed, never queued.
        let opts = RequestOpts {
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            ..Default::default()
        };
        let out = srv.try_submit(&spec, ExecInputs::random_for(&spec, 0), opts);
        assert_eq!(out.shed_reason(), Some(ShedReason::DeadlineExpired));
        // expires while queued behind the 50 ms blocker: dropped at
        // dequeue with a structured error, before any backend run.
        let blocker = srv.submit(&spec, ExecInputs::random_for(&spec, 1));
        let doomed = srv.submit_with(
            &spec,
            ExecInputs::random_for(&spec, 2),
            RequestOpts::default().with_deadline_in(Duration::from_millis(5)),
        );
        match doomed.wait() {
            Err(Error::Runtime(msg)) => assert!(msg.contains("deadline"), "{msg}"),
            other => panic!("expected deadline error, got {other:?}"),
        }
        assert!(blocker.wait().is_ok());
        let report = srv.join();
        assert_eq!(report.metrics.shed_deadline, 1);
        assert_eq!(report.metrics.deadline_missed, 1);
        // the missed request was admitted, so it counts as answered.
        assert_eq!(report.requests, 2);
        assert_eq!(report.failed, 1);
    }

    #[test]
    fn drain_stops_admissions_and_settles_inflight() {
        let srv = slow_server(
            ServeConfig { max_batch: 1, workers: 1, ..Default::default() },
            Duration::from_millis(20),
        );
        let spec = Spec::single(RoutineKind::Dot, "d", 256, DataSource::Pl);
        let t0 = srv.submit(&spec, ExecInputs::random_for(&spec, 0));
        let t1 = srv.submit(&spec, ExecInputs::random_for(&spec, 1));
        assert!(srv.drain(Duration::from_secs(30)), "pool must settle well within 30 s");
        assert!(t0.wait().is_ok());
        assert!(t1.wait().is_ok());
        // post-drain: blocking submit resolves to a structured rejection
        // (regression: used to enqueue and hang forever).
        match srv.submit(&spec, ExecInputs::random_for(&spec, 2)).wait() {
            Err(Error::Runtime(msg)) => assert!(msg.contains("draining"), "{msg}"),
            other => panic!("expected draining rejection, got {other:?}"),
        }
        let out = srv.try_submit(&spec, ExecInputs::random_for(&spec, 3), RequestOpts::default());
        assert_eq!(out.shed_reason(), Some(ShedReason::Draining));
        let report = srv.join();
        assert_eq!(report.requests, 2);
        assert_eq!(report.metrics.shed_draining, 2);
    }

    #[test]
    fn drain_timeout_purges_stragglers_with_structured_error() {
        let srv = slow_server(
            ServeConfig { max_batch: 1, workers: 1, ..Default::default() },
            Duration::from_millis(50),
        );
        let spec = Spec::single(RoutineKind::Scal, "s", 256, DataSource::Pl);
        let tickets: Vec<Ticket> =
            (0..3).map(|i| srv.submit(&spec, ExecInputs::random_for(&spec, i))).collect();
        assert!(!srv.drain(Duration::ZERO), "zero-timeout drain over a busy pool must purge");
        for t in tickets {
            match t.wait() {
                Ok(_) => {}
                Err(Error::Runtime(msg)) => {
                    assert!(msg.contains("drained"), "{msg}");
                }
                other => panic!("unexpected outcome {other:?}"),
            }
        }
        let report = srv.join();
        // every admitted request was answered — executed or purged.
        assert_eq!(report.requests, 3);
        assert!(report.metrics.drain_purged >= 2, "at least the queued stragglers are purged");
    }

    #[test]
    fn wait_timeout_bounds_exposure_then_still_delivers() {
        let srv = slow_server(
            ServeConfig { max_batch: 1, workers: 1, ..Default::default() },
            Duration::from_millis(50),
        );
        let spec = Spec::single(RoutineKind::Axpy, "a", 256, DataSource::Pl);
        let ticket = srv.submit(&spec, ExecInputs::random_for(&spec, 0));
        match ticket.wait_timeout(Duration::from_millis(1)) {
            Err(Error::Runtime(msg)) => assert!(msg.contains("timed out"), "{msg}"),
            other => panic!("expected timeout, got {other:?}"),
        }
        // the ticket is still live: the response arrives on a later wait.
        assert!(ticket.wait_timeout(Duration::from_secs(30)).is_ok());
        srv.join();
    }

    #[test]
    fn adaptive_pool_grows_under_backlog() {
        let srv = slow_server(
            ServeConfig {
                max_batch: 1,
                workers: 1,
                min_workers: 1,
                max_workers: 3,
                target_queue_wait: Duration::from_micros(50),
                ..Default::default()
            },
            Duration::from_millis(20),
        );
        // distinct sizes defeat coalescing, forcing a backlog.
        let tickets: Vec<Ticket> = (0..8)
            .map(|i| {
                let spec = Spec::single(RoutineKind::Axpy, "a", 256 + 16 * i, DataSource::Pl);
                let inputs = ExecInputs::random_for(&spec, i as u64);
                srv.submit(&spec, inputs)
            })
            .collect();
        for t in tickets {
            assert!(t.wait().is_ok());
        }
        let report = srv.join();
        assert!(
            report.metrics.pool_grown >= 1,
            "a 20 ms-per-request backlog over a 50 µs target must grow the pool (metrics: {:?})",
            report.metrics
        );
    }

    #[test]
    fn report_json_round_trips() {
        let srv = server(ServeConfig::default());
        let spec = Spec::single(RoutineKind::Dot, "d", 128, DataSource::Pl);
        srv.submit(&spec, ExecInputs::random_for(&spec, 0)).wait().unwrap();
        let report = srv.join();
        let text = report.to_json().to_pretty();
        let parsed = crate::util::json::Json::parse(&text).expect("report JSON must parse");
        match parsed {
            crate::util::json::Json::Obj(pairs) => {
                assert!(pairs.iter().any(|(k, _)| k == "metrics"));
                assert!(pairs.iter().any(|(k, _)| k == "throughput_rps"));
            }
            other => panic!("expected object, got {other:?}"),
        }
        assert!(report.summary().contains("served 1 request(s)"));
    }
}
