//! The serving layer (DESIGN.md §9): queue → batcher → backend pool.
//!
//! [`RoutineServer`] is the host-side front door the ROADMAP's
//! "heavy traffic" north-star asks for: callers submit `(Spec, ExecInputs)`
//! requests and get per-request [`ExecOutcome`]s back, while the server
//!
//! 1. **queues** requests in a bounded queue (back-pressure: `submit`
//!    blocks when `queue_capacity` is reached),
//! 2. **batches** them — a dispatcher that dequeues a request coalesces
//!    every queued request with the same plan-cache key into one batch (up
//!    to `max_batch`, lingering up to `linger` for stragglers), and
//! 3. **dispatches** each batch to a shared [`Backend`] via
//!    `execute_batch`, so per-plan setup — and for the simulator the whole
//!    DES run — is paid once per batch instead of once per request.
//!
//! Lowering goes through a shared [`Pipeline`], so cold specs are
//! single-flight across every dispatcher thread and warm specs are plan
//! cache hits. Queueing, batching and latency statistics are surfaced in a
//! [`ServeReport`].

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::pipeline::{CacheStats, Pipeline, PlanKey};
use crate::runtime::{Backend, ExecInputs, ExecOutcome};
use crate::spec::Spec;
use crate::{Error, Result};

/// Serving-layer knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Largest batch one dispatch may coalesce (1 disables batching).
    pub max_batch: usize,
    /// How long a dispatcher waits for same-key stragglers before
    /// dispatching a non-full batch. Zero still coalesces whatever is
    /// already queued.
    pub linger: Duration,
    /// Bounded queue depth; `submit` blocks (back-pressure) when full.
    pub queue_capacity: usize,
    /// Dispatcher threads draining the queue (the backend pool width).
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            linger: Duration::from_micros(500),
            queue_capacity: 256,
            workers: 2,
        }
    }
}

/// One queued request. `key` is interned at submit time ([`PlanKey`]):
/// the batcher's queue scans compare hashes, and the dispatcher hands the
/// same key to the pipeline — the canonical JSON is rendered and hashed
/// exactly once per request.
struct Request {
    spec: Spec,
    key: PlanKey,
    inputs: ExecInputs,
    enqueued: Instant,
    tx: mpsc::Sender<Result<ExecOutcome>>,
}

/// A handle to one submitted request.
pub struct Ticket {
    rx: mpsc::Receiver<Result<ExecOutcome>>,
}

impl Ticket {
    /// Block until the server has executed (or failed) the request.
    pub fn wait(self) -> Result<ExecOutcome> {
        match self.rx.recv() {
            Ok(outcome) => outcome,
            Err(_) => Err(Error::Runtime("request dropped by server".into())),
        }
    }
}

/// Latency/queue-wait samples kept for percentile reporting. A ring of
/// the most recent samples bounds server memory (and `report()`'s sort)
/// regardless of how many requests a long-lived server answers.
const STAT_SAMPLE_CAP: usize = 65_536;

#[derive(Default)]
struct StatsInner {
    completed: u64,
    failed: u64,
    batches: u64,
    batch_size_sum: u64,
    max_batch: usize,
    /// Per-request submit→response seconds (most recent `STAT_SAMPLE_CAP`).
    latencies: Vec<f64>,
    /// Per-request submit→dequeue seconds (most recent `STAT_SAMPLE_CAP`).
    queue_waits: Vec<f64>,
    last_done: Option<Instant>,
}

/// Record into a bounded ring: grow until the cap, then overwrite the
/// slot of the `count`-th request (oldest-first).
fn record_sample(samples: &mut Vec<f64>, count: u64, value: f64) {
    if samples.len() < STAT_SAMPLE_CAP {
        samples.push(value);
    } else {
        samples[(count % STAT_SAMPLE_CAP as u64) as usize] = value;
    }
}

/// Queueing/batching/latency statistics for one server's lifetime.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Requests answered (including failures).
    pub requests: u64,
    /// Requests answered with an error.
    pub failed: u64,
    /// Batches dispatched to the backend.
    pub batches: u64,
    /// Mean coalesced batch size (requests / batches).
    pub mean_batch: f64,
    /// Largest batch dispatched.
    pub max_batch: usize,
    /// Median submit→response latency, seconds (over a bounded window of
    /// the most recent `STAT_SAMPLE_CAP` requests).
    pub p50_latency_s: f64,
    /// 99th-percentile submit→response latency, seconds (same window).
    pub p99_latency_s: f64,
    /// Median submit→dequeue wait, seconds (queueing delay, same window).
    pub p50_queue_wait_s: f64,
    /// First submit → last response span, seconds.
    pub wall_s: f64,
    /// Requests per second over `wall_s`.
    pub throughput_rps: f64,
    /// Shared plan-cache counters (hits/misses/evictions/coalesced).
    pub cache: CacheStats,
}

impl ServeReport {
    pub fn summary(&self) -> String {
        let mut s = format!(
            "served {} request(s) ({} failed) in {} batch(es), mean batch {:.2} (max {})\n\
             latency p50 {:.3} ms / p99 {:.3} ms, queue wait p50 {:.3} ms\n\
             throughput {:.0} req/s over {:.3} s\n\
             plan cache: {} hit(s) ({} coalesced) / {} miss(es), {} eviction(s), {} resident\n\
             plan store: {} disk hit(s), {} write(s), {} rejected",
            self.requests,
            self.failed,
            self.batches,
            self.mean_batch,
            self.max_batch,
            self.p50_latency_s * 1e3,
            self.p99_latency_s * 1e3,
            self.p50_queue_wait_s * 1e3,
            self.throughput_rps,
            self.wall_s,
            self.cache.hits,
            self.cache.coalesced,
            self.cache.misses,
            self.cache.evictions,
            self.cache.entries,
            self.cache.disk_hits,
            self.cache.disk_writes,
            self.cache.rejected,
        );
        if self.cache.tuned + self.cache.tune_skipped > 0 {
            s.push_str(&format!(
                "\nautotuner: {} tuned lowering(s), {} tuned warm start(s)",
                self.cache.tuned, self.cache.tune_skipped
            ));
        }
        s
    }
}

struct ServerShared {
    pipeline: Arc<Pipeline>,
    backend: Arc<dyn Backend>,
    cfg: ServeConfig,
    queue: Mutex<VecDeque<Request>>,
    not_empty: Condvar,
    not_full: Condvar,
    shutdown: AtomicBool,
    stats: Mutex<StatsInner>,
    /// Set once by the first `submit` (lock-free afterwards); anchors the
    /// report's throughput span.
    first_submit: OnceLock<Instant>,
}

/// A thread-pooled, batching routine server over one shared [`Pipeline`]
/// and one shared [`Backend`]. Dropping the server drains the queue,
/// answers every outstanding request, and joins the worker threads.
pub struct RoutineServer {
    shared: Arc<ServerShared>,
    workers: Vec<JoinHandle<()>>,
}

impl RoutineServer {
    pub fn new(
        pipeline: Arc<Pipeline>,
        backend: Arc<dyn Backend>,
        cfg: ServeConfig,
    ) -> RoutineServer {
        let cfg = ServeConfig {
            max_batch: cfg.max_batch.max(1),
            queue_capacity: cfg.queue_capacity.max(1),
            workers: cfg.workers.max(1),
            ..cfg
        };
        let shared = Arc::new(ServerShared {
            pipeline,
            backend,
            cfg,
            queue: Mutex::new(VecDeque::new()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            shutdown: AtomicBool::new(false),
            stats: Mutex::new(StatsInner::default()),
            first_submit: OnceLock::new(),
        });
        let workers = (0..shared.cfg.workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("aieblas-serve-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn serve worker")
            })
            .collect();
        RoutineServer { shared, workers }
    }

    /// Enqueue one request; blocks while the queue is at capacity.
    pub fn submit(&self, spec: &Spec, inputs: ExecInputs) -> Ticket {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        self.shared.first_submit.get_or_init(|| now);
        let req =
            Request { spec: spec.clone(), key: PlanKey::of(spec), inputs, enqueued: now, tx };
        {
            let mut q = self.shared.queue.lock().expect("serve queue poisoned");
            while q.len() >= self.shared.cfg.queue_capacity {
                q = self.shared.not_full.wait(q).expect("serve queue poisoned");
            }
            q.push_back(req);
        }
        self.shared.not_empty.notify_all();
        Ticket { rx }
    }

    /// Submit every request, then wait for all responses (in order).
    pub fn serve_all(&self, requests: Vec<(Spec, ExecInputs)>) -> Vec<Result<ExecOutcome>> {
        let tickets: Vec<Ticket> =
            requests.into_iter().map(|(spec, inputs)| self.submit(&spec, inputs)).collect();
        tickets.into_iter().map(Ticket::wait).collect()
    }

    /// Snapshot the server's queueing/batching/latency statistics.
    pub fn report(&self) -> ServeReport {
        let stats = self.shared.stats.lock().expect("serve stats poisoned");
        let mut latencies = stats.latencies.clone();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut waits = stats.queue_waits.clone();
        waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let wall_s = match (self.shared.first_submit.get(), stats.last_done) {
            (Some(t0), Some(t1)) => t1.duration_since(*t0).as_secs_f64(),
            _ => 0.0,
        };
        ServeReport {
            requests: stats.completed,
            failed: stats.failed,
            batches: stats.batches,
            mean_batch: if stats.batches == 0 {
                0.0
            } else {
                stats.batch_size_sum as f64 / stats.batches as f64
            },
            max_batch: stats.max_batch,
            p50_latency_s: percentile(&latencies, 50.0),
            p99_latency_s: percentile(&latencies, 99.0),
            p50_queue_wait_s: percentile(&waits, 50.0),
            wall_s,
            throughput_rps: if wall_s > 0.0 { stats.completed as f64 / wall_s } else { 0.0 },
            cache: self.shared.pipeline.cache().stats(),
        }
    }

    /// The shared pipeline (and its plan cache) behind this server.
    pub fn pipeline(&self) -> &Arc<Pipeline> {
        &self.shared.pipeline
    }

    /// Shut down: drain the queue, answer everything, join the workers,
    /// and return the final report.
    pub fn join(mut self) -> ServeReport {
        self.shutdown_and_join();
        self.report()
    }

    fn shutdown_and_join(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // take-and-release the queue lock so no worker misses the flag
        // between its empty-check and its wait.
        drop(self.shared.queue.lock().expect("serve queue poisoned"));
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for RoutineServer {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

/// `p`th percentile of an ascending-sorted series (nearest-rank).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn worker_loop(shared: &ServerShared) {
    loop {
        let mut batch: Vec<Request> = Vec::new();
        {
            let mut q = shared.queue.lock().expect("serve queue poisoned");
            loop {
                if let Some(first) = q.pop_front() {
                    batch.push(first);
                    break;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.not_empty.wait(q).expect("serve queue poisoned");
            }
            shared.not_full.notify_all();

            // coalesce: pull every queued same-key request (other keys stay
            // for the other dispatchers), lingering for stragglers until
            // the batch fills or the deadline passes.
            let deadline = Instant::now() + shared.cfg.linger;
            // the prefix [0, i) has been scanned and is other-key; new
            // arrivals only append at the back, so each linger wakeup
            // resumes the scan instead of rescanning the whole queue under
            // the lock. Another dispatcher removing ahead of `i` while we
            // wait can shift an unscanned entry into the prefix — that
            // entry is merely coalesced into a later batch, never lost.
            let mut i = 0;
            loop {
                while batch.len() < shared.cfg.max_batch && i < q.len() {
                    if q[i].key == batch[0].key {
                        batch.push(q.remove(i).expect("index checked"));
                        shared.not_full.notify_all();
                    } else {
                        i += 1;
                    }
                }
                if batch.len() >= shared.cfg.max_batch || shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = shared
                    .not_empty
                    .wait_timeout(q, deadline - now)
                    .expect("serve queue poisoned");
                q = guard;
            }
        }
        dispatch_batch(shared, batch);
    }
}

fn dispatch_batch(shared: &ServerShared, mut batch: Vec<Request>) {
    let dequeued = Instant::now();
    let per_request_err = |msg: &str, n: usize| -> Vec<Result<ExecOutcome>> {
        (0..n).map(|_| Err(Error::Runtime(msg.to_string()))).collect()
    };
    // lower once per batch (single-flight dedups concurrent cold lowerings
    // from other dispatchers), then execute. A panicking backend must not
    // kill this dispatcher — queued requests would never be answered — so
    // the whole attempt is unwind-isolated and turned into per-request
    // errors.
    let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        shared
            .pipeline
            .lower_keyed(&batch[0].key, &batch[0].spec)
            .and_then(|plan| shared.backend.prepare(plan))
            .map(|prepared| {
                let inputs: Vec<ExecInputs> =
                    batch.iter_mut().map(|r| std::mem::take(&mut r.inputs)).collect();
                shared.backend.execute_batch(&prepared, &inputs)
            })
    }));
    let outcomes: Vec<Result<ExecOutcome>> = match attempt {
        Ok(Ok(outcomes)) if outcomes.len() == batch.len() => outcomes,
        // a backend returning the wrong outcome count would leave zipped-
        // away requests hanging in Ticket::wait; fail the whole batch.
        Ok(Ok(outcomes)) => per_request_err(
            &format!(
                "backend returned {} outcome(s) for {} request(s)",
                outcomes.len(),
                batch.len()
            ),
            batch.len(),
        ),
        Ok(Err(e)) => per_request_err(&e.to_string(), batch.len()),
        Err(_) => per_request_err("backend panicked while executing batch", batch.len()),
    };
    let done = Instant::now();
    let mut stats = shared.stats.lock().expect("serve stats poisoned");
    stats.batches += 1;
    stats.batch_size_sum += batch.len() as u64;
    stats.max_batch = stats.max_batch.max(batch.len());
    // monotonic: a late-locking worker with an earlier completion must not
    // move the span's end backwards (it would inflate throughput_rps).
    stats.last_done = Some(stats.last_done.map_or(done, |prev| prev.max(done)));
    for (req, outcome) in batch.into_iter().zip(outcomes) {
        let idx = stats.completed;
        stats.completed += 1;
        if outcome.is_err() {
            stats.failed += 1;
        }
        record_sample(&mut stats.latencies, idx, done.duration_since(req.enqueued).as_secs_f64());
        record_sample(
            &mut stats.queue_waits,
            idx,
            dequeued.duration_since(req.enqueued).as_secs_f64(),
        );
        // a dropped Ticket just means the caller stopped caring.
        let _ = req.tx.send(outcome);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::blas::RoutineKind;
    use crate::runtime::CpuBackend;
    use crate::spec::DataSource;

    fn server(cfg: ServeConfig) -> RoutineServer {
        RoutineServer::new(
            Arc::new(Pipeline::new(ArchConfig::vck5000())),
            Arc::new(CpuBackend),
            cfg,
        )
    }

    #[test]
    fn single_request_round_trips() {
        let srv = server(ServeConfig::default());
        let spec = Spec::single(RoutineKind::Axpy, "a", 1024, DataSource::Pl);
        let inputs = ExecInputs::random_for(&spec, 1);
        let outcome = srv.submit(&spec, inputs).wait().unwrap();
        assert_eq!(outcome.backend, "cpu");
        assert_eq!(outcome.results.len(), 1);
        let report = srv.join();
        assert_eq!(report.requests, 1);
        assert_eq!(report.failed, 0);
        assert_eq!(report.batches, 1);
        assert_eq!(report.cache.misses, 1);
    }

    #[test]
    fn invalid_spec_fails_per_request_not_server() {
        let srv = server(ServeConfig::default());
        let bad = Spec { routines: vec![], ..Default::default() };
        let good = Spec::single(RoutineKind::Dot, "d", 256, DataSource::Pl);
        let bad_ticket = srv.submit(&bad, ExecInputs::default());
        let good_ticket = srv.submit(&good, ExecInputs::random_for(&good, 2));
        assert!(bad_ticket.wait().is_err());
        assert!(good_ticket.wait().is_ok(), "server must survive failed requests");
        let report = srv.join();
        assert_eq!((report.requests, report.failed), (2, 1));
    }

    #[test]
    fn drop_drains_outstanding_requests() {
        let spec = Spec::single(RoutineKind::Scal, "s", 512, DataSource::Pl);
        let tickets: Vec<Ticket> = {
            let srv = server(ServeConfig { workers: 1, ..Default::default() });
            (0..16).map(|i| srv.submit(&spec, ExecInputs::random_for(&spec, i))).collect()
            // server dropped here with requests possibly still queued
        };
        for t in tickets {
            assert!(t.wait().is_ok(), "drop must answer queued requests, not abandon them");
        }
    }

    #[test]
    fn panicking_backend_fails_requests_without_killing_workers() {
        struct PanicBackend;
        impl Backend for PanicBackend {
            fn name(&self) -> &'static str {
                "panic"
            }
            fn prepare(
                &self,
                plan: Arc<crate::pipeline::ExecutablePlan>,
            ) -> crate::Result<crate::runtime::Prepared> {
                Ok(crate::runtime::Prepared::new(plan, self.name()))
            }
            fn execute(
                &self,
                _prepared: &crate::runtime::Prepared,
                _inputs: &ExecInputs,
            ) -> crate::Result<ExecOutcome> {
                panic!("injected backend panic")
            }
        }

        let srv = RoutineServer::new(
            Arc::new(Pipeline::new(ArchConfig::vck5000())),
            Arc::new(PanicBackend),
            ServeConfig { workers: 1, ..Default::default() },
        );
        let spec = Spec::single(RoutineKind::Dot, "d", 128, DataSource::Pl);
        // two sequential requests: if the first panic killed the only
        // worker, the second would hang forever instead of erroring.
        for i in 0..2 {
            let err = srv.submit(&spec, ExecInputs::random_for(&spec, i)).wait();
            match err {
                Err(Error::Runtime(msg)) => assert!(msg.contains("panicked"), "{msg}"),
                other => panic!("expected runtime error, got {other:?}"),
            }
        }
        let report = srv.join();
        assert_eq!((report.requests, report.failed), (2, 2));
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 99.0), 4.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
