//! Serving-layer statistics and machine-readable metrics (DESIGN.md §9, §12).
//!
//! Split from `mod.rs` so the hot path is honest about what it touches:
//! each dispatcher records into **its own** [`StatsShard`] (one shard per
//! dispatcher id, so the shard mutex is uncontended in steady state) and
//! bumps lock-free [`Counters`]; `report()` merges the shards into a
//! [`StatsSnapshot`] (counter sums are exact — every answered request is
//! recorded in exactly one shard) and does all sorting *outside* any
//! lock. Latency samples live in fixed-size deterministic [`Reservoir`]s,
//! so a long-running server's stats memory is O(1) in request count.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::admission::{Priority, ShedReason};
use super::ServeConfig;
use crate::pipeline::CacheStats;
use crate::util::json::{obj, Json};

/// Number of stats shards. Dispatcher `id` owns shard `id % STATS_SHARDS`;
/// the adaptive pool tops out well below this, so in practice every live
/// dispatcher records into a private shard.
pub(crate) const STATS_SHARDS: usize = 16;

/// Latency/queue-wait samples kept for percentile reporting, totalled
/// across shards (each shard's reservoir holds `1/STATS_SHARDS` of this).
pub(crate) const STAT_SAMPLE_CAP: usize = 65_536;

/// Per-priority-class totals are smaller: three of them exist per shard
/// and they only feed the p50/p99 columns.
pub(crate) const PRIO_SAMPLE_CAP: usize = 16_384;

/// At most this many distinct tenants get their own completion counter;
/// the rest share an `"<other>"` bucket so hostile tenant-id cardinality
/// cannot grow server memory without bound.
pub(crate) const TENANT_METRIC_CAP: usize = 32;

/// Fixed-size uniform sample of an unbounded stream (Algorithm R over a
/// deterministic xorshift64* stream). Replaces the old most-recent-window
/// ring: memory and `report()` sort cost stay O(cap) however many
/// requests a long-lived server answers, and — unlike the ring — the pool
/// is an unbiased sample of the *whole* stream, so lifetime p50/p99 do
/// not silently become "p50 of the last window". Deterministic: the same
/// observation sequence always yields the same sample set.
pub(crate) struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: u64,
}

impl Reservoir {
    pub(crate) fn new(cap: usize) -> Reservoir {
        assert!(cap > 0, "reservoir cap must be positive");
        Reservoir { cap, seen: 0, samples: Vec::new(), rng: 0x9E37_79B9_7F4A_7C15 }
    }

    /// Record one observation: fill to `cap`, then the `i`-th observation
    /// replaces a uniformly random resident sample with probability
    /// `cap/i` (Algorithm R), keeping the pool uniform over the stream.
    pub(crate) fn record(&mut self, value: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(value);
            return;
        }
        // xorshift64* : deterministic, nonzero-seeded, passes enough of
        // BigCrush for sampling duty without pulling in the util Rng's
        // 4-word state per reservoir.
        self.rng ^= self.rng << 13;
        self.rng ^= self.rng >> 7;
        self.rng ^= self.rng << 17;
        let j = self.rng.wrapping_mul(0x2545_F491_4F6C_DD1D) % self.seen;
        if (j as usize) < self.cap {
            self.samples[j as usize] = value;
        }
    }

    pub(crate) fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub(crate) fn seen(&self) -> u64 {
        self.seen
    }
}

/// One dispatcher's private statistics. All counters are exact (a request
/// is recorded in exactly one shard); the latency pools are bounded
/// reservoirs merged at snapshot time.
pub(crate) struct StatsShard {
    pub(crate) completed: u64,
    pub(crate) failed: u64,
    pub(crate) batches: u64,
    pub(crate) batch_size_sum: u64,
    pub(crate) max_batch: usize,
    /// Per-request submit→response seconds (uniform reservoir).
    pub(crate) latencies: Reservoir,
    /// Per-request submit→dequeue seconds (uniform reservoir).
    pub(crate) queue_waits: Reservoir,
    /// Submit→response seconds by priority lane (High/Normal/Background).
    pub(crate) lat_by_prio: [Reservoir; 3],
    pub(crate) count_by_prio: [u64; 3],
    /// Completions per tenant (bounded by `TENANT_METRIC_CAP`).
    pub(crate) completed_by_tenant: HashMap<String, u64>,
    pub(crate) last_done: Option<Instant>,
}

impl StatsShard {
    pub(crate) fn new() -> StatsShard {
        let cap = STAT_SAMPLE_CAP / STATS_SHARDS;
        let prio_cap = PRIO_SAMPLE_CAP / STATS_SHARDS;
        StatsShard {
            completed: 0,
            failed: 0,
            batches: 0,
            batch_size_sum: 0,
            max_batch: 0,
            latencies: Reservoir::new(cap),
            queue_waits: Reservoir::new(cap),
            lat_by_prio: [
                Reservoir::new(prio_cap),
                Reservoir::new(prio_cap),
                Reservoir::new(prio_cap),
            ],
            count_by_prio: [0; 3],
            completed_by_tenant: HashMap::new(),
            last_done: None,
        }
    }

    /// Account one answered request. `done` is when the response was sent;
    /// `last_done` stays monotonic so a late-locking worker with an
    /// earlier completion cannot move the span's end backwards.
    pub(crate) fn record_request(
        &mut self,
        priority: Priority,
        tenant: Option<&str>,
        latency_s: f64,
        wait_s: f64,
        failed: bool,
        done: Instant,
    ) {
        self.completed += 1;
        if failed {
            self.failed += 1;
        }
        self.latencies.record(latency_s);
        self.queue_waits.record(wait_s);
        let lane = priority.lane();
        self.lat_by_prio[lane].record(latency_s);
        self.count_by_prio[lane] += 1;
        if let Some(tenant) = tenant {
            let key = if self.completed_by_tenant.len() >= TENANT_METRIC_CAP
                && !self.completed_by_tenant.contains_key(tenant)
            {
                "<other>"
            } else {
                tenant
            };
            *self.completed_by_tenant.entry(key.to_string()).or_insert(0) += 1;
        }
        self.last_done = Some(self.last_done.map_or(done, |prev| prev.max(done)));
    }
}

/// All dispatchers' stats: one [`StatsShard`] per dispatcher id. A
/// dispatcher locks only its own shard (uncontended in steady state —
/// the lock exists so `report()` can read a consistent shard, not to
/// mediate between writers); the merge happens at snapshot time.
pub(crate) struct ShardedStats {
    shards: Vec<Mutex<StatsShard>>,
}

impl ShardedStats {
    pub(crate) fn new() -> ShardedStats {
        ShardedStats {
            shards: (0..STATS_SHARDS).map(|_| Mutex::new(StatsShard::new())).collect(),
        }
    }

    /// The shard owned by dispatcher `id` (ids wrap at `STATS_SHARDS`;
    /// caller threads with no dispatcher id — the drain purge — use 0).
    pub(crate) fn shard(&self, id: usize) -> &Mutex<StatsShard> {
        &self.shards[id % STATS_SHARDS]
    }

    /// Merge every shard into one snapshot. Counter sums are exact — each
    /// answered request was recorded under exactly one shard lock, and
    /// the merge locks each shard in turn, so at quiescence this equals
    /// what a single global `Mutex<StatsInner>` would have accumulated.
    /// Percentiles come from pooling the per-shard reservoirs; with the
    /// batcher spreading work across dispatchers the shard streams are
    /// near-identically distributed and pooling is an unbiased estimate.
    pub(crate) fn snapshot(&self) -> StatsSnapshot {
        let mut snap = StatsSnapshot {
            completed: 0,
            failed: 0,
            batches: 0,
            batch_size_sum: 0,
            max_batch: 0,
            latencies: Vec::new(),
            queue_waits: Vec::new(),
            lat_by_prio: [Vec::new(), Vec::new(), Vec::new()],
            count_by_prio: [0; 3],
            completed_by_tenant: HashMap::new(),
            last_done: None,
        };
        for shard in &self.shards {
            let s = shard.lock().expect("stats shard poisoned");
            snap.completed += s.completed;
            snap.failed += s.failed;
            snap.batches += s.batches;
            snap.batch_size_sum += s.batch_size_sum;
            snap.max_batch = snap.max_batch.max(s.max_batch);
            snap.latencies.extend_from_slice(s.latencies.samples());
            snap.queue_waits.extend_from_slice(s.queue_waits.samples());
            for lane in 0..3 {
                snap.lat_by_prio[lane].extend_from_slice(s.lat_by_prio[lane].samples());
                snap.count_by_prio[lane] += s.count_by_prio[lane];
            }
            for (tenant, n) in &s.completed_by_tenant {
                let key = if snap.completed_by_tenant.len() >= TENANT_METRIC_CAP
                    && !snap.completed_by_tenant.contains_key(tenant)
                {
                    "<other>"
                } else {
                    tenant.as_str()
                };
                *snap.completed_by_tenant.entry(key.to_string()).or_insert(0) += n;
            }
            snap.last_done = match (snap.last_done, s.last_done) {
                (Some(a), Some(b)) => Some(a.max(b)),
                (a, b) => a.or(b),
            };
        }
        snap
    }
}

pub(crate) struct StatsSnapshot {
    pub(crate) completed: u64,
    pub(crate) failed: u64,
    pub(crate) batches: u64,
    pub(crate) batch_size_sum: u64,
    pub(crate) max_batch: usize,
    pub(crate) latencies: Vec<f64>,
    pub(crate) queue_waits: Vec<f64>,
    pub(crate) lat_by_prio: [Vec<f64>; 3],
    pub(crate) count_by_prio: [u64; 3],
    pub(crate) completed_by_tenant: HashMap<String, u64>,
    pub(crate) last_done: Option<Instant>,
}

/// Lock-free event counters bumped outside any mutex.
#[derive(Default)]
pub(crate) struct Counters {
    /// Sheds by [`ShedReason::index`].
    pub(crate) shed: [AtomicU64; 5],
    /// Requests dropped at dequeue because their deadline had passed.
    pub(crate) deadline_missed: AtomicU64,
    /// Requests purged (answered with an error) by a drain timeout.
    pub(crate) drain_purged: AtomicU64,
    pub(crate) pool_grown: AtomicU64,
    pub(crate) pool_shrunk: AtomicU64,
}

impl Counters {
    pub(crate) fn shed(&self, reason: ShedReason) {
        self.shed[reason.index()].fetch_add(1, Ordering::Relaxed);
    }
}

/// Adaptive-pool state: the current dispatcher count plus a queue-wait
/// EWMA. The EWMA is stored as f64 bits in an atomic; concurrent
/// observers may drop an update under a race, which only slows the
/// signal — it steers pool sizing, not accounting.
pub(crate) struct PoolState {
    pub(crate) active: AtomicUsize,
    wait_ewma_bits: AtomicU64,
}

const EWMA_ALPHA: f64 = 0.2;

impl PoolState {
    pub(crate) fn new(workers: usize) -> PoolState {
        PoolState { active: AtomicUsize::new(workers), wait_ewma_bits: AtomicU64::new(0) }
    }

    pub(crate) fn observe_wait(&self, wait_s: f64) {
        let prev = f64::from_bits(self.wait_ewma_bits.load(Ordering::Relaxed));
        let next = if prev == 0.0 { wait_s } else { prev + EWMA_ALPHA * (wait_s - prev) };
        self.wait_ewma_bits.store(next.to_bits(), Ordering::Relaxed);
    }

    pub(crate) fn wait_ewma(&self) -> f64 {
        f64::from_bits(self.wait_ewma_bits.load(Ordering::Relaxed))
    }
}

/// Latency percentiles for one priority class.
#[derive(Debug, Clone)]
pub struct PriorityLatency {
    pub class: Priority,
    pub completed: u64,
    pub p50_s: f64,
    pub p99_s: f64,
}

/// Machine-readable hardening counters: everything admission control,
/// deadlines, drain and the adaptive pool did to this server's traffic.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub shed_queue_full: u64,
    pub shed_watermark: u64,
    pub shed_tenant_quota: u64,
    pub shed_draining: u64,
    pub shed_deadline: u64,
    /// Dropped at dequeue (deadline passed while queued).
    pub deadline_missed: u64,
    /// Answered with an error by a drain timeout purge.
    pub drain_purged: u64,
    pub pool_grown: u64,
    pub pool_shrunk: u64,
    /// Dispatchers alive when the report was taken.
    pub pool_workers: usize,
    pub pool_min_workers: usize,
    pub pool_max_workers: usize,
    /// Requests this process answered locally because the owning shard's
    /// circuit breaker was open (HTTP layer; DESIGN.md §14).
    pub failover_served: u64,
    /// Failover requests that also had to lower the plan locally (neither
    /// the memory cache nor the shared store had it warm).
    pub failover_lowerings: u64,
    /// Peer circuit breakers tripped open (HTTP layer).
    pub breaker_trips: u64,
    /// Peer circuit breakers closed again after a successful trial.
    pub breaker_closes: u64,
    /// One entry per priority class (High, Normal, Background).
    pub priorities: Vec<PriorityLatency>,
    /// Completions per tenant (at most `TENANT_METRIC_CAP` + `<other>`).
    pub tenants: Vec<(String, u64)>,
}

impl ServeMetrics {
    /// Requests refused at admission, all reasons.
    pub fn shed_total(&self) -> u64 {
        self.shed_queue_full
            + self.shed_watermark
            + self.shed_tenant_quota
            + self.shed_draining
            + self.shed_deadline
    }

    pub fn to_json(&self) -> Json {
        let priorities = Json::Arr(
            self.priorities
                .iter()
                .map(|p| {
                    obj(vec![
                        ("class", p.class.name().into()),
                        ("completed", (p.completed as f64).into()),
                        ("p50_s", p.p50_s.into()),
                        ("p99_s", p.p99_s.into()),
                    ])
                })
                .collect(),
        );
        let tenants = Json::Arr(
            self.tenants
                .iter()
                .map(|(t, n)| {
                    obj(vec![("tenant", t.as_str().into()), ("completed", (*n as f64).into())])
                })
                .collect(),
        );
        obj(vec![
            ("shed_queue_full", (self.shed_queue_full as f64).into()),
            ("shed_watermark", (self.shed_watermark as f64).into()),
            ("shed_tenant_quota", (self.shed_tenant_quota as f64).into()),
            ("shed_draining", (self.shed_draining as f64).into()),
            ("shed_deadline", (self.shed_deadline as f64).into()),
            ("shed_total", (self.shed_total() as f64).into()),
            ("deadline_missed", (self.deadline_missed as f64).into()),
            ("drain_purged", (self.drain_purged as f64).into()),
            ("pool_grown", (self.pool_grown as f64).into()),
            ("pool_shrunk", (self.pool_shrunk as f64).into()),
            ("pool_workers", self.pool_workers.into()),
            ("pool_min_workers", self.pool_min_workers.into()),
            ("pool_max_workers", self.pool_max_workers.into()),
            ("failover_served", (self.failover_served as f64).into()),
            ("failover_lowerings", (self.failover_lowerings as f64).into()),
            ("breaker_trips", (self.breaker_trips as f64).into()),
            ("breaker_closes", (self.breaker_closes as f64).into()),
            ("priorities", priorities),
            ("tenants", tenants),
        ])
    }
}

/// Queueing/batching/latency statistics for one server's lifetime.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Requests answered (including failures). Shed requests never enter
    /// this count: `attempts == requests + metrics.shed_total()`.
    pub requests: u64,
    /// Requests answered with an error.
    pub failed: u64,
    /// Batches dispatched to the backend.
    pub batches: u64,
    /// Mean coalesced batch size (requests / batches).
    pub mean_batch: f64,
    /// Largest batch dispatched.
    pub max_batch: usize,
    /// Median submit→response latency, seconds (over a bounded window of
    /// the most recent `STAT_SAMPLE_CAP` requests).
    pub p50_latency_s: f64,
    /// 99th-percentile submit→response latency, seconds (same window).
    pub p99_latency_s: f64,
    /// Median submit→dequeue wait, seconds (queueing delay, same window).
    pub p50_queue_wait_s: f64,
    /// First submit → last response span, seconds.
    pub wall_s: f64,
    /// Requests per second over `wall_s`.
    pub throughput_rps: f64,
    /// Shared plan-cache counters (hits/misses/evictions/coalesced).
    pub cache: CacheStats,
    /// Admission/deadline/drain/pool hardening counters.
    pub metrics: ServeMetrics,
}

impl ServeReport {
    pub fn summary(&self) -> String {
        let mut s = format!(
            "served {} request(s) ({} failed) in {} batch(es), mean batch {:.2} (max {})\n\
             latency p50 {:.3} ms / p99 {:.3} ms, queue wait p50 {:.3} ms\n\
             throughput {:.0} req/s over {:.3} s\n\
             plan cache: {} hit(s) ({} coalesced) / {} miss(es), {} eviction(s), {} resident\n\
             plan store: {} disk hit(s), {} write(s), {} rejected",
            self.requests,
            self.failed,
            self.batches,
            self.mean_batch,
            self.max_batch,
            self.p50_latency_s * 1e3,
            self.p99_latency_s * 1e3,
            self.p50_queue_wait_s * 1e3,
            self.throughput_rps,
            self.wall_s,
            self.cache.hits,
            self.cache.coalesced,
            self.cache.misses,
            self.cache.evictions,
            self.cache.entries,
            self.cache.disk_hits,
            self.cache.disk_writes,
            self.cache.rejected,
        );
        if self.cache.tuned + self.cache.tune_skipped > 0 {
            s.push_str(&format!(
                "\nautotuner: {} tuned lowering(s), {} tuned warm start(s)",
                self.cache.tuned, self.cache.tune_skipped
            ));
        }
        if self.cache.tmp_swept + self.cache.store_fallbacks > 0 {
            s.push_str(&format!(
                "\nstore recovery: {} stale tmp(s) swept at open, {} write fallback(s)",
                self.cache.tmp_swept, self.cache.store_fallbacks
            ));
        }
        let m = &self.metrics;
        if m.shed_total() > 0 || m.deadline_missed > 0 || m.drain_purged > 0 {
            s.push_str(&format!(
                "\nadmission: {} shed (full {}, watermark {}, quota {}, draining {}, deadline {}), \
                 {} deadline miss(es), {} drain-purged",
                m.shed_total(),
                m.shed_queue_full,
                m.shed_watermark,
                m.shed_tenant_quota,
                m.shed_draining,
                m.shed_deadline,
                m.deadline_missed,
                m.drain_purged,
            ));
        }
        if m.pool_grown + m.pool_shrunk > 0 || m.pool_min_workers != m.pool_max_workers {
            s.push_str(&format!(
                "\npool: {} worker(s) in [{}, {}], grew {} time(s), shrank {} time(s)",
                m.pool_workers,
                m.pool_min_workers,
                m.pool_max_workers,
                m.pool_grown,
                m.pool_shrunk,
            ));
        }
        if m.failover_served + m.breaker_trips + m.breaker_closes > 0 {
            s.push_str(&format!(
                "\nfleet: {} failover request(s) ({} lowered locally), \
                 breaker tripped {} time(s), closed {} time(s)",
                m.failover_served,
                m.failover_lowerings,
                m.breaker_trips,
                m.breaker_closes,
            ));
        }
        let classes_used = m.priorities.iter().filter(|p| p.completed > 0).count();
        for p in &m.priorities {
            if p.completed > 0 && classes_used > 1 {
                s.push_str(&format!(
                    "\npriority {}: {} done, p50 {:.3} ms / p99 {:.3} ms",
                    p.class,
                    p.completed,
                    p.p50_s * 1e3,
                    p.p99_s * 1e3,
                ));
            }
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let cache = obj(vec![
            ("hits", (self.cache.hits as f64).into()),
            ("coalesced", (self.cache.coalesced as f64).into()),
            ("misses", (self.cache.misses as f64).into()),
            ("evictions", (self.cache.evictions as f64).into()),
            ("entries", self.cache.entries.into()),
            ("disk_hits", (self.cache.disk_hits as f64).into()),
            ("disk_writes", (self.cache.disk_writes as f64).into()),
            ("rejected", (self.cache.rejected as f64).into()),
            ("tuned", (self.cache.tuned as f64).into()),
            ("tune_skipped", (self.cache.tune_skipped as f64).into()),
            ("tmp_swept", (self.cache.tmp_swept as f64).into()),
            ("store_fallbacks", (self.cache.store_fallbacks as f64).into()),
        ]);
        obj(vec![
            ("requests", (self.requests as f64).into()),
            ("failed", (self.failed as f64).into()),
            ("batches", (self.batches as f64).into()),
            ("mean_batch", self.mean_batch.into()),
            ("max_batch", self.max_batch.into()),
            ("p50_latency_s", self.p50_latency_s.into()),
            ("p99_latency_s", self.p99_latency_s.into()),
            ("p50_queue_wait_s", self.p50_queue_wait_s.into()),
            ("wall_s", self.wall_s.into()),
            ("throughput_rps", self.throughput_rps.into()),
            ("cache", cache),
            ("metrics", self.metrics.to_json()),
        ])
    }
}

/// `p`th percentile of an ascending-sorted series (nearest-rank).
pub(crate) fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Assemble the report from a stats snapshot (sorts happen here, with no
/// lock held) plus the lock-free counters and pool state.
pub(crate) fn build_report(
    snap: StatsSnapshot,
    wall_s: f64,
    cache: CacheStats,
    counters: &Counters,
    pool: &PoolState,
    cfg: &ServeConfig,
) -> ServeReport {
    let mut latencies = snap.latencies;
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut waits = snap.queue_waits;
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let priorities = Priority::ALL
        .iter()
        .map(|&class| {
            let mut lat = snap.lat_by_prio[class.lane()].clone();
            lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            PriorityLatency {
                class,
                completed: snap.count_by_prio[class.lane()],
                p50_s: percentile(&lat, 50.0),
                p99_s: percentile(&lat, 99.0),
            }
        })
        .collect();
    let mut tenants: Vec<(String, u64)> = snap.completed_by_tenant.into_iter().collect();
    tenants.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    let metrics = ServeMetrics {
        shed_queue_full: counters.shed[ShedReason::QueueFull.index()].load(Ordering::Relaxed),
        shed_watermark: counters.shed[ShedReason::AboveWatermark.index()].load(Ordering::Relaxed),
        shed_tenant_quota: counters.shed[ShedReason::TenantQuota.index()].load(Ordering::Relaxed),
        shed_draining: counters.shed[ShedReason::Draining.index()].load(Ordering::Relaxed),
        shed_deadline: counters.shed[ShedReason::DeadlineExpired.index()].load(Ordering::Relaxed),
        deadline_missed: counters.deadline_missed.load(Ordering::Relaxed),
        drain_purged: counters.drain_purged.load(Ordering::Relaxed),
        pool_grown: counters.pool_grown.load(Ordering::Relaxed),
        pool_shrunk: counters.pool_shrunk.load(Ordering::Relaxed),
        pool_workers: pool.active.load(Ordering::Relaxed),
        pool_min_workers: cfg.min_workers,
        pool_max_workers: cfg.max_workers,
        // fleet counters live in the HTTP layer; `http::handlers::statsz`
        // overlays them onto this report before serializing.
        failover_served: 0,
        failover_lowerings: 0,
        breaker_trips: 0,
        breaker_closes: 0,
        priorities,
        tenants,
    };
    ServeReport {
        requests: snap.completed,
        failed: snap.failed,
        batches: snap.batches,
        mean_batch: if snap.batches == 0 {
            0.0
        } else {
            snap.batch_size_sum as f64 / snap.batches as f64
        },
        max_batch: snap.max_batch,
        p50_latency_s: percentile(&latencies, 50.0),
        p99_latency_s: percentile(&latencies, 99.0),
        p50_queue_wait_s: percentile(&waits, 50.0),
        wall_s,
        throughput_rps: if wall_s > 0.0 { snap.completed as f64 / wall_s } else { 0.0 },
        cache,
        metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 99.0), 4.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn reservoir_is_bounded_and_deterministic() {
        let mut a = Reservoir::new(8);
        let mut b = Reservoir::new(8);
        for i in 0..10_000 {
            a.record(i as f64);
            b.record(i as f64);
        }
        assert_eq!(a.samples().len(), 8, "memory stays O(cap)");
        assert_eq!(a.seen(), 10_000);
        assert_eq!(a.samples(), b.samples(), "same stream, same sample set");
    }

    /// Feed a shuffled grid over [0, 1) — whose exact percentiles are
    /// known — through a reservoir sized like one stats shard; the
    /// sampled p50/p99 must land within sampling-error tolerance.
    #[test]
    fn reservoir_percentiles_within_tolerance_of_exact() {
        let n = 100_000u64;
        let mut values: Vec<f64> = (0..n).map(|i| i as f64 / n as f64).collect();
        let mut rng = crate::util::rng::Rng::new(42);
        for i in (1..values.len()).rev() {
            values.swap(i, rng.below(i as u64 + 1) as usize);
        }
        let mut res = Reservoir::new(STAT_SAMPLE_CAP / STATS_SHARDS);
        for v in values {
            res.record(v);
        }
        let mut sampled = res.samples().to_vec();
        sampled.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = percentile(&sampled, 50.0);
        let p99 = percentile(&sampled, 99.0);
        assert!((p50 - 0.5).abs() < 0.05, "sampled p50 {p50} vs exact 0.5");
        assert!((p99 - 0.99).abs() < 0.05, "sampled p99 {p99} vs exact 0.99");
    }

    /// The sharded merge: counters sum exactly, and pooled percentiles
    /// stay within tolerance when the per-dispatcher streams are
    /// identically distributed (round-robin, like the batcher's fan-out).
    #[test]
    fn sharded_snapshot_merges_counters_exactly_and_percentiles_closely() {
        let stats = ShardedStats::new();
        let n = 40_000u64;
        let t0 = Instant::now();
        for i in 0..n {
            let latency = (i % 1000) as f64 / 1000.0;
            let mut shard =
                stats.shard(i as usize % STATS_SHARDS).lock().expect("stats shard poisoned");
            shard.record_request(Priority::Normal, None, latency, 0.0, i % 10 == 0, t0);
        }
        let snap = stats.snapshot();
        assert_eq!(snap.completed, n, "completions sum exactly across shards");
        assert_eq!(snap.failed, n / 10);
        assert_eq!(snap.count_by_prio[Priority::Normal.lane()], n);
        let mut lat = snap.latencies;
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = percentile(&lat, 50.0);
        assert!((p50 - 0.5).abs() < 0.05, "merged p50 {p50} vs exact ~0.5");
    }

    #[test]
    fn tenant_cardinality_is_bounded() {
        let mut stats = StatsShard::new();
        let t0 = Instant::now();
        for i in 0..(TENANT_METRIC_CAP + 10) {
            stats.record_request(Priority::Normal, Some(&format!("t{i}")), 0.0, 0.0, false, t0);
        }
        assert!(stats.completed_by_tenant.len() <= TENANT_METRIC_CAP + 1);
        assert_eq!(stats.completed_by_tenant.get("<other>"), Some(&10));
    }

    /// The merge folds tenant maps with the same cardinality cap a single
    /// shard enforces, so a hostile tenant spread across dispatchers
    /// still cannot grow the report without bound.
    #[test]
    fn merged_tenant_cardinality_is_bounded() {
        let stats = ShardedStats::new();
        let t0 = Instant::now();
        for i in 0..(STATS_SHARDS * TENANT_METRIC_CAP) {
            let mut shard = stats.shard(i % STATS_SHARDS).lock().expect("stats shard poisoned");
            shard.record_request(Priority::Normal, Some(&format!("t{i}")), 0.0, 0.0, false, t0);
        }
        let snap = stats.snapshot();
        assert!(snap.completed_by_tenant.len() <= TENANT_METRIC_CAP + 1);
        let total: u64 = snap.completed_by_tenant.values().sum();
        assert_eq!(total, (STATS_SHARDS * TENANT_METRIC_CAP) as u64, "no completion lost");
    }

    #[test]
    fn ewma_converges_toward_observations() {
        let pool = PoolState::new(2);
        assert_eq!(pool.wait_ewma(), 0.0);
        for _ in 0..64 {
            pool.observe_wait(1.0);
        }
        assert!(pool.wait_ewma() > 0.99, "ewma {}", pool.wait_ewma());
    }

    #[test]
    fn metrics_json_has_shed_total() {
        let m = ServeMetrics { shed_queue_full: 2, shed_deadline: 1, ..Default::default() };
        let j = m.to_json().to_pretty();
        let parsed = Json::parse(&j).unwrap();
        match parsed {
            Json::Obj(pairs) => {
                let total = pairs.iter().find(|(k, _)| k == "shed_total").unwrap();
                match total.1 {
                    Json::Num(n) => assert_eq!(n, 3.0),
                    ref other => panic!("expected number, got {other:?}"),
                }
            }
            other => panic!("expected object, got {other:?}"),
        }
    }
}
