//! The AIEBLAS JSON specification (paper §III, Fig. 1 ①).
//!
//! The user describes *what* they need — routine kinds, unique kernel
//! names, problem sizes — plus optional non-functional parameters (window
//! size, vector width, placement hints, DDR burst mode) that default to
//! predefined values, and optional routine→routine connections that the
//! generator turns into on-chip dataflow edges.
//!
//! Example (the paper's axpydot composition, Fig. 1):
//! ```json
//! {
//!   "platform": "vck5000",
//!   "data_source": "pl",
//!   "routines": [
//!     {"routine": "axpy", "name": "vadd",  "size": 65536, "alpha": -2.0},
//!     {"routine": "dot",  "name": "vdot",  "size": 65536,
//!      "placement": {"col": 10, "row": 2}}
//!   ],
//!   "connections": [
//!     {"from": "vadd.z", "to": "vdot.x"}
//!   ]
//! }
//! ```

pub mod validate;

pub use validate::{arch_for, validate};

use crate::blas::RoutineKind;
use crate::util::json::Json;
use crate::{Error, Result};

/// Where unconnected routine inputs come from (Fig. 3's two variants).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DataSource {
    /// PL data movers read/write DRAM (the realistic configuration).
    #[default]
    Pl,
    /// Data generated directly on-chip (the paper's "no PL" upper bound).
    OnChip,
}

impl DataSource {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pl" => Ok(DataSource::Pl),
            "onchip" | "on_chip" | "no_pl" => Ok(DataSource::OnChip),
            other => Err(Error::Spec(format!(
                "unknown data_source {other:?} (expected \"pl\" or \"onchip\")"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DataSource::Pl => "pl",
            DataSource::OnChip => "onchip",
        }
    }
}

/// Optional placement hint for one kernel (paper §III: "users can set an
/// optional field in the JSON configuration specifying a placement
/// constraint for each kernel").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    pub col: usize,
    pub row: usize,
}

/// One requested routine instance.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutineSpec {
    /// Routine type (axpy, gemv, ...).
    pub kind: RoutineKind,
    /// Unique kernel name used for generation.
    pub name: String,
    /// Problem size `n` (vectors length n, matrices n×n).
    pub size: usize,
    /// Window size in *elements*; defaults to min(4096, size) shrunk to a
    /// divisor of `size` (mirrors `python/compile/kernels/common.py`).
    pub window: Option<usize>,
    /// Vector datapath width in bits; defaults to the maximum supported
    /// (512, paper §III).
    pub vector_bits: usize,
    /// Optional placement constraint.
    pub placement: Option<Placement>,
    /// Burst-optimized PL movers (ablation A1; paper future-work 1).
    pub burst: bool,
    /// Compile-time scalar constants (alpha/beta) when the user wants them
    /// baked into the generated kernel rather than streamed.
    pub alpha: Option<f32>,
    pub beta: Option<f32>,
    /// Multi-AIE split factor (paper §V future work 2): partition the
    /// vector across `split` kernels, each with its own PL ports, plus an
    /// on-chip combiner for reductions. 1 = no split.
    pub split: usize,
}

impl RoutineSpec {
    /// A routine instance with every non-functional parameter at its
    /// default (the same defaults the JSON decoder applies).
    pub fn new(kind: RoutineKind, name: impl Into<String>, size: usize) -> RoutineSpec {
        RoutineSpec {
            kind,
            name: name.into(),
            size,
            window: None,
            vector_bits: 512,
            placement: None,
            burst: false,
            alpha: None,
            beta: None,
            split: 1,
        }
    }

    /// Number of non-scalar (windowed) ports this routine moves.
    pub fn vector_ports(&self) -> usize {
        self.kind
            .inputs()
            .iter()
            .chain(self.kind.outputs())
            .filter(|p| p.ty != crate::blas::PortType::Scalar)
            .count()
    }

    /// Largest window (elements) whose ping-pong-buffered set of per-port
    /// windows fits the 32 KB tile-local memory. Matrix-windowed routines
    /// (level ≥ 2) stage 16-row blocks, so each window element costs 16×.
    pub fn max_window_for_memory(&self, local_mem_bytes: usize) -> usize {
        let per_elem = if self.kind.level() >= 2 { 16 } else { 1 };
        let denom = 2 * self.vector_ports().max(1) * per_elem * crate::arch::F32_BYTES;
        (local_mem_bytes / denom).max(1)
    }

    /// Effective window in elements: the requested `window_size`, or a
    /// power-of-two default sized to the 32 KB tile budget; always shrunk
    /// to a divisor of `size` (the AIEBLAS window-divisibility invariant).
    pub fn effective_window(&self) -> usize {
        let default = {
            let max_w = self.max_window_for_memory(32 * 1024);
            // largest power of two <= max_w
            let mut w = 1usize;
            while w * 2 <= max_w {
                w *= 2;
            }
            w
        };
        let req = self.window.unwrap_or(default).min(self.size.max(1));
        let mut w = req.max(1);
        while self.size % w != 0 {
            w -= 1;
        }
        w
    }
}

/// A dataflow connection `from = "kernel.port"` → `to = "kernel.port"`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Connection {
    pub from_kernel: String,
    pub from_port: String,
    pub to_kernel: String,
    pub to_port: String,
}

impl Connection {
    fn parse_endpoint(s: &str, which: &str) -> Result<(String, String)> {
        match s.split_once('.') {
            Some((k, p)) if !k.is_empty() && !p.is_empty() => {
                Ok((k.to_string(), p.to_string()))
            }
            _ => Err(Error::Spec(format!(
                "connection {which} endpoint {s:?} must be \"kernel.port\""
            ))),
        }
    }
}

/// The full parsed specification.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Spec {
    pub platform: String,
    pub data_source: DataSource,
    pub routines: Vec<RoutineSpec>,
    pub connections: Vec<Connection>,
}

impl Spec {
    /// Parse and validate a JSON spec document.
    pub fn from_json_str(s: &str) -> Result<Spec> {
        let json = Json::parse(s)?;
        let spec = Self::from_json(&json)?;
        validate(&spec)?;
        Ok(spec)
    }

    /// Load from a file.
    pub fn from_file(path: &std::path::Path) -> Result<Spec> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text)
    }

    /// Structural decode (no cross-field validation — see [`validate`]).
    pub fn from_json(json: &Json) -> Result<Spec> {
        let obj = json
            .as_obj()
            .ok_or_else(|| Error::Spec("spec root must be an object".into()))?;

        // reject unknown top-level keys early: typos in non-functional
        // parameters silently reverting to defaults is exactly the failure
        // mode a generator-facing spec format must not have.
        for key in obj.keys() {
            if !["platform", "data_source", "routines", "connections"].contains(&key.as_str()) {
                return Err(Error::Spec(format!("unknown top-level key {key:?}")));
            }
        }

        let platform = json
            .get("platform")
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| Error::Spec("platform must be a string".into()))
            })
            .transpose()?
            .unwrap_or_else(|| "vck5000".to_string());

        let data_source = match json.get("data_source") {
            None => DataSource::default(),
            Some(v) => DataSource::parse(
                v.as_str()
                    .ok_or_else(|| Error::Spec("data_source must be a string".into()))?,
            )?,
        };

        let routines_json = json
            .get("routines")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Spec("spec needs a \"routines\" array".into()))?;
        let mut routines = Vec::with_capacity(routines_json.len());
        for (i, r) in routines_json.iter().enumerate() {
            routines.push(Self::routine_from_json(r, i)?);
        }

        let mut connections = Vec::new();
        if let Some(conns) = json.get("connections") {
            let arr = conns
                .as_arr()
                .ok_or_else(|| Error::Spec("connections must be an array".into()))?;
            for c in arr {
                let from = c
                    .get("from")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Spec("connection needs \"from\"".into()))?;
                let to = c
                    .get("to")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Spec("connection needs \"to\"".into()))?;
                let (fk, fp) = Connection::parse_endpoint(from, "from")?;
                let (tk, tp) = Connection::parse_endpoint(to, "to")?;
                connections.push(Connection {
                    from_kernel: fk,
                    from_port: fp,
                    to_kernel: tk,
                    to_port: tp,
                });
            }
        }

        Ok(Spec { platform, data_source, routines, connections })
    }

    fn routine_from_json(r: &Json, index: usize) -> Result<RoutineSpec> {
        let ctx = || format!("routines[{index}]");
        let obj = r
            .as_obj()
            .ok_or_else(|| Error::Spec(format!("{} must be an object", ctx())))?;
        for key in obj.keys() {
            if ![
                "routine", "name", "size", "window_size", "vector_width",
                "placement", "burst", "alpha", "beta", "split",
            ]
            .contains(&key.as_str())
            {
                return Err(Error::Spec(format!("{}: unknown key {key:?}", ctx())));
            }
        }
        let kind_name = r
            .get("routine")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Spec(format!("{} needs \"routine\"", ctx())))?;
        let kind = RoutineKind::from_name(kind_name).ok_or_else(|| {
            Error::Spec(format!(
                "{}: unknown routine {kind_name:?} (known: {})",
                ctx(),
                RoutineKind::ALL.map(|k| k.name()).join(", ")
            ))
        })?;
        let name = r
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Spec(format!("{} needs a unique \"name\"", ctx())))?
            .to_string();
        let size = r
            .get("size")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Spec(format!("{} needs integer \"size\"", ctx())))?;
        let window = match r.get("window_size") {
            None => None,
            Some(v) => Some(v.as_usize().ok_or_else(|| {
                Error::Spec(format!("{}: window_size must be a positive integer", ctx()))
            })?),
        };
        let vector_bits = match r.get("vector_width") {
            None => 512,
            Some(v) => v.as_usize().ok_or_else(|| {
                Error::Spec(format!("{}: vector_width must be an integer", ctx()))
            })?,
        };
        let placement = match r.get("placement") {
            None => None,
            Some(p) => {
                let col = p.get("col").and_then(Json::as_usize);
                let row = p.get("row").and_then(Json::as_usize);
                match (col, row) {
                    (Some(col), Some(row)) => Some(Placement { col, row }),
                    _ => {
                        return Err(Error::Spec(format!(
                            "{}: placement needs integer \"col\" and \"row\"",
                            ctx()
                        )))
                    }
                }
            }
        };
        let burst = match r.get("burst") {
            None => false,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| Error::Spec(format!("{}: burst must be a bool", ctx())))?,
        };
        let alpha = r.get("alpha").and_then(Json::as_f64).map(|v| v as f32);
        let beta = r.get("beta").and_then(Json::as_f64).map(|v| v as f32);
        let split = match r.get("split") {
            None => 1,
            Some(v) => v.as_usize().filter(|&k| k >= 1).ok_or_else(|| {
                Error::Spec(format!("{}: split must be a positive integer", ctx()))
            })?,
        };
        Ok(RoutineSpec {
            kind,
            name,
            size,
            window,
            vector_bits,
            placement,
            burst,
            alpha,
            beta,
            split,
        })
    }

    /// Find a routine by kernel name.
    pub fn routine(&self, name: &str) -> Option<&RoutineSpec> {
        self.routines.iter().find(|r| r.name == name)
    }

    /// Canonical plan-cache key: the compact canonical JSON rendering,
    /// which covers the routine set, sizes, every non-functional parameter
    /// and the platform (specs equal under [`PartialEq`] produce equal
    /// keys; see `pipeline::cache`).
    pub fn cache_key(&self) -> String {
        self.to_json().to_compact()
    }

    /// Render back to canonical JSON (round-trips through `from_json`).
    pub fn to_json(&self) -> Json {
        use crate::util::json::obj;
        let routines: Vec<Json> = self
            .routines
            .iter()
            .map(|r| {
                let mut fields: Vec<(&str, Json)> = vec![
                    ("routine", r.kind.name().into()),
                    ("name", r.name.clone().into()),
                    ("size", r.size.into()),
                ];
                if let Some(w) = r.window {
                    fields.push(("window_size", w.into()));
                }
                if r.vector_bits != 512 {
                    fields.push(("vector_width", r.vector_bits.into()));
                }
                if let Some(p) = r.placement {
                    fields.push((
                        "placement",
                        obj(vec![("col", p.col.into()), ("row", p.row.into())]),
                    ));
                }
                if r.burst {
                    fields.push(("burst", true.into()));
                }
                if let Some(a) = r.alpha {
                    fields.push(("alpha", (a as f64).into()));
                }
                if let Some(b) = r.beta {
                    fields.push(("beta", (b as f64).into()));
                }
                if r.split > 1 {
                    fields.push(("split", r.split.into()));
                }
                obj(fields)
            })
            .collect();
        let connections: Vec<Json> = self
            .connections
            .iter()
            .map(|c| {
                obj(vec![
                    ("from", format!("{}.{}", c.from_kernel, c.from_port).into()),
                    ("to", format!("{}.{}", c.to_kernel, c.to_port).into()),
                ])
            })
            .collect();
        obj(vec![
            ("platform", self.platform.clone().into()),
            ("data_source", self.data_source.name().into()),
            ("routines", Json::Arr(routines)),
            ("connections", Json::Arr(connections)),
        ])
    }
}

/// Convenience constructors used throughout tests/benches/examples.
impl Spec {
    /// A single-routine spec with defaults (the Fig. 3 single-routine runs).
    pub fn single(kind: RoutineKind, name: &str, size: usize, source: DataSource) -> Spec {
        Spec {
            platform: "vck5000".into(),
            data_source: source,
            routines: vec![RoutineSpec::new(kind, name, size)],
            connections: Vec::new(),
        }
    }

    /// A `stages`-deep on-chip pipeline of one routine kind: each stage's
    /// first vector output feeds the next stage's first vector input (the
    /// deep-pipeline shape `benches/sim_engine.rs` and the simulator
    /// parity tests stress). Panics if `kind` lacks vector I/O.
    pub fn chain(kind: RoutineKind, stages: usize, size: usize) -> Spec {
        use crate::blas::PortType;
        let out = kind
            .outputs()
            .iter()
            .find(|p| p.ty == PortType::Vector)
            .expect("chain: routine kind has no vector output");
        let inp = kind
            .inputs()
            .iter()
            .find(|p| p.ty == PortType::Vector)
            .expect("chain: routine kind has no vector input");
        let mut spec = Spec { platform: "vck5000".into(), ..Default::default() };
        for i in 0..stages {
            spec.routines.push(RoutineSpec::new(kind, format!("s{i}"), size));
        }
        for i in 0..stages.saturating_sub(1) {
            spec.connections.push(Connection {
                from_kernel: format!("s{i}"),
                from_port: out.name.to_string(),
                to_kernel: format!("s{}", i + 1),
                to_port: inp.name.to_string(),
            });
        }
        spec
    }

    /// The paper's Fig. 1 axpydot composition: axpy (z = w − αv) feeding a
    /// dot product on-chip.
    pub fn axpydot_dataflow(size: usize, alpha: f32) -> Spec {
        Spec {
            platform: "vck5000".into(),
            data_source: DataSource::Pl,
            routines: vec![
                RoutineSpec {
                    alpha: Some(-alpha),
                    ..RoutineSpec::new(RoutineKind::Axpy, "axpy_stage", size)
                },
                RoutineSpec::new(RoutineKind::Dot, "dot_stage", size),
            ],
            connections: vec![Connection {
                from_kernel: "axpy_stage".into(),
                from_port: "z".into(),
                to_kernel: "dot_stage".into(),
                to_port: "x".into(),
            }],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"{
        "platform": "vck5000",
        "data_source": "pl",
        "routines": [
            {"routine": "axpy", "name": "vadd", "size": 65536, "alpha": -2.0},
            {"routine": "dot", "name": "vdot", "size": 65536,
             "window_size": 2048, "vector_width": 256,
             "placement": {"col": 10, "row": 2}}
        ],
        "connections": [
            {"from": "vadd.z", "to": "vdot.x"}
        ]
    }"#;

    #[test]
    fn parses_full_spec() {
        let s = Spec::from_json_str(GOOD).unwrap();
        assert_eq!(s.platform, "vck5000");
        assert_eq!(s.data_source, DataSource::Pl);
        assert_eq!(s.routines.len(), 2);
        assert_eq!(s.routines[0].kind, RoutineKind::Axpy);
        assert_eq!(s.routines[0].alpha, Some(-2.0));
        assert_eq!(s.routines[1].window, Some(2048));
        assert_eq!(s.routines[1].vector_bits, 256);
        assert_eq!(s.routines[1].placement, Some(Placement { col: 10, row: 2 }));
        assert_eq!(s.connections.len(), 1);
        assert_eq!(s.connections[0].from_kernel, "vadd");
        assert_eq!(s.connections[0].to_port, "x");
    }

    #[test]
    fn defaults_applied() {
        let s = Spec::from_json_str(
            r#"{"routines": [{"routine": "axpy", "name": "a", "size": 1024}]}"#,
        )
        .unwrap();
        assert_eq!(s.platform, "vck5000");
        assert_eq!(s.data_source, DataSource::Pl);
        assert_eq!(s.routines[0].vector_bits, 512);
        assert_eq!(s.routines[0].effective_window(), 1024); // min(4096, n)
        assert!(!s.routines[0].burst);
    }

    #[test]
    fn effective_window_divides_size() {
        let mut r = Spec::single(RoutineKind::Axpy, "a", 1000, DataSource::Pl).routines[0].clone();
        r.window = Some(300);
        assert_eq!(1000 % r.effective_window(), 0);
        assert!(r.effective_window() <= 300);
    }

    #[test]
    fn rejects_unknown_keys() {
        let bad = r#"{"routines": [], "typo_key": 1}"#;
        assert!(matches!(Spec::from_json_str(bad), Err(Error::Spec(_))));
        let bad2 = r#"{"routines": [{"routine": "axpy", "name": "a", "size": 8, "windw": 4}]}"#;
        assert!(Spec::from_json_str(bad2).is_err());
    }

    #[test]
    fn rejects_unknown_routine() {
        let bad = r#"{"routines": [{"routine": "qr", "name": "a", "size": 8}]}"#;
        let err = Spec::from_json_str(bad).unwrap_err().to_string();
        assert!(err.contains("unknown routine"), "{err}");
    }

    #[test]
    fn rejects_malformed_endpoint() {
        let bad = r#"{"routines": [{"routine": "axpy", "name": "a", "size": 8}],
                      "connections": [{"from": "a", "to": "b.x"}]}"#;
        assert!(Spec::from_json_str(bad).is_err());
    }

    #[test]
    fn json_round_trip() {
        let s = Spec::from_json_str(GOOD).unwrap();
        let rendered = s.to_json().to_pretty();
        let reparsed = Spec::from_json_str(&rendered).unwrap();
        assert_eq!(s, reparsed);
    }

    #[test]
    fn axpydot_helper_is_valid() {
        let s = Spec::axpydot_dataflow(4096, 2.0);
        validate(&s).unwrap();
        assert_eq!(s.routines[0].alpha, Some(-2.0));
    }

    #[test]
    fn chain_helper_is_valid() {
        let s = Spec::chain(RoutineKind::Copy, 8, 4096);
        validate(&s).unwrap();
        assert_eq!(s.routines.len(), 8);
        assert_eq!(s.connections.len(), 7);
        assert_eq!(s.connections[0].from_port, "z");
        assert_eq!(s.connections[0].to_port, "x");
    }

    #[test]
    fn cache_key_distinguishes_specs() {
        let a = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        let b = Spec::single(RoutineKind::Axpy, "a", 8192, DataSource::Pl);
        assert_ne!(a.cache_key(), b.cache_key());
        assert_eq!(a.cache_key(), a.clone().cache_key());
        let mut c = a.clone();
        c.routines[0].window = Some(1024);
        assert_ne!(a.cache_key(), c.cache_key(), "non-functional params must key separately");
    }
}
