//! Cross-field validation of a parsed [`Spec`].
//!
//! Catches, at spec time, every configuration the generator or device
//! would reject later: duplicate kernel names, dangling or type-mismatched
//! connections, doubly-driven inputs, cyclic dataflow, placements outside
//! the 8×50 grid or colliding, windows that exceed tile-local memory, and
//! unsupported vector widths.

use std::collections::{BTreeMap, BTreeSet};

use super::Spec;
use crate::arch::{ArchConfig, F32_BYTES};

use crate::{Error, Result};

/// Vector widths the AIE1 datapath supports for 32-bit lanes.
const SUPPORTED_VECTOR_BITS: [usize; 4] = [64, 128, 256, 512];

pub fn validate(spec: &Spec) -> Result<()> {
    let arch = arch_for(&spec.platform)?;
    arch.validate()?;

    if spec.routines.is_empty() {
        return Err(Error::Spec("spec contains no routines".into()));
    }

    // --- per-routine checks -------------------------------------------------
    let mut names = BTreeSet::new();
    let mut placements: BTreeMap<(usize, usize), &str> = BTreeMap::new();
    for r in &spec.routines {
        if r.name.is_empty()
            || !r.name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
            || r.name.chars().next().is_some_and(|c| c.is_ascii_digit())
        {
            return Err(Error::Spec(format!(
                "kernel name {:?} must be a C identifier (codegen emits it verbatim)",
                r.name
            )));
        }
        if !names.insert(r.name.as_str()) {
            return Err(Error::Spec(format!("duplicate kernel name {:?}", r.name)));
        }
        if r.size == 0 {
            return Err(Error::Spec(format!("{}: size must be > 0", r.name)));
        }
        if let Some(w) = r.window {
            if w == 0 {
                return Err(Error::Spec(format!("{}: window_size must be > 0", r.name)));
            }
        }
        if !SUPPORTED_VECTOR_BITS.contains(&r.vector_bits) {
            return Err(Error::Spec(format!(
                "{}: vector_width {} unsupported (one of {SUPPORTED_VECTOR_BITS:?})",
                r.name, r.vector_bits
            )));
        }
        // window memory budget: input windows + output window, double
        // buffered, must fit the 32 KB tile-local memory.
        let w = r.effective_window();
        let per_elem = if r.kind.level() >= 2 { 16 } else { 1 };
        let bytes = 2 * r.vector_ports() * w * per_elem * F32_BYTES; // ping-pong
        if bytes > arch.local_mem_bytes {
            return Err(Error::Spec(format!(
                "{}: windows need {} B double-buffered, exceeding {} B tile memory — reduce window_size",
                r.name, bytes, arch.local_mem_bytes
            )));
        }
        if r.split > 1 {
            if r.kind.level() != 1 || r.kind.is_composite() {
                return Err(Error::Spec(format!(
                    "{}: split is only supported for level-1 routines",
                    r.name
                )));
            }
            if matches!(r.kind, crate::blas::RoutineKind::Nrm2 | crate::blas::RoutineKind::Iamax | crate::blas::RoutineKind::Rot) {
                return Err(Error::Spec(format!(
                    "{}: split unsupported for {} (non-additive combine)",
                    r.name,
                    r.kind
                )));
            }
            if r.size % r.split != 0 {
                return Err(Error::Spec(format!(
                    "{}: split {} does not divide size {}",
                    r.name, r.split, r.size
                )));
            }
            if r.split > 64 {
                return Err(Error::Spec(format!("{}: split {} > 64", r.name, r.split)));
            }
            if spec.connections.iter().any(|c| c.from_kernel == r.name || c.to_kernel == r.name) {
                return Err(Error::Spec(format!(
                    "{}: split routines cannot participate in dataflow connections",
                    r.name
                )));
            }
        }
        if let Some(p) = r.placement {
            if p.col >= arch.cols || p.row >= arch.rows {
                return Err(Error::Placement(format!(
                    "{}: placement ({},{}) outside the {}×{} grid",
                    r.name, p.col, p.row, arch.cols, arch.rows
                )));
            }
            if let Some(prev) = placements.insert((p.col, p.row), &r.name) {
                return Err(Error::Placement(format!(
                    "kernels {:?} and {:?} both pinned to ({},{})",
                    prev, r.name, p.col, p.row
                )));
            }
        }
    }

    // --- connection checks --------------------------------------------------
    let mut driven: BTreeSet<(String, String)> = BTreeSet::new();
    let mut out_used: BTreeSet<(String, String)> = BTreeSet::new();
    for c in &spec.connections {
        let from = spec.routine(&c.from_kernel).ok_or_else(|| {
            Error::Spec(format!("connection from unknown kernel {:?}", c.from_kernel))
        })?;
        let to = spec.routine(&c.to_kernel).ok_or_else(|| {
            Error::Spec(format!("connection to unknown kernel {:?}", c.to_kernel))
        })?;
        if c.from_kernel == c.to_kernel {
            return Err(Error::Spec(format!("{:?} connects to itself", c.from_kernel)));
        }
        let out_port = from
            .kind
            .outputs()
            .iter()
            .find(|p| p.name == c.from_port)
            .ok_or_else(|| {
                Error::Spec(format!(
                    "{} has no output port {:?} (has: {})",
                    c.from_kernel,
                    c.from_port,
                    port_names(from.kind.outputs())
                ))
            })?;
        let in_port = to
            .kind
            .inputs()
            .iter()
            .find(|p| p.name == c.to_port)
            .ok_or_else(|| {
                Error::Spec(format!(
                    "{} has no input port {:?} (has: {})",
                    c.to_kernel,
                    c.to_port,
                    port_names(to.kind.inputs())
                ))
            })?;
        if out_port.ty != in_port.ty {
            return Err(Error::Spec(format!(
                "type mismatch on {}.{} ({:?}) -> {}.{} ({:?})",
                c.from_kernel, c.from_port, out_port.ty, c.to_kernel, c.to_port, in_port.ty
            )));
        }
        if from.size != to.size {
            return Err(Error::Spec(format!(
                "size mismatch: {} is n={} but {} is n={}",
                c.from_kernel, from.size, c.to_kernel, to.size
            )));
        }
        if !driven.insert((c.to_kernel.clone(), c.to_port.clone())) {
            return Err(Error::Spec(format!(
                "input {}.{} driven by two connections",
                c.to_kernel, c.to_port
            )));
        }
        // An output window CAN legally fan out on the AIE via stream
        // broadcast, but AIEBLAS restricts each output to one consumer
        // (decoupled window semantics); enforce that too.
        if !out_used.insert((c.from_kernel.clone(), c.from_port.clone())) {
            return Err(Error::Spec(format!(
                "output {}.{} consumed by two connections (unsupported; insert a copy kernel)",
                c.from_kernel, c.from_port
            )));
        }
    }

    check_acyclic(spec)?;
    Ok(())
}

/// Resolve the named platform to an architecture description.
pub fn arch_for(platform: &str) -> Result<ArchConfig> {
    match platform {
        "vck5000" | "" => Ok(ArchConfig::vck5000()),
        "ryzen_ai" => Ok(ArchConfig::ryzen_ai()),
        other => Err(Error::Spec(format!(
            "unknown platform {other:?} (supported: vck5000, ryzen_ai)"
        ))),
    }
}

fn port_names(ports: &[crate::blas::Port]) -> String {
    ports.iter().map(|p| p.name).collect::<Vec<_>>().join(", ")
}

/// The dataflow graph must be a DAG: windows decouple producers and
/// consumers, but a cycle would deadlock the ping-pong handshake.
fn check_acyclic(spec: &Spec) -> Result<()> {
    let index: BTreeMap<&str, usize> = spec
        .routines
        .iter()
        .enumerate()
        .map(|(i, r)| (r.name.as_str(), i))
        .collect();
    let n = spec.routines.len();
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for c in &spec.connections {
        adj[index[c.from_kernel.as_str()]].push(index[c.to_kernel.as_str()]);
    }
    // Kahn's algorithm.
    let mut indeg = vec![0usize; n];
    for edges in &adj {
        for &t in edges {
            indeg[t] += 1;
        }
    }
    let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut seen = 0;
    while let Some(u) = queue.pop() {
        seen += 1;
        for &t in &adj[u] {
            indeg[t] -= 1;
            if indeg[t] == 0 {
                queue.push(t);
            }
        }
    }
    if seen != n {
        let cyclic: Vec<&str> = (0..n)
            .filter(|&i| indeg[i] > 0)
            .map(|i| spec.routines[i].name.as_str())
            .collect();
        return Err(Error::Spec(format!(
            "dataflow connections form a cycle through: {}",
            cyclic.join(" -> ")
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::spec::{Connection, DataSource, Placement, RoutineSpec};

    fn routine(name: &str, kind: RoutineKind, size: usize) -> RoutineSpec {
        RoutineSpec {
            kind,
            name: name.into(),
            size,
            window: None,
            vector_bits: 512,
            placement: None,
            burst: false,
            alpha: None,
            beta: None,
            split: 1,
        }
    }

    fn two_connected(size_a: usize, size_b: usize) -> Spec {
        Spec {
            platform: "vck5000".into(),
            data_source: DataSource::Pl,
            routines: vec![
                routine("a", RoutineKind::Axpy, size_a),
                routine("b", RoutineKind::Dot, size_b),
            ],
            connections: vec![Connection {
                from_kernel: "a".into(),
                from_port: "z".into(),
                to_kernel: "b".into(),
                to_port: "x".into(),
            }],
        }
    }

    #[test]
    fn valid_composition_passes() {
        validate(&two_connected(4096, 4096)).unwrap();
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut s = two_connected(64, 64);
        s.routines[1].name = "a".into();
        s.connections.clear();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn non_identifier_name_rejected() {
        let mut s = Spec::single(RoutineKind::Axpy, "ok", 64, DataSource::Pl);
        s.routines[0].name = "has-dash".into();
        assert!(validate(&s).is_err());
        s.routines[0].name = "1starts_with_digit".into();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn size_mismatch_on_connection_rejected() {
        let err = validate(&two_connected(4096, 8192)).unwrap_err().to_string();
        assert!(err.contains("size mismatch"), "{err}");
    }

    #[test]
    fn type_mismatch_rejected() {
        // axpy.z (vector) -> gemv.alpha (scalar)
        let mut s = Spec {
            platform: "vck5000".into(),
            data_source: DataSource::Pl,
            routines: vec![
                routine("a", RoutineKind::Axpy, 64),
                routine("g", RoutineKind::Gemv, 64),
            ],
            connections: vec![Connection {
                from_kernel: "a".into(),
                from_port: "z".into(),
                to_kernel: "g".into(),
                to_port: "alpha".into(),
            }],
        };
        let err = validate(&s).unwrap_err().to_string();
        assert!(err.contains("type mismatch"), "{err}");
        // and unknown port
        s.connections[0].to_port = "nonexistent".into();
        assert!(validate(&s).unwrap_err().to_string().contains("no input port"));
    }

    #[test]
    fn doubly_driven_input_rejected() {
        let mut s = Spec {
            platform: "vck5000".into(),
            data_source: DataSource::Pl,
            routines: vec![
                routine("a", RoutineKind::Axpy, 64),
                routine("b", RoutineKind::Scal, 64),
                routine("c", RoutineKind::Dot, 64),
            ],
            connections: vec![
                Connection {
                    from_kernel: "a".into(),
                    from_port: "z".into(),
                    to_kernel: "c".into(),
                    to_port: "x".into(),
                },
                Connection {
                    from_kernel: "b".into(),
                    from_port: "z".into(),
                    to_kernel: "c".into(),
                    to_port: "x".into(),
                },
            ],
        };
        let err = validate(&s).unwrap_err().to_string();
        assert!(err.contains("driven by two"), "{err}");
        // fan-out of one output also rejected
        s.connections[1] = Connection {
            from_kernel: "a".into(),
            from_port: "z".into(),
            to_kernel: "c".into(),
            to_port: "y".into(),
        };
        let err = validate(&s).unwrap_err().to_string();
        assert!(err.contains("consumed by two"), "{err}");
    }

    #[test]
    fn cycle_rejected() {
        // scal -> copy -> scal: a 2-cycle of vector kernels.
        let s = Spec {
            platform: "vck5000".into(),
            data_source: DataSource::Pl,
            routines: vec![
                routine("s1", RoutineKind::Scal, 64),
                routine("c1", RoutineKind::Copy, 64),
            ],
            connections: vec![
                Connection {
                    from_kernel: "s1".into(),
                    from_port: "z".into(),
                    to_kernel: "c1".into(),
                    to_port: "x".into(),
                },
                Connection {
                    from_kernel: "c1".into(),
                    from_port: "z".into(),
                    to_kernel: "s1".into(),
                    to_port: "x".into(),
                },
            ],
        };
        let err = validate(&s).unwrap_err().to_string();
        assert!(err.contains("cycle"), "{err}");
    }

    #[test]
    fn placement_bounds_and_collisions() {
        let mut s = Spec::single(RoutineKind::Axpy, "a", 64, DataSource::Pl);
        s.routines[0].placement = Some(Placement { col: 50, row: 0 }); // cols are 0..50
        assert!(validate(&s).is_err());

        let mut s2 = two_connected(64, 64);
        s2.routines[0].placement = Some(Placement { col: 3, row: 3 });
        s2.routines[1].placement = Some(Placement { col: 3, row: 3 });
        let err = validate(&s2).unwrap_err().to_string();
        assert!(err.contains("both pinned"), "{err}");
    }

    #[test]
    fn window_exceeding_local_memory_rejected() {
        let mut s = Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::Pl);
        s.routines[0].window = Some(1 << 16); // 3 vec ports * 2 (pingpong) * 64Ki * 4B >> 32KB
        let err = validate(&s).unwrap_err().to_string();
        assert!(err.contains("exceeding"), "{err}");
    }

    #[test]
    fn unsupported_vector_width_rejected() {
        let mut s = Spec::single(RoutineKind::Axpy, "a", 64, DataSource::Pl);
        s.routines[0].vector_bits = 384;
        assert!(validate(&s).is_err());
    }

    #[test]
    fn unknown_platform_rejected() {
        let mut s = Spec::single(RoutineKind::Axpy, "a", 64, DataSource::Pl);
        s.platform = "cerebras".into();
        assert!(validate(&s).is_err());
    }

    #[test]
    fn empty_spec_rejected() {
        let s = Spec { routines: vec![], ..Default::default() };
        assert!(validate(&s).is_err());
    }
}
