//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: the offline registry has no
//! `thiserror` (DESIGN.md §1).

use std::fmt;

/// All the ways an AIEBLAS operation can fail.
#[derive(Debug)]
pub enum Error {
    /// User specification problems (paper §III JSON spec).
    Spec(String),

    /// JSON syntax errors in spec/manifest files.
    Json(crate::util::json::JsonError),

    /// Dataflow-graph construction/validation problems.
    Graph(String),

    /// Placement/floorplanning failures (grid exhausted, conflicting hints).
    Placement(String),

    /// Stream routing failures (no path, port over-subscription).
    Routing(String),

    /// Simulation-time failures (deadlock, conservation violation).
    Sim(String),

    /// Runtime failures (artifact missing, backend prepare/execute errors).
    Runtime(String),

    /// Code-generation failures.
    Codegen(String),

    Io(std::io::Error),

    /// XLA/PJRT failures (only produced with the `pjrt` feature).
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Spec(m) => write!(f, "spec error: {m}"),
            Error::Json(e) => write!(f, "{e}"),
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Placement(m) => write!(f, "placement error: {m}"),
            Error::Routing(m) => write!(f, "routing error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Codegen(m) => write!(f, "codegen error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Json(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Json(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(Error::Spec("bad".into()).to_string(), "spec error: bad");
        assert_eq!(Error::Sim("stuck".into()).to_string(), "simulation error: stuck");
    }

    #[test]
    fn json_error_converts() {
        let e = crate::util::json::Json::parse("{").unwrap_err();
        let err: Error = e.into();
        assert!(err.to_string().contains("json parse error"));
    }
}
