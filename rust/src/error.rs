//! Crate-wide error type.

use thiserror::Error;

/// All the ways an AIEBLAS operation can fail.
#[derive(Debug, Error)]
pub enum Error {
    /// User specification problems (paper §III JSON spec).
    #[error("spec error: {0}")]
    Spec(String),

    /// JSON syntax errors in spec/manifest files.
    #[error(transparent)]
    Json(#[from] crate::util::json::JsonError),

    /// Dataflow-graph construction/validation problems.
    #[error("graph error: {0}")]
    Graph(String),

    /// Placement/floorplanning failures (grid exhausted, conflicting hints).
    #[error("placement error: {0}")]
    Placement(String),

    /// Stream routing failures (no path, port over-subscription).
    #[error("routing error: {0}")]
    Routing(String),

    /// Simulation-time failures (deadlock, conservation violation).
    #[error("simulation error: {0}")]
    Sim(String),

    /// PJRT runtime failures (artifact missing, compile/execute errors).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Code-generation failures.
    #[error("codegen error: {0}")]
    Codegen(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(Error::Spec("bad".into()).to_string(), "spec error: bad");
        assert_eq!(Error::Sim("stuck".into()).to_string(), "simulation error: stuck");
    }

    #[test]
    fn json_error_converts() {
        let e = crate::util::json::Json::parse("{").unwrap_err();
        let err: Error = e.into();
        assert!(err.to_string().contains("json parse error"));
    }
}
