//! Simulator-guided placement autotuner (DESIGN.md §11).
//!
//! Cold lowerings historically installed the *first* valid plan: the
//! default greedy placement, the spec's transfer modes, one routing. This
//! module instead enumerates a bounded candidate space per spec+arch —
//! placement-heuristic parameters ([`candidate_params`]) crossed with the
//! PL mover transfer mode (a burst-forced graph variant when the spec
//! leaves naive movers on the table) — and installs the candidate with the
//! smallest makespan under a two-tier oracle:
//!
//! 1. **analytic** ([`crate::sim::analytic`]): closed-form steady-state
//!    makespan for uniform periodic pipelines, microseconds per candidate,
//!    prunes the bulk of the space;
//! 2. **DES**: the event engine confirms the surviving shortlist, sharing
//!    one [`prepare`]-derived warm-up per graph variant and re-stamping
//!    only the routing-dependent edge latencies per candidate
//!    (`Prep::with_routing`).
//!
//! Candidate 0 is *always* the untuned default (default placement
//! parameters, as-spec transfer modes), so `full` mode can never install
//! a plan the DES scores worse than the untuned one, and `analytic` mode
//! only moves off the default when the model predicts a win beyond a
//! no-regret margin. Every candidate passes the same graph invariants and
//! routing checks as an untuned lowering, and tuning never changes
//! numerics: placement, routing and transfer mode are timing-only knobs,
//! so tuned and untuned plans are bit-identical on every backend
//! (enforced by `rust/tests/tune_parity.rs`).
//!
//! [`prepare`]: crate::sim
use std::time::Instant;

use crate::arch::ArchConfig;
use crate::graph::place::{candidate_params, place_with, PlaceParams};
use crate::graph::route::{check_routing, route, RouteCost};
use crate::pipeline::{place_and_route, plan_routines, ExecutablePlan, PlacedGraph, RoutinePlan};
use crate::sim;
use crate::spec::Spec;
use crate::{Error, Result};

/// Version of the tuner's candidate space + scoring rules, stamped into
/// persisted tuned entries. A tuning-enabled pipeline rejects tuned store
/// entries from any other version (the search space changed, so the cached
/// decision may no longer be the winner); untuned readers still accept
/// them — the plan itself is valid either way.
pub const TUNER_VERSION: u32 = 1;

/// `analytic` mode keeps the untuned default unless the predicted win
/// beats this fraction — the model is validated to ~5% against the DES,
/// so sub-margin differences are noise, and staying on candidate 0 is the
/// no-regret choice.
const ANALYTIC_NO_REGRET_MARGIN: f64 = 0.02;

/// How hard a cold lowering searches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TuneMode {
    /// No search: lower the default plan (the historical behaviour).
    #[default]
    Off,
    /// Analytic model only — microseconds of search, no DES runs.
    Analytic,
    /// Analytic pruning + DES confirmation of the shortlist.
    Full,
}

impl TuneMode {
    pub fn parse(s: &str) -> Result<TuneMode> {
        match s {
            "off" => Ok(TuneMode::Off),
            "analytic" => Ok(TuneMode::Analytic),
            "full" => Ok(TuneMode::Full),
            other => Err(Error::Runtime(format!(
                "unknown tune mode {other:?} (expected off|analytic|full)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TuneMode::Off => "off",
            TuneMode::Analytic => "analytic",
            TuneMode::Full => "full",
        }
    }
}

/// Budget caps for one tuning search.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    pub mode: TuneMode,
    /// Placement-parameter candidates per graph variant (≥ 1; candidate
    /// enumeration is deterministic, so this is a strict prefix).
    pub max_candidates: usize,
    /// DES runs `full` mode may spend (candidate 0 always simulates, on
    /// top of this budget if necessary).
    pub shortlist: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig { mode: TuneMode::Off, max_candidates: 12, shortlist: 4 }
    }
}

/// One scored candidate, as shown in the CLI `tune` table.
#[derive(Debug, Clone)]
pub struct CandidateReport {
    /// Human-readable knob setting, e.g. `"bias=1 scan=col passes=4 +burst"`.
    pub label: String,
    pub params: PlaceParams,
    /// True when this candidate forces naive PL movers to burst mode.
    pub forced_burst: bool,
    pub route_cost: RouteCost,
    /// Analytic prediction (`None`: outside the model's validity).
    pub predicted_s: Option<f64>,
    /// DES-confirmed makespan (`None`: pruned before simulation).
    pub simulated_s: Option<f64>,
    pub chosen: bool,
}

/// What one search looked at and decided.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub mode: TuneMode,
    pub candidates: Vec<CandidateReport>,
    /// Index of the installed candidate (0 = untuned default kept).
    pub chosen: usize,
    /// Wall-clock search time, seconds.
    pub search_s: f64,
}

impl TuneReport {
    /// Did the search install something other than the untuned default?
    pub fn improved(&self) -> bool {
        self.chosen != 0
    }

    pub fn chosen_candidate(&self) -> Option<&CandidateReport> {
        self.candidates.get(self.chosen)
    }
}

/// A tuned lowering: the installed plan plus the search evidence.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    pub plan: ExecutablePlan,
    pub report: TuneReport,
}

/// Internal: one enumerated candidate with its shared-warm-up `Prep`.
struct Candidate {
    variant: usize,
    params: PlaceParams,
    forced_burst: bool,
    placed: PlacedGraph,
    cost: RouteCost,
    prep: sim::Prep,
    predicted: Option<f64>,
    simulated: Option<f64>,
}

/// Tune one spec: enumerate, score, and install the winner. `Off` mode
/// degrades to a plain (untuned) lowering with an empty candidate table.
pub fn tune_spec(spec: &Spec, default_arch: &ArchConfig, cfg: &TuneConfig) -> Result<TuneOutcome> {
    let t0 = Instant::now();
    let base = plan_routines(spec, default_arch)?;
    let base_placed = place_and_route(&base)?;
    if cfg.mode == TuneMode::Off {
        return Ok(TuneOutcome {
            plan: ExecutablePlan { plan: base, placed: base_placed },
            report: TuneReport {
                mode: TuneMode::Off,
                candidates: Vec::new(),
                chosen: 0,
                search_s: t0.elapsed().as_secs_f64(),
            },
        });
    }

    // Graph variants: the as-spec graph always; a burst-forced clone when
    // the spec has naive PL movers (burst only changes the DDR efficiency
    // model — mover *timing* — never data values, so it is a legal knob).
    let mut variants: Vec<(RoutinePlan, bool)> = Vec::with_capacity(2);
    let has_naive_movers =
        base.built.graph.num_pl_movers() > 0 && spec.routines.iter().any(|r| !r.burst);
    variants.push((base, false));
    if has_naive_movers {
        let mut burst_spec = spec.clone();
        for r in &mut burst_spec.routines {
            r.burst = true;
        }
        if let Ok(plan) = plan_routines(&burst_spec, default_arch) {
            variants.push((plan, true));
        }
    }

    // Enumerate and score analytically. One full `prepare` per variant;
    // each candidate only re-stamps the routing-dependent latencies.
    let params_list = candidate_params(cfg.max_candidates);
    let mut cands: Vec<Candidate> = Vec::new();
    for (vi, (rp, forced_burst)) in variants.iter().enumerate() {
        let graph = &rp.built.graph;
        let mut base_prep: Option<sim::Prep> = None;
        for params in &params_list {
            let (placement, routing) = if vi == 0 && *params == PlaceParams::default() {
                // candidate 0 reuses the untuned lowering verbatim.
                (base_placed.placement.clone(), base_placed.routing.clone())
            } else {
                let Ok(placement) = place_with(graph, &rp.arch, params) else { continue };
                let Ok(routing) = route(graph, &placement, &rp.arch) else { continue };
                if check_routing(graph, &routing).is_err() {
                    continue;
                }
                (placement, routing)
            };
            let cost = routing.cost_summary();
            let prep = match &base_prep {
                Some(p) => p.with_routing(graph, &routing, &rp.arch),
                None => sim::prepare(graph, &routing, &rp.arch),
            };
            if base_prep.is_none() {
                base_prep = Some(prep.clone());
            }
            let predicted = sim::analytic::predict(graph, &prep);
            cands.push(Candidate {
                variant: vi,
                params: *params,
                forced_burst: *forced_burst,
                placed: PlacedGraph { placement, routing },
                cost,
                prep,
                predicted,
                simulated: None,
            });
        }
    }
    debug_assert!(
        !cands.is_empty()
            && cands[0].variant == 0
            && cands[0].params == PlaceParams::default()
            && !cands[0].forced_burst,
        "candidate 0 must be the untuned default"
    );

    let chosen = match cfg.mode {
        TuneMode::Off => 0,
        TuneMode::Analytic => pick_analytic(&cands),
        TuneMode::Full => {
            simulate_shortlist(&variants, &mut cands, cfg.shortlist);
            pick_simulated(&cands)
        }
    };

    let plan = ExecutablePlan {
        plan: variants[cands[chosen].variant].0.clone(),
        placed: cands[chosen].placed.clone(),
    };
    let candidates = cands
        .iter()
        .enumerate()
        .map(|(i, c)| CandidateReport {
            label: {
                let mut label = c.params.describe();
                if c.forced_burst {
                    label.push_str(" +burst");
                }
                label
            },
            params: c.params,
            forced_burst: c.forced_burst,
            route_cost: c.cost,
            predicted_s: c.predicted,
            simulated_s: c.simulated,
            chosen: i == chosen,
        })
        .collect();
    Ok(TuneOutcome {
        plan,
        report: TuneReport {
            mode: cfg.mode,
            candidates,
            chosen,
            search_s: t0.elapsed().as_secs_f64(),
        },
    })
}

/// Analytic selection: minimum predicted makespan (route cost, then index,
/// break ties), accepted only beyond the no-regret margin. Keeps the
/// default whenever the model cannot price candidate 0.
fn pick_analytic(cands: &[Candidate]) -> usize {
    let Some(p0) = cands[0].predicted else {
        return 0; // outside the model's validity: no evidence, no move
    };
    let mut best = 0usize;
    let mut best_p = p0;
    for (i, c) in cands.iter().enumerate().skip(1) {
        let Some(p) = c.predicted else { continue };
        let better = match p.total_cmp(&best_p) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Equal => c.cost.key() < cands[best].cost.key(),
            std::cmp::Ordering::Greater => false,
        };
        if better {
            best = i;
            best_p = p;
        }
    }
    if best != 0 && best_p >= p0 * (1.0 - ANALYTIC_NO_REGRET_MARGIN) {
        return 0;
    }
    best
}

/// DES-confirm the most promising candidates (by prediction, then route
/// cost), always including candidate 0 so the untuned baseline is priced.
fn simulate_shortlist(variants: &[(RoutinePlan, bool)], cands: &mut [Candidate], budget: usize) {
    let mut order: Vec<usize> = (0..cands.len()).collect();
    order.sort_by(|&a, &b| {
        match (cands[a].predicted, cands[b].predicted) {
            (Some(x), Some(y)) => x
                .total_cmp(&y)
                .then(cands[a].cost.key().cmp(&cands[b].cost.key()))
                .then(a.cmp(&b)),
            (Some(_), None) => std::cmp::Ordering::Less,
            (None, Some(_)) => std::cmp::Ordering::Greater,
            (None, None) => cands[a].cost.key().cmp(&cands[b].cost.key()).then(a.cmp(&b)),
        }
    });
    let mut shortlist: Vec<usize> = order.into_iter().take(budget.max(1)).collect();
    if !shortlist.contains(&0) {
        shortlist.push(0);
    }
    for i in shortlist {
        let c = &mut cands[i];
        let rp = &variants[c.variant].0;
        // a candidate whose simulation fails is simply never chosen; the
        // untuned default needs no simulation to remain installable.
        c.simulated = sim::simulate_prepared(
            &rp.built.graph,
            &c.placed.placement,
            &c.placed.routing,
            &rp.arch,
            &c.prep,
            0,
        )
        .ok()
        .map(|r| r.makespan_s);
    }
}

/// Full-mode selection: minimum DES makespan over the simulated shortlist,
/// lowest index on ties. Candidate 0 is always in the shortlist, so the
/// winner is never DES-worse than the untuned plan.
fn pick_simulated(cands: &[Candidate]) -> usize {
    let mut best: Option<(usize, f64)> = None;
    for (i, c) in cands.iter().enumerate() {
        let Some(s) = c.simulated else { continue };
        let better = match best {
            None => true,
            Some((_, bs)) => s.total_cmp(&bs) == std::cmp::Ordering::Less,
        };
        if better {
            best = Some((i, s));
        }
    }
    best.map_or(0, |(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::pipeline::lower_spec;
    use crate::sim::simulate_plan;
    use crate::spec::{DataSource, Spec};

    fn vck() -> ArchConfig {
        ArchConfig::vck5000()
    }

    fn cfg(mode: TuneMode) -> TuneConfig {
        TuneConfig { mode, ..TuneConfig::default() }
    }

    #[test]
    fn mode_parse_round_trips() {
        for mode in [TuneMode::Off, TuneMode::Analytic, TuneMode::Full] {
            assert_eq!(TuneMode::parse(mode.name()).unwrap(), mode);
        }
        assert!(TuneMode::parse("fast").is_err());
    }

    #[test]
    fn off_mode_is_the_untuned_lowering() {
        let spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        let out = tune_spec(&spec, &vck(), &cfg(TuneMode::Off)).unwrap();
        let untuned = lower_spec(&spec).unwrap();
        assert_eq!(out.plan.graph(), untuned.graph());
        assert_eq!(out.plan.placement().locations, untuned.placement().locations);
        assert!(out.report.candidates.is_empty());
        assert!(!out.report.improved());
    }

    #[test]
    fn full_mode_flips_naive_movers_and_never_loses_to_untuned() {
        // axpy over naive PL movers: the burst variant is the headline win.
        let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl);
        let out = tune_spec(&spec, &vck(), &cfg(TuneMode::Full)).unwrap();
        let untuned_sim =
            out.report.candidates[0].simulated_s.expect("candidate 0 always simulates");
        let chosen = out.report.chosen_candidate().unwrap();
        let chosen_sim = chosen.simulated_s.expect("full mode picks a simulated candidate");
        assert!(chosen_sim <= untuned_sim, "tuned {chosen_sim} !<= untuned {untuned_sim}");
        assert!(chosen.forced_burst, "naive movers must tune to burst");
        assert!(
            chosen_sim <= 0.9 * untuned_sim,
            "burst flip must be a ≥10% win ({chosen_sim} vs {untuned_sim})"
        );
        // the installed plan really is the scored one.
        assert_eq!(simulate_plan(&out.plan).unwrap().makespan_s, chosen_sim);
    }

    #[test]
    fn analytic_mode_finds_the_burst_win_without_des() {
        let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl);
        let out = tune_spec(&spec, &vck(), &cfg(TuneMode::Analytic)).unwrap();
        let chosen = out.report.chosen_candidate().unwrap();
        assert!(chosen.forced_burst, "analytic tier must see the ≥3× mover speedup");
        assert!(chosen.simulated_s.is_none(), "analytic mode must not run the DES");
        let tuned = simulate_plan(&out.plan).unwrap().makespan_s;
        let untuned = simulate_plan(&lower_spec(&spec).unwrap()).unwrap().makespan_s;
        assert!(tuned < untuned, "tuned {tuned} !< untuned {untuned}");
    }

    #[test]
    fn analytic_mode_keeps_default_outside_model_validity() {
        // gemv is multi-rate: the analytic tier must refuse to guess.
        let spec = Spec::single(RoutineKind::Gemv, "g", 256, DataSource::Pl);
        let out = tune_spec(&spec, &vck(), &cfg(TuneMode::Analytic)).unwrap();
        assert_eq!(out.report.chosen, 0, "no prediction for candidate 0 ⇒ no move");
        assert!(out.report.candidates.iter().all(|c| c.predicted_s.is_none()));
    }

    #[test]
    fn already_burst_spec_gets_no_burst_variant() {
        let mut spec = Spec::single(RoutineKind::Axpy, "a", 1 << 14, DataSource::Pl);
        spec.routines[0].burst = true;
        let out = tune_spec(&spec, &vck(), &cfg(TuneMode::Full)).unwrap();
        assert!(out.report.candidates.iter().all(|c| !c.forced_burst));
    }

    #[test]
    fn tuned_plan_passes_the_same_checks_as_untuned() {
        let spec = Spec::axpydot_dataflow(1 << 14, 2.0);
        let out = tune_spec(&spec, &vck(), &cfg(TuneMode::Full)).unwrap();
        out.plan.graph().check_invariants().unwrap();
        check_routing(out.plan.graph(), out.plan.routing()).unwrap();
        assert_eq!(out.plan.graph().nodes.len(), out.plan.placement().locations.len());
    }

    #[test]
    fn candidate_tables_are_bounded_and_deterministic() {
        let spec = Spec::single(RoutineKind::Dot, "d", 1 << 14, DataSource::Pl);
        let config = TuneConfig { mode: TuneMode::Analytic, max_candidates: 6, shortlist: 2 };
        let a = tune_spec(&spec, &vck(), &config).unwrap();
        let b = tune_spec(&spec, &vck(), &config).unwrap();
        assert!(a.report.candidates.len() <= 2 * 6, "two variants × six params max");
        assert_eq!(a.report.chosen, b.report.chosen, "tuning must be deterministic");
        let labels: Vec<&str> = a.report.candidates.iter().map(|c| c.label.as_str()).collect();
        let labels_b: Vec<&str> = b.report.candidates.iter().map(|c| c.label.as_str()).collect();
        assert_eq!(labels, labels_b);
    }
}
