//! Simulation reports: per-kernel utilization, interface bandwidth, and
//! the headline metrics the Fig. 3 harness prints.

use crate::arch::ArchConfig;
use crate::graph::place::{Location, Placement};
use crate::graph::route::Routing;
use crate::graph::{Graph, NodeKind};
use crate::sim::NodeSched;

/// Per-kernel activity summary.
#[derive(Debug, Clone)]
pub struct KernelStats {
    pub name: String,
    pub location: String,
    pub iterations: usize,
    pub busy_s: f64,
    /// busy / makespan.
    pub utilization: f64,
}

/// The simulator's output for one graph execution.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// End-to-end simulated device time, seconds.
    pub makespan_s: f64,
    /// Bytes moved across the PL↔AIE interfaces (both directions).
    pub interface_bytes: u64,
    /// Bytes transferred to/from device DRAM.
    pub device_bytes: u64,
    /// Total floating-point ops across AIE kernels.
    pub flops: u64,
    /// Per-kernel stats (AIE kernels only).
    pub kernels: Vec<KernelStats>,
    /// PL→AIE / AIE→PL channels in use.
    pub pl_to_aie_channels: usize,
    pub aie_to_pl_channels: usize,
    /// NoC hops across all routed edges.
    pub noc_hops: usize,
}

impl SimReport {
    /// Achieved off-chip bandwidth (bytes/s).
    pub fn achieved_ddr_bw(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            return 0.0;
        }
        self.device_bytes as f64 / self.makespan_s
    }

    /// Achieved arithmetic rate (FLOP/s).
    pub fn achieved_flops(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            return 0.0;
        }
        self.flops as f64 / self.makespan_s
    }

    /// One-line summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "device time {:.3} ms | {:.2} GB/s off-chip | {:.2} GFLOP/s | {} AIE kernels | {}+{} PL channels",
            self.makespan_s * 1e3,
            self.achieved_ddr_bw() / 1e9,
            self.achieved_flops() / 1e9,
            self.kernels.len(),
            self.pl_to_aie_channels,
            self.aie_to_pl_channels,
        )
    }
}

/// Assemble the report (called by both simulation engines). Takes the
/// engine's [`NodeSched`] slice directly — no per-call iteration-count
/// vector is allocated.
pub(crate) fn build(
    graph: &Graph,
    placement: &Placement,
    routing: &Routing,
    _arch: &ArchConfig,
    makespan: f64,
    busy_total: &[f64],
    sched: &[NodeSched],
) -> SimReport {
    let mut kernels = Vec::new();
    let mut flops = 0u64;
    for node in &graph.nodes {
        if let NodeKind::AieKernel { kind, size, .. } = &node.kind {
            flops += kind.flops(*size);
            let location = match placement.of(node.id) {
                Location::Tile { col, row } => format!("aie({col},{row})"),
                Location::Shim { col } => format!("shim({col})"),
                Location::OffChip => "offchip".to_string(),
            };
            kernels.push(KernelStats {
                name: node.name.clone(),
                location,
                iterations: sched[node.id].iters,
                busy_s: busy_total[node.id],
                utilization: if makespan > 0.0 { busy_total[node.id] / makespan } else { 0.0 },
            });
        }
    }

    let mut interface_bytes = 0u64;
    let mut device_bytes = 0u64;
    for e in &graph.edges {
        let r = routing.of(e.id);
        if r.uses_pl_to_aie || r.uses_aie_to_pl {
            interface_bytes += e.total_bytes() as u64;
            device_bytes += e.total_bytes() as u64;
        }
    }

    SimReport {
        makespan_s: makespan,
        interface_bytes,
        device_bytes,
        flops,
        kernels,
        pl_to_aie_channels: routing.pl_to_aie_used,
        aie_to_pl_channels: routing.aie_to_pl_used,
        noc_hops: routing.total_hops(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::sim::simulate_spec;
    use crate::spec::{DataSource, Spec};

    #[test]
    fn report_accounting() {
        let n = 1usize << 16;
        let r = simulate_spec(&Spec::single(RoutineKind::Axpy, "a", n, DataSource::Pl)).unwrap();
        // alpha + x + y in, z out = (3n + 1) * 4 bytes off-chip
        assert_eq!(r.device_bytes, (3 * n + 1) as u64 * 4);
        assert_eq!(r.flops, 2 * n as u64);
        assert_eq!(r.kernels.len(), 1);
        assert!(r.achieved_ddr_bw() > 0.0);
        assert!(r.summary().contains("device time"));
    }

    #[test]
    fn onchip_moves_no_device_bytes() {
        let r = simulate_spec(&Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::OnChip))
            .unwrap();
        assert_eq!(r.device_bytes, 0);
        assert_eq!(r.interface_bytes, 0);
    }
}
