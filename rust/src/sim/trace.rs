//! Execution-trace capture + export.
//!
//! The simulator can record every (node, iteration) service interval and
//! export it as a Chrome-tracing JSON (`chrome://tracing`, Perfetto) or a
//! text Gantt chart — the observability a user needs to see *why* the
//! non-dataflow axpydot is 2× slower (the dot stage idles until the DDR
//! round trip completes).
//!
//! A recorded [`Span`] is four words: node id, iteration, start, end.
//! Node names and lane labels live in a per-trace label table set once by
//! the engine ([`Trace::set_labels`]) and resolved only at render time —
//! recording a span allocates nothing (the engine's traced hot path used
//! to clone two `String`s per iteration).

use crate::util::json::{obj, Json};

/// One recorded service interval. Display strings are *not* stored here;
/// resolve them through [`Trace::name_of`] / [`Trace::lane_of`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub node: usize,
    pub iteration: usize,
    pub start_s: f64,
    pub end_s: f64,
}

/// A full execution trace: the spans plus the node-indexed label table
/// (`(name, lane)` per node) they are rendered against.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
    labels: Vec<(String, String)>,
}

impl Trace {
    pub fn record(&mut self, span: Span) {
        self.spans.push(span);
    }

    /// Install the node-indexed `(name, lane)` label table (computed once
    /// per simulation, not per span).
    pub fn set_labels(&mut self, labels: Vec<(String, String)>) {
        self.labels = labels;
    }

    /// Kernel name for a node id (`node<N>` when no label was installed).
    pub fn name_of(&self, node: usize) -> String {
        match self.labels.get(node) {
            Some((name, _)) => name.clone(),
            None => format!("node{node}"),
        }
    }

    /// Row label (tile/shim location) for a node id.
    pub fn lane_of(&self, node: usize) -> String {
        match self.labels.get(node) {
            Some((_, lane)) => lane.clone(),
            None => format!("node{node}"),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Total simulated time covered.
    pub fn makespan_s(&self) -> f64 {
        self.spans.iter().map(|s| s.end_s).fold(0.0, f64::max)
    }

    /// Per-node labels resolved once per render (the hot per-span loops
    /// below must not allocate label strings per span): `(names, lanes,
    /// used)`, indexed by node id, where `used[i]` marks nodes that appear
    /// in at least one span (only those get lanes/rows).
    fn label_tables(&self) -> (Vec<String>, Vec<String>, Vec<bool>) {
        let n = self.spans.iter().map(|s| s.node + 1).max().unwrap_or(0);
        let mut used = vec![false; n];
        for s in &self.spans {
            used[s.node] = true;
        }
        (
            (0..n).map(|i| self.name_of(i)).collect(),
            (0..n).map(|i| self.lane_of(i)).collect(),
            used,
        )
    }

    /// The sorted, deduplicated lane list of the nodes actually traced.
    fn used_lanes<'t>(node_lanes: &'t [String], used: &[bool]) -> Vec<&'t str> {
        let mut lanes: Vec<&str> = node_lanes
            .iter()
            .zip(used)
            .filter(|(_, &u)| u)
            .map(|(l, _)| l.as_str())
            .collect();
        lanes.sort_unstable();
        lanes.dedup();
        lanes
    }

    /// Chrome-tracing "trace event" JSON (µs timestamps, `X` complete
    /// events, one tid per node lane).
    pub fn to_chrome_json(&self) -> String {
        let (names, node_lanes, used) = self.label_tables();
        let lanes = Self::used_lanes(&node_lanes, &used);
        // node → tid, resolved once per node instead of once per span.
        let tid_of: Vec<usize> = node_lanes
            .iter()
            .map(|lane| lanes.iter().position(|l| l == lane).unwrap_or(0))
            .collect();
        let events: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                obj(vec![
                    ("name", format!("{}#{}", names[s.node], s.iteration).into()),
                    ("cat", "sim".into()),
                    ("ph", "X".into()),
                    ("ts", (s.start_s * 1e6).into()),
                    ("dur", ((s.end_s - s.start_s) * 1e6).into()),
                    ("pid", 1usize.into()),
                    ("tid", tid_of[s.node].into()),
                ])
            })
            .collect();
        let meta: Vec<Json> = lanes
            .iter()
            .enumerate()
            .map(|(tid, lane)| {
                obj(vec![
                    ("name", "thread_name".into()),
                    ("ph", "M".into()),
                    ("pid", 1usize.into()),
                    ("tid", tid.into()),
                    ("args", obj(vec![("name", (*lane).into())])),
                ])
            })
            .collect();
        let mut all = meta;
        all.extend(events);
        obj(vec![("traceEvents", Json::Arr(all))]).to_compact()
    }

    /// Text Gantt chart: one row per lane, `width` columns over the
    /// makespan, `#` where the lane is busy.
    pub fn to_gantt(&self, width: usize) -> String {
        let total = self.makespan_s();
        if total <= 0.0 || self.spans.is_empty() {
            return String::from("(empty trace)\n");
        }
        let (_, node_lanes, used) = self.label_tables();
        let lanes = Self::used_lanes(&node_lanes, &used);
        let name_w = lanes.iter().map(|l| l.len()).max().unwrap_or(4).max(4);
        let mut out = String::new();
        for lane in &lanes {
            let mut cells = vec![' '; width];
            for s in self.spans.iter().filter(|s| node_lanes[s.node] == *lane) {
                let a = ((s.start_s / total) * width as f64) as usize;
                let b = (((s.end_s / total) * width as f64).ceil() as usize).min(width);
                for c in cells.iter_mut().take(b).skip(a.min(width.saturating_sub(1))) {
                    *c = '#';
                }
            }
            out.push_str(&format!(
                "{lane:<name_w$} |{}|\n",
                cells.iter().collect::<String>()
            ));
        }
        out.push_str(&format!(
            "{:<name_w$}  0{:>w$}\n",
            "",
            crate::util::table::fmt_time(total),
            w = width
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::default();
        t.set_labels(vec![
            ("axpy".into(), "aie(0,0)".into()),
            ("dot".into(), "aie(1,0)".into()),
        ]);
        t.record(Span { node: 0, iteration: 0, start_s: 0.0, end_s: 1e-6 });
        t.record(Span { node: 1, iteration: 0, start_s: 1e-6, end_s: 2e-6 });
        t
    }

    #[test]
    fn makespan_is_last_end() {
        assert_eq!(sample().makespan_s(), 2e-6);
    }

    #[test]
    fn spans_are_slim() {
        // the satellite's point: recording a span must not carry Strings.
        assert!(std::mem::size_of::<Span>() <= 4 * 8);
    }

    #[test]
    fn chrome_json_is_valid_and_complete() {
        let j = sample().to_chrome_json();
        let parsed = Json::parse(&j).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 thread-name metadata + 2 spans
        assert_eq!(events.len(), 4);
        let span = &events[2];
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(1.0)); // 1 µs
        assert_eq!(span.get("name").unwrap().as_str(), Some("axpy#0"));
    }

    #[test]
    fn gantt_renders_rows_per_lane() {
        let g = sample().to_gantt(20);
        assert_eq!(g.lines().count(), 3); // 2 lanes + axis
        assert!(g.contains("aie(0,0)"));
        assert!(g.contains('#'));
    }

    #[test]
    fn unlabelled_nodes_render_with_fallback() {
        let mut t = Trace::default();
        t.record(Span { node: 7, iteration: 3, start_s: 0.0, end_s: 1e-6 });
        assert_eq!(t.name_of(7), "node7");
        assert!(t.to_gantt(10).contains("node7"));
        assert!(Json::parse(&t.to_chrome_json()).is_ok());
    }

    #[test]
    fn empty_trace_handled() {
        assert_eq!(Trace::default().to_gantt(10), "(empty trace)\n");
        assert!(Json::parse(&Trace::default().to_chrome_json()).is_ok());
    }
}
