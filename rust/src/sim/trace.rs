//! Execution-trace capture + export.
//!
//! The simulator can record every (node, iteration) service interval and
//! export it as a Chrome-tracing JSON (`chrome://tracing`, Perfetto) or a
//! text Gantt chart — the observability a user needs to see *why* the
//! non-dataflow axpydot is 2× slower (the dot stage idles until the DDR
//! round trip completes).

use crate::util::json::{obj, Json};

/// One recorded service interval.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub node: usize,
    pub name: String,
    /// Row label (tile/shim location).
    pub lane: String,
    pub iteration: usize,
    pub start_s: f64,
    pub end_s: f64,
}

/// A full execution trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
}

impl Trace {
    pub fn record(&mut self, span: Span) {
        self.spans.push(span);
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Total simulated time covered.
    pub fn makespan_s(&self) -> f64 {
        self.spans.iter().map(|s| s.end_s).fold(0.0, f64::max)
    }

    /// Chrome-tracing "trace event" JSON (µs timestamps, `X` complete
    /// events, one tid per node lane).
    pub fn to_chrome_json(&self) -> String {
        let mut lanes: Vec<&str> = self.spans.iter().map(|s| s.lane.as_str()).collect();
        lanes.sort_unstable();
        lanes.dedup();
        let tid_of = |lane: &str| lanes.iter().position(|&l| l == lane).unwrap();
        let events: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                obj(vec![
                    ("name", format!("{}#{}", s.name, s.iteration).into()),
                    ("cat", "sim".into()),
                    ("ph", "X".into()),
                    ("ts", (s.start_s * 1e6).into()),
                    ("dur", ((s.end_s - s.start_s) * 1e6).into()),
                    ("pid", 1usize.into()),
                    ("tid", tid_of(&s.lane).into()),
                ])
            })
            .collect();
        let meta: Vec<Json> = lanes
            .iter()
            .enumerate()
            .map(|(tid, lane)| {
                obj(vec![
                    ("name", "thread_name".into()),
                    ("ph", "M".into()),
                    ("pid", 1usize.into()),
                    ("tid", tid.into()),
                    ("args", obj(vec![("name", (*lane).into())])),
                ])
            })
            .collect();
        let mut all = meta;
        all.extend(events);
        obj(vec![("traceEvents", Json::Arr(all))]).to_compact()
    }

    /// Text Gantt chart: one row per lane, `width` columns over the
    /// makespan, `#` where the lane is busy.
    pub fn to_gantt(&self, width: usize) -> String {
        let total = self.makespan_s();
        if total <= 0.0 || self.spans.is_empty() {
            return String::from("(empty trace)\n");
        }
        let mut lanes: Vec<&str> = self.spans.iter().map(|s| s.lane.as_str()).collect();
        lanes.sort_unstable();
        lanes.dedup();
        let name_w = lanes.iter().map(|l| l.len()).max().unwrap_or(4).max(4);
        let mut out = String::new();
        for lane in &lanes {
            let mut cells = vec![' '; width];
            for s in self.spans.iter().filter(|s| s.lane == *lane) {
                let a = ((s.start_s / total) * width as f64) as usize;
                let b = (((s.end_s / total) * width as f64).ceil() as usize).min(width);
                for c in cells.iter_mut().take(b).skip(a.min(width.saturating_sub(1))) {
                    *c = '#';
                }
            }
            out.push_str(&format!(
                "{lane:<name_w$} |{}|\n",
                cells.iter().collect::<String>()
            ));
        }
        out.push_str(&format!(
            "{:<name_w$}  0{:>w$}\n",
            "",
            crate::util::table::fmt_time(total),
            w = width
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::default();
        t.record(Span {
            node: 0,
            name: "axpy".into(),
            lane: "aie(0,0)".into(),
            iteration: 0,
            start_s: 0.0,
            end_s: 1e-6,
        });
        t.record(Span {
            node: 1,
            name: "dot".into(),
            lane: "aie(1,0)".into(),
            iteration: 0,
            start_s: 1e-6,
            end_s: 2e-6,
        });
        t
    }

    #[test]
    fn makespan_is_last_end() {
        assert_eq!(sample().makespan_s(), 2e-6);
    }

    #[test]
    fn chrome_json_is_valid_and_complete() {
        let j = sample().to_chrome_json();
        let parsed = Json::parse(&j).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 thread-name metadata + 2 spans
        assert_eq!(events.len(), 4);
        let span = &events[2];
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(1.0)); // 1 µs
    }

    #[test]
    fn gantt_renders_rows_per_lane() {
        let g = sample().to_gantt(20);
        assert_eq!(g.lines().count(), 3); // 2 lanes + axis
        assert!(g.contains("aie(0,0)"));
        assert!(g.contains('#'));
    }

    #[test]
    fn empty_trace_handled() {
        assert_eq!(Trace::default().to_gantt(10), "(empty trace)\n");
        assert!(Json::parse(&Trace::default().to_chrome_json()).is_ok());
    }
}
