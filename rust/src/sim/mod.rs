//! Discrete-event simulation of a placed + routed dataflow graph.
//!
//! Token-dataflow semantics at *window* granularity, matching the ADF
//! execution model: each node repeatedly (1) waits for one window on each
//! of its input edges, (2) waits for buffer space on each output edge
//! (ping-pong double buffering → capacity 2), (3) occupies its resource
//! for the window's service time, (4) emits output windows, which arrive
//! at the consumer after the edge's transfer latency.
//!
//! Pipelining across composed routines — the paper's central performance
//! mechanism (Fig. 3 "w/ DF") — emerges naturally: the dot kernel starts
//! on window *i* while the axpy kernel computes window *i+1*.
//!
//! Edges with fewer windows than the node's iteration count (e.g. the
//! scalar alpha stream, or gemv's x vector re-read per row block) are
//! consumed/produced at evenly spread iterations (rate-matched dataflow).
//!
//! Two engines implement these semantics (DESIGN.md §7):
//! * [`engine`] (default) — event-driven: ready-queue scheduling, O(1)
//!   ring-buffer edge state, incremental stride counters, and a
//!   steady-state fast-forward that advances periodic regions in closed
//!   form.
//! * [`naive`] — the original worklist-of-rounds reference, kept under
//!   `#[cfg(test)]` / the `sim-naive` feature so parity can be asserted.

pub mod report;
pub mod trace;

mod engine;
#[cfg(any(test, feature = "sim-naive"))]
pub mod naive;

#[cfg(test)]
mod parity_tests;

use crate::aie::seconds_per_window;
use crate::arch::ArchConfig;
use crate::graph::place::Placement;
use crate::graph::route::Routing;
use crate::graph::{Graph, NodeKind};
use crate::pl::window_transfer_s;
use crate::Result;

pub use report::SimReport;

/// Double-buffer depth of window edges (ADF ping-pong).
pub(crate) const EDGE_CAPACITY: usize = 2;

/// Per-node simulation schedule derived from the graph.
pub(crate) struct NodeSched {
    /// Total iterations (windows to process).
    pub(crate) iters: usize,
    /// Service time per iteration, seconds.
    pub(crate) service_s: f64,
    /// One-time launch overhead, seconds.
    pub(crate) launch_s: f64,
}

/// Everything both engines derive from the graph before the event loop:
/// per-node schedules, per-edge latencies and window counts, and the
/// adjacency lists (the worklist loop touching `graph.edges` per iteration
/// was the top profile entry — see EXPERIMENTS.md §Perf).
pub(crate) struct Prep {
    pub(crate) sched: Vec<NodeSched>,
    pub(crate) edge_latency: Vec<f64>,
    pub(crate) in_adj: Vec<Vec<usize>>,
    pub(crate) out_adj: Vec<Vec<usize>>,
    pub(crate) edge_windows: Vec<usize>,
}

pub(crate) fn prepare(graph: &Graph, routing: &Routing, arch: &ArchConfig) -> Prep {
    let n = graph.nodes.len();
    let active_movers = graph.num_pl_movers().max(1);

    // --- derive schedules ---------------------------------------------------
    let mut sched = Vec::with_capacity(n);
    for node in &graph.nodes {
        let in_w: usize = graph.in_edges(node.id).map(|e| e.num_windows()).max().unwrap_or(0);
        let out_w: usize = graph.out_edges(node.id).map(|e| e.num_windows()).max().unwrap_or(0);
        let iters = in_w.max(out_w).max(1);
        let (service_s, launch_s) = match &node.kind {
            NodeKind::AieKernel { kind, window, vector_bits, size, .. } => {
                // per-iteration window elements: the dominant in-edge's.
                let we = graph
                    .in_edges(node.id)
                    .chain(graph.out_edges(node.id))
                    .filter(|e| e.num_windows() == iters)
                    .map(|e| e.window_elements)
                    .max()
                    .unwrap_or((*window).min(*size));
                (
                    seconds_per_window(*kind, we, *vector_bits, arch),
                    arch.kernel_call_cycles as f64 * arch.aie_cycle_s(),
                )
            }
            NodeKind::PlMm2s { burst } | NodeKind::PlS2mm { burst } => {
                let bytes = graph
                    .out_edges(node.id)
                    .chain(graph.in_edges(node.id))
                    .map(|e| e.window_bytes())
                    .max()
                    .unwrap_or(0);
                (window_transfer_s(arch, bytes, *burst, active_movers), 0.0)
            }
            NodeKind::Combine { parts } => {
                // k scalar adds + stream reads: trivially cheap next to
                // window compute; modelled as one overhead slot.
                (
                    (*parts as u64 + arch.window_overhead_cycles) as f64 * arch.aie_cycle_s(),
                    0.0,
                )
            }
            NodeKind::OnChipSource | NodeKind::OnChipSink => {
                // synthetic generation: one vector write per lane-group —
                // effectively free next to real transfers, but not zero.
                (arch.window_overhead_cycles as f64 * arch.aie_cycle_s(), 0.0)
            }
        };
        sched.push(NodeSched { iters, service_s, launch_s });
    }

    // --- edge latency (beyond producer service) -----------------------------
    let mut edge_latency = vec![0.0f64; graph.edges.len()];
    for e in &graph.edges {
        let r = routing.of(e.id);
        let hop_s = r.hops as f64 * arch.noc_hop_cycles as f64 * arch.aie_cycle_s();
        let src_pl = graph.node(e.src).kind.is_pl();
        let dst_pl = graph.node(e.dst).kind.is_pl();
        let stream_s = if !r.neighbour && !src_pl && !dst_pl {
            // AIE→AIE over the stream network: 4 B/cycle serialization.
            e.window_bytes() as f64 / arch.stream_bytes_per_cycle() * arch.aie_cycle_s()
        } else {
            0.0 // PL transfers are costed in the mover's service time
        };
        edge_latency[e.id] = hop_s + stream_s;
    }

    // --- adjacency ----------------------------------------------------------
    let mut in_adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut out_adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for e in &graph.edges {
        in_adj[e.dst].push(e.id);
        out_adj[e.src].push(e.id);
    }
    let edge_windows: Vec<usize> = graph.edges.iter().map(|e| e.num_windows()).collect();

    Prep { sched, edge_latency, in_adj, out_adj, edge_windows }
}

/// Simulate a placed+routed graph; returns the timing report.
pub fn simulate(
    graph: &Graph,
    placement: &Placement,
    routing: &Routing,
    arch: &ArchConfig,
) -> Result<SimReport> {
    simulate_inner(graph, placement, routing, arch, None)
}

/// Simulate and additionally record a full execution trace (Chrome-trace /
/// Gantt export via [`trace::Trace`]).
pub fn simulate_traced(
    graph: &Graph,
    placement: &Placement,
    routing: &Routing,
    arch: &ArchConfig,
) -> Result<(SimReport, trace::Trace)> {
    let mut t = trace::Trace::default();
    let rep = simulate_inner(graph, placement, routing, arch, Some(&mut t))?;
    Ok((rep, t))
}

fn simulate_inner(
    graph: &Graph,
    placement: &Placement,
    routing: &Routing,
    arch: &ArchConfig,
    tracer: Option<&mut trace::Trace>,
) -> Result<SimReport> {
    let prep = prepare(graph, routing, arch);
    let (makespan, busy_total, _stats) = engine::run(graph, placement, &prep, tracer)?;
    Ok(report::build(graph, placement, routing, arch, makespan, &busy_total, &prep.sched))
}

/// Simulate an already-lowered plan (the [`crate::runtime::SimBackend`]
/// execution primitive).
pub fn simulate_plan(plan: &crate::pipeline::ExecutablePlan) -> Result<SimReport> {
    simulate(plan.graph(), plan.placement(), plan.routing(), plan.arch())
}

/// Convenience: lower a spec through the staged pipeline (uncached) and
/// simulate it.
pub fn simulate_spec(spec: &crate::spec::Spec) -> Result<SimReport> {
    simulate_plan(&crate::pipeline::lower_spec(spec)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::spec::{DataSource, Spec};

    fn sim(spec: &Spec) -> SimReport {
        simulate_spec(spec).unwrap()
    }

    #[test]
    fn axpy_pl_simulates() {
        let r = sim(&Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl));
        assert!(r.makespan_s > 0.0);
        assert!(r.device_bytes > 0);
    }

    #[test]
    fn no_pl_is_faster_than_pl() {
        // Fig. 3 claim C1: on-chip generation removes the off-chip
        // bottleneck for memory-bound routines.
        for n in [1usize << 14, 1 << 18, 1 << 20] {
            let pl = sim(&Spec::single(RoutineKind::Axpy, "a", n, DataSource::Pl));
            let onchip = sim(&Spec::single(RoutineKind::Axpy, "a", n, DataSource::OnChip));
            assert!(
                onchip.makespan_s < pl.makespan_s,
                "n={n}: onchip {} !< pl {}",
                onchip.makespan_s,
                pl.makespan_s
            );
        }
    }

    #[test]
    fn gemv_no_pl_faster() {
        for n in [128usize, 512] {
            let pl = sim(&Spec::single(RoutineKind::Gemv, "g", n, DataSource::Pl));
            let onchip = sim(&Spec::single(RoutineKind::Gemv, "g", n, DataSource::OnChip));
            assert!(onchip.makespan_s < pl.makespan_s, "n={n}");
        }
    }

    #[test]
    fn time_grows_with_size() {
        let small = sim(&Spec::single(RoutineKind::Axpy, "a", 1 << 12, DataSource::Pl));
        let large = sim(&Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::Pl));
        assert!(large.makespan_s > 10.0 * small.makespan_s);
    }

    #[test]
    fn dataflow_axpydot_beats_sum_of_stages() {
        // DF pipeline must beat sequential axpy-then-dot (the no-DF lower
        // bound is roughly the sum plus the DDR round trip).
        let n = 1 << 20;
        let df = sim(&Spec::axpydot_dataflow(n, 2.0));
        let axpy = sim(&Spec::single(RoutineKind::Axpy, "a", n, DataSource::Pl));
        let dot = sim(&Spec::single(RoutineKind::Dot, "d", n, DataSource::Pl));
        let sequential = axpy.makespan_s + dot.makespan_s;
        assert!(
            df.makespan_s < sequential,
            "DF {} !< sequential {}",
            df.makespan_s,
            sequential
        );
    }

    #[test]
    fn composite_expansion_simulates_like_explicit_composition() {
        let n = 1 << 16;
        let explicit = sim(&Spec::axpydot_dataflow(n, 2.0));
        let composite = sim(&Spec::single(RoutineKind::Axpydot, "ad", n, DataSource::Pl));
        let ratio = composite.makespan_s / explicit.makespan_s;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn utilization_bounded() {
        let r = sim(&Spec::single(RoutineKind::Dot, "d", 1 << 18, DataSource::Pl));
        for k in &r.kernels {
            assert!(k.utilization >= 0.0 && k.utilization <= 1.0 + 1e-9, "{}", k.name);
        }
    }

    #[test]
    fn burst_improves_pl_bound_routine() {
        let n = 1 << 20;
        let mut naive = Spec::single(RoutineKind::Axpy, "a", n, DataSource::Pl);
        let mut burst = naive.clone();
        naive.routines[0].burst = false;
        burst.routines[0].burst = true;
        let t_naive = sim(&naive).makespan_s;
        let t_burst = sim(&burst).makespan_s;
        assert!(t_burst < t_naive, "burst {t_burst} !< naive {t_naive}");
    }

    #[test]
    fn scalar_only_edges_do_not_deadlock() {
        // dot produces a single scalar token; ensure rate-matching handles
        // 1-token edges over many iterations.
        let r = sim(&Spec::single(RoutineKind::Dot, "d", 1 << 14, DataSource::Pl));
        assert!(r.makespan_s > 0.0);
    }
}
