//! Discrete-event simulation of a placed + routed dataflow graph.
//!
//! Token-dataflow semantics at *window* granularity, matching the ADF
//! execution model: each node repeatedly (1) waits for one window on each
//! of its input edges, (2) waits for buffer space on each output edge
//! (ping-pong double buffering → capacity 2), (3) occupies its resource
//! for the window's service time, (4) emits output windows, which arrive
//! at the consumer after the edge's transfer latency.
//!
//! Pipelining across composed routines — the paper's central performance
//! mechanism (Fig. 3 "w/ DF") — emerges naturally: the dot kernel starts
//! on window *i* while the axpy kernel computes window *i+1*.
//!
//! Edges with fewer windows than the node's iteration count (e.g. the
//! scalar alpha stream, or gemv's x vector re-read per row block) are
//! consumed/produced at evenly spread iterations (rate-matched dataflow).
//!
//! Two engines implement these semantics (DESIGN.md §7):
//! * [`engine`] (default) — event-driven: ready-queue scheduling, O(1)
//!   ring-buffer edge state, incremental stride counters, a *multi-rate*
//!   steady-state fast-forward that advances periodic regions (uniform
//!   and rate-mismatched alike) in closed form, and parallel simulation
//!   of weakly-connected components over `util::threadpool`.
//! * [`naive`] — the original worklist-of-rounds reference, kept under
//!   `#[cfg(test)]` / the `sim-naive` feature so parity can be asserted.

pub mod analytic;
pub mod report;
pub mod trace;

mod engine;
#[cfg(any(test, feature = "sim-naive"))]
pub mod naive;

#[cfg(test)]
mod parity_tests;

use crate::aie::seconds_per_window;
use crate::arch::ArchConfig;
use crate::graph::place::Placement;
use crate::graph::route::Routing;
use crate::graph::{Graph, NodeKind};
use crate::pl::window_transfer_s;
use crate::Result;

pub use report::SimReport;

/// Double-buffer depth of window edges (ADF ping-pong).
pub(crate) const EDGE_CAPACITY: usize = 2;

/// Largest per-node steady-state pattern period (iterations per component
/// hyperperiod) the multi-rate fast-forward will track. Periods beyond
/// this would need proportionally long detection windows and finish-time
/// history, so such nodes simply run through the event loop (gemv's
/// `n/16`-iteration row-block period fits up to n = 8192).
pub(crate) const PERIOD_CAP: usize = 512;

/// Engine configuration for [`simulate_with`] — the defaults are what
/// [`simulate`] uses; benches pin them down to compare engine generations
/// (`multirate: false, threads: 1` pins the PR 2 configuration:
/// uniform-rate fast-forward only, one component at a time).
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Allow fast-forward on rate-mismatched periodic regions (multi-rate
    /// hyperperiod jumps). When false, only uniform-rate regions jump.
    pub multirate: bool,
    /// Worker threads for independent weakly-connected components.
    /// `0` = auto: `AIEBLAS_SIM_THREADS` env var, else all cores.
    pub threads: usize,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { multirate: true, threads: 0 }
    }
}

/// Resolve the effective component-parallelism width.
fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::env::var("AIEBLAS_SIM_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n: &usize| n > 0)
        .unwrap_or_else(crate::util::threadpool::num_threads)
}

/// Per-node simulation schedule derived from the graph.
#[derive(Clone)]
pub(crate) struct NodeSched {
    /// Total iterations (windows to process).
    pub(crate) iters: usize,
    /// Service time per iteration, seconds.
    pub(crate) service_s: f64,
    /// One-time launch overhead, seconds.
    pub(crate) launch_s: f64,
}

/// Weakly-connected components of the dataflow graph, computed **once per
/// plan** in [`prepare`] (PR 2 recomputed them per engine run). They are
/// both the fast-forward regions and the parallel-simulation units: no
/// edge crosses a component, so each one simulates independently.
#[derive(Clone)]
pub(crate) struct Components {
    /// Per-node component id.
    pub(crate) of_node: Vec<usize>,
    /// Component count.
    pub(crate) count: usize,
    /// Global node ids per component, ascending.
    pub(crate) nodes: Vec<Vec<usize>>,
    /// Global edge ids per component, ascending.
    pub(crate) edges: Vec<Vec<usize>>,
    /// Global node id → dense index within its component's `nodes`.
    pub(crate) node_local: Vec<usize>,
    /// Global edge id → dense index within its component's `edges`.
    pub(crate) edge_local: Vec<usize>,
    /// Total iterations per component (engine termination counts).
    pub(crate) total_iters: Vec<usize>,
}

/// Everything both engines derive from the graph before the event loop:
/// per-node schedules, per-edge latencies and window counts, adjacency
/// lists, and the component partition + steady-state periods that drive
/// the event engine's multi-rate fast-forward and parallel execution.
#[derive(Clone)]
pub(crate) struct Prep {
    pub(crate) sched: Vec<NodeSched>,
    pub(crate) edge_latency: Vec<f64>,
    pub(crate) in_adj: Vec<Vec<usize>>,
    pub(crate) out_adj: Vec<Vec<usize>>,
    pub(crate) edge_windows: Vec<usize>,
    /// Per-node steady-state pattern period in own iterations (iterations
    /// per component hyperperiod). `0` = ineligible for fast-forward
    /// (transient node, or period beyond [`PERIOD_CAP`]).
    pub(crate) period: Vec<usize>,
    /// Per-edge tokens fired per component hyperperiod. `0` = sporadic:
    /// the edge fires too rarely (or too irregularly) to translate with a
    /// jump, so jumps must keep it silent.
    pub(crate) unit_tokens: Vec<usize>,
    /// Whether multi-rate detection is enabled. Gates the engine's
    /// slaved-node shortcut so the pinned PR 2 configuration
    /// (`SimOptions { multirate: false, .. }`) keeps PR 2 *semantics* —
    /// uniform-rate-only fast-forward, full stability window for every
    /// node. (It is a reconstruction, not the PR 2 binary: margin
    /// constants and jump rounding differ slightly.)
    pub(crate) multirate: bool,
    pub(crate) comp: Components,
}

impl Prep {
    /// Re-derive only what routing affects. Everything else in a `Prep` —
    /// schedules, adjacency, windows, components, periods — depends on the
    /// graph alone, so the placement autotuner prepares **once per graph
    /// variant** and stamps each placement/routing candidate with fresh
    /// per-edge latencies instead of re-running the full derivation.
    pub(crate) fn with_routing(&self, graph: &Graph, routing: &Routing, arch: &ArchConfig) -> Prep {
        let mut prep = self.clone();
        prep.edge_latency = edge_latencies(graph, routing, arch);
        prep
    }
}

pub(crate) fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Union-find weakly-connected components over the dataflow edges.
fn components(graph: &Graph, sched: &[NodeSched]) -> Components {
    fn find(parent: &mut [usize], mut x: usize) -> usize {
        while parent[x] != x {
            parent[x] = parent[parent[x]];
            x = parent[x];
        }
        x
    }
    let n = graph.nodes.len();
    let mut parent: Vec<usize> = (0..n).collect();
    for e in &graph.edges {
        let (a, b) = (find(&mut parent, e.src), find(&mut parent, e.dst));
        if a != b {
            parent[a] = b;
        }
    }
    let mut label = vec![usize::MAX; n];
    let mut count = 0;
    let mut of_node = vec![0usize; n];
    for id in 0..n {
        let root = find(&mut parent, id);
        if label[root] == usize::MAX {
            label[root] = count;
            count += 1;
        }
        of_node[id] = label[root];
    }
    let mut nodes: Vec<Vec<usize>> = vec![Vec::new(); count];
    let mut edges: Vec<Vec<usize>> = vec![Vec::new(); count];
    let mut node_local = vec![0usize; n];
    let mut edge_local = vec![0usize; graph.edges.len()];
    let mut total_iters = vec![0usize; count];
    for id in 0..n {
        let c = of_node[id];
        node_local[id] = nodes[c].len();
        nodes[c].push(id);
        total_iters[c] += sched[id].iters;
    }
    for e in &graph.edges {
        let c = of_node[e.src];
        edge_local[e.id] = edges[c].len();
        edges[c].push(e.id);
    }
    Components { of_node, count, nodes, edges, node_local, edge_local, total_iters }
}

/// Derive per-node steady-state periods and per-edge hyperperiod token
/// counts (DESIGN.md §7, multi-rate fast-forward).
///
/// Within one component, steady-state throughput balance forces every
/// node to complete the same *fraction* of its total iterations per unit
/// time, so the joint firing pattern repeats after node `i` completes
/// `iters_i / g` iterations, where `g` is the gcd of the participating
/// nodes' iteration counts and the participating edges' window counts
/// (then every edge fires exactly `w / g` tokens per hyperperiod, and
/// every stride accumulator returns to its starting value). Excluded from
/// `g` — and handled by the jump's silent-edge bounds instead:
///
/// * **transient nodes** — every incident edge carries ≤ [`EDGE_CAPACITY`]
///   windows total, so the node drains completely during warm-up (scalar
///   alpha/beta movers); their tiny `iters` would otherwise collapse `g`;
/// * **sporadic edges** — edges whose firing pattern repeats only after
///   more than [`PERIOD_CAP`] iterations of either endpoint (the scalar
///   result stream consumed on the final iteration).
fn derive_periods(
    graph: &Graph,
    sched: &[NodeSched],
    edge_windows: &[usize],
    comp: &Components,
    multirate: bool,
) -> (Vec<usize>, Vec<usize>) {
    let n = graph.nodes.len();
    let mut period = vec![0usize; n];
    let mut unit_tokens = vec![0usize; graph.edges.len()];

    // transient: the node can run to completion without any consumer
    // progress (all edges fit the ping-pong buffers), so it never shapes
    // the steady state.
    let mut transient = vec![true; n];
    for e in &graph.edges {
        if edge_windows[e.id] > EDGE_CAPACITY {
            transient[e.src] = false;
            transient[e.dst] = false;
        }
    }

    for c in 0..comp.count {
        // classify this component's edges.
        let mut sporadic: Vec<bool> = Vec::with_capacity(comp.edges[c].len());
        for &eid in &comp.edges[c] {
            let e = &graph.edges[eid];
            let w = edge_windows[eid];
            let s = if w == 0 {
                true // degenerate zero-token edge: never fires
            } else if multirate {
                // An edge out of (or into) a transient node is sporadic by
                // construction: once the transient side drains during
                // warm-up, the edge only fires on the other side's final
                // iterations (the scalar alpha stream) — and its tiny
                // window count would otherwise collapse the component gcd.
                let (si, di) = (sched[e.src].iters, sched[e.dst].iters);
                transient[e.src]
                    || transient[e.dst]
                    || si / gcd(w, si) > PERIOD_CAP
                    || di / gcd(w, di) > PERIOD_CAP
            } else {
                // PR 2 semantics: only uniform-rate edges translate.
                w != sched[e.src].iters || w != sched[e.dst].iters
            };
            sporadic.push(s);
        }

        if !multirate {
            // PR 2 engine: period-1 detection for every node, one token
            // per iteration on uniform edges.
            for &id in &comp.nodes[c] {
                period[id] = 1;
            }
            for (i, &eid) in comp.edges[c].iter().enumerate() {
                unit_tokens[eid] = usize::from(!sporadic[i]);
            }
            continue;
        }

        // the component hyperperiod divisor.
        let mut g = 0usize;
        for &id in &comp.nodes[c] {
            if !transient[id] {
                g = gcd(g, sched[id].iters);
            }
        }
        for (i, &eid) in comp.edges[c].iter().enumerate() {
            if !sporadic[i] {
                g = gcd(g, edge_windows[eid]);
            }
        }
        if g == 0 {
            continue; // all-transient component: nothing periodic to track
        }
        for &id in &comp.nodes[c] {
            if !transient[id] {
                let p = sched[id].iters / g;
                if p <= PERIOD_CAP {
                    period[id] = p;
                }
            }
        }
        for (i, &eid) in comp.edges[c].iter().enumerate() {
            if !sporadic[i] {
                unit_tokens[eid] = edge_windows[eid] / g;
            }
        }
    }
    (period, unit_tokens)
}

pub(crate) fn prepare(graph: &Graph, routing: &Routing, arch: &ArchConfig) -> Prep {
    prepare_opts(graph, routing, arch, true)
}

pub(crate) fn prepare_opts(
    graph: &Graph,
    routing: &Routing,
    arch: &ArchConfig,
    multirate: bool,
) -> Prep {
    let n = graph.nodes.len();
    let active_movers = graph.num_pl_movers().max(1);

    // --- derive schedules ---------------------------------------------------
    let mut sched = Vec::with_capacity(n);
    for node in &graph.nodes {
        let in_w: usize = graph.in_edges(node.id).map(|e| e.num_windows()).max().unwrap_or(0);
        let out_w: usize = graph.out_edges(node.id).map(|e| e.num_windows()).max().unwrap_or(0);
        let iters = in_w.max(out_w).max(1);
        let (service_s, launch_s) = match &node.kind {
            NodeKind::AieKernel { kind, window, vector_bits, size, .. } => {
                // per-iteration window elements: the dominant in-edge's.
                let we = graph
                    .in_edges(node.id)
                    .chain(graph.out_edges(node.id))
                    .filter(|e| e.num_windows() == iters)
                    .map(|e| e.window_elements)
                    .max()
                    .unwrap_or((*window).min(*size));
                (
                    seconds_per_window(*kind, we, *vector_bits, arch),
                    arch.kernel_call_cycles as f64 * arch.aie_cycle_s(),
                )
            }
            NodeKind::PlMm2s { burst } | NodeKind::PlS2mm { burst } => {
                let bytes = graph
                    .out_edges(node.id)
                    .chain(graph.in_edges(node.id))
                    .map(|e| e.window_bytes())
                    .max()
                    .unwrap_or(0);
                (window_transfer_s(arch, bytes, *burst, active_movers), 0.0)
            }
            NodeKind::Combine { parts } => {
                // k scalar adds + stream reads: trivially cheap next to
                // window compute; modelled as one overhead slot.
                (
                    (*parts as u64 + arch.window_overhead_cycles) as f64 * arch.aie_cycle_s(),
                    0.0,
                )
            }
            NodeKind::OnChipSource | NodeKind::OnChipSink => {
                // synthetic generation: one vector write per lane-group —
                // effectively free next to real transfers, but not zero.
                (arch.window_overhead_cycles as f64 * arch.aie_cycle_s(), 0.0)
            }
        };
        sched.push(NodeSched { iters, service_s, launch_s });
    }

    // --- edge latency (beyond producer service) -----------------------------
    let edge_latency = edge_latencies(graph, routing, arch);

    // --- adjacency ----------------------------------------------------------
    let mut in_adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut out_adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for e in &graph.edges {
        in_adj[e.dst].push(e.id);
        out_adj[e.src].push(e.id);
    }
    let edge_windows: Vec<usize> = graph.edges.iter().map(|e| e.num_windows()).collect();

    // --- components + steady-state periods (once per plan) ------------------
    let comp = components(graph, &sched);
    let (period, unit_tokens) = derive_periods(graph, &sched, &edge_windows, &comp, multirate);

    Prep {
        sched,
        edge_latency,
        in_adj,
        out_adj,
        edge_windows,
        period,
        unit_tokens,
        multirate,
        comp,
    }
}

/// Per-edge transfer latency beyond the producer's service time — the only
/// part of a [`Prep`] that depends on routing (see [`Prep::with_routing`]).
pub(crate) fn edge_latencies(graph: &Graph, routing: &Routing, arch: &ArchConfig) -> Vec<f64> {
    let mut edge_latency = vec![0.0f64; graph.edges.len()];
    for e in &graph.edges {
        let r = routing.of(e.id);
        let hop_s = r.hops as f64 * arch.noc_hop_cycles as f64 * arch.aie_cycle_s();
        let src_pl = graph.node(e.src).kind.is_pl();
        let dst_pl = graph.node(e.dst).kind.is_pl();
        let stream_s = if !r.neighbour && !src_pl && !dst_pl {
            // AIE→AIE over the stream network: 4 B/cycle serialization.
            e.window_bytes() as f64 / arch.stream_bytes_per_cycle() * arch.aie_cycle_s()
        } else {
            0.0 // PL transfers are costed in the mover's service time
        };
        edge_latency[e.id] = hop_s + stream_s;
    }
    edge_latency
}

/// Simulate a placed+routed graph; returns the timing report.
pub fn simulate(
    graph: &Graph,
    placement: &Placement,
    routing: &Routing,
    arch: &ArchConfig,
) -> Result<SimReport> {
    simulate_with(graph, placement, routing, arch, &SimOptions::default())
}

/// [`simulate`] with explicit engine options (fast-forward generation,
/// component-parallelism width). Results are bit-identical across every
/// `threads` setting — parallelism only changes which host thread runs
/// which component (enforced by `sim::parity_tests`).
pub fn simulate_with(
    graph: &Graph,
    placement: &Placement,
    routing: &Routing,
    arch: &ArchConfig,
    opts: &SimOptions,
) -> Result<SimReport> {
    simulate_inner(graph, placement, routing, arch, None, opts)
}

/// Simulate and additionally record a full execution trace (Chrome-trace /
/// Gantt export via [`trace::Trace`]).
pub fn simulate_traced(
    graph: &Graph,
    placement: &Placement,
    routing: &Routing,
    arch: &ArchConfig,
) -> Result<(SimReport, trace::Trace)> {
    let mut t = trace::Trace::default();
    let rep =
        simulate_inner(graph, placement, routing, arch, Some(&mut t), &SimOptions::default())?;
    Ok((rep, t))
}

fn simulate_inner(
    graph: &Graph,
    placement: &Placement,
    routing: &Routing,
    arch: &ArchConfig,
    tracer: Option<&mut trace::Trace>,
    opts: &SimOptions,
) -> Result<SimReport> {
    let prep = prepare_opts(graph, routing, arch, opts.multirate);
    let threads = resolve_threads(opts.threads);
    let (makespan, busy_total, _stats) = engine::run(graph, placement, &prep, tracer, threads)?;
    Ok(report::build(graph, placement, routing, arch, makespan, &busy_total, &prep.sched))
}

/// Run the event engine against an already-derived [`Prep`] — the tuner's
/// DES tier, which shares one preparation across a whole candidate batch
/// (`threads` as in [`SimOptions`]; 0 = auto).
pub(crate) fn simulate_prepared(
    graph: &Graph,
    placement: &Placement,
    routing: &Routing,
    arch: &ArchConfig,
    prep: &Prep,
    threads: usize,
) -> Result<SimReport> {
    let threads = resolve_threads(threads);
    let (makespan, busy_total, _stats) = engine::run(graph, placement, prep, None, threads)?;
    Ok(report::build(graph, placement, routing, arch, makespan, &busy_total, &prep.sched))
}

/// Simulate an already-lowered plan (the [`crate::runtime::SimBackend`]
/// execution primitive).
pub fn simulate_plan(plan: &crate::pipeline::ExecutablePlan) -> Result<SimReport> {
    simulate(plan.graph(), plan.placement(), plan.routing(), plan.arch())
}

/// Convenience: lower a spec through the staged pipeline (uncached) and
/// simulate it.
pub fn simulate_spec(spec: &crate::spec::Spec) -> Result<SimReport> {
    simulate_plan(&crate::pipeline::lower_spec(spec)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::spec::{DataSource, Spec};

    fn sim(spec: &Spec) -> SimReport {
        simulate_spec(spec).unwrap()
    }

    #[test]
    fn axpy_pl_simulates() {
        let r = sim(&Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl));
        assert!(r.makespan_s > 0.0);
        assert!(r.device_bytes > 0);
    }

    #[test]
    fn no_pl_is_faster_than_pl() {
        // Fig. 3 claim C1: on-chip generation removes the off-chip
        // bottleneck for memory-bound routines.
        for n in [1usize << 14, 1 << 18, 1 << 20] {
            let pl = sim(&Spec::single(RoutineKind::Axpy, "a", n, DataSource::Pl));
            let onchip = sim(&Spec::single(RoutineKind::Axpy, "a", n, DataSource::OnChip));
            assert!(
                onchip.makespan_s < pl.makespan_s,
                "n={n}: onchip {} !< pl {}",
                onchip.makespan_s,
                pl.makespan_s
            );
        }
    }

    #[test]
    fn gemv_no_pl_faster() {
        for n in [128usize, 512] {
            let pl = sim(&Spec::single(RoutineKind::Gemv, "g", n, DataSource::Pl));
            let onchip = sim(&Spec::single(RoutineKind::Gemv, "g", n, DataSource::OnChip));
            assert!(onchip.makespan_s < pl.makespan_s, "n={n}");
        }
    }

    #[test]
    fn time_grows_with_size() {
        let small = sim(&Spec::single(RoutineKind::Axpy, "a", 1 << 12, DataSource::Pl));
        let large = sim(&Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::Pl));
        assert!(large.makespan_s > 10.0 * small.makespan_s);
    }

    #[test]
    fn dataflow_axpydot_beats_sum_of_stages() {
        // DF pipeline must beat sequential axpy-then-dot (the no-DF lower
        // bound is roughly the sum plus the DDR round trip).
        let n = 1 << 20;
        let df = sim(&Spec::axpydot_dataflow(n, 2.0));
        let axpy = sim(&Spec::single(RoutineKind::Axpy, "a", n, DataSource::Pl));
        let dot = sim(&Spec::single(RoutineKind::Dot, "d", n, DataSource::Pl));
        let sequential = axpy.makespan_s + dot.makespan_s;
        assert!(
            df.makespan_s < sequential,
            "DF {} !< sequential {}",
            df.makespan_s,
            sequential
        );
    }

    #[test]
    fn composite_expansion_simulates_like_explicit_composition() {
        let n = 1 << 16;
        let explicit = sim(&Spec::axpydot_dataflow(n, 2.0));
        let composite = sim(&Spec::single(RoutineKind::Axpydot, "ad", n, DataSource::Pl));
        let ratio = composite.makespan_s / explicit.makespan_s;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn utilization_bounded() {
        let r = sim(&Spec::single(RoutineKind::Dot, "d", 1 << 18, DataSource::Pl));
        for k in &r.kernels {
            assert!(k.utilization >= 0.0 && k.utilization <= 1.0 + 1e-9, "{}", k.name);
        }
    }

    #[test]
    fn burst_improves_pl_bound_routine() {
        let n = 1 << 20;
        let mut naive = Spec::single(RoutineKind::Axpy, "a", n, DataSource::Pl);
        let mut burst = naive.clone();
        naive.routines[0].burst = false;
        burst.routines[0].burst = true;
        let t_naive = sim(&naive).makespan_s;
        let t_burst = sim(&burst).makespan_s;
        assert!(t_burst < t_naive, "burst {t_burst} !< naive {t_naive}");
    }

    #[test]
    fn scalar_only_edges_do_not_deadlock() {
        // dot produces a single scalar token; ensure rate-matching handles
        // 1-token edges over many iterations.
        let r = sim(&Spec::single(RoutineKind::Dot, "d", 1 << 14, DataSource::Pl));
        assert!(r.makespan_s > 0.0);
    }

    #[test]
    fn components_label_disconnected_pipelines() {
        use crate::blas::PortType;
        use crate::graph::{EdgeKind, NodeKind};
        let mut g = Graph::default();
        let a = g.add_node("a", NodeKind::OnChipSource);
        let b = g.add_node("b", NodeKind::OnChipSink);
        let c = g.add_node("c", NodeKind::OnChipSource);
        let d = g.add_node("d", NodeKind::OnChipSink);
        g.add_edge(a, "out", b, "in", PortType::Vector, EdgeKind::Window, 64, 16);
        g.add_edge(c, "out", d, "in", PortType::Vector, EdgeKind::Window, 64, 16);
        let sched: Vec<NodeSched> = (0..4)
            .map(|_| NodeSched { iters: 4, service_s: 1e-6, launch_s: 0.0 })
            .collect();
        let comp = components(&g, &sched);
        assert_eq!(comp.count, 2);
        assert_eq!(comp.of_node[a], comp.of_node[b]);
        assert_eq!(comp.of_node[c], comp.of_node[d]);
        assert_ne!(comp.of_node[a], comp.of_node[c]);
        assert_eq!(comp.total_iters, vec![4 + 4, 4 + 4]);
    }

    #[test]
    fn components_partition_covers_graph() {
        let plan = crate::pipeline::lower_spec(&Spec::axpydot_dataflow(4096, 2.0)).unwrap();
        let prep = prepare(plan.graph(), plan.routing(), plan.arch());
        let comp = &prep.comp;
        assert_eq!(comp.of_node.len(), plan.graph().nodes.len());
        let nodes_listed: usize = comp.nodes.iter().map(Vec::len).sum();
        let edges_listed: usize = comp.edges.iter().map(Vec::len).sum();
        assert_eq!(nodes_listed, plan.graph().nodes.len());
        assert_eq!(edges_listed, plan.graph().edges.len());
        for (id, &c) in comp.of_node.iter().enumerate() {
            assert_eq!(comp.nodes[c][comp.node_local[id]], id);
        }
        for e in &plan.graph().edges {
            let c = comp.of_node[e.src];
            assert_eq!(comp.of_node[e.dst], c, "edges never cross components");
            assert_eq!(comp.edges[c][comp.edge_local[e.id]], e.id);
        }
        let total: usize = comp.total_iters.iter().sum();
        assert_eq!(total, prep.sched.iter().map(|s| s.iters).sum::<usize>());
    }

    #[test]
    fn with_routing_refresh_and_prepared_run_match_full_simulation() {
        let plan = crate::pipeline::lower_spec(&Spec::axpydot_dataflow(1 << 14, 2.0)).unwrap();
        let prep = prepare(plan.graph(), plan.routing(), plan.arch());
        let restamped = prep.with_routing(plan.graph(), plan.routing(), plan.arch());
        assert_eq!(prep.edge_latency, restamped.edge_latency);
        let full = simulate_plan(&plan).unwrap();
        let shared = simulate_prepared(
            plan.graph(),
            plan.placement(),
            plan.routing(),
            plan.arch(),
            &restamped,
            0,
        )
        .unwrap();
        assert_eq!(full.makespan_s, shared.makespan_s, "shared prep must be exact");
    }

    #[test]
    fn gemv_kernel_gets_a_multirate_period() {
        // gemv's kernel consumes the re-read x edge every n/16 iterations;
        // the derived period must capture that (and stay within the cap).
        let n = 1024;
        let plan =
            crate::pipeline::lower_spec(&Spec::single(RoutineKind::Gemv, "g", n, DataSource::Pl))
                .unwrap();
        let prep = prepare(plan.graph(), plan.routing(), plan.arch());
        let kernel = plan.graph().node_by_name("g").unwrap();
        let p = prep.period[kernel.id];
        assert!(p > 1, "gemv kernel must be multi-rate periodic, got period {p}");
        assert_eq!(prep.sched[kernel.id].iters % p, 0, "period divides iterations");
        // every non-sporadic edge fires an integral token count per
        // hyperperiod, consistent on both sides.
        for e in &plan.graph().edges {
            let t = prep.unit_tokens[e.id];
            if t == 0 {
                continue;
            }
            for side in [e.src, e.dst] {
                let ps = prep.period[side];
                assert!(ps > 0, "shiftable edge endpoints must be eligible");
                assert_eq!(
                    ps * prep.edge_windows[e.id] % prep.sched[side].iters,
                    0,
                    "accumulators must return to their value each hyperperiod"
                );
                assert_eq!(ps * prep.edge_windows[e.id] / prep.sched[side].iters, t);
            }
        }
    }

    #[test]
    fn uniform_pipeline_period_is_one() {
        let plan = crate::pipeline::lower_spec(&Spec::single(
            RoutineKind::Axpy,
            "a",
            1 << 16,
            DataSource::Pl,
        ))
        .unwrap();
        let prep = prepare(plan.graph(), plan.routing(), plan.arch());
        let kernel = plan.graph().node_by_name("a").unwrap();
        assert_eq!(prep.period[kernel.id], 1, "uniform regions keep period-1 detection");
    }
}
